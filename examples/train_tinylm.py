"""End-to-end training driver example: train a ~100M-param TinyLlama-family
model for a few hundred steps on synthetic data, with checkpointing and a
simulated failure + automatic restart at step 60.

    PYTHONPATH=src python examples/train_tinylm.py [--steps 300]

(Heavier than the smoke tests: ~100M params on CPU. Use --tiny for a quick
pass.)
"""

import argparse
import sys

sys.argv = [sys.argv[0]]  # repro.launch.train has its own parser

from repro.launch.train import run  # noqa: E402


class Args:
    arch = "tinyllama-1.1b"
    smoke = False
    steps = 300
    batch = 4
    seq = 256
    lr = 3e-3
    warmup = 30
    seed = 0
    microbatches = 2
    model_parallel = 1
    ckpt_dir = "runs/tinylm_example"
    save_every = 50
    log_every = 10
    fail_at = 60


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true",
                    help="use the reduced config (fast CPU pass)")
    ns = ap.parse_args()
    args = Args()
    args.steps = ns.steps
    if ns.tiny:
        args.smoke = True
        args.seq = 64
        args.batch = 8
    else:
        # ~100M-param variant of the tinyllama family for CPU training
        from repro.configs import tinyllama_1_1b
        from repro.models.config import reduced
        small = tinyllama_1_1b.CONFIG.with_(
            n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
            d_ff=2048, vocab=32_000, remat="none",
            compute_dtype="float32")
        tinyllama_1_1b.SMOKE = small  # route --smoke to the 100M config
        args.smoke = True
        print(f"training ~{small.param_count()/1e6:.0f}M params "
              f"({small.n_layers}L d={small.d_model})")
    out = run(args)
    print(f"loss {out['first_loss']:.3f} -> {out['last_loss']:.3f}")
    assert out["last_loss"] < out["first_loss"], "training did not learn"


if __name__ == "__main__":
    main()
