"""Cocco as the TPU execution planner (DESIGN.md §3): co-explore the fusion
partition + VMEM working set for each assigned architecture's transformer
block and print the resulting execution plans.

    PYTHONPATH=src python examples/cocco_plan_search.py [--arch glm4-9b]

Equivalent CLI:

    PYTHONPATH=src python -m repro plan-tpu [--arch glm4-9b]
"""

import argparse

from repro.api import plan_tpu
from repro.configs import ARCHS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default=None,
                    help="default: all archs")
    ap.add_argument("--samples", type=int, default=2000)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ARCHS
    for arch in archs:
        plan = plan_tpu(arch, sample_budget=args.samples)
        print(plan.summary())


if __name__ == "__main__":
    main()
