"""Cocco as the TPU execution planner (DESIGN.md §3): co-explore the fusion
partition + VMEM working set for each assigned architecture's transformer
block and print the resulting execution plans.

    PYTHONPATH=src python examples/cocco_plan_search.py [--arch glm4-9b]
"""

import argparse

from repro.configs import ARCHS, get_config
from repro.core.tpu_adapter import plan_architecture


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default=None,
                    help="default: all archs")
    ap.add_argument("--samples", type=int, default=2000)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ARCHS
    for arch in archs:
        cfg = get_config(arch)
        plan = plan_architecture(cfg, sample_budget=args.samples)
        print(plan.summary())


if __name__ == "__main__":
    main()
