"""Batched serving example: generate from three archs (dense GQA, SSM,
enc-dec) through the same engine API — with the memory-capacity plan for
each arch requested from an in-process plan service first (the Cocco side
of serving: plan the block's buffering before running the model; repeat
runs replay the plan from the store in milliseconds).

    PYTHONPATH=src python examples/serve_lm.py
"""

import tempfile

import jax
import numpy as np

from repro.api import ExploreSpec, ResultStore
from repro.configs import get_config
from repro.core.ga import HWSpace, Objective
from repro.models import lm_init, param_values
from repro.serve import (
    EncDecEngine,
    PlanService,
    Request,
    ServeConfig,
    ServeEngine,
)

def plan_block(planner: PlanService, arch: str) -> None:
    """Ask the plan service for the arch's layer-0 execution plan."""
    spec = ExploreSpec(workload=f"tpu:{arch}:0?tokens=512",
                       strategy="greedy",
                       objective=Objective(metric="ema", alpha=None),
                       hw=HWSpace(mode="fixed"),
                       sample_budget=500, seed=0)
    resp = planner.plan(spec)
    print(f"  plan: {resp.result.summary()}")
    print(f"  plan: served_from={resp.served_from} "
          f"in {resp.latency_ms:.1f}ms")


def main():
    planner = PlanService(ResultStore(
        tempfile.mkdtemp(prefix="serve-lm-plans-")))
    rng = np.random.default_rng(0)
    for arch in ("tinyllama-1.1b", "xlstm-350m"):
        cfg = get_config(arch, smoke=True)
        print(f"{arch}: planning block buffering")
        plan_block(planner, arch)
        values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
        eng = ServeEngine(cfg, values, ServeConfig(max_batch=4, max_len=64))
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                        max_new_tokens=6) for i in range(4)]
        outs = eng.generate(reqs)
        print(f"{arch}:")
        for rid in sorted(outs):
            print(f"  req {rid} -> {outs[rid]}")

    cfg = get_config("whisper-base", smoke=True)
    print("whisper-base: planning block buffering")
    plan_block(planner, "whisper-base")
    values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
    eng = EncDecEngine(cfg, values, ServeConfig(max_batch=2, max_len=32))
    frames = rng.normal(size=(2, 12, cfg.d_model)).astype(np.float32)
    outs = eng.transcribe(frames, max_new_tokens=6)
    print("whisper-base:")
    for i, o in enumerate(outs):
        print(f"  audio {i} -> {o}")
    planner.close()


if __name__ == "__main__":
    main()
