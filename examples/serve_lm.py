"""Batched serving example: generate from three archs (dense GQA, SSM,
enc-dec) through the same engine API.

    PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm_init, param_values
from repro.serve import EncDecEngine, Request, ServeConfig, ServeEngine


def main():
    rng = np.random.default_rng(0)
    for arch in ("tinyllama-1.1b", "xlstm-350m"):
        cfg = get_config(arch, smoke=True)
        values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
        eng = ServeEngine(cfg, values, ServeConfig(max_batch=4, max_len=64))
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                        max_new_tokens=6) for i in range(4)]
        outs = eng.generate(reqs)
        print(f"{arch}:")
        for rid in sorted(outs):
            print(f"  req {rid} -> {outs[rid]}")

    cfg = get_config("whisper-base", smoke=True)
    values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
    eng = EncDecEngine(cfg, values, ServeConfig(max_batch=2, max_len=32))
    frames = rng.normal(size=(2, 12, cfg.d_model)).astype(np.float32)
    outs = eng.transcribe(frames, max_new_tokens=6)
    print("whisper-base:")
    for i, o in enumerate(outs):
        print(f"  audio {i} -> {o}")


if __name__ == "__main__":
    main()
