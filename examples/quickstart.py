"""Quickstart: run Cocco's hardware-mapping co-exploration on ResNet-50.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core loop end-to-end in ~a minute: build the
computation graph, co-explore (partition x memory config), and compare
against the Halide-greedy and Irregular-NN DP baselines.
"""

from repro.core import AcceleratorConfig, CachedEvaluator, Objective, co_explore
from repro.core.baselines import dp_partition, greedy_partition
from repro.core.netlib import build


def main():
    g = build("resnet50")
    print(g.summary())

    acc = AcceleratorConfig()  # 1MB GLB + 1.125MB WBUF, 2 TOPS (paper §5.1.2)
    obj = Objective(metric="ema")
    ev = CachedEvaluator(g)

    _, greedy_plan, _ = greedy_partition(g, acc, obj, ev=ev)
    _, dp_plan, _ = dp_partition(g, acc, obj, ev=ev)
    print(f"greedy (Halide):      EMA {greedy_plan.ema_total/1e6:8.2f} MB")
    print(f"DP (Irregular-NN):    EMA {dp_plan.ema_total/1e6:8.2f} MB")

    res = co_explore(g, mode="shared", metric="energy", alpha=0.002,
                     sample_budget=4000, population=60, seed=0)
    print(f"\nCocco co-exploration: {res.summary()}")
    print(f"  {res.n_subgraphs} subgraphs; largest fuses "
          f"{max(len(s) for s in res.groups)} layers")
    print(f"  vs greedy EMA: {res.plan.ema_total / greedy_plan.ema_total:.2f}x")


if __name__ == "__main__":
    main()
