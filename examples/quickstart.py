"""Quickstart: run Cocco's hardware-mapping co-exploration on ResNet-50.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core loop end-to-end in ~a minute through the unified
exploration API: build one ExploreSpec, run the Halide-greedy and
Irregular-NN DP baselines and Cocco's GA from the strategy registry (all
sharing one cost evaluator), and rank the results.

Equivalent CLI:

    PYTHONPATH=src python -m repro compare --workload resnet50 \
        --strategies greedy,dp,ga --metric energy --alpha 0.002 \
        --hw-mode shared --budget 4000 --opt population=60
"""

from repro.api import ExploreSpec, GAOptions, compare
from repro.core import HWSpace, Objective


def main():
    spec = ExploreSpec(
        workload="resnet50",
        strategy="ga",
        objective=Objective(metric="energy", alpha=0.002),
        hw=HWSpace(mode="shared"),
        sample_budget=4000,
        seed=0,
        options=GAOptions(population=60),
    )
    results = {r.strategy: r for r in compare(spec, ["greedy", "dp", "ga"])}

    greedy_plan = results["greedy"].plan
    dp_plan = results["dp"].plan
    print(f"greedy (Halide):      EMA {greedy_plan.ema_total/1e6:8.2f} MB")
    print(f"DP (Irregular-NN):    EMA {dp_plan.ema_total/1e6:8.2f} MB")

    res = results["ga"]
    print(f"\nCocco co-exploration: {res.summary()}")
    print(f"  {res.n_subgraphs} subgraphs; largest fuses "
          f"{max(len(s) for s in res.groups)} layers")
    print(f"  vs greedy EMA: {res.plan.ema_total / greedy_plan.ema_total:.2f}x")

    # every run is a reproducible artifact: spec and result round-trip JSON
    print(f"\nspec JSON: {len(spec.to_json())} bytes; "
          f"result JSON: {len(res.to_json())} bytes")


if __name__ == "__main__":
    main()
