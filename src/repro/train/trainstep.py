"""Jittable train/eval steps: loss + grad + AdamW + microbatch accumulation.

``make_train_step`` builds the function that the launcher jits with
in/out shardings; gradient accumulation loops microbatches with a
``lax.scan`` so the HLO stays O(1) in the number of microbatches.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import lm_loss
from repro.models.config import ModelConfig

from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch`` leaves have leading dim [global_batch, ...]; with
    ``microbatches > 1`` the batch is reshaped to [M, B/M, ...] and gradients
    are accumulated across the scan (compute/communication overlap: the
    gradient all-reduce only happens once, after accumulation, because the
    psum is deferred to the final pytree sum under SPMD).
    """

    def loss_fn(params, mb):
        loss, metrics = lm_loss(params, cfg, mb)
        return loss, metrics

    def train_step(params, opt_state: AdamWState, batch: Dict[str, Any]):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zero_g = jax.tree.map(jnp.zeros_like, params)

            def body(carry, mb):
                acc, _ = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, l), m

            (grads, loss), ms = lax.scan(body, (zero_g, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda x: x[-1], ms)

        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss_total"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, metrics = lm_loss(params, cfg, batch)
        return metrics

    return eval_step
