"""AdamW + gradient clipping + LR schedules, from scratch on pytrees.

The optimizer state mirrors the parameter tree (so sharding specs transfer
leaf-for-leaf), with an optional lower-precision state dtype for the largest
models (cfg.opt_dtype, see DESIGN.md §8).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # int32 scalar
    mu: Any                    # pytree like params
    nu: Any                    # pytree like params


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"   # "bfloat16" for the 200B+ configs
    schedule: str = "cosine"       # constant | cosine | linear_warmup_cosine
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        frac = jnp.ones(())
    else:
        t = jnp.clip((s - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(math.pi * t))
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                      tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.zeros(())))


def adamw_update(
    grads, state: AdamWState, params, cfg: AdamWConfig,
    no_decay: Optional[Callable[[Tuple], bool]] = None,
):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else jnp.ones(())
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_keys = {id(l): path for path, l in
                 jax.tree_util.tree_flatten_with_path(params)[0]} \
        if no_decay else {}

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim > 1 else 0.0  # no decay on norms
        p_new = p.astype(jnp.float32) * (1 - lr * decay) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr, "clip_scale": scale}
    return new_params, AdamWState(step, new_mu, new_nu), metrics
