from .optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    global_norm,
    schedule_lr,
)
from .trainstep import make_eval_step, make_train_step

__all__ = [k for k in dir() if not k.startswith("_")]
