"""Baseline partition/DSE methods (paper §4.2).

* greedy        — Halide-style function grouping [47]: start from singletons,
                  repeatedly apply the connected merge with the greatest cost
                  benefit until no merge helps.
* dp            — Irregular-NN [73]: order layers by (depth, id); DP over the
                  sequence where every subgraph must be a contiguous run.
* enumeration   — Fused-CNN/Jangda [4, 25] state-compression DP over downward-
                  closed node sets ("ideals"); exact but exponential, so it is
                  budgeted and reports completion.
* sa            — simulated annealing [33] re-using Cocco's mutation operators.
* two-step      — RS+GA / GS+GA: sample capacities, run partition-only GA per
                  capacity, keep the best (paper §5.1.3).
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, replace
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .cost import AcceleratorConfig, CachedEvaluator, PlanCost
from .ga import (
    Genome,
    HWSpace,
    Objective,
    SearchResult,
    evaluate_genomes,
    mutate,
    run_ga,
)
from .graph import Graph
from .partition import (
    groups_of,
    normalize,
    singleton_partition,
    split_to_fit,
)


# ---------------------------------------------------------------------------
# greedy (Halide)
# ---------------------------------------------------------------------------

def greedy_partition(
    g: Graph,
    acc: AcceleratorConfig,
    objective: Objective,
    out_tile: int = 1,
    ev: Optional[CachedEvaluator] = None,
    eval_budget: int = 30_000,
) -> Tuple[List[Set[int]], PlanCost, int]:
    """Returns (groups, plan, evaluations).  ``eval_budget`` bounds the
    quadratic merge search on large irregular graphs (the paper's greedy has
    the same scaling problem — §4.2.2)."""
    ev = ev or CachedEvaluator(g, out_tile=out_tile)
    groups = singleton_partition(g)

    def plan_cost(gr: List[Set[int]]) -> float:
        return objective.cost(ev.plan(gr, acc), acc)

    cur_cost = plan_cost(groups)
    n_eval = 1
    while n_eval < eval_budget:
        gid = {u: i for i, s in enumerate(groups) for u in s}
        pairs = {(min(gid[e.src], gid[e.dst]), max(gid[e.src], gid[e.dst]))
                 for e in g.edges if gid[e.src] != gid[e.dst]}
        best_delta, best_groups = 0.0, None
        for a, b in sorted(pairs):
            cand = [set(s) for s in groups]
            cand[a] |= cand[b]
            del cand[b]
            try:
                cand = normalize(g, cand)
            except RuntimeError:
                continue
            # skip merges made infeasible (greedy cannot stream multi-layer)
            if any(not ev.subgraph(s, acc).feasible for s in cand):
                continue
            c = plan_cost(cand)
            n_eval += 1
            if cur_cost - c > best_delta:
                best_delta, best_groups = cur_cost - c, cand
        if best_groups is None:
            break
        groups, cur_cost = best_groups, cur_cost - best_delta
    return groups, ev.plan(groups, acc), n_eval


# ---------------------------------------------------------------------------
# DP (Irregular-NN): contiguous runs in depth order
# ---------------------------------------------------------------------------

def _depth_order(g: Graph) -> List[int]:
    depth = [0] * g.n
    for v in g.topo_order():
        for e in g.in_edges(v):
            depth[v] = max(depth[v], depth[e.src] + 1)
    return sorted(range(g.n), key=lambda v: (depth[v], v))


def dp_partition(
    g: Graph,
    acc: AcceleratorConfig,
    objective: Objective,
    out_tile: int = 1,
    ev: Optional[CachedEvaluator] = None,
) -> Tuple[List[Set[int]], PlanCost, int]:
    ev = ev or CachedEvaluator(g, out_tile=out_tile)
    # the recurrence sums per-subgraph costs, so decompose by the additive
    # objective (for non-additive metrics: the documented ema surrogate —
    # see Objective.decomposition); the caller scores the plan we return
    # with the true objective
    objective = objective.decomposition()
    order = _depth_order(g)
    n = g.n
    INF = math.inf
    dp = [INF] * (n + 1)
    back = [-1] * (n + 1)
    dp[0] = 0.0
    n_eval = 0
    for i in range(1, n + 1):
        for j in range(i - 1, -1, -1):
            seg = set(order[j:i])
            # subgraphs must be connected; contiguity in depth order is the
            # paper's constraint, connectivity ours (invalid otherwise)
            if len(seg) > 1 and not g.is_connected(seg):
                continue
            c = ev.subgraph(seg, acc)
            n_eval += 1
            if not c.feasible:
                continue
            plan = ev.plan([seg], acc)
            val = dp[j] + objective.cost(plan, acc) - (
                acc.buf_size_total if objective.alpha is not None else 0.0
            )
            if dp[j] + 1e-12 < INF and val < dp[i]:
                dp[i] = val
                back[i] = j
    # reconstruct
    groups: List[Set[int]] = []
    i = n
    while i > 0:
        j = back[i]
        if j < 0:  # fallback: singleton
            groups.append({order[i - 1]})
            i -= 1
        else:
            groups.append(set(order[j:i]))
            i = j
    groups.reverse()
    try:
        groups = normalize(g, groups)
    except RuntimeError:
        groups = singleton_partition(g)
    groups = split_to_fit(g, groups, acc, out_tile=out_tile, ev=ev)
    return groups, ev.plan(groups, acc), n_eval


# ---------------------------------------------------------------------------
# enumeration (state-compression DP over ideals)
# ---------------------------------------------------------------------------

@dataclass
class EnumResult:
    groups: Optional[List[Set[int]]]
    plan: Optional[PlanCost]
    complete: bool
    states: int


def enumerate_partitions(
    g: Graph,
    acc: AcceleratorConfig,
    objective: Objective,
    out_tile: int = 1,
    state_budget: int = 2_000_000,
    ev: Optional[CachedEvaluator] = None,
) -> EnumResult:
    """Exact DP: dp[ideal] = min partition cost of the ideal, transitioning by
    appending one feasible connected subgraph whose union is again an ideal.
    The per-layer cost is additive, so this is optimal (non-additive
    metrics decompose by ``Objective.decomposition()``'s ema surrogate and
    the caller re-scores the plan with the true objective).  Exponential in
    the graph's antichain structure — budgeted."""
    ev = ev or CachedEvaluator(g, out_tile=out_tile)
    objective = objective.decomposition()
    preds = [set(g.preds(v)) for v in range(g.n)]
    succs = [set(g.succs(v)) for v in range(g.n)]
    full = frozenset(range(g.n))
    dp: Dict[FrozenSet[int], float] = {frozenset(): 0.0}
    back: Dict[FrozenSet[int], Tuple[FrozenSet[int], FrozenSet[int]]] = {}
    # process ideals in order of size using a dict-of-size frontier
    by_size: Dict[int, List[FrozenSet[int]]] = {0: [frozenset()]}
    states = 0
    complete = True

    for size in range(g.n):
        for ideal in by_size.get(size, []):
            base = dp[ideal]
            frontier = [v for v in range(g.n)
                        if v not in ideal and preds[v] <= ideal]
            # --- collect: grow connected subgraphs from each frontier node
            # (dedup by set).  The walk never depends on cost results, so it
            # runs to completion before any evaluation — which lets the whole
            # ideal's candidate set go through the engine as one batch.
            seen_subs: Set[FrozenSet[int]] = set()
            subs_in_order: List[FrozenSet[int]] = []
            stack: List[FrozenSet[int]] = [frozenset([v]) for v in frontier]
            while stack:
                sub = stack.pop()
                if sub in seen_subs:
                    continue
                seen_subs.add(sub)
                states += 1
                if states > state_budget:
                    complete = False
                    stack.clear()
                    break
                subs_in_order.append(sub)
                # extensions: nodes adjacent to sub, addable (preds satisfied)
                for u in sorted(sub):
                    for w in sorted(succs[u] | preds[u]):
                        if w in ideal or w in sub:
                            continue
                        if preds[w] <= (ideal | sub):
                            ext = frozenset(sub | {w})
                            if ext not in seen_subs:
                                stack.append(ext)
            # --- submit + apply: DP transitions in walk order
            costs = ev.evaluate_batch([(set(sub), acc)
                                       for sub in subs_in_order])
            for sub, c in zip(subs_in_order, costs):
                if not c.feasible:
                    continue
                plan = ev.plan([set(sub)], acc)
                cost = objective.cost(plan, acc) - (
                    acc.buf_size_total if objective.alpha is not None else 0.0
                )
                nxt = frozenset(ideal | sub)
                val = base + cost
                if val < dp.get(nxt, math.inf):
                    dp[nxt] = val
                    back[nxt] = (ideal, sub)
                    by_size.setdefault(len(nxt), []).append(nxt)
            if not complete:
                break
        if not complete:
            break

    if full not in dp:
        return EnumResult(None, None, complete=False, states=states)
    groups: List[Set[int]] = []
    cur = full
    while cur:
        prev, sub = back[cur]
        groups.append(set(sub))
        cur = prev
    groups.reverse()
    return EnumResult(groups, ev.plan(groups, acc), complete, states)


# ---------------------------------------------------------------------------
# simulated annealing
# ---------------------------------------------------------------------------

def run_sa(
    g: Graph,
    objective: Objective,
    hw: HWSpace,
    sample_budget: int = 50_000,
    t0: float = 1.0,
    t_end: float = 1e-3,
    seed: int = 0,
    out_tile: int = 1,
    ev: Optional[CachedEvaluator] = None,
) -> SearchResult:
    """SA with Cocco's mutation operators as the neighbourhood (§4.2.4).

    Each step's pending genome goes through the same collect-then-submit
    evaluation path as a GA generation (:func:`~repro.core.ga.evaluate_genomes`
    with a batch of one), so SA shares the engine's repair/costing code
    instead of a private evaluation loop.
    """
    rng = random.Random(seed)
    ev = ev or CachedEvaluator(g, out_tile=out_tile)

    def evaluate(ind: Genome) -> None:
        evaluate_genomes(g, [ind], objective, ev)

    from .partition import random_partition

    cur = Genome(random_partition(g, rng), hw.sample(rng))
    evaluate(cur)
    best = cur.clone()
    best.cost, best.plan = cur.cost, cur.plan
    history = [(1, best.cost)]
    samples = 1
    # relative temperature: scale by initial cost magnitude
    scale = max(abs(cur.cost), 1e-9)
    while samples < sample_budget:
        frac = samples / sample_budget
        temp = scale * t0 * (t_end / t0) ** frac
        cand = mutate(g, cur, hw, rng)
        evaluate(cand)
        samples += 1
        d = cand.cost - cur.cost
        if d <= 0 or rng.random() < math.exp(-d / max(temp, 1e-12)):
            cur = cand
        if cand.cost < best.cost:
            best = cand.clone()
            best.cost, best.plan = cand.cost, cand.plan
        history.append((samples, best.cost))
    return SearchResult(best=best, history=history, population_log=[],
                        samples=samples, evaluations=ev.evaluations)


# ---------------------------------------------------------------------------
# two-step schemes (RS+GA / GS+GA)
# ---------------------------------------------------------------------------

def run_two_step(
    g: Graph,
    objective: Objective,
    hw: HWSpace,
    sampler: str = "random",          # "random" | "grid"
    capacity_samples: int = 10,
    samples_per_capacity: int = 5_000,
    seed: int = 0,
    out_tile: int = 1,
    ev: Optional[CachedEvaluator] = None,
) -> SearchResult:
    """Decoupled capacity search then partition-only GA per capacity.

    ``ev`` shares one :class:`CachedEvaluator` across the per-capacity GA
    runs (cache keys include the hardware point, so entries never collide);
    the returned ``evaluations`` is the number of cache misses this call
    incurred, whichever evaluator was used.
    """
    rng = random.Random(seed)
    ev = ev or CachedEvaluator(g, out_tile=out_tile)
    ev_start = ev.evaluations
    if hw.mode == "fixed":
        # degenerate: the single capacity is the base point itself
        picks = [(hw.base.glb_bytes, hw.base.wbuf_bytes)]
    else:
        if hw.mode == "separate":
            cands = [(gl, wb) for gl in hw.glb_candidates
                     for wb in hw.wbuf_candidates]
        else:
            cands = [(sh, 0) for sh in hw.shared_candidates]
        if sampler == "random":
            picks = [cands[rng.randrange(len(cands))]
                     for _ in range(capacity_samples)]
        else:  # grid: coarse, large-to-small (paper §5.3.2)
            step = max(1, len(cands) // capacity_samples)
            picks = list(reversed(cands))[::step][:capacity_samples]

    best: Optional[Genome] = None
    history: List[Tuple[int, float]] = []
    samples = 0
    running = math.inf
    for (glb, wb) in picks:
        shared = hw.base.shared if hw.mode == "fixed" else hw.mode == "shared"
        acc = replace(hw.base, glb_bytes=glb, wbuf_bytes=wb, shared=shared)
        res = run_ga(
            g, objective, HWSpace(mode="fixed", base=acc),
            sample_budget=samples_per_capacity,
            population=min(100, max(10, samples_per_capacity // 5)),
            seed=rng.randrange(1 << 30), out_tile=out_tile,
            ev=ev,
        )
        for (_, c) in res.history:
            samples += 1
            running = min(running, c)
            history.append((samples, running))
        if best is None or res.best.cost < best.cost:
            best = res.best
    return SearchResult(best=best, history=history, population_log=[],
                        samples=samples,
                        evaluations=ev.evaluations - ev_start)
