"""Cocco-on-TPU: the paper's co-exploration as the framework's execution
planner (DESIGN.md §3).

The TPU memory hierarchy maps onto the paper's model as
    HBM  <-> external memory (DRAM),   VMEM <-> global buffer,
and a transformer block's op-DAG maps onto a Cocco computation graph whose
rows are tokens: pointwise ops (norms, projections, gates) are F=1,s=1
edges; attention over the sequence is a FULL edge (the S x S score tensor is
the production-centric strawman).  Running the paper's co-exploration over
this graph chooses (a) which ops fuse into VMEM-resident regions — the
fusion groups we implement as Pallas kernels / XLA fusions — and (b) the
VMEM working-set budget per group, which sizes the kernels' BlockSpecs.

``plan_architecture`` returns an ExecutionPlan consumed by the launcher
(block sizes, fusion groups, HBM-traffic estimate) and reported in
EXPERIMENTS.md §Perf as the paper-faithful planning step.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Set, Tuple

from repro.models.config import FFN_MOE, FFN_MOE_RESIDUAL, ModelConfig

from .cost import MB, AcceleratorConfig
from .graph import FULL, Graph

if TYPE_CHECKING:  # repro.api imports repro.core; keep the cycle lazy
    from repro.api import ExploreResult

# TPU v5e-class accelerator constants for the Cocco cost model
VMEM_BYTES = 96 * MB            # usable VMEM working set
TPU_ACC = AcceleratorConfig(
    glb_bytes=VMEM_BYTES,
    wbuf_bytes=0,
    shared=True,
    macs_per_cycle=104_000,      # ~197 TFLOP/s bf16 @ 0.94 GHz
    freq_hz=0.94e9,
    dram_bytes_per_sec=819e9,    # HBM
    e_dram_pj_per_byte=3.0,      # HBM access energy (~0.4 pJ/bit)
    e_mac_pj=0.15,               # bf16 MAC
)

VMEM_CANDIDATES = tuple(m * MB for m in (16, 32, 48, 64, 96, 128))


def build_block_graph(cfg: ModelConfig, layer_idx: int, tokens: int,
                      tp_degree: int = 16) -> Graph:
    """One transformer block as a Cocco graph.  Rows = tokens; line bytes =
    per-token tensor width (bf16, TP-sharded).  Weights are the per-device
    TP shards."""
    spec = cfg.block_specs()[layer_idx]
    d = cfg.d_model
    bf = 2
    g = Graph(f"{cfg.name}.L{layer_idx}.{spec.code}")

    def line(width):  # per-token bytes after TP sharding of the width dim
        return max(1, int(width * bf))

    x = g.add_node("x", tokens, line(d))
    n1 = g.add_node("norm1", tokens, line(d), weight_bytes=d * bf,
                    macs=4 * d)
    g.add_edge(x, n1)

    h, kh = cfg.n_heads, cfg.n_kv_heads
    dh, dv = cfg.head_dim, cfg.v_dim
    if spec.mixer in ("attn", "attn_local", "attn_mla"):
        qkv_w = (d * (h * dh + 2 * kh * dh)) // tp_degree * bf
        qkv = g.add_node("qkv", tokens, line((h * dh + 2 * kh * dh)
                                             // tp_degree),
                         weight_bytes=qkv_w,
                         macs=tokens and 2 * d * (h * dh + 2 * kh * dh)
                         // tp_degree)
        g.add_edge(n1, qkv)
        attn = g.add_node("attn", tokens, line(h * dv // tp_degree),
                          macs=4 * tokens * (h // tp_degree) * dh // 2)
        g.add_edge(qkv, attn, kind=FULL)   # sequence-global dependency
        proj = g.add_node("attn_proj", tokens, line(d),
                          weight_bytes=h * dv * d // tp_degree * bf,
                          macs=2 * h * dv * d // tp_degree)
        g.add_edge(attn, proj)
        mix_out = g.add_node("add1", tokens, line(d), macs=d)
        g.add_edge(proj, mix_out)
        g.add_edge(x, mix_out)
    else:  # ssm/recurrent mixers: token-local once state is carried
        di = cfg.mamba_expand * d if spec.mixer == "mamba" else 2 * d
        inp = g.add_node("ssm_in", tokens, line(2 * di // tp_degree),
                         weight_bytes=d * 2 * di // tp_degree * bf,
                         macs=2 * d * 2 * di // tp_degree)
        g.add_edge(n1, inp)
        conv = g.add_node("ssm_conv", tokens, line(di // tp_degree),
                          weight_bytes=4 * di // tp_degree * bf,
                          macs=8 * di // tp_degree, )
        g.add_edge(inp, conv, F=4, s=1)
        scan = g.add_node("ssm_scan", tokens, line(di // tp_degree),
                          macs=10 * di * cfg.mamba_d_state // tp_degree)
        g.add_edge(conv, scan, F=1, s=1)
        outp = g.add_node("ssm_out", tokens, line(d),
                          weight_bytes=di * d // tp_degree * bf,
                          macs=2 * di * d // tp_degree)
        g.add_edge(scan, outp)
        mix_out = g.add_node("add1", tokens, line(d), macs=d)
        g.add_edge(outp, mix_out)
        g.add_edge(x, mix_out)

    if spec.ffn == "none":
        g.nodes[mix_out].is_output = True
        return g

    n2 = g.add_node("norm2", tokens, line(d), weight_bytes=d * bf, macs=4 * d)
    g.add_edge(mix_out, n2)
    dff = (cfg.d_ff_expert if spec.ffn in (FFN_MOE, FFN_MOE_RESIDUAL)
           else cfg.d_ff)
    dff_eff = dff * (cfg.top_k if spec.ffn in (FFN_MOE, FFN_MOE_RESIDUAL)
                     else 1)
    up = g.add_node("ffn_up_gate", tokens, line(2 * dff_eff // tp_degree),
                    weight_bytes=2 * d * dff_eff // tp_degree * bf,
                    macs=4 * d * dff_eff // tp_degree)
    g.add_edge(n2, up)
    gate = g.add_node("ffn_act", tokens, line(dff_eff // tp_degree),
                      macs=8 * dff_eff // tp_degree)
    g.add_edge(up, gate)
    down = g.add_node("ffn_down", tokens, line(d),
                      weight_bytes=dff_eff * d // tp_degree * bf,
                      macs=2 * dff_eff * d // tp_degree)
    g.add_edge(gate, down)
    out = g.add_node("add2", tokens, line(d), macs=d, is_output=True)
    g.add_edge(down, out)
    g.add_edge(mix_out, out)
    return g


@dataclass
class ExecutionPlan:
    arch: str
    layer_idx: int
    vmem_budget: int
    fusion_groups: List[List[str]]
    hbm_bytes: int
    hbm_bytes_unfused: int
    block_m: int                    # suggested kernel row-block size
    result: Optional["ExploreResult"] = None

    @property
    def traffic_saving(self) -> float:
        if self.hbm_bytes_unfused <= 0:
            return 0.0
        return 1.0 - self.hbm_bytes / self.hbm_bytes_unfused

    def summary(self) -> str:
        groups = " | ".join("+".join(gr) for gr in self.fusion_groups)
        return (f"{self.arch} L{self.layer_idx}: VMEM {self.vmem_budget//MB}MB, "
                f"HBM traffic -{self.traffic_saving*100:.0f}% vs unfused, "
                f"block_m={self.block_m}, groups: {groups}")


def plan_architecture(cfg: ModelConfig, tokens_local: int = 8192,
                      layer_idx: Optional[int] = None,
                      sample_budget: int = 3_000,
                      seed: int = 0) -> ExecutionPlan:
    """Run the paper's co-exploration over one block of the arch and derive
    the execution plan (fusion groups + VMEM budget + block size)."""
    if layer_idx is None:
        pre, p, reps, rem = cfg.layout()
        layer_idx = pre  # first scanned layer: the repeating workhorse
    g = build_block_graph(cfg, layer_idx, tokens_local)
    out_tile = max(128, tokens_local // 64)
    # VMEM is fixed hardware on TPU: partition under the fixed budget
    # (Formula 1); the *claimed working set* of the winning plan is the
    # memory-configuration output (it sizes the kernels' BlockSpecs).
    from repro.api import ExploreSpec, GAOptions
    from repro.api import run as api_run
    from repro.core.ga import HWSpace, Objective

    from .cost import CachedEvaluator
    from .memory import subgraph_footprint

    ev = CachedEvaluator(g, out_tile=out_tile)
    spec = ExploreSpec(workload=g.name, strategy="ga",
                       objective=Objective(metric="ema", alpha=None),
                       hw=HWSpace(mode="fixed", base=TPU_ACC),
                       sample_budget=sample_budget, seed=seed,
                       out_tile=out_tile, options=GAOptions(population=48))
    res = api_run(spec, graph=g, ev=ev)
    unfused = ev.plan([{v} for v in range(g.n)], TPU_ACC)
    groups = [[g.nodes[v].name for v in sorted(s)] for s in res.groups
              if len(s) > 0]
    claimed = max((subgraph_footprint(g, s, out_tile=out_tile).total_bytes
                   for s in res.groups), default=1)
    vmem = min((c for c in VMEM_CANDIDATES if c >= claimed),
               default=VMEM_CANDIDATES[-1])
    # block_m: rows of the widest fused group that fit half the VMEM budget
    widest = max((sum(g.nodes[v].line_bytes for v in s) for s in res.groups),
                 default=1)
    block_m = max(128, min(tokens_local, (vmem // 2) // max(widest, 1)))
    block_m = 1 << (block_m.bit_length() - 1)  # round down to pow2
    return ExecutionPlan(
        arch=cfg.name, layer_idx=layer_idx, vmem_budget=vmem,
        fusion_groups=groups, hbm_bytes=res.plan.ema_total,
        hbm_bytes_unfused=unfused.ema_total, block_m=block_m, result=res,
    )
