"""Accelerator cost model (paper §5.1.2): a Simba-like NPU core.

4x4 PEs x 8x8 MACs = 1024 MACs/cycle @ 1 GHz (2 TOPS), a global (activation)
buffer and a weight buffer (or one shared buffer), 16 GB/s DRAM, 12.5 pJ/bit
DRAM energy.  Weights of the *next* subgraph are prefetched during the current
subgraph's compute; subgraph latency = max(compute cycles, IO cycles).

Per-subgraph external memory access (EMA):
  * input activations crossing into the subgraph      (loaded once — full reuse),
  * output activations needed outside                  (stored once),
  * weights of the subgraph's layers                   (loaded once).

Feasibility rules (documented deviations in DESIGN.md §8):
  * activation footprint (consumption-centric allocations, incl. external
    input buffers) must fit the global buffer,
  * multi-layer subgraphs keep all member weights resident: sum of weights
    must fit the weight buffer; single-layer subgraphs may stream weights
    (reloading them once per row-block sweep if the input cannot be held).

Energy = DRAM traffic + buffer accesses (capacity-dependent pJ/B from an
ARM-memory-compiler-style sqrt model) + MAC energy.
"""

from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Set, Tuple

from .graph import FULL, Graph
from .memory import subgraph_footprint
from .tiling import derive_schedule

KB = 1024
MB = 1024 * 1024


@dataclass(frozen=True)
class AcceleratorConfig:
    """Hardware point being evaluated (the DSE genome's HW half)."""

    glb_bytes: int = 1 * MB              # global (activation) buffer
    wbuf_bytes: int = int(1.125 * MB)    # weight buffer
    shared: bool = False                 # one buffer for acts + weights
    macs_per_cycle: int = 1024           # 4x4 PEs x 8x8 MACs
    freq_hz: float = 1e9
    dram_bytes_per_sec: float = 16e9
    e_dram_pj_per_byte: float = 100.0    # 12.5 pJ/bit
    e_mac_pj: float = 0.05               # INT8 MAC @ 12nm
    n_cores: int = 1
    e_noc_pj_per_byte: float = 2.0       # core-to-core crossbar (Arteris-like)
    weight_share_cores: int = 1          # §5.4.2: cores hold 1/n of weights

    @property
    def buf_size_total(self) -> int:
        return self.glb_bytes if self.shared else self.glb_bytes + self.wbuf_bytes

    def sram_pj_per_byte(self, capacity_bytes: int) -> float:
        """Access energy grows ~sqrt(capacity) (bank/wire scaling)."""
        return 0.2 + 0.25 * math.sqrt(max(capacity_bytes, 1) / (64 * KB))

    @property
    def dram_bytes_per_cycle(self) -> float:
        return self.dram_bytes_per_sec / self.freq_hz


# paper's search grids (§5.3.1)
GLB_CANDIDATES = [k * KB for k in range(128, 2048 + 1, 64)]
WBUF_CANDIDATES = [k * KB for k in range(144, 2304 + 1, 72)]
SHARED_CANDIDATES = [k * KB for k in range(128, 3072 + 1, 64)]


@dataclass
class SubgraphCost:
    nodes: Tuple[int, ...]
    ema_in: int = 0
    ema_out: int = 0
    ema_w: int = 0
    macs: int = 0
    footprint: int = 0
    weight_resident: int = 0
    glb_access_bytes: int = 0
    wbuf_access_bytes: int = 0
    feasible: bool = True
    reason: str = ""

    @property
    def ema_total(self) -> int:
        return self.ema_in + self.ema_out + self.ema_w

    def compute_cycles(self, acc: AcceleratorConfig) -> float:
        return self.macs / acc.macs_per_cycle

    def io_cycles(self, acc: AcceleratorConfig) -> float:
        return self.ema_total / acc.dram_bytes_per_cycle

    def latency_cycles(self, acc: AcceleratorConfig) -> float:
        return max(self.compute_cycles(acc), self.io_cycles(acc))

    def energy_pj(self, acc: AcceleratorConfig) -> float:
        if acc.shared:
            e_glb = acc.sram_pj_per_byte(acc.glb_bytes)
            e_w = e_glb
        else:
            e_glb = acc.sram_pj_per_byte(acc.glb_bytes)
            e_w = acc.sram_pj_per_byte(acc.wbuf_bytes)
        return (
            self.ema_total * acc.e_dram_pj_per_byte
            + self.glb_access_bytes * e_glb
            + self.wbuf_access_bytes * e_w
            + self.macs * acc.e_mac_pj
        )


@dataclass
class PlanCost:
    """Aggregate cost of a full partition plan (paper Formulas 1 & 2)."""

    subgraphs: List[SubgraphCost]
    acc: AcceleratorConfig

    @property
    def feasible(self) -> bool:
        return all(s.feasible for s in self.subgraphs)

    @property
    def ema_total(self) -> int:
        return sum(s.ema_total for s in self.subgraphs)

    @property
    def energy_pj(self) -> float:
        return sum(s.energy_pj(self.acc) for s in self.subgraphs)

    @property
    def latency_cycles(self) -> float:
        return sum(s.latency_cycles(self.acc) for s in self.subgraphs)

    @property
    def latency_s(self) -> float:
        return self.latency_cycles / self.acc.freq_hz

    def avg_bandwidth(self) -> float:
        """bytes/s sustained over the whole network."""
        lat = self.latency_s
        return self.ema_total / lat if lat > 0 else 0.0

    def peak_bandwidth(self) -> float:
        """max over subgraphs of (act IO + next subgraph's weight prefetch) /
        subgraph latency (paper Fig. 3 caption)."""
        peak = 0.0
        for i, s in enumerate(self.subgraphs):
            nxt_w = (self.subgraphs[i + 1].ema_w
                     if i + 1 < len(self.subgraphs) else 0)
            lat = s.latency_cycles(self.acc) / self.acc.freq_hz
            if lat > 0:
                peak = max(peak, (s.ema_in + s.ema_out + nxt_w) / lat)
        return peak

    def metric(self, name: str) -> float:
        if name == "ema":
            return float(self.ema_total)
        if name == "energy":
            return self.energy_pj
        if name == "latency":
            return self.latency_cycles
        raise ValueError(name)


# ---------------------------------------------------------------------------
# the pure cost kernel
# ---------------------------------------------------------------------------
#
# A (frozenset(nodes), hardware-point) query is a side-effect-free function of
# the graph, split into two pure halves so batched executors can exploit the
# split (see core/engine.py):
#
#   compute_structure(g, nodes, out_tile)  — the expensive, hardware-
#       independent half: EMA sums, schedule derivation, footprint, on-chip
#       access traffic.  Depends only on the node set (and out_tile).
#   finish_cost(structure, acc)            — the cheap, hardware-dependent
#       half: feasibility vs the buffer capacities, single-layer weight
#       streaming, multi-core weight sharing.  Pure elementwise arithmetic,
#       so a whole batch vectorizes (engine.VectorExecutor).
#
# evaluate_subgraph == finish_cost(compute_structure(...), acc) exactly.


@dataclass(frozen=True)
class SubgraphStructure:
    """Hardware-independent half of a subgraph's cost (pure in the node set).

    ``sched_error`` carries the ``derive_schedule`` failure message when the
    subgraph has no consumption-centric schedule (then every hardware point
    is infeasible and the remaining fields stay at their defaults).
    """

    nodes: Tuple[int, ...]
    macs: int = 0
    weight_total: int = 0
    ema_in: int = 0
    ema_out: int = 0
    footprint: int = 0
    glb_access_bytes: int = 0
    sched_error: Optional[str] = None


def compute_structure(g: Graph, nodes: Set[int],
                      out_tile: int = 1) -> SubgraphStructure:
    """Hardware-independent analysis of one subgraph (pure function)."""
    nodes = set(nodes)
    ntuple = tuple(sorted(nodes))
    macs = sum(g.nodes[v].macs for v in nodes)
    weight_total = sum(g.nodes[v].weight_bytes for v in nodes)

    # ---- EMA ------------------------------------------------------------
    ext_in = {e.src for e in g.boundary_in(nodes)}
    ema_in = sum(g.nodes[t].out_bytes for t in ext_in)
    out_tensors = {e.src for e in g.boundary_out(nodes)}
    out_tensors |= {v for v in nodes if g.nodes[v].is_output}
    ema_out = sum(g.nodes[t].out_bytes for t in out_tensors)

    # ---- schedule + footprint -------------------------------------------
    try:
        sched = derive_schedule(g, nodes, out_tile=out_tile)
    except ValueError as err:
        return SubgraphStructure(nodes=ntuple, macs=macs,
                                 weight_total=weight_total,
                                 ema_in=ema_in, ema_out=ema_out,
                                 sched_error=str(err))
    fp = subgraph_footprint(g, nodes, schedule=sched)

    # ---- on-chip access traffic ------------------------------------------
    # each produced byte written once; each byte read ~F/s times per consumer
    glb = 0
    for t, ts in sched.tensors.items():
        b = g.nodes[t].out_bytes
        glb += b  # write (from DRAM or from PE)
        for e in g.out_edges(t):
            if e.dst in nodes:
                amp = (e.F / e.s) if e.kind != FULL else 1.0
                glb += int(b * amp)
    return SubgraphStructure(nodes=ntuple, macs=macs,
                             weight_total=weight_total,
                             ema_in=ema_in, ema_out=ema_out,
                             footprint=fp.total_bytes, glb_access_bytes=glb)


def finish_cost(st: SubgraphStructure, acc: AcceleratorConfig) -> SubgraphCost:
    """Hardware-dependent half: capacities, streaming, weight sharing.

    Pure arithmetic in ``st``'s fields and ``acc``'s capacities — the
    branch structure here is what ``engine.VectorExecutor`` vectorizes.
    """
    sc = SubgraphCost(nodes=st.nodes, macs=st.macs,
                      weight_resident=st.weight_total,
                      ema_in=st.ema_in, ema_out=st.ema_out,
                      ema_w=st.weight_total)
    if st.sched_error is not None:
        sc.feasible = False
        sc.reason = f"schedule: {st.sched_error}"
        return sc
    sc.footprint = st.footprint

    glb_cap = acc.glb_bytes
    wbuf_cap = acc.glb_bytes if acc.shared else acc.wbuf_bytes
    # multi-core weight sharing (§5.4.2): each core buffers 1/n of the weights
    sc.weight_resident = sc.weight_resident // max(acc.weight_share_cores, 1)
    single = len(st.nodes) == 1
    if acc.shared:
        if sc.footprint + sc.weight_resident > glb_cap:
            if not single:
                sc.feasible = False
                sc.reason = "shared buffer overflow"
            else:
                _stream_single_layer(sc, glb_cap)
    else:
        if sc.footprint > glb_cap:
            if not single:
                sc.feasible = False
                sc.reason = "global buffer overflow"
            else:
                _stream_single_layer(sc, glb_cap)
        if sc.feasible and not single and sc.weight_resident > wbuf_cap:
            sc.feasible = False
            sc.reason = "weight buffer overflow"
        if sc.feasible and single and sc.weight_resident > wbuf_cap:
            pass  # single layer streams weights (already loaded once)

    sc.glb_access_bytes = st.glb_access_bytes
    sc.wbuf_access_bytes = sc.weight_resident  # one streaming pass per sweep
    return sc


def evaluate_subgraph(
    g: Graph,
    nodes: Set[int],
    acc: AcceleratorConfig,
    consumers_outside: Optional[Dict[int, int]] = None,
    out_tile: int = 1,
) -> SubgraphCost:
    """Cost one subgraph. ``consumers_outside[t]`` = number of later subgraphs
    reading tensor t (re-reads cost EMA each time; charged at the reader)."""
    return finish_cost(compute_structure(g, nodes, out_tile=out_tile), acc)


def _stream_single_layer(sc: SubgraphCost, glb_cap: int) -> None:
    """Single layer whose line-buffer footprint exceeds the buffer: sweep the
    output in row blocks; weights are re-streamed once per block."""
    n_blocks = max(1, math.ceil(sc.footprint / max(glb_cap, 1)))
    sc.ema_w = sc.weight_resident * n_blocks
    sc.footprint = min(sc.footprint, glb_cap)
    sc.reason = f"streamed in {n_blocks} blocks"


class CostKernel:
    """The pure evaluation kernel: graph + out_tile + a structure memo.

    ``cost(nodes, acc)`` is a deterministic, side-effect-free function of
    its arguments; the only state here is memoization of
    :func:`compute_structure` (itself pure), shared by every executor
    backend.  Worker processes hold their own ``CostKernel`` and stay warm
    across batches.
    """

    def __init__(self, g: Graph, out_tile: int = 1) -> None:
        self.g = g
        self.out_tile = out_tile
        self._structures: Dict[frozenset, SubgraphStructure] = {}

    def structure(self, nodes: frozenset) -> SubgraphStructure:
        st = self._structures.get(nodes)
        if st is None:
            st = compute_structure(self.g, set(nodes), out_tile=self.out_tile)
            self._structures[nodes] = st
        return st

    def cost(self, nodes: frozenset, acc: AcceleratorConfig) -> SubgraphCost:
        return finish_cost(self.structure(nodes), acc)


def evaluate_partition(
    g: Graph,
    groups: Sequence[Set[int]],
    acc: AcceleratorConfig,
    out_tile: int = 1,
) -> PlanCost:
    """Cost a full plan: ``groups`` in execution order."""
    # count cross-subgraph readers per tensor (multi-reader tensors are
    # re-loaded by each reading subgraph; charged naturally since each group's
    # ema_in includes every external tensor it touches)
    subs = [evaluate_subgraph(g, set(s), acc, out_tile=out_tile)
            for s in groups]
    return PlanCost(subgraphs=subs, acc=acc)


class CachedEvaluator:
    """Memoizes per-subgraph costs across a whole search run.

    The schedule/footprint half depends only on the node set; the feasibility/
    streaming half also depends on the accelerator config, so the cache key is
    (frozenset(nodes), glb, wbuf, shared).  GA populations re-evaluate mostly
    unchanged subgraphs, giving ~2 orders of magnitude speedup.

    The evaluator is cache + counters only; *how* misses are computed is the
    ``executor``'s job (:mod:`repro.core.engine`): ``serial`` evaluates them
    inline through the pure :class:`CostKernel`, ``process`` shards a batch
    over worker processes, ``vector`` batches the hardware-dependent
    arithmetic through NumPy.  Every backend returns identical costs (the
    kernel is deterministic), so search results do not depend on the backend.
    """

    def __init__(self, g: Graph, out_tile: int = 1,
                 executor: Optional["Executor"] = None) -> None:
        self.g = g
        self.out_tile = out_tile
        self.kernel = CostKernel(g, out_tile=out_tile)
        self._executor = executor
        self._cache: Dict[Tuple, SubgraphCost] = {}
        self.evaluations = 0   # cache misses (true cost-model invocations)
        self.lookups = 0
        self.merged = 0        # entries adopted from other evaluators
        self._run_scopes: List[Set[Tuple]] = []

    @property
    def executor(self) -> "Executor":
        if self._executor is None:
            from .engine import SerialExecutor  # deferred: engine imports us
            self._executor = SerialExecutor()
        return self._executor

    def close(self) -> None:
        """Release executor resources (worker pools); the cache survives."""
        if self._executor is not None:
            self._executor.close()

    def _key(self, nodes: frozenset, acc: AcceleratorConfig) -> Tuple:
        return (nodes, acc.glb_bytes, acc.wbuf_bytes, acc.shared,
                acc.weight_share_cores)

    def subgraph(self, nodes: Set[int], acc: AcceleratorConfig) -> SubgraphCost:
        fs = frozenset(nodes)
        key = self._key(fs, acc)
        self.lookups += 1
        for scope in self._run_scopes:
            scope.add(key)
        hit = self._cache.get(key)
        if hit is None:
            hit = self.kernel.cost(fs, acc)
            self._cache[key] = hit
            self.evaluations += 1
        return hit

    def evaluate_batch(
        self, queries: Sequence[Tuple[Set[int], AcceleratorConfig]],
    ) -> List[SubgraphCost]:
        """Evaluate a batch of (nodes, acc) queries through the executor.

        Cache hits are served directly; distinct misses are submitted to the
        executor as one batch (where ``process``/``vector`` backends get
        their parallelism) and adopted into the cache on return.  Results
        come back in query order and are identical to issuing
        :meth:`subgraph` serially — batching changes the execution schedule,
        never the values or the distinct-query accounting.
        """
        results: List[Optional[SubgraphCost]] = [None] * len(queries)
        miss_keys: List[Tuple] = []
        miss_queries: List[Tuple[frozenset, AcceleratorConfig]] = []
        miss_pos: Dict[Tuple, List[int]] = {}
        for i, (nodes, acc) in enumerate(queries):
            fs = frozenset(nodes)
            key = self._key(fs, acc)
            self.lookups += 1
            for scope in self._run_scopes:
                scope.add(key)
            hit = self._cache.get(key)
            if hit is not None:
                results[i] = hit
            elif key in miss_pos:
                miss_pos[key].append(i)
            else:
                miss_pos[key] = [i]
                miss_keys.append(key)
                miss_queries.append((fs, acc))
        if miss_queries:
            costs = self.executor.evaluate(self.kernel, miss_queries)
            # every miss counts as one true cost-model invocation, whichever
            # executor computed it — so run_ga/run_sa report the same
            # ``evaluations`` under every backend; ``merged`` stays reserved
            # for cross-evaluator adoption (parallel compare join)
            for key, cost in zip(miss_keys, costs):
                self._cache[key] = cost
                self.evaluations += 1
                for i in miss_pos[key]:
                    results[i] = cost
        return results  # type: ignore[return-value]

    @contextmanager
    def count_run(self) -> Iterator[Set[Tuple]]:
        """Track the *distinct* (subgraph, hardware-point) queries of one run.

        Unlike ``evaluations`` (raw cache misses, which shrink as the cache
        warms), the yielded set has the same size however warm the cache is —
        so a strategy's reported evaluation count is identical whether it runs
        alone, after other strategies on a shared evaluator, or in a cold
        worker process.  Scopes nest: an inner run's queries also count toward
        every enclosing scope.
        """
        touched: Set[Tuple] = set()
        self._run_scopes.append(touched)
        try:
            yield touched
        finally:
            # pop by position, not value: nested scope sets can be *equal*
            # (same keys), and scopes unwind strictly LIFO
            assert self._run_scopes[-1] is touched
            self._run_scopes.pop()

    def merge_cache(self, entries: Mapping[Tuple, SubgraphCost]) -> int:
        """Adopt another evaluator's cache entries (parallel-worker join).

        Existing keys win (the cost model is deterministic, so both sides
        hold equal values anyway).  Returns the number of new entries.
        """
        added = 0
        for key, val in entries.items():
            if key not in self._cache:
                self._cache[key] = val
                added += 1
        self.merged += added
        return added

    def cache_snapshot(self) -> Dict[Tuple, SubgraphCost]:
        """Picklable copy of the memo table, for cross-process merging."""
        return dict(self._cache)

    def plan(self, groups: Sequence[Set[int]], acc: AcceleratorConfig) -> PlanCost:
        return PlanCost(
            subgraphs=[self.subgraph(s, acc) for s in groups], acc=acc
        )

    def plan_batch(
        self,
        items: Sequence[Tuple[Sequence[Set[int]], AcceleratorConfig]],
    ) -> List[PlanCost]:
        """Cost many plans in one executor batch (order preserved)."""
        queries = [(s, acc) for groups, acc in items for s in groups]
        costs = self.evaluate_batch(queries)
        plans: List[PlanCost] = []
        pos = 0
        for groups, acc in items:
            n = len(groups)
            plans.append(PlanCost(subgraphs=costs[pos:pos + n], acc=acc))
            pos += n
        return plans
