"""Accelerator cost model (paper §5.1.2): a Simba-like NPU core.

4x4 PEs x 8x8 MACs = 1024 MACs/cycle @ 1 GHz (2 TOPS), a global (activation)
buffer and a weight buffer (or one shared buffer), 16 GB/s DRAM, 12.5 pJ/bit
DRAM energy.  Weights of the *next* subgraph are prefetched during the current
subgraph's compute; subgraph latency = max(compute cycles, IO cycles).

Per-subgraph external memory access (EMA):
  * input activations crossing into the subgraph      (loaded once — full reuse),
  * output activations needed outside                  (stored once),
  * weights of the subgraph's layers                   (loaded once).

Feasibility rules (documented deviations in DESIGN.md §8):
  * activation footprint (consumption-centric allocations, incl. external
    input buffers) must fit the global buffer,
  * multi-layer subgraphs keep all member weights resident: sum of weights
    must fit the weight buffer; single-layer subgraphs may stream weights
    (reloading them once per row-block sweep if the input cannot be held).

Energy = DRAM traffic + buffer accesses (capacity-dependent pJ/B from an
ARM-memory-compiler-style sqrt model) + MAC energy.
"""

from __future__ import annotations

import math
import os
import time
from contextlib import contextmanager
from dataclasses import dataclass, field, replace as dataclass_replace
from typing import (
    Any,
    Dict,
    Iterator,
    List,
    Mapping,
    Optional,
    Sequence,
    Set,
    Tuple,
)

from repro.obs import recorder as obs

from .graph import FULL, Graph
from .memory import subgraph_footprint
from .tiling import derive_schedule

KB = 1024
MB = 1024 * 1024

# every metric PlanCost.metric / Objective accept; "bandwidth" is the
# percentile of the plan's traffic-segment profile (see traffic_segments);
# "noc_p95" / "noc_link_peak" are the multi-core broadcast-fabric analogues
# (see noc_segments) — zero whenever weight_share_cores == 1
METRICS: Tuple[str, ...] = ("ema", "energy", "latency", "bandwidth",
                            "noc_p95", "noc_link_peak")
BANDWIDTH_PERCENTILE = 95.0

# reason prefix _stream_single_layer stamps on a streamed subgraph; the
# single definition both writers and readers (traffic_breakdown) share —
# the word is part of serialized artifacts, so change it only with a
# golden regeneration
STREAM_REASON = "streamed"


@dataclass(frozen=True)
class AcceleratorConfig:
    """Hardware point being evaluated (the DSE genome's HW half)."""

    glb_bytes: int = 1 * MB              # global (activation) buffer
    wbuf_bytes: int = int(1.125 * MB)    # weight buffer
    shared: bool = False                 # one buffer for acts + weights
    macs_per_cycle: int = 1024           # 4x4 PEs x 8x8 MACs
    freq_hz: float = 1e9
    dram_bytes_per_sec: float = 16e9
    e_dram_pj_per_byte: float = 100.0    # 12.5 pJ/bit
    e_mac_pj: float = 0.05               # INT8 MAC @ 12nm
    n_cores: int = 1
    e_noc_pj_per_byte: float = 2.0       # core-to-core crossbar (Arteris-like)
    weight_share_cores: int = 1          # §5.4.2: cores hold 1/n of weights

    def __post_init__(self) -> None:
        # fail typos/garbage at construction (like Objective.metric): the
        # kernel used to clamp a zero/negative share with max(share, 1),
        # silently turning a config error into single-core arithmetic
        if self.weight_share_cores < 1:
            raise ValueError(
                f"weight_share_cores must be >= 1, got "
                f"{self.weight_share_cores}; use 1 for a single core "
                f"(no weight sharing)")
        if self.n_cores < 1:
            raise ValueError(
                f"n_cores must be >= 1, got {self.n_cores}")

    @property
    def buf_size_total(self) -> int:
        return self.glb_bytes if self.shared else self.glb_bytes + self.wbuf_bytes

    def sram_pj_per_byte(self, capacity_bytes: int) -> float:
        """Access energy grows ~sqrt(capacity) (bank/wire scaling)."""
        return 0.2 + 0.25 * math.sqrt(max(capacity_bytes, 1) / (64 * KB))

    @property
    def dram_bytes_per_cycle(self) -> float:
        return self.dram_bytes_per_sec / self.freq_hz


# paper's search grids (§5.3.1)
GLB_CANDIDATES = [k * KB for k in range(128, 2048 + 1, 64)]
WBUF_CANDIDATES = [k * KB for k in range(144, 2304 + 1, 72)]
SHARED_CANDIDATES = [k * KB for k in range(128, 3072 + 1, 64)]


@dataclass(frozen=True)
class TrafficBreakdown:
    """How one subgraph's DRAM traffic decomposes over its lifetime.

    ``weight_first`` is loaded once before the subgraph starts (and is what
    the next-subgraph weight prefetch moves under the previous subgraph's
    compute); ``weight_stream`` is re-streamed *during* execution by a
    single-layer row-block sweep (``stream_blocks`` sweeps total, 1 = no
    streaming).  Invariant: ``weight_first + weight_stream == ema_w``.
    This is the per-subgraph hook :mod:`repro.sim` lowers into a timeline.
    """

    ema_in: int
    ema_out: int
    weight_first: int
    weight_stream: int
    stream_blocks: int

    @property
    def total(self) -> int:
        return self.ema_in + self.ema_out + self.weight_first \
            + self.weight_stream


def time_weighted_percentile(pairs: Sequence[Tuple[float, float]],
                             p: float) -> float:
    """Percentile ``p`` (0..100) of ``value`` weighted by ``weight``.

    ``pairs`` is (value, weight); zero-weight pairs are ignored.  Returns
    the smallest value v such that at least p% of the total weight lies at
    values <= v — the step-function percentile the trace simulator and the
    plan-level bandwidth metric share, so both layers agree exactly.
    """
    live = [(v, w) for v, w in pairs if w > 0]
    if not live:
        return 0.0
    live.sort(key=lambda vw: vw[0])
    total = sum(w for _, w in live)
    acc = 0.0
    for v, w in live:
        acc += w
        if acc >= (p / 100.0) * total - 1e-12 * total:
            return v
    return live[-1][0]


@dataclass
class SubgraphCost:
    nodes: Tuple[int, ...]
    ema_in: int = 0
    ema_out: int = 0
    ema_w: int = 0
    macs: int = 0
    footprint: int = 0
    weight_resident: int = 0
    glb_access_bytes: int = 0
    wbuf_access_bytes: int = 0
    # §5.4.2 multi-core weight sharing: bytes rotated across the core-to-core
    # fabric so every core sees the full weight set while buffering only its
    # 1/n shard — (weight_share_cores - 1) * ema_w, zero on a single core
    noc_bytes: int = 0
    feasible: bool = True
    reason: str = ""

    @property
    def ema_total(self) -> int:
        return self.ema_in + self.ema_out + self.ema_w

    def compute_cycles(self, acc: AcceleratorConfig) -> float:
        return self.macs / acc.macs_per_cycle

    def io_cycles(self, acc: AcceleratorConfig) -> float:
        return self.ema_total / acc.dram_bytes_per_cycle

    def latency_cycles(self, acc: AcceleratorConfig) -> float:
        return max(self.compute_cycles(acc), self.io_cycles(acc))

    def traffic_breakdown(self) -> TrafficBreakdown:
        """Split ``ema_*`` into the phases the trace simulator executes.

        Streaming is recovered from the cost itself (``ema_w`` is
        ``weight_resident * n_blocks`` when ``_stream_single_layer`` ran),
        so round-tripped plans decompose identically to fresh ones.
        """
        streamed = self.reason.startswith(STREAM_REASON)
        if streamed and self.weight_resident > 0:
            first = self.weight_resident
            blocks = self.ema_w // self.weight_resident
        else:
            first = self.ema_w
            blocks = 1
        return TrafficBreakdown(
            ema_in=self.ema_in, ema_out=self.ema_out, weight_first=first,
            weight_stream=self.ema_w - first, stream_blocks=max(blocks, 1))

    def energy_pj(self, acc: AcceleratorConfig) -> float:
        if acc.shared:
            e_glb = acc.sram_pj_per_byte(acc.glb_bytes)
            e_w = e_glb
        else:
            e_glb = acc.sram_pj_per_byte(acc.glb_bytes)
            e_w = acc.sram_pj_per_byte(acc.wbuf_bytes)
        return (
            self.ema_total * acc.e_dram_pj_per_byte
            + self.glb_access_bytes * e_glb
            + self.wbuf_access_bytes * e_w
            + self.noc_bytes * acc.e_noc_pj_per_byte
            + self.macs * acc.e_mac_pj
        )


@dataclass
class PlanCost:
    """Aggregate cost of a full partition plan (paper Formulas 1 & 2)."""

    subgraphs: List[SubgraphCost]
    acc: AcceleratorConfig

    @property
    def feasible(self) -> bool:
        return all(s.feasible for s in self.subgraphs)

    @property
    def ema_total(self) -> int:
        return sum(s.ema_total for s in self.subgraphs)

    @property
    def energy_pj(self) -> float:
        return sum(s.energy_pj(self.acc) for s in self.subgraphs)

    @property
    def latency_cycles(self) -> float:
        return sum(s.latency_cycles(self.acc) for s in self.subgraphs)

    @property
    def latency_s(self) -> float:
        return self.latency_cycles / self.acc.freq_hz

    def avg_bandwidth(self) -> float:
        """bytes/s sustained over the whole network."""
        lat = self.latency_s
        return self.ema_total / lat if lat > 0 else 0.0

    def peak_bandwidth(self) -> float:
        """max segment bandwidth requirement over the plan's timeline
        (paper Fig. 3 caption: act IO + the next subgraph's weight prefetch
        over each subgraph's latency, plus any single-layer block
        re-streaming; the link-bound weight prologue is excluded).  One
        timeline model with :meth:`traffic_segments`, so this equals the
        trace simulator's peak at one-step-per-subgraph resolution by
        construction."""
        freq = self.acc.freq_hz
        return max((bytes_ / cycles * freq
                    for bytes_, cycles in self.traffic_segments()
                    if cycles > 0), default=0.0)

    def prologue_traffic(self) -> Tuple[int, float]:
        """``(bytes, cycles)`` of the initial weight load before subgraph 0.

        The prologue streams the first subgraph's resident weights at the
        DRAM link rate with nothing to overlap, so its duration is defined
        *by* the interface rate — its bandwidth is the link rate by
        construction and carries no plan-dependent requirement signal,
        which is why it is excluded from :meth:`traffic_segments` (it still
        counts toward totals and sustained bandwidth).
        """
        if not self.subgraphs:
            return (0, 0.0)
        first0 = self.subgraphs[0].traffic_breakdown().weight_first
        return (first0, first0 / self.acc.dram_bytes_per_cycle)

    def traffic_segments(self) -> List[Tuple[int, float]]:
        """``(dram_bytes, duration_cycles)`` per bandwidth-requirement
        segment: one per subgraph.

        Each segment's duration is the analytical subgraph latency and its
        bytes are the activations crossing DRAM, any single-layer weight
        re-streaming, and the *next* subgraph's prefetched weights
        (double-buffered under this subgraph's compute, paper Fig. 3).
        The weight prologue is deliberately excluded — it is link-bound by
        construction (see :meth:`prologue_traffic`).  This is exactly what
        :func:`repro.sim.simulate_plan` produces when its row-granular
        steps are coalesced to one step per subgraph — the trace layer's
        profile statistics pin that equivalence.
        """
        segs: List[Tuple[int, float]] = []
        subs = self.subgraphs
        for i, s in enumerate(subs):
            b = s.traffic_breakdown()
            nxt = (subs[i + 1].traffic_breakdown().weight_first
                   if i + 1 < len(subs) else 0)
            segs.append((b.ema_in + b.ema_out + b.weight_stream + nxt,
                         s.latency_cycles(self.acc)))
        return segs

    def bandwidth_percentile(self, p: float = BANDWIDTH_PERCENTILE) -> float:
        """Time-weighted percentile of segment bandwidth, in bytes/s."""
        freq = self.acc.freq_hz
        pairs = [(bytes_ / cycles * freq, cycles)
                 for bytes_, cycles in self.traffic_segments() if cycles > 0]
        return time_weighted_percentile(pairs, p)

    @property
    def noc_total(self) -> int:
        """Total weight-broadcast bytes over the core-to-core fabric."""
        return sum(s.noc_bytes for s in self.subgraphs)

    def noc_segments(self) -> List[Tuple[int, float]]:
        """``(noc_bytes, duration_cycles)`` per requirement segment: one per
        subgraph, on the *same* timeline as :meth:`traffic_segments`.

        A weight byte crosses the fabric when it arrives from DRAM, so
        segment ``i`` broadcasts its own re-streamed blocks plus the next
        subgraph's prefetched first load — ``(share - 1) *`` the weight
        bytes of the matching DRAM segment.  The prologue broadcast (the
        first subgraph's initial weights) is excluded for the same reason
        the DRAM prologue is (see :meth:`prologue_traffic`); it still
        counts toward :attr:`noc_total`.
        """
        share = self.acc.weight_share_cores
        segs: List[Tuple[int, float]] = []
        subs = self.subgraphs
        for i, s in enumerate(subs):
            b = s.traffic_breakdown()
            nxt = (subs[i + 1].traffic_breakdown().weight_first
                   if i + 1 < len(subs) else 0)
            segs.append(((share - 1) * (b.weight_stream + nxt),
                         s.latency_cycles(self.acc)))
        return segs

    def noc_percentile(self, p: float = BANDWIDTH_PERCENTILE) -> float:
        """Time-weighted percentile of aggregate NoC bandwidth, bytes/s."""
        freq = self.acc.freq_hz
        pairs = [(bytes_ / cycles * freq, cycles)
                 for bytes_, cycles in self.noc_segments() if cycles > 0]
        return time_weighted_percentile(pairs, p)

    def noc_link_peak(self) -> float:
        """Peak *per-link* NoC bandwidth over the timeline, in bytes/s.

        The rotation fabric is symmetric over ``weight_share_cores`` links
        (each core forwards its shard to one neighbour per hop), so a
        segment's broadcast bytes spread evenly: per link, ``bytes /
        share``.
        """
        share = self.acc.weight_share_cores
        freq = self.acc.freq_hz
        return max((bytes_ / share / cycles * freq
                    for bytes_, cycles in self.noc_segments()
                    if cycles > 0), default=0.0)

    def metric(self, name: str) -> float:
        if name == "ema":
            return float(self.ema_total)
        if name == "energy":
            return self.energy_pj
        if name == "latency":
            return self.latency_cycles
        if name == "bandwidth":
            return self.bandwidth_percentile()
        if name == "noc_p95":
            return self.noc_percentile(95.0)
        if name == "noc_link_peak":
            return self.noc_link_peak()
        raise ValueError(
            f"unknown plan metric {name!r}; valid metrics: "
            f"{', '.join(METRICS)}")


# ---------------------------------------------------------------------------
# the pure cost kernel
# ---------------------------------------------------------------------------
#
# A (frozenset(nodes), hardware-point) query is a side-effect-free function of
# the graph, split into two pure halves so batched executors can exploit the
# split (see core/engine.py):
#
#   compute_structure(g, nodes, out_tile)  — the expensive, hardware-
#       independent half: EMA sums, schedule derivation, footprint, on-chip
#       access traffic.  Depends only on the node set (and out_tile).
#   finish_cost(structure, acc)            — the cheap, hardware-dependent
#       half: feasibility vs the buffer capacities, single-layer weight
#       streaming, multi-core weight sharing.  Pure elementwise arithmetic,
#       so a whole batch vectorizes (engine.VectorExecutor).
#
# evaluate_subgraph == finish_cost(compute_structure(...), acc) exactly.


@dataclass(frozen=True)
class SubgraphStructure:
    """Hardware-independent half of a subgraph's cost (pure in the node set).

    ``sched_error`` carries the ``derive_schedule`` failure message when the
    subgraph has no consumption-centric schedule (then every hardware point
    is infeasible and the remaining fields stay at their defaults).
    """

    nodes: Tuple[int, ...]
    macs: int = 0
    weight_total: int = 0
    ema_in: int = 0
    ema_out: int = 0
    footprint: int = 0
    glb_access_bytes: int = 0
    sched_error: Optional[str] = None


def compute_structure(g: Graph, nodes: Set[int],
                      out_tile: int = 1) -> SubgraphStructure:
    """Hardware-independent analysis of one subgraph (pure function)."""
    nodes = set(nodes)
    ntuple = tuple(sorted(nodes))
    macs = sum(g.nodes[v].macs for v in nodes)
    weight_total = sum(g.nodes[v].weight_bytes for v in nodes)

    # ---- EMA ------------------------------------------------------------
    ext_in = {e.src for e in g.boundary_in(nodes)}
    ema_in = sum(g.nodes[t].out_bytes for t in ext_in)
    out_tensors = {e.src for e in g.boundary_out(nodes)}
    out_tensors |= {v for v in nodes if g.nodes[v].is_output}
    ema_out = sum(g.nodes[t].out_bytes for t in out_tensors)

    # ---- schedule + footprint -------------------------------------------
    try:
        sched = derive_schedule(g, nodes, out_tile=out_tile)
    except ValueError as err:
        return SubgraphStructure(nodes=ntuple, macs=macs,
                                 weight_total=weight_total,
                                 ema_in=ema_in, ema_out=ema_out,
                                 sched_error=str(err))
    fp = subgraph_footprint(g, nodes, schedule=sched)

    # ---- on-chip access traffic ------------------------------------------
    # each produced byte written once; each byte read ~F/s times per consumer
    glb = 0
    for t, ts in sched.tensors.items():
        b = g.nodes[t].out_bytes
        glb += b  # write (from DRAM or from PE)
        for e in g.out_edges(t):
            if e.dst in nodes:
                amp = (e.F / e.s) if e.kind != FULL else 1.0
                glb += int(b * amp)
    return SubgraphStructure(nodes=ntuple, macs=macs,
                             weight_total=weight_total,
                             ema_in=ema_in, ema_out=ema_out,
                             footprint=fp.total_bytes, glb_access_bytes=glb)


def finish_cost(st: SubgraphStructure, acc: AcceleratorConfig) -> SubgraphCost:
    """Hardware-dependent half: capacities, streaming, weight sharing.

    Pure arithmetic in ``st``'s fields and ``acc``'s capacities — the
    branch structure here is what ``engine.VectorExecutor`` vectorizes.
    """
    sc = SubgraphCost(nodes=st.nodes, macs=st.macs,
                      weight_resident=st.weight_total,
                      ema_in=st.ema_in, ema_out=st.ema_out,
                      ema_w=st.weight_total)
    if st.sched_error is not None:
        sc.feasible = False
        sc.reason = f"schedule: {st.sched_error}"
        sc.noc_bytes = (acc.weight_share_cores - 1) * sc.ema_w
        return sc
    sc.footprint = st.footprint

    glb_cap = acc.glb_bytes
    wbuf_cap = acc.glb_bytes if acc.shared else acc.wbuf_bytes
    # multi-core weight sharing (§5.4.2): each core buffers 1/n of the
    # weights (construction validates weight_share_cores >= 1)
    sc.weight_resident = sc.weight_resident // acc.weight_share_cores
    single = len(st.nodes) == 1
    if acc.shared:
        if sc.footprint + sc.weight_resident > glb_cap:
            if not single:
                sc.feasible = False
                sc.reason = "shared buffer overflow"
            else:
                _stream_single_layer(sc, glb_cap)
    else:
        if sc.footprint > glb_cap:
            if not single:
                sc.feasible = False
                sc.reason = "global buffer overflow"
            else:
                _stream_single_layer(sc, glb_cap)
        if sc.feasible and not single and sc.weight_resident > wbuf_cap:
            sc.feasible = False
            sc.reason = "weight buffer overflow"
        if sc.feasible and single and sc.weight_resident > wbuf_cap:
            pass  # single layer streams weights (already loaded once)

    sc.glb_access_bytes = st.glb_access_bytes
    sc.wbuf_access_bytes = sc.weight_resident  # one streaming pass per sweep
    # §5.4.2 NoC charge: every DRAM-loaded weight byte (ema_w, *after* any
    # streaming resolution — a streamed sweep rotates each re-loaded block
    # too) crosses the fabric to the weight_share_cores - 1 peer cores
    sc.noc_bytes = (acc.weight_share_cores - 1) * sc.ema_w
    return sc


def evaluate_subgraph(
    g: Graph,
    nodes: Set[int],
    acc: AcceleratorConfig,
    consumers_outside: Optional[Dict[int, int]] = None,
    out_tile: int = 1,
) -> SubgraphCost:
    """Cost one subgraph. ``consumers_outside[t]`` = number of later subgraphs
    reading tensor t (re-reads cost EMA each time; charged at the reader)."""
    return finish_cost(compute_structure(g, nodes, out_tile=out_tile), acc)


def _stream_single_layer(sc: SubgraphCost, glb_cap: int) -> None:
    """Single layer whose line-buffer footprint exceeds the buffer: sweep the
    output in row blocks; weights are re-streamed once per block."""
    n_blocks = max(1, math.ceil(sc.footprint / max(glb_cap, 1)))
    sc.ema_w = sc.weight_resident * n_blocks
    sc.footprint = min(sc.footprint, glb_cap)
    sc.reason = f"{STREAM_REASON} in {n_blocks} blocks"


# canonical memoization default: on everywhere, disabled only for honest
# before/after measurement (REPRO_STRUCT_CANON=0)
_CANON_ENV = "REPRO_STRUCT_CANON"


def canonical_structure_key(g: Graph, nodes: Set[int],
                            out_tile: int = 1) -> Tuple:
    """Content fingerprint of a subgraph query (hashable, label-free).

    Two node sets map to the same key iff relabeling each set's nodes by
    ascending index (internal nodes to ``0..k-1``, external producers to
    ``0..m-1``) yields identical structures over every field
    :func:`compute_structure` reads:

    * per internal node, in sorted-index order:
      ``(out_len, line_bytes, weight_bytes, macs, writes_out)`` where
      ``writes_out`` folds ``is_output`` with "has a consumer outside the
      set" (their union is what feeds ``ema_out``);
    * internal edges as ``(src', dst', F, s, kind)`` with relabeled
      endpoints, sorted;
    * per external producer, in sorted-index order:
      ``(out_len, line_bytes)`` (what ``ema_in``/footprint read);
    * external in-edges as ``(producer', dst', F, s, kind)``, sorted;
    * ``out_tile``.

    Sorted-index relabeling is order-preserving, and every stage of
    :func:`~repro.core.tiling.derive_schedule` is a well-founded recursion
    on consumers (stage 2) or a unique co-prime rate solution (stage 3), so
    equal keys imply field-for-field equal structures up to the ``nodes``
    tuple — the property the canonical memo in :class:`CostKernel` relies
    on and ``tests/test_canonical_structure.py`` fuzzes.  The one
    label-*dependent* output, a ``sched_error`` message (it embeds concrete
    node indices), is excluded from canonical caching by the kernel.
    """
    ntuple = tuple(sorted(nodes))
    nset = set(ntuple)
    rel = {v: i for i, v in enumerate(ntuple)}
    node_sig: List[Tuple] = []
    int_edges: List[Tuple] = []
    ext_cons: Dict[int, List[Tuple]] = {}
    for v in ntuple:
        nd = g.nodes[v]
        writes_out = nd.is_output
        if not writes_out:
            for e in g.out_edges(v):
                if e.dst not in nset:
                    writes_out = True
                    break
        node_sig.append((nd.out_len, nd.line_bytes, nd.weight_bytes,
                         nd.macs, writes_out))
        for e in g.in_edges(v):
            if e.src in nset:
                int_edges.append((rel[e.src], rel[v], e.F, e.s, e.kind))
            else:
                ext_cons.setdefault(e.src, []).append(
                    (rel[v], e.F, e.s, e.kind))
    ext_sig: List[Tuple] = []
    ext_edges: List[Tuple] = []
    for j, p in enumerate(sorted(ext_cons)):
        nd = g.nodes[p]
        ext_sig.append((nd.out_len, nd.line_bytes))
        for tail in sorted(ext_cons[p]):
            ext_edges.append((j,) + tail)
    int_edges.sort()
    return (out_tile, tuple(node_sig), tuple(int_edges),
            tuple(ext_sig), tuple(ext_edges))


class CostKernel:
    """The pure evaluation kernel: graph + out_tile + a tiered structure memo.

    ``cost(nodes, acc)`` is a deterministic, side-effect-free function of
    its arguments; the only state here is memoization of
    :func:`compute_structure` (itself pure), shared by every executor
    backend.  Worker processes hold their own ``CostKernel`` and stay warm
    across batches.

    The memo has up to three tiers, consulted in order:

    1. **raw** — exact ``frozenset(nodes)`` key (the original memo);
    2. **canonical** — :func:`canonical_structure_key` content fingerprint,
       so isomorphic subgraphs (the repeated blocks of ``tpu:``/``netlib:``
       models, GA mutation motifs) share one ``derive_schedule`` call.  A
       canonical hit re-stamps ``SubgraphStructure.nodes`` with the query's
       own tuple, so results stay bitwise-identical to per-node-set
       evaluation.  Structures with a ``sched_error`` are cached *only* by
       raw key — the error message embeds concrete node indices;
    3. **disk** (optional) — a :class:`~repro.core.structcache.
       StructureCache` warming the canonical tier across processes and
       runs, gated like the result store.

    Canonical memoization is on by default; set ``REPRO_STRUCT_CANON=0``
    (or ``canonical=False``) to disable it for before/after measurement.
    """

    def __init__(self, g: Graph, out_tile: int = 1,
                 canonical: Optional[bool] = None,
                 struct_cache: Optional[Any] = None) -> None:
        self.g = g
        self.out_tile = out_tile
        if canonical is None:
            canonical = os.environ.get(_CANON_ENV, "1") != "0"
        self.canonical = bool(canonical)
        self.struct_cache = struct_cache
        self._structures: Dict[frozenset, SubgraphStructure] = {}
        self._canon: Dict[Tuple, SubgraphStructure] = {}
        # profiling counters (--profile surfaces these via the evaluator)
        self.structure_raw_hits = 0
        self.structure_canon_hits = 0
        self.structure_disk_hits = 0
        self.structure_misses = 0
        self.structure_merged = 0     # canonical entries adopted from peers
        self.structure_time_s = 0.0   # wall time inside compute_structure

    def structure(self, nodes: frozenset) -> SubgraphStructure:
        st = self._structures.get(nodes)
        if st is not None:
            self.structure_raw_hits += 1
            return st
        key: Optional[Tuple] = None
        if self.canonical:
            key = canonical_structure_key(self.g, nodes, self.out_tile)
            st = self._canon.get(key)
            if st is None and self.struct_cache is not None:
                st = self.struct_cache.get(key)
                if st is not None:
                    self.structure_disk_hits += 1
                    self._canon[key] = st
            elif st is not None:
                self.structure_canon_hits += 1
            if st is not None:
                st = dataclass_replace(st, nodes=tuple(sorted(nodes)))
                self._structures[nodes] = st
                return st
        t0 = time.perf_counter()
        st = compute_structure(self.g, set(nodes), out_tile=self.out_tile)
        self.structure_time_s += time.perf_counter() - t0
        self.structure_misses += 1
        self._structures[nodes] = st
        if key is not None and st.sched_error is None:
            self._canon[key] = st
            if self.struct_cache is not None:
                self.struct_cache.put(key, st)
        return st

    def cost(self, nodes: frozenset, acc: AcceleratorConfig) -> SubgraphCost:
        return finish_cost(self.structure(nodes), acc)

    def canon_snapshot(self) -> Dict[Tuple, SubgraphStructure]:
        """Picklable copy of the canonical tier (cross-process shipping)."""
        return dict(self._canon)

    def merge_canon(
            self, entries: Mapping[Tuple, SubgraphStructure]) -> int:
        """Adopt canonical entries from a peer kernel (worker join).

        Existing keys win — the kernel is deterministic, so both sides hold
        structures equal up to the ``nodes`` stamp, which every canonical
        hit re-stamps anyway.  Returns the number of new entries.
        """
        added = 0
        canon = self._canon
        for key, st in entries.items():
            if key not in canon:
                canon[key] = st
                added += 1
        self.structure_merged += added
        return added


def evaluate_partition(
    g: Graph,
    groups: Sequence[Set[int]],
    acc: AcceleratorConfig,
    out_tile: int = 1,
) -> PlanCost:
    """Cost a full plan: ``groups`` in execution order."""
    # count cross-subgraph readers per tensor (multi-reader tensors are
    # re-loaded by each reading subgraph; charged naturally since each group's
    # ema_in includes every external tensor it touches)
    subs = [evaluate_subgraph(g, set(s), acc, out_tile=out_tile)
            for s in groups]
    return PlanCost(subgraphs=subs, acc=acc)


class CachedEvaluator:
    """Memoizes per-subgraph costs across a whole search run.

    The schedule/footprint half depends only on the node set; the feasibility/
    streaming half also depends on the accelerator config, so the cache key is
    (frozenset(nodes), glb, wbuf, shared).  GA populations re-evaluate mostly
    unchanged subgraphs, giving ~2 orders of magnitude speedup.

    The evaluator is cache + counters only; *how* misses are computed is the
    ``executor``'s job (:mod:`repro.core.engine`): ``serial`` evaluates them
    inline through the pure :class:`CostKernel`, ``process`` shards a batch
    over worker processes, ``vector`` batches the hardware-dependent
    arithmetic through NumPy.  Every backend returns identical costs (the
    kernel is deterministic), so search results do not depend on the backend.
    """

    def __init__(self, g: Graph, out_tile: int = 1,
                 executor: Optional["Executor"] = None,
                 canonical: Optional[bool] = None,
                 struct_cache: Optional[Any] = None) -> None:
        self.g = g
        self.out_tile = out_tile
        self.kernel = CostKernel(g, out_tile=out_tile, canonical=canonical,
                                 struct_cache=struct_cache)
        self._executor = executor
        self._cache: Dict[Tuple, SubgraphCost] = {}
        self.evaluations = 0   # cache misses (true cost-model invocations)
        self.lookups = 0
        self.merged = 0        # entries adopted from other evaluators
        self._run_scopes: List[Set[Tuple]] = []

    @property
    def executor(self) -> "Executor":
        if self._executor is None:
            from .engine import SerialExecutor  # deferred: engine imports us
            self._executor = SerialExecutor()
        return self._executor

    def close(self) -> None:
        """Release executor resources (worker pools); the cache survives."""
        if self._executor is not None:
            self._executor.close()

    def _key(self, nodes: frozenset, acc: AcceleratorConfig) -> Tuple:
        return (nodes, acc.glb_bytes, acc.wbuf_bytes, acc.shared,
                acc.weight_share_cores)

    def subgraph(self, nodes: Set[int], acc: AcceleratorConfig) -> SubgraphCost:
        fs = frozenset(nodes)
        key = self._key(fs, acc)
        self.lookups += 1
        for scope in self._run_scopes:
            scope.add(key)
        hit = self._cache.get(key)
        if hit is None:
            hit = self.kernel.cost(fs, acc)
            self._cache[key] = hit
            self.evaluations += 1
        return hit

    def evaluate_batch(
        self, queries: Sequence[Tuple[Set[int], AcceleratorConfig]],
    ) -> List[SubgraphCost]:
        """Evaluate a batch of (nodes, acc) queries through the executor.

        Cache hits are served directly; distinct misses are submitted to the
        executor as one batch (where ``process``/``vector`` backends get
        their parallelism) and adopted into the cache on return.  Results
        come back in query order and are identical to issuing
        :meth:`subgraph` serially — batching changes the execution schedule,
        never the values or the distinct-query accounting.
        """
        results: List[Optional[SubgraphCost]] = [None] * len(queries)
        miss_keys: List[Tuple] = []
        miss_queries: List[Tuple[frozenset, AcceleratorConfig]] = []
        miss_pos: Dict[Tuple, List[int]] = {}
        for i, (nodes, acc) in enumerate(queries):
            fs = frozenset(nodes)
            key = self._key(fs, acc)
            self.lookups += 1
            for scope in self._run_scopes:
                scope.add(key)
            hit = self._cache.get(key)
            if hit is not None:
                results[i] = hit
            elif key in miss_pos:
                miss_pos[key].append(i)
            else:
                miss_pos[key] = [i]
                miss_keys.append(key)
                miss_queries.append((fs, acc))
        if miss_queries:
            with obs.span("evaluate_batch", queries=len(queries),
                          misses=len(miss_queries),
                          backend=self.executor.name):
                costs = self.executor.evaluate(self.kernel, miss_queries)
            # every miss counts as one true cost-model invocation, whichever
            # executor computed it — so run_ga/run_sa report the same
            # ``evaluations`` under every backend; ``merged`` stays reserved
            # for cross-evaluator adoption (parallel compare join)
            for key, cost in zip(miss_keys, costs):
                self._cache[key] = cost
                self.evaluations += 1
                for i in miss_pos[key]:
                    results[i] = cost
        return results  # type: ignore[return-value]

    @contextmanager
    def count_run(self) -> Iterator[Set[Tuple]]:
        """Track the *distinct* (subgraph, hardware-point) queries of one run.

        Unlike ``evaluations`` (raw cache misses, which shrink as the cache
        warms), the yielded set has the same size however warm the cache is —
        so a strategy's reported evaluation count is identical whether it runs
        alone, after other strategies on a shared evaluator, or in a cold
        worker process.  Scopes nest: an inner run's queries also count toward
        every enclosing scope.
        """
        touched: Set[Tuple] = set()
        self._run_scopes.append(touched)
        try:
            yield touched
        finally:
            # pop by position, not value: nested scope sets can be *equal*
            # (same keys), and scopes unwind strictly LIFO
            assert self._run_scopes[-1] is touched
            self._run_scopes.pop()

    def merge_cache(self, entries: Mapping[Tuple, SubgraphCost]) -> int:
        """Adopt another evaluator's cache entries (parallel-worker join).

        Existing keys win (the cost model is deterministic, so both sides
        hold equal values anyway).  Returns the number of new entries.
        """
        added = 0
        for key, val in entries.items():
            if key not in self._cache:
                self._cache[key] = val
                added += 1
        self.merged += added
        return added

    def cache_snapshot(self) -> Dict[Tuple, SubgraphCost]:
        """Picklable copy of the memo table, for cross-process merging."""
        return dict(self._cache)

    def merge_structures(
            self, entries: Mapping[Tuple, SubgraphStructure]) -> int:
        """Adopt canonical structure entries from a peer evaluator's kernel
        (the structure half of parallel ``compare``'s merge-on-join; the
        cost half is :meth:`merge_cache`).  Returns new entries adopted."""
        return self.kernel.merge_canon(entries)

    def structure_snapshot(self) -> Dict[Tuple, SubgraphStructure]:
        """Picklable copy of the kernel's canonical structure tier."""
        return self.kernel.canon_snapshot()

    def counters(self) -> Dict[str, Any]:
        """One flat dict of every cache/structure counter (the ``--profile``
        surface).  Structure counters are process-local: misses evaluated by
        a worker backend show up here only as adopted canonical entries
        (``structure_merged``), not as local derivations."""
        k = self.kernel
        out: Dict[str, Any] = {
            "lookups": self.lookups,
            "evaluations": self.evaluations,
            "merged": self.merged,
            "structure_raw_hits": k.structure_raw_hits,
            "structure_canon_hits": k.structure_canon_hits,
            "structure_disk_hits": k.structure_disk_hits,
            "structure_misses": k.structure_misses,
            "structure_merged": k.structure_merged,
            "structure_derive_s": k.structure_time_s,
            "canonical": k.canonical,
        }
        if k.struct_cache is not None:
            out["structure_disk_writes"] = k.struct_cache.writes
        return out

    def plan(self, groups: Sequence[Set[int]], acc: AcceleratorConfig) -> PlanCost:
        return PlanCost(
            subgraphs=[self.subgraph(s, acc) for s in groups], acc=acc
        )

    def plan_batch(
        self,
        items: Sequence[Tuple[Sequence[Set[int]], AcceleratorConfig]],
    ) -> List[PlanCost]:
        """Cost many plans in one executor batch (order preserved)."""
        queries = [(s, acc) for groups, acc in items for s in groups]
        costs = self.evaluate_batch(queries)
        plans: List[PlanCost] = []
        pos = 0
        for groups, acc in items:
            n = len(groups)
            plans.append(PlanCost(subgraphs=costs[pos:pos + n], acc=acc))
            pos += n
        return plans
