"""Consumption-centric subgraph execution scheme (paper §3.1, Fig. 5).

Given a subgraph (a set of nodes of a :class:`~repro.core.graph.Graph` plus the
external tensors feeding it), derive for every tensor resident in the global
buffer:

* ``delta``  -- the update offset Delta: rows of new data produced per update,
* ``x``      -- the buffer allocation in rows (the paper's ``x``),
* ``upd_num``-- updates per subgraph-level elementary operation (stage 3),

using the three-stage flow:

  stage 1:  output nodes of the subgraph get a chosen tile size (``out_tile``
            rows; smaller tiles hold larger subgraphs, paper §3.1),
  stage 2:  reverse topological order; ``Delta(u) = lcm_v{ Delta(v) * s(v) }``
            over sliding consumers v, and
            ``x(u) = max_v f_v(Delta(u) / s(v))`` with
            ``f_v(k) = F(v) + (k-1) * s(v)``,
  stage 3:  per-edge steady-state balance ``rate(u) * Delta(u) =
            rate(v) * Delta(v) * s(v)`` solved exactly over the rationals and
            scaled to the minimal co-prime integer solution (the paper's unique
            co-prime ``upd_num`` vector).

``full`` edges (attention/FC-over-sequence/global pooling) force the producer's
entire tensor to be buffered and split the pipeline into phases; the rate system
is solved per sliding-connected component.

External inputs of the subgraph are modelled as virtual nodes (the paper's
negative-numbered nodes): they stream rows from DRAM and are buffered like any
other tensor.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional, Set, Tuple

from .graph import FULL, SLIDING, Edge, Graph


@dataclass
class TensorSchedule:
    """Execution-scheme result for one resident tensor (node output)."""

    node: int                 # graph node index (producer of this tensor)
    delta: int                # update offset in rows
    x: int                    # allocated rows in the buffer
    upd_num: int              # updates per elementary operation
    external: bool            # True if produced outside the subgraph (DRAM load)
    full_resident: bool = False  # buffered in entirety (full-edge consumer)

    def alloc_rows(self) -> int:
        return self.x


@dataclass
class SubgraphSchedule:
    """Full execution scheme of one subgraph."""

    nodes: List[int]                       # internal nodes, topological order
    tensors: Dict[int, TensorSchedule]     # keyed by producer node idx
    n_elementary_ops: int                  # ops per full sweep
    phases: int                            # 1 + number of full-edge cuts

    def footprint_rows(self, node: int) -> int:
        return self.tensors[node].x


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def derive_schedule(
    g: Graph,
    nodes: Set[int],
    out_tile: int = 1,
) -> SubgraphSchedule:
    """Derive the consumption-centric execution scheme for ``nodes``.

    Tensors considered: outputs of every internal node, plus every external
    producer feeding the subgraph (virtual input nodes).
    """
    if not nodes:
        raise ValueError("empty subgraph")
    internal = sorted(nodes)
    ext_producers = sorted({e.src for e in g.boundary_in(nodes)})
    all_tensors = internal + [p for p in ext_producers if p not in nodes]

    # Consumers *inside* the subgraph of each tensor.
    cons: Dict[int, List[Edge]] = {t: [] for t in all_tensors}
    for e in g.edges:
        if e.dst in nodes and e.src in cons:
            cons[e.src].append(e)

    delta: Dict[int, int] = {}
    x: Dict[int, int] = {}
    full_res: Dict[int, bool] = {}

    # Stage 1 + 2: reverse topological order over tensors (graph indices are
    # topological; external producers always precede their consumers).
    for t in sorted(all_tensors, reverse=True):
        out_len = g.nodes[t].out_len
        sliding_cons = [e for e in cons[t] if e.kind == SLIDING]
        full_cons = [e for e in cons[t] if e.kind == FULL]
        is_subgraph_output = t in nodes and not cons[t]

        if is_subgraph_output:
            # Stage 1: output nodes drive the execution with the chosen tile.
            delta[t] = min(out_tile, out_len)
            x[t] = delta[t]
            full_res[t] = False
            continue

        if sliding_cons:
            d = 1
            for e in sliding_cons:
                d = _lcm(d, delta[e.dst] * e.s)
            d = min(d, out_len)
            req = 0
            for e in sliding_cons:
                k = max(1, d // e.s)
                # paper's f_v(k) = F + (k-1)s with k = delta(u)/s(v), i.e.
                # x = F + delta - s.  Exact for delta-quantum production with
                # prologue phase alignment (head starts at x, then +delta) and
                # row-granular consumption; steady-state peak occupancy is
                # max_a [F + a] over consumer offsets a = j*s mod delta,
                # a_max = delta - s.  Verified mechanically by core/simulate.py.
                req = max(req, e.window(k))
            xx = min(req, out_len)
        else:
            d, xx = out_len, out_len  # only full consumers: produce everything
        if full_cons:
            xx = out_len  # entire tensor must become resident
        delta[t] = d
        x[t] = xx
        full_res[t] = bool(full_cons) or (xx >= out_len and bool(full_cons))

    # Stage 3: minimal co-prime integer rates.  Solve per weakly-connected
    # component of the *sliding* dependency structure among all tensors.
    upd: Dict[int, int] = {t: 1 for t in all_tensors}
    adj: Dict[int, List[Tuple[int, Edge, bool]]] = {t: [] for t in all_tensors}
    for t in all_tensors:
        for e in cons[t]:
            if e.kind != SLIDING:
                continue
            adj[t].append((e.dst, e, True))    # producer -> consumer
            adj[e.dst].append((t, e, False))   # consumer -> producer

    seen: Set[int] = set()
    for root in all_tensors:
        if root in seen:
            continue
        comp: List[int] = []
        rate: Dict[int, Fraction] = {root: Fraction(1)}
        stack = [root]
        seen.add(root)
        while stack:
            u = stack.pop()
            comp.append(u)
            for (v, e, forward) in adj[u]:
                # balance: rate(src) * delta(src) == rate(dst) * delta(dst) * s
                if forward:  # u = src, v = dst
                    r = rate[u] * delta[u] / (delta[v] * e.s)
                else:        # u = dst, v = src
                    r = rate[u] * delta[u] * e.s / delta[v]
                if v in rate:
                    if rate[v] != r:
                        raise ValueError(
                            f"inconsistent stride structure at node {v}: "
                            f"{rate[v]} vs {r} (parallel paths with mismatched "
                            f"total stride)"
                        )
                else:
                    rate[v] = r
                    seen.add(v)
                    stack.append(v)
        # scale component rates to minimal co-prime integers
        denom_lcm = 1
        for r in rate.values():
            denom_lcm = _lcm(denom_lcm, r.denominator)
        ints = {t: int(r * denom_lcm) for t, r in rate.items()}
        gg = 0
        for val in ints.values():
            gg = math.gcd(gg, val)
        for t in comp:
            upd[t] = ints[t] // gg if gg else 1

    # Elementary operations per sweep: driven by the subgraph's sink tensor(s).
    sinks = [t for t in internal if not cons[t]]
    n_ops = 1
    for t in sinks:
        per_op = upd[t] * delta[t]
        n_ops = max(n_ops, math.ceil(g.nodes[t].out_len / per_op))

    # Count phases: each tensor consumed through a full edge ends a phase.
    n_full = sum(1 for t in all_tensors
                 if any(e.kind == FULL for e in cons[t]))
    tensors = {
        t: TensorSchedule(
            node=t,
            delta=delta[t],
            x=x[t],
            upd_num=upd[t],
            external=t not in nodes,
            full_resident=x[t] >= g.nodes[t].out_len
            and any(e.kind == FULL for e in cons[t]),
        )
        for t in all_tensors
    }
    return SubgraphSchedule(
        nodes=internal, tensors=tensors, n_elementary_ops=n_ops,
        phases=1 + n_full,
    )


def production_centric_footprint(
    g: Graph, nodes: Set[int], in_tile: int = 1
) -> Dict[int, int]:
    """The strawman of Fig. 4(a): forward-derive tile sizes from a fixed input
    tile; producers emit everything derivable, consumers lag behind the
    smallest branch, so extra rows pile up.  Returns rows resident per tensor —
    used in tests/benchmarks to show the consumption-centric scheme needs
    less memory (paper Fig. 4)."""
    internal = sorted(nodes)
    ext = sorted({e.src for e in g.boundary_in(nodes)})
    produced: Dict[int, int] = {}  # rows produced per elementary op
    for t in ext:
        produced[t] = max(in_tile, 1)
    resident: Dict[int, int] = {t: produced[t] for t in ext}
    for t in internal:
        ins = [e for e in g.in_edges(t)]
        if not ins:
            produced[t] = in_tile
            resident[t] = in_tile
            continue
        k = None
        for e in ins:
            if e.kind == FULL:
                k = 0
                break
            avail = produced.get(e.src, 0)
            kk = max(0, (avail - e.F) // e.s + 1)
            k = kk if k is None else min(k, kk)
        produced[t] = max(0, k or 0)
        resident[t] = max(produced[t], 1)
    # rows that can actually be consumed downstream this op
    consumed: Dict[int, int] = {}
    for t in reversed(internal + ext):
        outs = [e for e in g.out_edges(t) if e.dst in nodes]
        if not outs:
            consumed[t] = produced.get(t, 0)
            continue
        need = 0
        for e in outs:
            if e.kind == FULL:
                need = g.nodes[t].out_len
                break
            need = max(need, e.F + (max(produced.get(e.dst, 0), 1) - 1) * e.s)
        consumed[t] = min(need, produced.get(t, 0))
    # surplus rows (produced but not consumable) are the extra memory
    return {
        t: resident[t] + max(0, produced.get(t, 0) - consumed.get(t, 0))
        for t in resident
    }
