"""Paper workloads as Cocco computation graphs (paper §5.1.1).

plain:        VGG16 [57]
multi-branch: ResNet50 / ResNet152 [20], GoogleNet [59], Transformer [64], GPT [52]
irregular:    RandWire-A/B [68] (seeded Watts-Strogatz generators, networkx),
              NasNet-A [75]

Modelling conventions (paper §5.1.1): FC layers are 1x1 convolutions; pooling
and element-wise layers are depth-wise convolutions without weights; scalar
ops (activations) are hidden in the PE pipeline.  Activations and weights are
INT8 (1 byte/element).  The sliding axis is the feature-map height (rows);
``line_bytes = W_out * C_out``.  'same' padding: H_out = ceil(H/s).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from .graph import FULL, Graph


class NetBuilder:
    """Tracks (H, W, C) through the net and emits graph nodes."""

    def __init__(self, name: str, h: int, w: int, c: int) -> None:
        self.g = Graph(name)
        # virtual input: a source node with the input tensor, no weights
        self.input = self.g.add_node("input", h, w * c)
        self.shapes: Dict[int, Tuple[int, int, int]] = {self.input: (h, w, c)}

    def shape(self, node: int) -> Tuple[int, int, int]:
        return self.shapes[node]

    def conv(self, src: int, cout: int, f: int = 1, s: int = 1,
             name: str = "conv", depthwise: bool = False,
             weightless: bool = False) -> int:
        h, w, c = self.shapes[src]
        ho, wo = math.ceil(h / s), math.ceil(w / s)
        if depthwise:
            cout = c
            wbytes = 0 if weightless else f * f * c
            macs = ho * wo * c * f * f
        else:
            wbytes = 0 if weightless else f * f * c * cout
            macs = ho * wo * cout * f * f * c
        idx = self.g.add_node(name, ho, wo * cout, wbytes, macs)
        self.g.add_edge(src, idx, F=min(f, h), s=s)
        self.shapes[idx] = (ho, wo, cout)
        return idx

    def pool(self, src: int, f: int, s: int, name: str = "pool") -> int:
        return self.conv(src, 0, f, s, name=name, depthwise=True,
                         weightless=True)

    def global_pool(self, src: int, name: str = "gap") -> int:
        h, w, c = self.shapes[src]
        idx = self.g.add_node(name, 1, c, 0, h * w * c)
        self.g.add_edge(src, idx, F=h, s=h)
        self.shapes[idx] = (1, 1, c)
        return idx

    def fc(self, src: int, cout: int, name: str = "fc") -> int:
        """FC over a (possibly spatial) input: flattens the window."""
        h, w, c = self.shapes[src]
        wbytes = h * w * c * cout
        macs = wbytes
        idx = self.g.add_node(name, 1, cout, wbytes, macs)
        self.g.add_edge(src, idx, F=h, s=max(h, 1))
        self.shapes[idx] = (1, 1, cout)
        return idx

    def eltwise(self, srcs: Sequence[int], name: str = "add") -> int:
        h, w, c = self.shapes[srcs[0]]
        idx = self.g.add_node(name, h, w * c, 0, h * w * c * len(srcs))
        for s in srcs:
            self.g.add_edge(s, idx, F=1, s=1)
        self.shapes[idx] = (h, w, c)
        return idx

    def concat(self, srcs: Sequence[int], name: str = "concat") -> int:
        h, w, _ = self.shapes[srcs[0]]
        ctot = sum(self.shapes[s][2] for s in srcs)
        idx = self.g.add_node(name, h, w * ctot, 0, 0)
        for s in srcs:
            self.g.add_edge(s, idx, F=1, s=1)
        self.shapes[idx] = (h, w, ctot)
        return idx

    def attention(self, src: int, name: str = "attn") -> int:
        """Sequence-global op: full dependency on the producer."""
        h, w, c = self.shapes[src]
        idx = self.g.add_node(name, h, w * c, 0, 0)
        self.g.add_edge(src, idx, kind=FULL)
        self.shapes[idx] = (h, w, c)
        return idx

    def mark_output(self, node: int) -> None:
        self.g.nodes[node].is_output = True

    def done(self, out: Optional[int] = None) -> Graph:
        if out is not None:
            self.mark_output(out)
        else:
            for v in self.g.sinks():
                self.g.nodes[v].is_output = True
        return self.g


# ---------------------------------------------------------------------------
# plain: VGG16
# ---------------------------------------------------------------------------

def vgg16() -> Graph:
    b = NetBuilder("vgg16", 224, 224, 3)
    x = b.input
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    i = 0
    for v in cfg:
        if v == "M":
            x = b.pool(x, 2, 2, name=f"pool{i}")
        else:
            x = b.conv(x, v, 3, 1, name=f"conv{i}")
            i += 1
    x = b.fc(x, 4096, "fc6")
    x = b.fc(x, 4096, "fc7")
    x = b.fc(x, 1000, "fc8")
    return b.done(x)


# ---------------------------------------------------------------------------
# multi-branch: ResNet-50 / ResNet-152
# ---------------------------------------------------------------------------

def _bottleneck(b: NetBuilder, x: int, cmid: int, stride: int,
                tag: str, project: bool) -> int:
    cout = cmid * 4
    y = b.conv(x, cmid, 1, 1, name=f"{tag}.c1")
    y = b.conv(y, cmid, 3, stride, name=f"{tag}.c2")
    y = b.conv(y, cout, 1, 1, name=f"{tag}.c3")
    if project:
        sc = b.conv(x, cout, 1, stride, name=f"{tag}.proj")
    else:
        sc = x
    return b.eltwise([y, sc], name=f"{tag}.add")


def _resnet(name: str, blocks: Sequence[int]) -> Graph:
    b = NetBuilder(name, 224, 224, 3)
    x = b.conv(b.input, 64, 7, 2, name="conv1")
    x = b.pool(x, 3, 2, name="pool1")
    cmid = 64
    for li, n in enumerate(blocks):
        for bi in range(n):
            stride = 2 if (li > 0 and bi == 0) else 1
            project = bi == 0
            x = _bottleneck(b, x, cmid, stride, f"l{li+1}b{bi}", project)
        cmid *= 2
    x = b.global_pool(x)
    x = b.fc(x, 1000, "fc")
    return b.done(x)


def resnet50() -> Graph:
    return _resnet("resnet50", [3, 4, 6, 3])


def resnet152() -> Graph:
    return _resnet("resnet152", [3, 8, 36, 3])


# ---------------------------------------------------------------------------
# multi-branch: GoogleNet
# ---------------------------------------------------------------------------

def _inception(b: NetBuilder, x: int, c1: int, c3r: int, c3: int,
               c5r: int, c5: int, cp: int, tag: str) -> int:
    br1 = b.conv(x, c1, 1, 1, name=f"{tag}.1x1")
    br2 = b.conv(x, c3r, 1, 1, name=f"{tag}.3x3r")
    br2 = b.conv(br2, c3, 3, 1, name=f"{tag}.3x3")
    br3 = b.conv(x, c5r, 1, 1, name=f"{tag}.5x5r")
    br3 = b.conv(br3, c5, 5, 1, name=f"{tag}.5x5")
    br4 = b.pool(x, 3, 1, name=f"{tag}.pool")
    br4 = b.conv(br4, cp, 1, 1, name=f"{tag}.poolp")
    return b.concat([br1, br2, br3, br4], name=f"{tag}.cat")


def googlenet() -> Graph:
    b = NetBuilder("googlenet", 224, 224, 3)
    x = b.conv(b.input, 64, 7, 2, name="conv1")
    x = b.pool(x, 3, 2, name="pool1")
    x = b.conv(x, 64, 1, 1, name="conv2r")
    x = b.conv(x, 192, 3, 1, name="conv2")
    x = b.pool(x, 3, 2, name="pool2")
    x = _inception(b, x, 64, 96, 128, 16, 32, 32, "i3a")
    x = _inception(b, x, 128, 128, 192, 32, 96, 64, "i3b")
    x = b.pool(x, 3, 2, name="pool3")
    x = _inception(b, x, 192, 96, 208, 16, 48, 64, "i4a")
    x = _inception(b, x, 160, 112, 224, 24, 64, 64, "i4b")
    x = _inception(b, x, 128, 128, 256, 24, 64, 64, "i4c")
    x = _inception(b, x, 112, 144, 288, 32, 64, 64, "i4d")
    x = _inception(b, x, 256, 160, 320, 32, 128, 128, "i4e")
    x = b.pool(x, 3, 2, name="pool4")
    x = _inception(b, x, 256, 160, 320, 32, 128, 128, "i5a")
    x = _inception(b, x, 384, 192, 384, 48, 128, 128, "i5b")
    x = b.global_pool(x)
    x = b.fc(x, 1000, "fc")
    return b.done(x)


# ---------------------------------------------------------------------------
# multi-branch: Transformer / GPT (tokens are rows; attention is seq-global)
# ---------------------------------------------------------------------------

def _tf_layer(b: NetBuilder, x: int, d: int, dff: int, tag: str) -> int:
    qkv = b.conv(x, 3 * d, 1, 1, name=f"{tag}.qkv")
    att = b.attention(qkv, name=f"{tag}.attn")
    # attention output has width d (scores are transient inside the PE array)
    h, w, _ = b.shapes[att]
    b.shapes[att] = (h, 1, d)
    b.g.nodes[att].line_bytes = d
    # score+context matmuls: 2 * S^2 * d MACs
    b.g.nodes[att].macs = 2 * h * h * d
    proj = b.conv(att, d, 1, 1, name=f"{tag}.proj")
    add1 = b.eltwise([proj, x], name=f"{tag}.add1")
    f1 = b.conv(add1, dff, 1, 1, name=f"{tag}.ffn1")
    f2 = b.conv(f1, d, 1, 1, name=f"{tag}.ffn2")
    return b.eltwise([f2, add1], name=f"{tag}.add2")


def transformer(layers: int = 6, d: int = 512, dff: int = 2048,
                seq: int = 512) -> Graph:
    """Vaswani base: 6 encoder + 6 decoder layers with cross-attention."""
    b = NetBuilder("transformer", seq, 1, d)
    x = b.input
    for i in range(layers):
        x = _tf_layer(b, x, d, dff, f"E{i}")
    memory = x
    # decoder input: second virtual source
    y = b.g.add_node("dec_input", seq, d)
    b.shapes[y] = (seq, 1, d)
    for i in range(layers):
        tag = f"D{i}"
        qkv = b.conv(y, 3 * d, 1, 1, name=f"{tag}.qkv")
        att = b.attention(qkv, name=f"{tag}.self")
        h, _, _ = b.shapes[att]
        b.shapes[att] = (h, 1, d)
        b.g.nodes[att].line_bytes = d
        b.g.nodes[att].macs = 2 * h * h * d
        proj = b.conv(att, d, 1, 1, name=f"{tag}.proj")
        add1 = b.eltwise([proj, y], name=f"{tag}.add1")
        # cross-attention: query from decoder (per-token), memory from encoder
        q = b.conv(add1, d, 1, 1, name=f"{tag}.q")
        ca = b.g.add_node(f"{tag}.cross", seq, d, weight_bytes=2 * d * d,
                          macs=2 * seq * seq * d + 2 * seq * d * d)
        b.g.add_edge(q, ca, F=1, s=1)
        b.g.add_edge(memory, ca, kind=FULL)
        b.shapes[ca] = (seq, 1, d)
        proj2 = b.conv(ca, d, 1, 1, name=f"{tag}.cproj")
        add2 = b.eltwise([proj2, add1], name=f"{tag}.add2")
        f1 = b.conv(add2, dff, 1, 1, name=f"{tag}.ffn1")
        f2 = b.conv(f1, d, 1, 1, name=f"{tag}.ffn2")
        y = b.eltwise([f2, add2], name=f"{tag}.add3")
    return b.done(y)


def gpt(layers: int = 12, d: int = 768, dff: int = 3072,
        seq: int = 512, vocab: int = 40478) -> Graph:
    b = NetBuilder("gpt", seq, 1, d)
    x = b.input
    for i in range(layers):
        x = _tf_layer(b, x, d, dff, f"L{i}")
    x = b.conv(x, vocab, 1, 1, name="lm_head")  # per-token projection d->vocab
    return b.done(x)


# ---------------------------------------------------------------------------
# irregular: RandWire (Watts–Strogatz, seeded) and NasNet-A
# ---------------------------------------------------------------------------

def _randwire_stage(b: NetBuilder, x: int, n: int, k: int, p: float,
                    c: int, stride: int, seed: int, tag: str) -> int:
    import networkx as nx

    ws = nx.connected_watts_strogatz_graph(n, k, p, seed=seed)
    order = sorted(ws.nodes())
    # DAG orientation: edge (i, j) with i < j
    ins: Dict[int, List[int]] = {i: [] for i in order}
    outs: Dict[int, List[int]] = {i: [] for i in order}
    for (i, j) in ws.edges():
        i, j = min(i, j), max(i, j)
        ins[j].append(i)
        outs[i].append(j)
    nodes: Dict[int, int] = {}
    for i in order:
        srcs = [nodes[j] for j in ins[i]]
        if not srcs:
            # stage input node (stride applied here)
            inp = b.conv(x, c, 3, stride, name=f"{tag}.n{i}.dw",
                         depthwise=False)
            nodes[i] = inp
            continue
        agg = srcs[0] if len(srcs) == 1 else b.eltwise(srcs, f"{tag}.n{i}.sum")
        # ReLU-sepconv3x3: depthwise + pointwise
        dw = b.conv(agg, 0, 3, 1, name=f"{tag}.n{i}.dw", depthwise=True)
        pw = b.conv(dw, c, 1, 1, name=f"{tag}.n{i}.pw")
        nodes[i] = pw
    sinks = [nodes[i] for i in order if not outs[i]]
    return sinks[0] if len(sinks) == 1 else b.eltwise(sinks, f"{tag}.out")


def randwire(variant: str = "A") -> Graph:
    """RandWire-A (small regime, C=78) / RandWire-B (regular regime, C=109)."""
    c = 78 if variant == "A" else 109
    seed0 = 11 if variant == "A" else 23
    b = NetBuilder(f"randwire_{variant.lower()}", 224, 224, 3)
    x = b.conv(b.input, c // 2, 3, 2, name="stem")
    for si, (n, mult, stride) in enumerate([(32, 1, 2), (32, 2, 2), (32, 4, 2)]):
        x = _randwire_stage(b, x, n=n, k=4, p=0.75, c=c * mult,
                            stride=stride, seed=seed0 + si, tag=f"s{si}")
    x = b.conv(x, 1280, 1, 1, name="head_conv")
    x = b.global_pool(x)
    x = b.fc(x, 1000, "fc")
    return b.done(x)


def _nasnet_sep(b: NetBuilder, x: int, c: int, f: int, s: int, tag: str) -> int:
    dw = b.conv(x, 0, f, s, name=f"{tag}.dw", depthwise=True)
    return b.conv(dw, c, 1, 1, name=f"{tag}.pw")


def _nasnet_adjust(b: NetBuilder, h: int, hm1: int, c: int,
                   tag: str) -> Tuple[int, int]:
    """Cell-entry squeeze: project both states to c channels / matching H."""
    hh = b.shapes[h][0]
    h = b.conv(h, c, 1, 1, name=f"{tag}.sq_h")
    s = max(1, b.shapes[hm1][0] // hh)
    hm1 = b.conv(hm1, c, 1, s, name=f"{tag}.sq_hm1")
    return h, hm1


def _nasnet_normal(b: NetBuilder, h: int, hm1: int, c: int, tag: str) -> int:
    """NasNet-A normal cell (5 blocks, Zoph et al. Fig. 4)."""
    h, hm1 = _nasnet_adjust(b, h, hm1, c, tag)
    b1 = b.eltwise([_nasnet_sep(b, h, c, 3, 1, f"{tag}.b1l"), h],
                   name=f"{tag}.b1")
    b2 = b.eltwise([_nasnet_sep(b, hm1, c, 3, 1, f"{tag}.b2l"),
                    _nasnet_sep(b, h, c, 5, 1, f"{tag}.b2r")],
                   name=f"{tag}.b2")
    b3 = b.eltwise([b.pool(h, 3, 1, name=f"{tag}.b3l"), hm1],
                   name=f"{tag}.b3")
    b4 = b.eltwise([b.pool(hm1, 3, 1, name=f"{tag}.b4l"),
                    b.pool(hm1, 3, 1, name=f"{tag}.b4r")],
                   name=f"{tag}.b4")
    b5 = b.eltwise([_nasnet_sep(b, hm1, c, 5, 1, f"{tag}.b5l"),
                    _nasnet_sep(b, hm1, c, 3, 1, f"{tag}.b5r")],
                   name=f"{tag}.b5")
    return b.concat([b1, b2, b3, b4, b5], name=f"{tag}.cat")


def _nasnet_reduction(b: NetBuilder, h: int, hm1: int, c: int, tag: str) -> int:
    """NasNet-A reduction cell (stride-2 blocks)."""
    h, hm1 = _nasnet_adjust(b, h, hm1, c, tag)
    b1 = b.eltwise([_nasnet_sep(b, hm1, c, 7, 2, f"{tag}.b1l"),
                    _nasnet_sep(b, h, c, 5, 2, f"{tag}.b1r")],
                   name=f"{tag}.b1")
    b2 = b.eltwise([b.pool(h, 3, 2, name=f"{tag}.b2l"),
                    _nasnet_sep(b, hm1, c, 7, 2, f"{tag}.b2r")],
                   name=f"{tag}.b2")
    b3 = b.eltwise([b.pool(h, 3, 2, name=f"{tag}.b3l"),
                    _nasnet_sep(b, hm1, c, 5, 2, f"{tag}.b3r")],
                   name=f"{tag}.b3")
    b4 = b.eltwise([b.pool(b1, 3, 1, name=f"{tag}.b4l"), b2],
                   name=f"{tag}.b4")
    b5 = b.eltwise([_nasnet_sep(b, b1, c, 3, 1, f"{tag}.b5l"), b3],
                   name=f"{tag}.b5")
    return b.concat([b2, b4, b5], name=f"{tag}.cat")


def nasnet(cells_per_stack: int = 4, c0: int = 44) -> Graph:
    """NasNet-A (mobile-ish: N=4, 44 filters)."""
    b = NetBuilder("nasnet", 224, 224, 3)
    x = b.conv(b.input, 32, 3, 2, name="stem")
    hm1, h = x, x
    c = c0
    for stack in range(3):
        if stack > 0:
            c *= 2
            r = _nasnet_reduction(b, h, hm1, c, f"r{stack}")
            hm1, h = h, r
        for i in range(cells_per_stack):
            n = _nasnet_normal(b, h, hm1, c, f"s{stack}c{i}")
            hm1, h = h, n
    x = b.global_pool(h)
    x = b.fc(x, 1000, "fc")
    return b.done(x)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# The single netlib table.  Every resolution surface — ``build`` here, the
# ``netlib:`` workload scheme in :mod:`repro.api.workloads`, and the CLI's
# ``workloads ls`` — consumes this dict, so the set of names cannot drift
# between them (tests/test_netlib.py pins the parity).
PAPER_MODELS = {
    "vgg16": vgg16,
    "resnet50": resnet50,
    "resnet152": resnet152,
    "googlenet": googlenet,
    "transformer": transformer,
    "gpt": gpt,
    "randwire_a": lambda: randwire("A"),
    "randwire_b": lambda: randwire("B"),
    "nasnet": nasnet,
}


def list_models() -> List[str]:
    return sorted(PAPER_MODELS)


def build(name: str) -> Graph:
    """Build the named paper model; the one netlib resolution path."""
    try:
        builder = PAPER_MODELS[name]
    except KeyError:
        raise ValueError(
            f"unknown netlib model {name!r}; known: {list_models()}"
        ) from None
    return builder()
