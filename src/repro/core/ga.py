"""Genetic co-exploration engine (paper §4.3–4.4, Fig. 9–10).

Genome = (partition scheme, memory configuration).  Operators:

* crossover (Fig. 9b): walk layers in topological order; each undecided layer
  picks a random parent and reproduces that parent's whole subgraph; already-
  decided members are either split out (Child-1) or merged into one of their
  subgraphs (Child-2) — chosen at random.  HW genes average-then-snap.
* mutations (Fig. 9c-e + DSE): modify-node, split-subgraph, merge-subgraph,
  mutation-DSE (normal perturbation snapped to the candidate grid).
* evaluation with in-situ split repair (§4.4.4) written back Lamarckian-style,
* tournament selection (§4.4.5) with elitism.

Fitness = -(cost); cost is Formula 1 (partition-only) or Formula 2
(``BUF_SIZE + alpha * sum_i Cost_M(subgraph_i)``).
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from .cost import (
    GLB_CANDIDATES,
    METRICS,
    SHARED_CANDIDATES,
    WBUF_CANDIDATES,
    AcceleratorConfig,
    CachedEvaluator,
    PlanCost,
)
from repro.obs import recorder as obs

from .graph import Graph
from .partition import (
    groups_of,
    normalize,
    random_partition,
    singleton_partition,
    split_group_topo,
    split_to_fit_batch,
)


# ---------------------------------------------------------------------------
# objective
# ---------------------------------------------------------------------------

# metrics that are additive over subgraphs: plan.metric(m) equals the sum
# of single-subgraph contributions, which is what the additive recurrences
# of the dp/enum baselines require.  "bandwidth" (a time-weighted
# percentile) and the NoC profile metrics ("noc_p95"/"noc_link_peak") are
# not additive — see Objective.decomposition().
ADDITIVE_METRICS: Tuple[str, ...] = ("ema", "energy", "latency")


@dataclass(frozen=True)
class Objective:
    """What the search minimizes."""

    metric: str = "energy"          # one of cost.METRICS
    alpha: Optional[float] = None   # None => Formula 1 (partition-only)

    def __post_init__(self) -> None:
        # fail typos at construction (and hence at ExploreSpec construction),
        # not thousands of samples into a search
        if self.metric not in METRICS:
            raise ValueError(
                f"unknown objective metric {self.metric!r}; valid metrics: "
                f"{', '.join(METRICS)}")

    @property
    def is_additive(self) -> bool:
        return self.metric in ADDITIVE_METRICS

    def decomposition(self) -> "Objective":
        """The objective the additive-DP baselines (dp/enum) decompose by.

        Their recurrences sum per-subgraph costs, which is exact only for
        additive metrics.  For the non-additive profile percentiles
        (``bandwidth``, ``noc_p95``, ``noc_link_peak``) they decompose by
        the additive ``ema`` surrogate — the byte count the bandwidth/NoC
        requirements derive from — and the caller scores the returned plan
        with the *true* objective (so ``ExploreResult.cost`` is always the
        real metric, never the surrogate).  Whole-plan strategies
        (ga/sa/greedy/two_step) optimize every metric directly.
        """
        if self.is_additive:
            return self
        return replace(self, metric="ema")

    def cost(self, plan: PlanCost, acc: AcceleratorConfig) -> float:
        m = plan.metric(self.metric)
        if self.alpha is None:
            return m
        return acc.buf_size_total + self.alpha * m


@dataclass(frozen=True)
class HWSpace:
    """Memory design space (paper §5.3.1).

    ``core_candidates`` adds an optional third genome axis (§5.4.2): the
    multi-core weight-sharing degree.  When non-empty, ``sample``/``blend``/
    ``mutate`` co-explore the core count (applied to both
    ``weight_share_cores`` and ``n_cores``) jointly with the buffer split
    and the partition; when empty (the default) the core count is pinned to
    ``base`` and no rng draw is spent on it, so pre-existing seeded searches
    are bitwise-unchanged.
    """

    mode: str = "fixed"             # "fixed" | "separate" | "shared"
    base: AcceleratorConfig = field(default_factory=AcceleratorConfig)
    glb_candidates: Tuple[int, ...] = tuple(GLB_CANDIDATES)
    wbuf_candidates: Tuple[int, ...] = tuple(WBUF_CANDIDATES)
    shared_candidates: Tuple[int, ...] = tuple(SHARED_CANDIDATES)
    core_candidates: Tuple[int, ...] = ()

    def __post_init__(self) -> None:
        if any(n < 1 for n in self.core_candidates):
            raise ValueError(
                f"core_candidates must all be >= 1, got "
                f"{self.core_candidates}")

    def _with_cores(self, acc: AcceleratorConfig,
                    n: int) -> AcceleratorConfig:
        if (acc.weight_share_cores, acc.n_cores) == (n, n):
            return acc
        return replace(acc, weight_share_cores=n, n_cores=n)

    def sample(self, rng: random.Random) -> AcceleratorConfig:
        if self.mode == "fixed":
            acc = self.base
        elif self.mode == "separate":
            acc = replace(
                self.base,
                glb_bytes=rng.choice(self.glb_candidates),
                wbuf_bytes=rng.choice(self.wbuf_candidates),
                shared=False,
            )
        elif self.mode == "shared":
            acc = replace(
                self.base,
                glb_bytes=rng.choice(self.shared_candidates),
                wbuf_bytes=0,
                shared=True,
            )
        else:
            raise ValueError(self.mode)
        if self.core_candidates:
            acc = self._with_cores(acc, rng.choice(self.core_candidates))
        return acc

    @staticmethod
    def _snap(value: float, cands: Sequence[int]) -> int:
        return min(cands, key=lambda c: abs(c - value))

    def blend(self, a: AcceleratorConfig, b: AcceleratorConfig,
              rng: random.Random) -> AcceleratorConfig:
        """Crossover of HW genes: average, snapped to the grid (§4.4.2)."""
        if self.mode == "fixed":
            acc = self.base
        elif self.mode == "separate":
            acc = replace(
                a,
                glb_bytes=self._snap((a.glb_bytes + b.glb_bytes) / 2,
                                     self.glb_candidates),
                wbuf_bytes=self._snap((a.wbuf_bytes + b.wbuf_bytes) / 2,
                                      self.wbuf_candidates),
            )
        else:
            acc = replace(
                a,
                glb_bytes=self._snap((a.glb_bytes + b.glb_bytes) / 2,
                                     self.shared_candidates),
            )
        if self.core_candidates:
            acc = self._with_cores(acc, self._snap(
                (a.weight_share_cores + b.weight_share_cores) / 2,
                self.core_candidates))
        return acc

    def mutate(self, acc: AcceleratorConfig, rng: random.Random,
               sigma_steps: float = 3.0) -> AcceleratorConfig:
        """mutation-DSE: normal perturbation around the current value (§4.4.3)."""

        def perturb(value: int, cands: Sequence[int]) -> int:
            step = cands[1] - cands[0] if len(cands) > 1 else 1
            return self._snap(rng.gauss(value, sigma_steps * step), cands)

        if self.mode == "fixed":
            out = self.base
        elif self.mode == "separate":
            out = replace(
                acc,
                glb_bytes=perturb(acc.glb_bytes, self.glb_candidates),
                wbuf_bytes=perturb(acc.wbuf_bytes, self.wbuf_candidates),
            )
        else:
            out = replace(
                acc,
                glb_bytes=perturb(acc.glb_bytes, self.shared_candidates))
        if self.core_candidates:
            out = self._with_cores(out, perturb(
                acc.weight_share_cores, self.core_candidates))
        return out


# ---------------------------------------------------------------------------
# genome
# ---------------------------------------------------------------------------

@dataclass
class Genome:
    groups: List[Set[int]]
    acc: AcceleratorConfig
    cost: float = math.inf
    plan: Optional[PlanCost] = None
    # lazy node->group index; rebuilt on demand after invalidate().  Excluded
    # from comparison/repr: it is derived state, never genome identity.
    _gid: Optional[List[int]] = field(default=None, repr=False, compare=False)

    def clone(self) -> "Genome":
        return Genome([set(s) for s in self.groups], self.acc)

    def membership(self, n: int) -> List[int]:
        """``membership(g.n)[v]`` = index of the group holding node ``v``.

        Built once per genome and shared by every crossover/mutate this
        genome participates in (the operators used to rebuild an O(n) dict
        per child).  Any code that rebinds or mutates ``groups`` must call
        :meth:`invalidate`; groups are disjoint by construction (normalize
        output), so "last group wins" below never actually ties.
        """
        gid = self._gid
        if gid is None or len(gid) != n:
            gid = [-1] * n
            for i, s in enumerate(self.groups):
                for v in s:
                    gid[v] = i
            self._gid = gid
        return gid

    def invalidate(self) -> None:
        """Drop the membership index after ``groups`` changed."""
        self._gid = None


# ---------------------------------------------------------------------------
# operators
# ---------------------------------------------------------------------------

def crossover(g: Graph, mom: Genome, dad: Genome, hw: HWSpace,
              rng: random.Random) -> Genome:
    parents = (mom, dad)
    # cached per-parent membership indexes: a parent is crossed many times
    # per generation, so the old per-call dict rebuild was O(n) * children
    gid_of = (mom.membership(g.n), dad.membership(g.n))

    decided = [-1] * g.n                  # node -> child group index
    child_groups: List[Set[int]] = []
    for v in g.topo_order():
        if decided[v] >= 0:
            continue
        p = rng.randrange(2)
        src_group = parents[p].groups[gid_of[p][v]]
        undecided: Set[int] = set()
        overlap: Set[int] = set()
        for u in src_group:
            (undecided if decided[u] < 0 else overlap).add(u)
        if overlap and rng.random() < 0.5:
            # Child-2 style: merge the undecided members into one subgraph of
            # an already-decided member
            tgt = decided[rng.choice(sorted(overlap))]
            child_groups[tgt] |= undecided
            for u in undecided:
                decided[u] = tgt
        else:
            # Child-1 style: split out a fresh subgraph
            idx = len(child_groups)
            child_groups.append(set(undecided))
            for u in undecided:
                decided[u] = idx
    groups = normalize(g, child_groups)
    return Genome(groups, hw.blend(mom.acc, dad.acc, rng))


def mutate(g: Graph, genome: Genome, hw: HWSpace, rng: random.Random,
           p_node: float = 0.35, p_split: float = 0.25, p_merge: float = 0.25,
           p_dse: float = 0.15) -> Genome:
    child = genome.clone()
    r = rng.random()
    groups = child.groups
    # the clone's groups equal the parent's, so the parent's cached
    # membership index answers node->group for the child's pre-mutation
    # state — no per-child O(n * groups) dict rebuild
    if r < p_node and g.n > 1:
        # modify-node: reassign a random node to a neighbour subgraph or a new one
        v = rng.randrange(g.n)
        gid = genome.membership(g.n)
        src = gid[v]
        neigh = {gid[u] for u in (g.preds(v) + g.succs(v))} - {src}
        choices = sorted(neigh) + ["new"]
        pick = rng.choice(choices)
        groups[src].discard(v)
        if pick == "new":
            groups.append({v})
        else:
            groups[pick].add(v)
        child.groups = normalize(g, [s for s in groups if s])
    elif r < p_node + p_split:
        multi = [i for i, s in enumerate(groups) if len(s) > 1]
        if multi:
            i = rng.choice(multi)
            pieces = rng.choice([2, 2, 3])
            rest = [s for j, s in enumerate(groups) if j != i]
            rest.extend(split_group_topo(g, groups[i], pieces))
            child.groups = normalize(g, rest)
    elif r < p_node + p_split + p_merge and len(groups) > 1:
        # merge two adjacent subgraphs (prefer connected pairs)
        gid = genome.membership(g.n)
        pairs = {(min(gid[e.src], gid[e.dst]), max(gid[e.src], gid[e.dst]))
                 for e in g.edges if gid[e.src] != gid[e.dst]}
        if pairs:
            a, b = rng.choice(sorted(pairs))
            groups[a] |= groups[b]
            del groups[b]
            child.groups = normalize(g, groups)
    else:
        child.acc = hw.mutate(child.acc, rng)
    return child


# ---------------------------------------------------------------------------
# the Cocco GA loop
# ---------------------------------------------------------------------------

@dataclass
class SearchResult:
    best: Genome
    history: List[Tuple[int, float]]            # (samples, best cost so far)
    population_log: List[List[Tuple[int, float, float]]]  # per-gen (bufsz, metric, cost)
    samples: int
    evaluations: int


def _emit_generation_telemetry(rec, best: "Genome",
                               evaluated: Sequence["Genome"],
                               pop: Sequence["Genome"]) -> None:
    """Per-generation convergence samples on an *enabled* recorder only —
    the disabled path never pays for the diversity signature scan."""
    if best is not None and math.isfinite(best.cost):
        rec.sample("ga.best_cost", best.cost)
    finite = [ind.cost for ind in evaluated if math.isfinite(ind.cost)]
    if finite:
        rec.sample("ga.mean_cost", sum(finite) / len(finite))
    # population diversity: fraction of distinct partition schemes
    sigs = {tuple(sorted(tuple(sorted(s)) for s in ind.groups))
            for ind in pop}
    rec.sample("ga.diversity", len(sigs) / max(len(pop), 1))


def evaluate_genomes(g: Graph, genomes: Sequence[Genome], obj: Objective,
                     ev: CachedEvaluator) -> None:
    """Batched genome evaluation: collect → submit → apply.

    Phase 1 runs the in-situ split repair (§4.4.4) for the whole batch, one
    evaluator batch per repair round; phase 2 costs every repaired plan in a
    single batch.  Repaired groups, plan, and cost are written back to each
    genome Lamarckian-style — exactly what the old per-genome ``_evaluate``
    did, but with "what to evaluate" separated from "how it's executed" so
    the engine's executor can parallelize within a generation.
    """
    if not genomes:
        return
    repaired = split_to_fit_batch(
        g, [(genome.groups, genome.acc) for genome in genomes], ev)
    for genome, groups in zip(genomes, repaired):
        genome.groups = groups
        genome.invalidate()  # repair rebound groups; drop the stale index
    plans = ev.plan_batch([(genome.groups, genome.acc)
                           for genome in genomes])
    for genome, plan in zip(genomes, plans):
        genome.plan = plan
        genome.cost = obj.cost(plan, genome.acc)


def run_ga(
    g: Graph,
    objective: Objective,
    hw: HWSpace,
    sample_budget: int = 50_000,
    population: int = 100,
    tournament_k: int = 4,
    crossover_frac: float = 0.5,
    elite: int = 2,
    seed: int = 0,
    out_tile: int = 1,
    init_groups: Optional[List[List[Set[int]]]] = None,
    log_populations: bool = False,
    ev: Optional[CachedEvaluator] = None,
) -> SearchResult:
    rng = random.Random(seed)
    ev = ev or CachedEvaluator(g, out_tile=out_tile)

    pop: List[Genome] = []
    if init_groups:
        for gr in init_groups[: population]:
            pop.append(Genome([set(s) for s in gr], hw.sample(rng)))
    while len(pop) < population:
        mode = rng.random()
        if mode < 0.2:
            groups = singleton_partition(g)
        else:
            groups = random_partition(g, rng,
                                      mean_size=rng.uniform(1.5, 6.0))
        pop.append(Genome(groups, hw.sample(rng)))

    samples = 0
    history: List[Tuple[int, float]] = []
    pop_log: List[List[Tuple[int, float, float]]] = []
    best: Optional[Genome] = None

    rec = obs.current()
    with rec.span("ga.generation", gen=0, population=len(pop)):
        evaluate_genomes(g, pop, objective, ev)
    for ind in pop:
        samples += 1
        if best is None or ind.cost < best.cost:
            best = ind.clone()
            best.cost, best.plan = ind.cost, ind.plan
        history.append((samples, best.cost))
    if rec.enabled:
        _emit_generation_telemetry(rec, best, pop, pop)

    gen = 0
    while samples < sample_budget:
        gen += 1
        with rec.span("ga.generation", gen=gen, samples=samples):
            # --- variation ---------------------------------------------
            offspring: List[Genome] = []
            n_children = population
            for _ in range(n_children):
                if rng.random() < crossover_frac and len(pop) >= 2:
                    mom, dad = rng.sample(pop, 2)
                    child = crossover(g, mom, dad, hw, rng)
                    if rng.random() < 0.5:
                        child = mutate(g, child, hw, rng)
                else:
                    child = mutate(g, rng.choice(pop), hw, rng)
                offspring.append(child)

            # --- evaluation: one engine batch per generation ------------
            # the budget cap is known up front (evaluation spends one
            # sample per child), so truncating *before* the batch
            # reproduces the serial early-break exactly
            evaluated = offspring[: sample_budget - samples]
            evaluate_genomes(g, evaluated, objective, ev)
            for ind in evaluated:
                samples += 1
                if ind.cost < best.cost:
                    best = ind.clone()
                    best.cost, best.plan = ind.cost, ind.plan
                history.append((samples, best.cost))

            # --- tournament selection over parents + offspring ----------
            pool = pop + evaluated
            new_pop: List[Genome] = sorted(pool, key=lambda i: i.cost)[:elite]
            while len(new_pop) < population:
                contenders = rng.sample(pool, min(tournament_k, len(pool)))
                new_pop.append(min(contenders, key=lambda i: i.cost))
            pop = new_pop
            if log_populations:
                pop_log.append([
                    (float(i.acc.buf_size_total),
                     float(i.plan.metric(objective.metric))
                     if i.plan else math.inf,
                     i.cost)
                    for i in pop
                ])
        if rec.enabled:
            _emit_generation_telemetry(rec, best, evaluated, pop)

    return SearchResult(best=best, history=history, population_log=pop_log,
                        samples=samples, evaluations=ev.evaluations)
