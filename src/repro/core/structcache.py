"""Disk-backed warm cache for canonical subgraph structures.

The :class:`~repro.core.cost.CostKernel` memoizes
:func:`~repro.core.cost.compute_structure` results under a *canonical*
content fingerprint (see :func:`~repro.core.cost.canonical_structure_key`),
so isomorphic subgraphs share one schedule derivation within a process.
This module extends that memo across processes and runs: a directory of
one-file-per-entry JSON artifacts, gated exactly like the
:class:`~repro.api.store.ResultStore` — nothing touches the filesystem
unless a cache directory is configured (``--struct-cache-dir`` or
``$REPRO_STRUCT_CACHE_DIR``).

Layout and safety:

* each entry is ``<sha256-of-serialized-key>.json`` holding a format
  header, the serialized canonical key itself, and the label-free
  structure fields (all exact integers, so JSON round-trips losslessly);
* reads verify the embedded key against the query key, so a hash
  collision, a foreign file, or a tampered entry can never serve a wrong
  structure — it just reads as a miss;
* writes are atomic (tmp file + ``os.replace``), so concurrent processes
  (compare workers, parallel benchmark sweeps) share one directory
  without locking: the last writer wins with identical bytes;
* structures with a ``sched_error`` are never written — their error
  message embeds concrete node indices, which a canonical (label-free)
  entry must not carry (the kernel enforces the same rule in memory).

A corrupt or unreadable entry is treated as a miss and overwritten by the
next write; the cache is purely a warm tier, never a source of truth.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Optional, Tuple, Union

from .cost import SubgraphStructure

STRUCT_FORMAT = "cocco-structcache"
STRUCT_FORMAT_VERSION = 1

# the label-free payload fields, in serialization order; ``nodes`` is
# deliberately absent (it is re-stamped per query by the kernel) and
# ``sched_error`` entries are rejected before they get here
_PAYLOAD_FIELDS = ("macs", "weight_total", "ema_in", "ema_out",
                   "footprint", "glb_access_bytes")


def serialize_key(key: Tuple) -> str:
    """Canonical JSON serialization of a canonical structure key.

    Tuples serialize as JSON arrays, so the string is identical whether
    built from the in-memory key (nested tuples) or from a round-tripped
    document (nested lists) — which is what makes the embedded-key
    verification in :meth:`StructureCache.get` exact.
    """
    return json.dumps(key, separators=(",", ":"), sort_keys=False)


def key_digest(key: Tuple) -> str:
    return hashlib.sha256(serialize_key(key).encode("utf-8")).hexdigest()


class StructureCache:
    """One directory of canonical-key structure entries (see module doc)."""

    def __init__(self, root: Union[str, os.PathLike]) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.writes = 0

    def _path(self, key: Tuple) -> Path:
        return self.root / f"{key_digest(key)}.json"

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*.json"))

    def get(self, key: Tuple) -> Optional[SubgraphStructure]:
        """The cached structure for ``key``, or None.

        The returned structure carries ``nodes=()`` — the caller re-stamps
        the concrete node tuple per query, exactly as with an in-memory
        canonical hit.
        """
        path = self._path(key)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            self.misses += 1
            return None
        if (not isinstance(doc, dict)
                or doc.get("format") != STRUCT_FORMAT
                or doc.get("version") != STRUCT_FORMAT_VERSION
                or serialize_key(doc.get("key", [])) != serialize_key(key)):
            self.misses += 1
            return None
        payload = doc.get("structure")
        if (not isinstance(payload, dict)
                or any(not isinstance(payload.get(name), int)
                       for name in _PAYLOAD_FIELDS)):
            self.misses += 1
            return None
        self.hits += 1
        return SubgraphStructure(
            nodes=(), **{name: payload[name] for name in _PAYLOAD_FIELDS})

    def put(self, key: Tuple, st: SubgraphStructure) -> None:
        """Write one entry atomically; ``sched_error`` structures are refused
        (their message embeds node indices a canonical entry must not carry).
        """
        if st.sched_error is not None:
            raise ValueError(
                "refusing to cache a sched_error structure canonically: "
                "its message embeds concrete node indices")
        doc = {
            "format": STRUCT_FORMAT,
            "version": STRUCT_FORMAT_VERSION,
            "key": key,
            "structure": {name: getattr(st, name)
                          for name in _PAYLOAD_FIELDS},
        }
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=str(self.root), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, separators=(",", ":"))
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.writes += 1
