"""Computation-graph IR for the Cocco scheme.

A DNN model is a DAG ``G = (V, E)`` (paper §4.1.1).  Every node is a layer
producing one output tensor.  For the memory scheme we model the *sliding*
spatial axis explicitly (rows, i.e. the H axis of an NWHC layout): a node's
output is ``out_len`` rows of ``line_bytes`` bytes each.  Every edge carries the
consumer's window semantics over the producer's rows:

* ``sliding`` edges have a kernel extent ``F`` and stride ``s`` (convolutions,
  pooling; pointwise ops are F=1, s=1),
* ``full`` edges require the producer's entire output to be resident before the
  consumer can start (attention over a sequence, global pooling, FC over the
  spatial axis).  These act as phase boundaries in the subgraph pipeline.

Units: activation/weight bytes are INT8 (1 byte/elem) as in the paper's
Simba-like platform.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

SLIDING = "sliding"
FULL = "full"


@dataclass(frozen=True)
class Edge:
    """Dependency ``src -> dst``: dst consumes src's output."""

    src: int
    dst: int
    F: int = 1          # window extent in producer rows (sliding only)
    s: int = 1          # stride in producer rows (sliding only)
    kind: str = SLIDING

    def window(self, k: int) -> int:
        """Rows of src needed for dst to produce ``k`` of its own rows.

        This is the paper's ``f_v(x) = F + (x - 1) * s`` (footnote 1).
        """
        if self.kind == FULL:
            raise ValueError("full edges have no finite window")
        return self.F + (k - 1) * self.s


@dataclass
class Node:
    """One layer.  ``out_len`` rows x ``line_bytes`` bytes/row output tensor."""

    idx: int
    name: str
    out_len: int                 # rows along the sliding axis (H_out)
    line_bytes: int              # W_out * C_out * act_bytes
    weight_bytes: int = 0
    macs: int = 0
    is_output: bool = False      # model output -> always written back to DRAM

    @property
    def out_bytes(self) -> int:
        return self.out_len * self.line_bytes


class Graph:
    """A DAG of layers.  Node indices are dense 0..N-1 in insertion order and
    insertion order must be a valid topological order (asserted)."""

    def __init__(self, name: str = "graph") -> None:
        self.name = name
        self.nodes: List[Node] = []
        self.edges: List[Edge] = []
        self._out: Dict[int, List[Edge]] = {}
        self._in: Dict[int, List[Edge]] = {}
        # undirected neighbour ids, for O(deg) connectivity queries (the GA's
        # normalize/repair loop calls them hundreds of thousands of times)
        self._und: Dict[int, List[int]] = {}
        # topo_order() memo (a tuple, so the shared value is mutation-proof);
        # invalidated by length whenever add_node grows the graph
        self._topo: Optional[Tuple[int, ...]] = None

    # -- construction -----------------------------------------------------
    def add_node(
        self,
        name: str,
        out_len: int,
        line_bytes: int,
        weight_bytes: int = 0,
        macs: int = 0,
        is_output: bool = False,
    ) -> int:
        idx = len(self.nodes)
        self.nodes.append(
            Node(idx, name, int(out_len), int(line_bytes), int(weight_bytes),
                 int(macs), is_output)
        )
        self._out[idx] = []
        self._in[idx] = []
        self._und[idx] = []
        return idx

    def add_edge(self, src: int, dst: int, F: int = 1, s: int = 1,
                 kind: str = SLIDING) -> None:
        if not (0 <= src < len(self.nodes) and 0 <= dst < len(self.nodes)):
            raise ValueError(f"bad edge ({src},{dst})")
        if src >= dst:
            raise ValueError("insertion order must be topological: src < dst")
        if kind not in (SLIDING, FULL):
            raise ValueError(
                f"edge kind must be {SLIDING!r} or {FULL!r}, got {kind!r}")
        if kind == SLIDING:
            if F < 1 or s < 1:
                raise ValueError("sliding edge needs F>=1, s>=1")
        e = Edge(src, dst, int(F), int(s), kind)
        self.edges.append(e)
        self._out[src].append(e)
        self._in[dst].append(e)
        self._und[src].append(dst)
        self._und[dst].append(src)

    # -- queries ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def n(self) -> int:
        return len(self.nodes)

    def in_edges(self, v: int) -> List[Edge]:
        return self._in[v]

    def out_edges(self, v: int) -> List[Edge]:
        return self._out[v]

    def preds(self, v: int) -> List[int]:
        return [e.src for e in self._in[v]]

    def succs(self, v: int) -> List[int]:
        return [e.dst for e in self._out[v]]

    def sources(self) -> List[int]:
        return [v.idx for v in self.nodes if not self._in[v.idx]]

    def sinks(self) -> List[int]:
        return [v.idx for v in self.nodes if not self._out[v.idx]]

    def topo_order(self) -> Sequence[int]:
        # insertion order is topological; memoized — search loops walk this
        # once per crossover/partition sample
        t = self._topo
        if t is None or len(t) != len(self.nodes):
            t = self._topo = tuple(range(len(self.nodes)))
        return t

    # -- subgraph helpers ---------------------------------------------------
    #
    # These iterate the subgraph's own adjacency lists (O(sum of member
    # degrees)) instead of every edge of the graph (O(E)) — compute_structure
    # calls them per node-set query, which made the O(E) scans a measurable
    # slice of structure-derivation time on 200+-node models.  Members are
    # walked in sorted order so the returned edge order is a deterministic
    # function of the node set (callers only ever set-reduce the result).

    def internal_edges(self, nodes: Set[int]) -> List[Edge]:
        _in = self._in
        return [e for v in sorted(nodes) for e in _in[v] if e.src in nodes]

    def boundary_in(self, nodes: Set[int]) -> List[Edge]:
        """Edges entering ``nodes`` from outside."""
        _in = self._in
        return [e for v in sorted(nodes) for e in _in[v]
                if e.src not in nodes]

    def boundary_out(self, nodes: Set[int]) -> List[Edge]:
        """Edges leaving ``nodes``."""
        _out = self._out
        return [e for v in sorted(nodes) for e in _out[v]
                if e.dst not in nodes]

    def is_connected(self, nodes: Set[int]) -> bool:
        """Weak connectivity of the induced subgraph (paper: subgraphs must be
        connected in G, otherwise meaningless)."""
        if not nodes:
            return False
        if len(nodes) == 1:
            return True
        und = self._und
        seen = set()
        stack = [next(iter(nodes))]
        while stack:
            v = stack.pop()
            if v in seen:
                continue
            seen.add(v)
            stack.extend(w for w in und[v] if w in nodes and w not in seen)
        return len(seen) == len(nodes)

    def weakly_connected_components(self, nodes: Set[int]) -> List[Set[int]]:
        if len(nodes) == 1:  # fast path: most GA groups are singletons
            return [set(nodes)]
        remaining = set(nodes)
        comps: List[Set[int]] = []
        und = self._und
        while remaining:
            root = next(iter(remaining))
            comp = set()
            stack = [root]
            while stack:
                v = stack.pop()
                if v in comp:
                    continue
                comp.add(v)
                # neighbours of an earlier component are never reachable, so
                # filtering against `remaining` equals filtering against the
                # full node set
                stack.extend(w for w in und[v]
                             if w in remaining and w not in comp)
            comps.append(comp)
            remaining -= comp
        return comps

    # -- totals -------------------------------------------------------------
    def total_weight_bytes(self) -> int:
        return sum(v.weight_bytes for v in self.nodes)

    def total_macs(self) -> int:
        return sum(v.macs for v in self.nodes)

    def total_act_bytes(self) -> int:
        return sum(v.out_bytes for v in self.nodes)

    def summary(self) -> str:
        return (
            f"{self.name}: {self.n} nodes, {len(self.edges)} edges, "
            f"{self.total_macs()/1e6:.1f} MMACs, "
            f"{self.total_weight_bytes()/1e6:.2f} MB weights, "
            f"{self.total_act_bytes()/1e6:.2f} MB activations"
        )


# ---------------------------------------------------------------------------
# Graph JSON: a documented import/export format for external netlists
# ---------------------------------------------------------------------------
#
# {
#   "format": "cocco-graph", "version": 1, "name": "<label>",
#   "nodes": [{"name", "out_len", "line_bytes", "weight_bytes", "macs",
#              "is_output"}, ...],            # index order == topological order
#   "edges": [{"src", "dst", "F", "s", "kind"}, ...]   # kind: sliding | full
# }
#
# Node order is significant (node i is the i-th entry; edges must satisfy
# src < dst), matching the in-memory invariant that insertion order is a
# valid topological order.  Optional node/edge fields take their dataclass
# defaults, so a minimal external netlist only needs names, shapes, and arcs.

GRAPH_FORMAT = "cocco-graph"
GRAPH_FORMAT_VERSION = 1


def graph_to_dict(g: Graph) -> Dict[str, Any]:
    """Serialize ``g`` to the documented Graph JSON dict (lossless)."""
    return {
        "format": GRAPH_FORMAT,
        "version": GRAPH_FORMAT_VERSION,
        "name": g.name,
        "nodes": [
            {
                "name": v.name,
                "out_len": v.out_len,
                "line_bytes": v.line_bytes,
                "weight_bytes": v.weight_bytes,
                "macs": v.macs,
                "is_output": v.is_output,
            }
            for v in g.nodes
        ],
        "edges": [
            {"src": e.src, "dst": e.dst, "F": e.F, "s": e.s, "kind": e.kind}
            for e in g.edges
        ],
    }


def graph_from_dict(d: Dict[str, Any]) -> Graph:
    """Build a :class:`Graph` from a Graph JSON dict, validating the format
    header, node dimensions (``out_len >= 1``, byte/MAC counts ``>= 0``),
    and — through ``add_node``/``add_edge`` — the construction-time
    invariants (topological edge order, window sanity, known edge kinds)."""
    if not isinstance(d, dict):
        raise ValueError(f"not a {GRAPH_FORMAT} document: expected a JSON "
                         f"object, got {type(d).__name__}")
    if d.get("format") != GRAPH_FORMAT:
        raise ValueError(f"not a {GRAPH_FORMAT} document "
                         f"(format={d.get('format')!r})")
    if d.get("version") != GRAPH_FORMAT_VERSION:
        raise ValueError(
            f"unsupported {GRAPH_FORMAT} version {d.get('version')!r} "
            f"(this build reads version {GRAPH_FORMAT_VERSION})")
    g = Graph(str(d.get("name", "graph")))
    for i, nd in enumerate(d.get("nodes", [])):
        try:
            name, out_len = str(nd["name"]), int(nd["out_len"])
            line_bytes = int(nd["line_bytes"])
        except KeyError as err:
            raise ValueError(
                f"node {i} is missing required key {err.args[0]!r} "
                f"(nodes need name, out_len, line_bytes)") from None
        wbytes, macs = int(nd.get("weight_bytes", 0)), int(nd.get("macs", 0))
        if out_len < 1 or line_bytes < 0 or wbytes < 0 or macs < 0:
            raise ValueError(
                f"node {i} ({name!r}) has invalid dimensions: "
                f"out_len={out_len} (need >=1), line_bytes={line_bytes}, "
                f"weight_bytes={wbytes}, macs={macs} (need >=0)")
        g.add_node(name, out_len, line_bytes, weight_bytes=wbytes,
                   macs=macs, is_output=bool(nd.get("is_output", False)))
    for i, ed in enumerate(d.get("edges", [])):
        try:
            src, dst = int(ed["src"]), int(ed["dst"])
        except KeyError as err:
            raise ValueError(
                f"edge {i} is missing required key {err.args[0]!r} "
                f"(edges need src, dst)") from None
        g.add_edge(src, dst, F=int(ed.get("F", 1)), s=int(ed.get("s", 1)),
                   kind=str(ed.get("kind", SLIDING)))
    if not g.nodes:
        raise ValueError(f"{GRAPH_FORMAT} document has no nodes")
    return g


def graph_to_json(g: Graph, indent: Optional[int] = 2) -> str:
    return json.dumps(graph_to_dict(g), indent=indent)


def graph_from_json(data: str) -> Graph:
    try:
        d = json.loads(data)
    except json.JSONDecodeError as err:
        raise ValueError(f"invalid graph JSON: {err}") from None
    return graph_from_dict(d)


def sequential_graph(
    layers: Sequence[Tuple[str, int, int, int, int, int, int]],
    name: str = "chain",
) -> Graph:
    """Build a plain chain. layers = [(name, out_len, line_bytes, wbytes, macs, F, s)].
    F, s describe the window each layer applies to its predecessor."""
    g = Graph(name)
    prev: Optional[int] = None
    for i, (lname, out_len, line_bytes, wb, macs, F, s) in enumerate(layers):
        idx = g.add_node(lname, out_len, line_bytes, wb, macs,
                         is_output=(i == len(layers) - 1))
        if prev is not None:
            g.add_edge(prev, idx, F=F, s=s)
        prev = idx
    return g
