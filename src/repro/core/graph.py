"""Computation-graph IR for the Cocco scheme.

A DNN model is a DAG ``G = (V, E)`` (paper §4.1.1).  Every node is a layer
producing one output tensor.  For the memory scheme we model the *sliding*
spatial axis explicitly (rows, i.e. the H axis of an NWHC layout): a node's
output is ``out_len`` rows of ``line_bytes`` bytes each.  Every edge carries the
consumer's window semantics over the producer's rows:

* ``sliding`` edges have a kernel extent ``F`` and stride ``s`` (convolutions,
  pooling; pointwise ops are F=1, s=1),
* ``full`` edges require the producer's entire output to be resident before the
  consumer can start (attention over a sequence, global pooling, FC over the
  spatial axis).  These act as phase boundaries in the subgraph pipeline.

Units: activation/weight bytes are INT8 (1 byte/elem) as in the paper's
Simba-like platform.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SLIDING = "sliding"
FULL = "full"


@dataclass(frozen=True)
class Edge:
    """Dependency ``src -> dst``: dst consumes src's output."""

    src: int
    dst: int
    F: int = 1          # window extent in producer rows (sliding only)
    s: int = 1          # stride in producer rows (sliding only)
    kind: str = SLIDING

    def window(self, k: int) -> int:
        """Rows of src needed for dst to produce ``k`` of its own rows.

        This is the paper's ``f_v(x) = F + (x - 1) * s`` (footnote 1).
        """
        if self.kind == FULL:
            raise ValueError("full edges have no finite window")
        return self.F + (k - 1) * self.s


@dataclass
class Node:
    """One layer.  ``out_len`` rows x ``line_bytes`` bytes/row output tensor."""

    idx: int
    name: str
    out_len: int                 # rows along the sliding axis (H_out)
    line_bytes: int              # W_out * C_out * act_bytes
    weight_bytes: int = 0
    macs: int = 0
    is_output: bool = False      # model output -> always written back to DRAM

    @property
    def out_bytes(self) -> int:
        return self.out_len * self.line_bytes


class Graph:
    """A DAG of layers.  Node indices are dense 0..N-1 in insertion order and
    insertion order must be a valid topological order (asserted)."""

    def __init__(self, name: str = "graph") -> None:
        self.name = name
        self.nodes: List[Node] = []
        self.edges: List[Edge] = []
        self._out: Dict[int, List[Edge]] = {}
        self._in: Dict[int, List[Edge]] = {}
        # undirected neighbour ids, for O(deg) connectivity queries (the GA's
        # normalize/repair loop calls them hundreds of thousands of times)
        self._und: Dict[int, List[int]] = {}

    # -- construction -----------------------------------------------------
    def add_node(
        self,
        name: str,
        out_len: int,
        line_bytes: int,
        weight_bytes: int = 0,
        macs: int = 0,
        is_output: bool = False,
    ) -> int:
        idx = len(self.nodes)
        self.nodes.append(
            Node(idx, name, int(out_len), int(line_bytes), int(weight_bytes),
                 int(macs), is_output)
        )
        self._out[idx] = []
        self._in[idx] = []
        self._und[idx] = []
        return idx

    def add_edge(self, src: int, dst: int, F: int = 1, s: int = 1,
                 kind: str = SLIDING) -> None:
        if not (0 <= src < len(self.nodes) and 0 <= dst < len(self.nodes)):
            raise ValueError(f"bad edge ({src},{dst})")
        if src >= dst:
            raise ValueError("insertion order must be topological: src < dst")
        if kind == SLIDING:
            if F < 1 or s < 1:
                raise ValueError("sliding edge needs F>=1, s>=1")
        e = Edge(src, dst, int(F), int(s), kind)
        self.edges.append(e)
        self._out[src].append(e)
        self._in[dst].append(e)
        self._und[src].append(dst)
        self._und[dst].append(src)

    # -- queries ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def n(self) -> int:
        return len(self.nodes)

    def in_edges(self, v: int) -> List[Edge]:
        return self._in[v]

    def out_edges(self, v: int) -> List[Edge]:
        return self._out[v]

    def preds(self, v: int) -> List[int]:
        return [e.src for e in self._in[v]]

    def succs(self, v: int) -> List[int]:
        return [e.dst for e in self._out[v]]

    def sources(self) -> List[int]:
        return [v.idx for v in self.nodes if not self._in[v.idx]]

    def sinks(self) -> List[int]:
        return [v.idx for v in self.nodes if not self._out[v.idx]]

    def topo_order(self) -> List[int]:
        return list(range(self.n))  # insertion order is topological

    # -- subgraph helpers ---------------------------------------------------
    def internal_edges(self, nodes: Set[int]) -> List[Edge]:
        return [e for e in self.edges if e.src in nodes and e.dst in nodes]

    def boundary_in(self, nodes: Set[int]) -> List[Edge]:
        """Edges entering ``nodes`` from outside."""
        return [e for e in self.edges if e.dst in nodes and e.src not in nodes]

    def boundary_out(self, nodes: Set[int]) -> List[Edge]:
        """Edges leaving ``nodes``."""
        return [e for e in self.edges if e.src in nodes and e.dst not in nodes]

    def is_connected(self, nodes: Set[int]) -> bool:
        """Weak connectivity of the induced subgraph (paper: subgraphs must be
        connected in G, otherwise meaningless)."""
        if not nodes:
            return False
        if len(nodes) == 1:
            return True
        und = self._und
        seen = set()
        stack = [next(iter(nodes))]
        while stack:
            v = stack.pop()
            if v in seen:
                continue
            seen.add(v)
            stack.extend(w for w in und[v] if w in nodes and w not in seen)
        return len(seen) == len(nodes)

    def weakly_connected_components(self, nodes: Set[int]) -> List[Set[int]]:
        if len(nodes) == 1:  # fast path: most GA groups are singletons
            return [set(nodes)]
        remaining = set(nodes)
        comps: List[Set[int]] = []
        und = self._und
        while remaining:
            root = next(iter(remaining))
            comp = set()
            stack = [root]
            while stack:
                v = stack.pop()
                if v in comp:
                    continue
                comp.add(v)
                # neighbours of an earlier component are never reachable, so
                # filtering against `remaining` equals filtering against the
                # full node set
                stack.extend(w for w in und[v]
                             if w in remaining and w not in comp)
            comps.append(comp)
            remaining -= comp
        return comps

    # -- totals -------------------------------------------------------------
    def total_weight_bytes(self) -> int:
        return sum(v.weight_bytes for v in self.nodes)

    def total_macs(self) -> int:
        return sum(v.macs for v in self.nodes)

    def total_act_bytes(self) -> int:
        return sum(v.out_bytes for v in self.nodes)

    def summary(self) -> str:
        return (
            f"{self.name}: {self.n} nodes, {len(self.edges)} edges, "
            f"{self.total_macs()/1e6:.1f} MMACs, "
            f"{self.total_weight_bytes()/1e6:.2f} MB weights, "
            f"{self.total_act_bytes()/1e6:.2f} MB activations"
        )


def sequential_graph(
    layers: Sequence[Tuple[str, int, int, int, int, int, int]],
    name: str = "chain",
) -> Graph:
    """Build a plain chain. layers = [(name, out_len, line_bytes, wbytes, macs, F, s)].
    F, s describe the window each layer applies to its predecessor."""
    g = Graph(name)
    prev: Optional[int] = None
    for i, (lname, out_len, line_bytes, wb, macs, F, s) in enumerate(layers):
        idx = g.add_node(lname, out_len, line_bytes, wb, macs,
                         is_output=(i == len(layers) - 1))
        if prev is not None:
            g.add_edge(prev, idx, F=F, s=s)
        prev = idx
    return g
