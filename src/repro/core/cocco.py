"""High-level Cocco API (paper Fig. 10).

.. deprecated::
    ``co_explore`` and ``partition_only`` are thin shims over the unified
    exploration API (:mod:`repro.api`): build an
    :class:`~repro.api.ExploreSpec` and call :func:`repro.api.run` instead.
    They are kept so existing imports and call sites keep working, and they
    still return a :class:`CoccoResult`.

``co_explore``     — Formula 2: joint (partition, memory-config) search.
``partition_only`` — Formula 1: partition under a fixed accelerator.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import List, Optional, Set, Tuple

from .cost import AcceleratorConfig, CachedEvaluator, PlanCost
from .ga import HWSpace, Objective
from .graph import Graph


@dataclass
class CoccoResult:
    graph: str
    groups: List[Set[int]]
    acc: AcceleratorConfig
    plan: PlanCost
    cost: float
    objective: Objective
    history: List[Tuple[int, float]]
    samples: int
    population_log: List = field(default_factory=list)

    @property
    def n_subgraphs(self) -> int:
        return len(self.groups)

    def summary(self) -> str:
        bw = self.plan.avg_bandwidth() / 1e9
        return (
            f"{self.graph}: {self.n_subgraphs} subgraphs | "
            f"cost={self.cost:.4g} | EMA={self.plan.ema_total/1e6:.2f} MB | "
            f"energy={self.plan.energy_pj/1e9:.3f} mJ | "
            f"avg BW={bw:.2f} GB/s | "
            f"GLB={self.acc.glb_bytes//1024}KB"
            + ("" if self.acc.shared else
               f" WBUF={self.acc.wbuf_bytes//1024}KB")
        )


def _run_ga_spec(
    g: Graph,
    obj: Objective,
    hw: HWSpace,
    sample_budget: int,
    population: int,
    seed: int,
    out_tile: int,
    log_populations: bool,
    ev: Optional[CachedEvaluator],
    ga_kw: dict,
) -> CoccoResult:
    """Shared shim body: ExploreSpec -> run -> CoccoResult."""
    from repro.api import ExploreSpec, GAOptions
    from repro.api import run as api_run

    init_groups = ga_kw.pop("init_groups", None)
    opts = GAOptions(population=population, log_populations=log_populations,
                     **ga_kw)
    spec = ExploreSpec(workload=g.name, strategy="ga", objective=obj, hw=hw,
                       sample_budget=sample_budget, seed=seed,
                       out_tile=out_tile, options=opts)
    res = api_run(spec, graph=g, ev=ev, init_groups=init_groups)
    return CoccoResult(
        graph=g.name,
        groups=res.groups,
        acc=res.acc,
        plan=res.plan,
        cost=res.cost,
        objective=obj,
        history=res.history,
        samples=res.samples,
        population_log=res.population_log,
    )


def partition_only(
    g: Graph,
    acc: Optional[AcceleratorConfig] = None,
    metric: str = "ema",
    sample_budget: int = 50_000,
    population: int = 100,
    seed: int = 0,
    out_tile: int = 1,
    ev: Optional[CachedEvaluator] = None,
    **ga_kw,
) -> CoccoResult:
    warnings.warn(
        "partition_only is deprecated; use repro.api.run(ExploreSpec(...)) "
        "with hw=HWSpace(mode='fixed', base=acc)",
        DeprecationWarning, stacklevel=2)
    acc = acc or AcceleratorConfig()
    obj = Objective(metric=metric, alpha=None)
    hw = HWSpace(mode="fixed", base=acc)
    log_populations = ga_kw.pop("log_populations", False)
    return _run_ga_spec(g, obj, hw, sample_budget, population, seed,
                        out_tile, log_populations, ev, ga_kw)


def co_explore(
    g: Graph,
    mode: str = "separate",              # "separate" | "shared"
    metric: str = "energy",
    alpha: float = 0.002,
    base: Optional[AcceleratorConfig] = None,
    sample_budget: int = 50_000,
    population: int = 100,
    seed: int = 0,
    out_tile: int = 1,
    log_populations: bool = False,
    ev: Optional[CachedEvaluator] = None,
    **ga_kw,
) -> CoccoResult:
    warnings.warn(
        "co_explore is deprecated; use repro.api.run(ExploreSpec(...)) "
        "with hw=HWSpace(mode=mode, base=base)",
        DeprecationWarning, stacklevel=2)
    base = base or AcceleratorConfig()
    obj = Objective(metric=metric, alpha=alpha)
    hw = HWSpace(mode=mode, base=base)
    return _run_ga_spec(g, obj, hw, sample_budget, population, seed,
                        out_tile, log_populations, ev, ga_kw)
