"""High-level Cocco API — moved to the unified exploration API.

The deprecated ``co_explore`` / ``partition_only`` shims (and their
``CoccoResult``) were removed now that every caller is on :mod:`repro.api`.
Build an :class:`~repro.api.ExploreSpec` and call :func:`repro.api.run`
instead:

* ``partition_only(g, acc, metric=m, ...)`` (Formula 1) became::

      from repro.api import ExploreSpec, GAOptions, run
      from repro.core import HWSpace, Objective
      run(ExploreSpec(workload=g.name, strategy="ga",
                      objective=Objective(metric=m, alpha=None),
                      hw=HWSpace(mode="fixed", base=acc)), graph=g)

* ``co_explore(g, mode=mode, metric=m, alpha=a, ...)`` (Formula 2) became::

      run(ExploreSpec(workload=g.name, strategy="ga",
                      objective=Objective(metric=m, alpha=a),
                      hw=HWSpace(mode=mode)), graph=g)

:func:`repro.api.run` returns an :class:`~repro.api.ExploreResult` — a
superset of the old ``CoccoResult`` (same groups/acc/plan/cost/history
fields, plus spec, meta, and JSON round-tripping).
"""
