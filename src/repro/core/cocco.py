"""High-level Cocco API (paper Fig. 10).

``co_explore``     — Formula 2: joint (partition, memory-config) search.
``partition_only`` — Formula 1: partition under a fixed accelerator.

Both return a :class:`CoccoResult` carrying the chosen plan, hardware point,
per-subgraph costs, and the convergence history for sample-efficiency plots.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Set, Tuple

from .cost import AcceleratorConfig, CachedEvaluator, PlanCost
from .ga import Genome, HWSpace, Objective, SearchResult, run_ga
from .graph import Graph


@dataclass
class CoccoResult:
    graph: str
    groups: List[Set[int]]
    acc: AcceleratorConfig
    plan: PlanCost
    cost: float
    objective: Objective
    history: List[Tuple[int, float]]
    samples: int
    population_log: List = field(default_factory=list)

    @property
    def n_subgraphs(self) -> int:
        return len(self.groups)

    def summary(self) -> str:
        bw = self.plan.avg_bandwidth() / 1e9
        return (
            f"{self.graph}: {self.n_subgraphs} subgraphs | "
            f"cost={self.cost:.4g} | EMA={self.plan.ema_total/1e6:.2f} MB | "
            f"energy={self.plan.energy_pj/1e9:.3f} mJ | "
            f"avg BW={bw:.2f} GB/s | "
            f"GLB={self.acc.glb_bytes//1024}KB"
            + ("" if self.acc.shared else
               f" WBUF={self.acc.wbuf_bytes//1024}KB")
        )


def _result(g: Graph, res: SearchResult, obj: Objective) -> CoccoResult:
    best = res.best
    return CoccoResult(
        graph=g.name,
        groups=best.groups,
        acc=best.acc,
        plan=best.plan,
        cost=best.cost,
        objective=obj,
        history=res.history,
        samples=res.samples,
        population_log=res.population_log,
    )


def partition_only(
    g: Graph,
    acc: Optional[AcceleratorConfig] = None,
    metric: str = "ema",
    sample_budget: int = 50_000,
    population: int = 100,
    seed: int = 0,
    out_tile: int = 1,
    ev: Optional[CachedEvaluator] = None,
    **ga_kw,
) -> CoccoResult:
    acc = acc or AcceleratorConfig()
    obj = Objective(metric=metric, alpha=None)
    hw = HWSpace(mode="fixed", base=acc)
    res = run_ga(g, obj, hw, sample_budget=sample_budget,
                 population=population, seed=seed, out_tile=out_tile,
                 ev=ev, **ga_kw)
    return _result(g, res, obj)


def co_explore(
    g: Graph,
    mode: str = "separate",              # "separate" | "shared"
    metric: str = "energy",
    alpha: float = 0.002,
    base: Optional[AcceleratorConfig] = None,
    sample_budget: int = 50_000,
    population: int = 100,
    seed: int = 0,
    out_tile: int = 1,
    log_populations: bool = False,
    ev: Optional[CachedEvaluator] = None,
    **ga_kw,
) -> CoccoResult:
    base = base or AcceleratorConfig()
    obj = Objective(metric=metric, alpha=alpha)
    hw = HWSpace(mode=mode, base=base)
    res = run_ga(g, obj, hw, sample_budget=sample_budget,
                 population=population, seed=seed, out_tile=out_tile,
                 log_populations=log_populations, ev=ev, **ga_kw)
    return _result(g, res, obj)
