"""Cocco core: graph-level memory scheme + hardware-mapping co-exploration.

The paper's primary contribution (ASPLOS'24).  See DESIGN.md §1–2.
"""

from .cost import (
    GLB_CANDIDATES,
    METRICS,
    SHARED_CANDIDATES,
    WBUF_CANDIDATES,
    AcceleratorConfig,
    CachedEvaluator,
    CostKernel,
    PlanCost,
    SubgraphCost,
    SubgraphStructure,
    TrafficBreakdown,
    compute_structure,
    evaluate_partition,
    evaluate_subgraph,
    finish_cost,
    time_weighted_percentile,
)
from .engine import (
    Executor,
    ProcessExecutor,
    SerialExecutor,
    VectorExecutor,
    make_executor,
)
from .ga import (
    Genome,
    HWSpace,
    Objective,
    SearchResult,
    evaluate_genomes,
    run_ga,
)
from .graph import FULL, SLIDING, Edge, Graph, Node, sequential_graph
from .memory import (
    FootprintReport,
    OccupancyTracker,
    Region,
    RegionTable,
    build_region_table,
    subgraph_footprint,
)
from .partition import (
    groups_of,
    is_valid,
    normalize,
    partition_of,
    random_partition,
    singleton_partition,
    split_to_fit,
    split_to_fit_batch,
)
from .simulate import DeadlockError, SimResult, simulate_subgraph
from .tiling import SubgraphSchedule, TensorSchedule, derive_schedule

__all__ = [k for k in dir() if not k.startswith("_")]
