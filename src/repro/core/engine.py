"""Batched evaluation engine: pluggable executors over the pure cost kernel.

:class:`~repro.core.cost.CachedEvaluator` is cache + counters; *how* a batch
of cache misses is computed is an :class:`Executor`'s job.  All backends run
the same pure kernel (:class:`~repro.core.cost.CostKernel`), so they return
identical costs and search results never depend on the backend:

* ``serial``  — evaluate misses inline, one by one (the default; this is
  exactly the pre-engine behaviour).
* ``process`` — shard a batch over a persistent ``ProcessPoolExecutor``.
  Each worker holds its own warm ``CostKernel`` (structure memo survives
  across batches); results are adopted into the parent evaluator's cache
  on join, like parallel ``compare``'s merge-on-join.  Wins when the
  structure half (schedule derivation) dominates — large graphs, cold
  caches, big GA generations.
* ``vector``  — compute each distinct node-set's structure once through the
  kernel memo, then batch the hardware-dependent half
  (:func:`~repro.core.cost.finish_cost`) through NumPy in one vectorized
  pass.  Wins when one subgraph is probed at many hardware points
  (co-exploration populations).  Bit-identical to the scalar kernel; inputs
  that could round differently in float64 (``> 2**53``) or overflow int64
  products fall back to the scalar path element-wise.
* ``jax``     — same struct-of-arrays batching as ``vector``, but the
  capacity/streaming/weight-sharing arithmetic runs as a jit-compiled jnp
  kernel (optionally a Pallas kernel for the streaming-block sweep) on
  whatever device jax targets (:mod:`repro.kernels.finish_batch`).  Wins on
  accelerator-resident generation evaluation — a whole GA generation's
  distinct queries become one device call.  The same element-wise guards as
  ``vector`` route out-of-range inputs to the scalar path, so it is
  bit-identical to ``serial`` too.  jax is an optional dependency: when it
  is not importable, :func:`make_executor` reports *why* and every other
  backend keeps working.

Pick a backend by name via :func:`make_executor` — the seam the API layer's
``eval_backend``/``eval_jobs`` options thread through;
:func:`backend_status` answers "would that name resolve?" without building
anything (the CLI's pre-flight check).
"""

from __future__ import annotations

import os
from concurrent.futures import ProcessPoolExecutor
from dataclasses import fields as dataclass_fields
from typing import List, Optional, Sequence, Tuple

from repro.obs import recorder as obs

from .cost import (
    STREAM_REASON,
    AcceleratorConfig,
    CostKernel,
    SubgraphCost,
    SubgraphStructure,
    finish_cost,
)
from .graph import Graph

EvalQuery = Tuple[frozenset, AcceleratorConfig]

# element-wise scalar-fallback guards for the array backends (vector/jax):
# float64 stays exact below 2**53; int64 products of two values below 2**31
# cannot overflow
_FLOAT_EXACT = 1 << 53
_PROD_SAFE = 1 << 31


def needs_scalar_fallback(st: SubgraphStructure,
                          acc: AcceleratorConfig) -> bool:
    """True when one query must take the scalar ``finish_cost`` path.

    The array backends batch the capacity/streaming arithmetic through
    float64-capable numerics, which are exact only while every operand stays
    below ``2**53`` and every int64 product's factors stay below ``2**31``;
    a failed schedule short-circuits in ``finish_cost`` and has nothing to
    batch.  The boundary is inclusive (``>=``) so the batched path never
    touches the first representable value that *could* round differently.
    The ``share * weight_total`` clause bounds the NoC product: with it (and
    the footprint bound on the block count), ``(share - 1) * ema_w`` stays
    below ``2**62`` even for a streamed sweep, so int64 cannot overflow.
    """
    return (st.sched_error is not None
            or max(st.footprint, st.weight_total) >= _PROD_SAFE
            or acc.weight_share_cores * st.weight_total >= _PROD_SAFE
            or max(acc.glb_bytes, acc.wbuf_bytes) >= _FLOAT_EXACT)


class Executor:
    """How a batch of distinct cost-kernel queries gets computed."""

    name = "abstract"

    def evaluate(self, kernel: CostKernel,
                 queries: Sequence[EvalQuery]) -> List[SubgraphCost]:
        raise NotImplementedError

    def close(self) -> None:  # release pools etc.; idempotent
        pass


class SerialExecutor(Executor):
    """Default backend: inline, one query at a time (pre-engine behaviour)."""

    name = "serial"

    def evaluate(self, kernel: CostKernel,
                 queries: Sequence[EvalQuery]) -> List[SubgraphCost]:
        return [kernel.cost(nodes, acc) for nodes, acc in queries]


# -- process backend ---------------------------------------------------------

def pool_mp_context():
    """The multiprocessing context every worker pool in the repo uses.

    Default start method (fork on Linux) while the process is jax-free:
    spawn/forkserver would re-import ``__main__`` and break REPL/stdin
    callers, and the workers themselves only run the pure kernel.  Once jax
    is imported the process is multithreaded and forking it both trips
    jax's at-fork ``RuntimeWarning`` and genuinely risks deadlock, so the
    pool switches to ``forkserver``: workers fork from a clean, jax-free
    server process instead of this one.  The kernel is deterministic, so
    results are identical under either context.
    """
    import multiprocessing as mp
    import sys

    if "jax" in sys.modules and "forkserver" in mp.get_all_start_methods():
        return mp.get_context("forkserver")
    return mp.get_context()


_WORKER_KERNEL: Optional[CostKernel] = None
_WORKER_CANON_SHIPPED = 0  # canonical entries already shipped to the parent

# wire order derived from the dataclass itself, so both protocol ends stay
# in sync across field reorders (and renames fail loudly at construction)
_COST_FIELDS = tuple(f.name for f in dataclass_fields(SubgraphCost))
_STRUCT_FIELDS = tuple(f.name for f in dataclass_fields(SubgraphStructure))


def _init_worker(g: Graph, out_tile: int, canonical: bool = True,
                 struct_cache_dir: Optional[str] = None) -> None:
    global _WORKER_KERNEL, _WORKER_CANON_SHIPPED
    struct_cache = None
    if struct_cache_dir:
        from .structcache import StructureCache

        struct_cache = StructureCache(struct_cache_dir)
    _WORKER_KERNEL = CostKernel(g, out_tile=out_tile, canonical=canonical,
                                struct_cache=struct_cache)
    _WORKER_CANON_SHIPPED = 0


def _worker_eval(
    accs: List[AcceleratorConfig],
    shard: List[Tuple[Tuple[int, ...], int]],
) -> Tuple[List[tuple], List[Tuple[tuple, tuple]]]:
    """Evaluate ``(nodes, acc-index)`` pairs; return plain field tuples.

    The compact protocol (an acc table instead of an acc per query, field
    tuples instead of dataclass instances) roughly halves the pickle cost,
    which is what bounds the process backend on cheap kernels.

    The second returned list ships the worker kernel's *new* canonical
    structure entries — those derived since this worker's previous shard —
    as ``(canonical_key, field-tuple)`` pairs with an empty ``nodes`` stamp
    (every canonical hit re-stamps it anyway).  The parent adopts them into
    its own kernel, so structures derived in workers keep paying off after
    the pool is gone (dict insertion order makes "new since last ship" a
    plain slice).
    """
    global _WORKER_CANON_SHIPPED
    assert _WORKER_KERNEL is not None, "worker pool not initialized"
    cost = _WORKER_KERNEL.cost
    out = []
    for nodes, ai in shard:
        c = cost(frozenset(nodes), accs[ai])
        out.append(tuple(getattr(c, name) for name in _COST_FIELDS))
    canon = _WORKER_KERNEL._canon
    fresh = []
    if len(canon) > _WORKER_CANON_SHIPPED:
        items = list(canon.items())[_WORKER_CANON_SHIPPED:]
        _WORKER_CANON_SHIPPED = len(canon)
        fresh = [(key,
                  tuple(() if name == "nodes" else getattr(st, name)
                        for name in _STRUCT_FIELDS))
                 for key, st in items]
    return out, fresh


class ProcessExecutor(Executor):
    """Shard batches over a persistent worker-process pool.

    The pool is created lazily on the first batch (bound to that kernel's
    graph/out_tile) and reused for every later batch, so workers keep their
    structure memos warm across GA generations.  ``close()`` (or evaluator
    ``close()``) shuts the pool down.
    """

    name = "process"

    def __init__(self, jobs: int = 2) -> None:
        self.jobs = max(1, int(jobs))
        self._pool: Optional[ProcessPoolExecutor] = None
        # the kernel the pool's workers were initialized for; held by
        # reference so a recycled id can never alias a different kernel
        self._pool_kernel: Optional[CostKernel] = None

    def _pool_for(self, kernel: CostKernel) -> ProcessPoolExecutor:
        if self._pool is not None and self._pool_kernel is not kernel:
            self.close()
        if self._pool is None:
            cache = kernel.struct_cache
            self._pool = ProcessPoolExecutor(
                max_workers=self.jobs,
                mp_context=pool_mp_context(),
                initializer=_init_worker,
                initargs=(kernel.g, kernel.out_tile, kernel.canonical,
                          str(cache.root) if cache is not None else None))
            self._pool_kernel = kernel
        return self._pool

    def evaluate(self, kernel: CostKernel,
                 queries: Sequence[EvalQuery]) -> List[SubgraphCost]:
        queries = list(queries)
        if len(queries) <= 2 * self.jobs:  # not worth the round-trips
            return [kernel.cost(nodes, acc) for nodes, acc in queries]
        pool = self._pool_for(kernel)
        # acc table: batches typically probe few distinct hardware points
        accs: List[AcceleratorConfig] = []
        acc_idx: dict = {}
        compact: List[Tuple[Tuple[int, ...], int]] = []
        for nodes, acc in queries:
            ai = acc_idx.get(id(acc))
            if ai is None:
                ai = acc_idx[id(acc)] = len(accs)
                accs.append(acc)
            compact.append((tuple(nodes), ai))
        n_shards = min(self.jobs, len(queries))
        rec = obs.current()
        with rec.span("executor.submit", backend=self.name,
                      shards=n_shards, queries=len(queries)):
            futures = [pool.submit(_worker_eval, accs, compact[i::n_shards])
                       for i in range(n_shards)]
        with rec.span("executor.join", backend=self.name):
            outs = [f.result() for f in futures]
        results: List[Optional[SubgraphCost]] = [None] * len(queries)
        for s, (shard_out, canon_wire) in enumerate(outs):
            for j, vals in enumerate(shard_out):
                results[s + j * n_shards] = SubgraphCost(
                    **dict(zip(_COST_FIELDS, vals)))
            if canon_wire:
                # adopt worker-derived canonical structures so they keep
                # serving hits in the parent (and in later serial batches)
                kernel.merge_canon({
                    key: SubgraphStructure(**dict(zip(_STRUCT_FIELDS, vals)))
                    for key, vals in canon_wire
                })
        return results  # type: ignore[return-value]

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None
            self._pool_kernel = None


# -- array backends (vector / jax) -------------------------------------------

class _BatchedFinishExecutor(Executor):
    """Shared struct-of-arrays structure for the array backends.

    Structures come from the kernel memo (one ``derive_schedule`` per
    distinct node set, like every backend).  The base class handles the
    guard partition (:func:`needs_scalar_fallback` lanes take the scalar
    ``finish_cost`` path element-wise), the int64 struct-of-arrays packing,
    and stitching array results back into :class:`SubgraphCost`s; a
    subclass only supplies :meth:`_finish_arrays` — the batched
    capacity/streaming/weight-sharing arithmetic itself.  Keeping one
    packing/stitching path means a new array backend cannot diverge from
    ``vector`` anywhere except inside the arithmetic the parity tests pin.
    """

    def _finish_arrays(self, fp, w_total, single, glb, wbuf, shared, share):
        """Batched ``finish_cost`` arithmetic over equal-length arrays.

        Returns ``(wr, n_blocks, ema_w, fp_out, noc, infeasible_buf,
        w_overflow, stream, feasible)`` arrays (int64 / bool), index-aligned
        with the inputs.
        """
        raise NotImplementedError

    def evaluate(self, kernel: CostKernel,
                 queries: Sequence[EvalQuery]) -> List[SubgraphCost]:
        import numpy as np

        queries = list(queries)
        results: List[Optional[SubgraphCost]] = [None] * len(queries)
        structs = [kernel.structure(nodes) for nodes, _ in queries]
        vec_idx = []
        for i, ((_, acc), st) in enumerate(zip(queries, structs)):
            if needs_scalar_fallback(st, acc):
                results[i] = finish_cost(st, acc)  # scalar fallback
            else:
                vec_idx.append(i)
        n_fallback = len(queries) - len(vec_idx)
        if n_fallback:
            obs.add("engine.scalar_fallback", n_fallback)
        if not vec_idx:
            return results  # type: ignore[return-value]

        sts = [structs[i] for i in vec_idx]
        accs = [queries[i][1] for i in vec_idx]
        fp = np.array([s.footprint for s in sts], dtype=np.int64)
        w_total = np.array([s.weight_total for s in sts], dtype=np.int64)
        single = np.array([len(s.nodes) == 1 for s in sts], dtype=bool)
        glb = np.array([a.glb_bytes for a in accs], dtype=np.int64)
        wbuf = np.array([a.wbuf_bytes for a in accs], dtype=np.int64)
        shared = np.array([a.shared for a in accs], dtype=bool)
        # construction validates weight_share_cores >= 1, no clamp needed
        share = np.array([a.weight_share_cores for a in accs], dtype=np.int64)

        (wr, n_blocks, ema_w, fp_out, noc, infeasible_buf, w_overflow,
         stream, feasible) = self._finish_arrays(fp, w_total, single, glb,
                                                 wbuf, shared, share)

        for j, i in enumerate(vec_idx):
            st = sts[j]
            if infeasible_buf[j]:
                reason = ("shared buffer overflow" if shared[j]
                          else "global buffer overflow")
            elif w_overflow[j]:
                reason = "weight buffer overflow"
            elif stream[j]:
                reason = f"{STREAM_REASON} in {int(n_blocks[j])} blocks"
            else:
                reason = ""
            results[i] = SubgraphCost(
                nodes=st.nodes,
                ema_in=st.ema_in,
                ema_out=st.ema_out,
                ema_w=int(ema_w[j]),
                macs=st.macs,
                footprint=int(fp_out[j]),
                weight_resident=int(wr[j]),
                glb_access_bytes=st.glb_access_bytes,
                wbuf_access_bytes=int(wr[j]),
                noc_bytes=int(noc[j]),
                feasible=bool(feasible[j]),
                reason=reason,
            )
        return results  # type: ignore[return-value]


class VectorExecutor(_BatchedFinishExecutor):
    """NumPy-vectorized ``finish_cost`` over a whole batch.

    The capacity/streaming/weight-sharing arithmetic runs as one vectorized
    pass over the batch.  Wins when one subgraph is probed at many hardware
    points (co-exploration populations).
    """

    name = "vector"

    def _finish_arrays(self, fp, w_total, single, glb, wbuf, shared, share):
        import numpy as np

        wr = w_total // share
        glb_cap = glb
        wbuf_cap = np.where(shared, glb, wbuf)
        overflow = np.where(shared, fp + wr > glb_cap, fp > glb_cap)
        infeasible_buf = overflow & ~single
        stream = overflow & single
        # mirrors _stream_single_layer: math.ceil of a float64 true division
        n_blocks = np.maximum(
            np.ceil(fp / np.maximum(glb_cap, 1)).astype(np.int64), 1)
        ema_w = np.where(stream, wr * n_blocks, w_total)
        fp_out = np.where(stream, np.minimum(fp, glb_cap), fp)
        w_overflow = ~shared & ~single & ~infeasible_buf & (wr > wbuf_cap)
        feasible = ~(infeasible_buf | w_overflow)
        # §5.4.2 NoC charge, mirroring finish_cost: every DRAM-loaded weight
        # byte crosses the fabric to the share - 1 peer cores
        noc = (share - 1) * ema_w
        return (wr, n_blocks, ema_w, fp_out, noc, infeasible_buf, w_overflow,
                stream, feasible)


# -- jax backend --------------------------------------------------------------

# probed lazily and cached: (available, detail); detail is the import
# failure when unavailable, so callers can say *why* jax is missing
_JAX_STATUS: Optional[Tuple[bool, str]] = None


def jax_status() -> Tuple[bool, str]:
    """``(available, detail)`` for the ``jax`` backend.

    ``detail`` is ``""`` when the batched kernel module imports cleanly and
    the import failure (e.g. ``ModuleNotFoundError: No module named 'jax'``)
    otherwise.  The probe runs once per process; jax is an optional
    dependency, so failure here is a normal, reportable state — never an
    error by itself.
    """
    global _JAX_STATUS
    if _JAX_STATUS is None:
        try:
            from repro.kernels import finish_batch  # noqa: F401
            _JAX_STATUS = (True, "")
        except Exception as err:  # ImportError or anything the import raised
            _JAX_STATUS = (False, f"{type(err).__name__}: {err}")
    return _JAX_STATUS


class JaxExecutor(_BatchedFinishExecutor):
    """jit-compiled jnp/Pallas ``finish_cost`` over a whole generation.

    The same struct-of-arrays batching as ``vector``, evaluated on-device
    through :func:`repro.kernels.finish_batch.finish_cost_batch` (int64
    arithmetic under ``jax.experimental.enable_x64``, batches padded to
    powers of two so GA generations of drifting size reuse compiled
    kernels).  ``pallas=True`` routes the hot streaming-block sweep through
    the Pallas kernel variant (interpret mode off-TPU); default comes from
    ``$REPRO_JAX_PALLAS``.  Both variants are bit-identical to ``serial``.
    """

    name = "jax"

    def __init__(self, pallas: Optional[bool] = None) -> None:
        if pallas is None:
            pallas = os.environ.get("REPRO_JAX_PALLAS", "0") == "1"
        self.pallas = bool(pallas)

    def _finish_arrays(self, fp, w_total, single, glb, wbuf, shared, share):
        from repro.kernels import finish_batch

        return finish_batch.finish_cost_batch(
            fp, w_total, single, glb, wbuf, shared, share,
            use_pallas=self.pallas)


BACKENDS = ("serial", "process", "vector", "jax")


def backend_status(backend: str) -> Tuple[bool, str]:
    """Would ``make_executor(backend)`` succeed?  ``(ok, why_not)``.

    The messages here are the single source for both :func:`make_executor`
    errors and the CLI's ``--eval-backend`` pre-flight check, mirroring
    ``Objective.metric`` validation: an unknown name lists the valid
    backends; an unavailable ``jax`` reports the underlying import failure.
    """
    if backend not in BACKENDS:
        return (False,
                f"unknown eval backend {backend!r}; valid backends: "
                f"{', '.join(BACKENDS)}")
    if backend == "jax":
        ok, detail = jax_status()
        if not ok:
            return (False,
                    f"eval backend 'jax' is unavailable ({detail}); "
                    f"install jax (CPU wheel: pip install jax) or use one "
                    f"of: {', '.join(b for b in BACKENDS if b != 'jax')}")
    return (True, "")


def make_executor(backend: Optional[str] = None, jobs: int = 1) -> Executor:
    """Resolve an ``eval_backend``/``eval_jobs`` pair to an executor.

    ``backend=None`` picks ``process`` when ``jobs > 1``, else ``serial``.
    Unknown names raise a :class:`ValueError` listing :data:`BACKENDS`; an
    unavailable ``jax`` raises one explaining why (the import failure).
    """
    if backend is None:
        backend = "process" if jobs and jobs > 1 else "serial"
    ok, why = backend_status(backend)
    if not ok:
        raise ValueError(why)
    if backend == "serial":
        return SerialExecutor()
    if backend == "process":
        return ProcessExecutor(jobs=jobs)
    if backend == "vector":
        return VectorExecutor()
    return JaxExecutor()
