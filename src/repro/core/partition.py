"""Graph-level partition schemes (paper §4.1.1).

A partition ``P : V -> N`` assigns each layer to a subgraph; validity requires
``P(u) <= P(v)`` for every edge (computed before use) and every subgraph to be
weakly connected.  Subgraphs execute in id order.

``normalize`` repairs an arbitrary grouping into a valid scheme (used after GA
crossover/mutations): split disconnected groups, break quotient-graph cycles by
topological bisection, then renumber groups in quotient-topological order.
``split_to_fit`` is the paper's in-situ tuning (§4.4.4): oversized subgraphs
are split during evaluation instead of discarding the genome.
"""

from __future__ import annotations

import random
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .cost import AcceleratorConfig, PlanCost, evaluate_partition, evaluate_subgraph
from .graph import Graph


Partition = List[int]  # P[node] = subgraph id


def groups_of(P: Sequence[int]) -> List[Set[int]]:
    """Group node sets ordered by subgraph id."""
    byid: Dict[int, Set[int]] = {}
    for v, pid in enumerate(P):
        byid.setdefault(pid, set()).add(v)
    return [byid[k] for k in sorted(byid)]


def partition_of(groups: Sequence[Set[int]], n: int) -> Partition:
    P = [0] * n
    for i, s in enumerate(groups):
        for v in s:
            P[v] = i
    return P


def is_valid(g: Graph, P: Sequence[int]) -> bool:
    for e in g.edges:
        if P[e.src] > P[e.dst]:
            return False
    for s in groups_of(P):
        if not g.is_connected(s):
            return False
    return True


def _quotient_edges(g: Graph, gid: Dict[int, int]) -> Set[Tuple[int, int]]:
    q = set()
    for e in g.edges:
        a, b = gid[e.src], gid[e.dst]
        if a != b:
            q.add((a, b))
    return q


def _topo_order_quotient(n_groups: int,
                         qedges: Set[Tuple[int, int]]) -> Optional[List[int]]:
    """Kahn; None if cyclic."""
    indeg = [0] * n_groups
    out: Dict[int, List[int]] = {i: [] for i in range(n_groups)}
    for a, b in qedges:
        out[a].append(b)
        indeg[b] += 1
    stack = [i for i in range(n_groups) if indeg[i] == 0]
    order = []
    while stack:
        # deterministic: smallest id first
        stack.sort(reverse=True)
        v = stack.pop()
        order.append(v)
        for w in out[v]:
            indeg[w] -= 1
            if indeg[w] == 0:
                stack.append(w)
    return order if len(order) == n_groups else None


def normalize(g: Graph, raw_groups: Sequence[Set[int]]) -> List[Set[int]]:
    """Repair arbitrary groups into a valid ordered partition."""
    # 1. split disconnected groups into weak components
    groups: List[Set[int]] = []
    for s in raw_groups:
        if not s:
            continue
        groups.extend(g.weakly_connected_components(set(s)))

    # 2. break quotient cycles by topological bisection of offending groups
    for _ in range(g.n + 1):
        gid = {}
        for i, s in enumerate(groups):
            for v in s:
                gid[v] = i
        qedges = _quotient_edges(g, gid)
        order = _topo_order_quotient(len(groups), qedges)
        if order is not None:
            # renumber groups in quotient topological order
            return [groups[i] for i in order]
        # find a group on a cycle: any group with both in- and out-quotient
        # edges to a common strongly-connected region; heuristic: split the
        # largest multi-node group by node-index median
        cand = max((s for s in groups if len(s) > 1), key=len, default=None)
        if cand is None:
            raise RuntimeError("cyclic quotient with singleton groups")
        med = sorted(cand)[len(cand) // 2]
        lo = {v for v in cand if v < med}
        hi = {v for v in cand if v >= med}
        groups.remove(cand)
        for part in (lo, hi):
            groups.extend(g.weakly_connected_components(part)) if part else None
    raise RuntimeError("normalize did not converge")


def split_group_topo(g: Graph, s: Set[int], pieces: int = 2) -> List[Set[int]]:
    """Split a group into ~equal topological slices (each then re-split into
    weak components)."""
    order = sorted(s)
    k = max(1, len(order) // pieces)
    out: List[Set[int]] = []
    for i in range(0, len(order), k):
        chunk = set(order[i: i + k])
        out.extend(g.weakly_connected_components(chunk))
    return out


def split_to_fit(
    g: Graph,
    groups: List[Set[int]],
    acc: AcceleratorConfig,
    out_tile: int = 1,
    max_rounds: int = 64,
    ev: Optional["CachedEvaluator"] = None,
) -> List[Set[int]]:
    """In-situ tuning (paper §4.4.4): bisect any infeasible subgraph until all
    fit the buffers (singletons stream, always feasible)."""
    from .cost import CachedEvaluator  # local import to avoid cycle at module load

    ev = ev or CachedEvaluator(g, out_tile=out_tile)
    for _ in range(max_rounds):
        changed = False
        new: List[Set[int]] = []
        for s in groups:
            if len(s) == 1:
                new.append(s)
                continue
            c = ev.subgraph(s, acc)
            if c.feasible:
                new.append(s)
            else:
                new.extend(split_group_topo(g, s, pieces=2))
                changed = True
        groups = new
        if not changed:
            return normalize(g, groups)
    return normalize(g, [{v} for s in groups for v in s])


def singleton_partition(g: Graph) -> List[Set[int]]:
    return [{v} for v in range(g.n)]


def random_partition(g: Graph, rng: random.Random,
                     mean_size: float = 3.0) -> List[Set[int]]:
    """Random valid partition: walk nodes in topological order; each node joins
    a random open predecessor group or starts a new one (paper §4.4.1)."""
    gid: Dict[int, int] = {}
    groups: List[Set[int]] = []
    p_join = 1.0 - 1.0 / max(mean_size, 1.0)
    for v in g.topo_order():
        cands = {gid[u] for u in g.preds(v) if u in gid}
        if cands and rng.random() < p_join:
            c = rng.choice(sorted(cands))
            groups[c].add(v)
            gid[v] = c
        else:
            gid[v] = len(groups)
            groups.append({v})
    return normalize(g, groups)


def evaluate_groups(
    g: Graph,
    groups: List[Set[int]],
    acc: AcceleratorConfig,
    out_tile: int = 1,
    repair: bool = True,
    ev: Optional["CachedEvaluator"] = None,
) -> Tuple[List[Set[int]], PlanCost]:
    """Evaluate (optionally repairing in-situ); returns (repaired groups, cost)."""
    from .cost import CachedEvaluator

    ev = ev or CachedEvaluator(g, out_tile=out_tile)
    if repair:
        groups = split_to_fit(g, groups, acc, out_tile=out_tile, ev=ev)
    plan = ev.plan(groups, acc)
    return groups, plan
