"""Graph-level partition schemes (paper §4.1.1).

A partition ``P : V -> N`` assigns each layer to a subgraph; validity requires
``P(u) <= P(v)`` for every edge (computed before use) and every subgraph to be
weakly connected.  Subgraphs execute in id order.

``normalize`` repairs an arbitrary grouping into a valid scheme (used after GA
crossover/mutations): split disconnected groups, break quotient-graph cycles by
topological bisection, then renumber groups in quotient-topological order.
``split_to_fit`` is the paper's in-situ tuning (§4.4.4): oversized subgraphs
are split during evaluation instead of discarding the genome.
"""

from __future__ import annotations

import random
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.obs import recorder as obs

from .cost import AcceleratorConfig, PlanCost, evaluate_partition, evaluate_subgraph
from .graph import Graph


Partition = List[int]  # P[node] = subgraph id


def groups_of(P: Sequence[int]) -> List[Set[int]]:
    """Group node sets ordered by subgraph id."""
    byid: Dict[int, Set[int]] = {}
    for v, pid in enumerate(P):
        byid.setdefault(pid, set()).add(v)
    return [byid[k] for k in sorted(byid)]


def partition_of(groups: Sequence[Set[int]], n: int) -> Partition:
    P = [0] * n
    for i, s in enumerate(groups):
        for v in s:
            P[v] = i
    return P


def is_valid(g: Graph, P: Sequence[int]) -> bool:
    for e in g.edges:
        if P[e.src] > P[e.dst]:
            return False
    for s in groups_of(P):
        if not g.is_connected(s):
            return False
    return True


def _quotient_edges(g: Graph, gid: Sequence[int]) -> Set[Tuple[int, int]]:
    q = set()
    for e in g.edges:
        a, b = gid[e.src], gid[e.dst]
        if a < 0 or b < 0:
            raise ValueError(
                f"groups do not cover node {e.src if a < 0 else e.dst}")
        if a != b:
            q.add((a, b))
    return q


def _topo_order_quotient(n_groups: int,
                         qedges: Set[Tuple[int, int]]) -> Optional[List[int]]:
    """Kahn, smallest id first (a min-heap pops the same order the previous
    sort-per-iteration implementation did); None if cyclic."""
    import heapq

    indeg = [0] * n_groups
    out: Dict[int, List[int]] = {i: [] for i in range(n_groups)}
    for a, b in qedges:
        out[a].append(b)
        indeg[b] += 1
    heap = [i for i in range(n_groups) if indeg[i] == 0]
    heapq.heapify(heap)
    order = []
    while heap:
        v = heapq.heappop(heap)
        order.append(v)
        for w in out[v]:
            indeg[w] -= 1
            if indeg[w] == 0:
                heapq.heappush(heap, w)
    return order if len(order) == n_groups else None


def normalize(g: Graph, raw_groups: Sequence[Set[int]]) -> List[Set[int]]:
    """Repair arbitrary groups into a valid ordered partition."""
    # 1. split disconnected groups into weak components (singletons are
    # trivially connected — GA offspring are mostly singletons, so skip
    # the component scan for them)
    groups: List[Set[int]] = []
    for s in raw_groups:
        if not s:
            continue
        if len(s) == 1:
            groups.append(set(s))
        else:
            groups.extend(g.weakly_connected_components(set(s)))

    # 2. break quotient cycles by topological bisection of offending groups
    for _ in range(g.n + 1):
        gid_arr = [-1] * g.n  # -1 = uncovered; _quotient_edges raises on it
        for i, s in enumerate(groups):
            for v in s:
                gid_arr[v] = i
        qedges = _quotient_edges(g, gid_arr)
        order = _topo_order_quotient(len(groups), qedges)
        if order is not None:
            # renumber groups in quotient topological order
            return [groups[i] for i in order]
        # find a group on a cycle: any group with both in- and out-quotient
        # edges to a common strongly-connected region; heuristic: split the
        # largest multi-node group by node-index median
        cand = max((s for s in groups if len(s) > 1), key=len, default=None)
        if cand is None:
            raise RuntimeError("cyclic quotient with singleton groups")
        med = sorted(cand)[len(cand) // 2]
        lo = {v for v in cand if v < med}
        hi = {v for v in cand if v >= med}
        groups.remove(cand)
        for part in (lo, hi):
            if not part:
                continue
            if len(part) == 1:
                groups.append(part)
            else:
                groups.extend(g.weakly_connected_components(part))
    raise RuntimeError("normalize did not converge")


def split_group_topo(g: Graph, s: Set[int], pieces: int = 2) -> List[Set[int]]:
    """Split a group into ~equal topological slices (each then re-split into
    weak components)."""
    order = sorted(s)
    k = max(1, len(order) // pieces)
    out: List[Set[int]] = []
    for i in range(0, len(order), k):
        chunk = set(order[i: i + k])
        if len(chunk) == 1:
            out.append(chunk)
        else:
            out.extend(g.weakly_connected_components(chunk))
    return out


def split_to_fit(
    g: Graph,
    groups: List[Set[int]],
    acc: AcceleratorConfig,
    out_tile: int = 1,
    max_rounds: int = 64,
    ev: Optional["CachedEvaluator"] = None,
) -> List[Set[int]]:
    """In-situ tuning (paper §4.4.4): bisect any infeasible subgraph until all
    fit the buffers (singletons stream, always feasible)."""
    from .cost import CachedEvaluator  # local import to avoid cycle at module load

    ev = ev or CachedEvaluator(g, out_tile=out_tile)
    return split_to_fit_batch(g, [(groups, acc)], ev, max_rounds=max_rounds)[0]


def split_to_fit_batch(
    g: Graph,
    items: Sequence[Tuple[List[Set[int]], AcceleratorConfig]],
    ev: "CachedEvaluator",
    max_rounds: int = 64,
) -> List[List[Set[int]]]:
    """Batched in-situ tuning: repair many plans against one evaluator batch
    per round.

    Round ``k`` collects every still-unrepaired plan's multi-node subgraphs
    into one feasibility batch (where the engine's executor parallelism
    applies), then applies the split decisions — the same decisions, in the
    same order, as running :func:`split_to_fit` per item, since feasibility
    of one subgraph never depends on the others.
    """
    out: List[Optional[List[Set[int]]]] = [None] * len(items)
    groups_of_item: List[List[Set[int]]] = [list(gr) for gr, _ in items]
    active = list(range(len(items)))
    for _ in range(max_rounds):
        if not active:
            break
        obs.add("repair.rounds")
        queries = [(s, items[i][1]) for i in active
                   for s in groups_of_item[i] if len(s) > 1]
        costs = ev.evaluate_batch(queries)
        pos = 0
        n_splits = 0
        still_active: List[int] = []
        for i in active:
            changed = False
            new: List[Set[int]] = []
            for s in groups_of_item[i]:
                if len(s) == 1:
                    new.append(s)
                    continue
                c = costs[pos]
                pos += 1
                if c.feasible:
                    new.append(s)
                else:
                    new.extend(split_group_topo(g, s, pieces=2))
                    changed = True
                    n_splits += 1
            groups_of_item[i] = new
            if changed:
                still_active.append(i)
            else:
                out[i] = normalize(g, new)
        if n_splits:
            obs.add("repair.splits", n_splits)
        active = still_active
    for i in active:  # max_rounds exhausted: fall back to singletons
        out[i] = normalize(g, [{v} for s in groups_of_item[i] for v in s])
    return out  # type: ignore[return-value]


def singleton_partition(g: Graph) -> List[Set[int]]:
    return [{v} for v in range(g.n)]


def random_partition(g: Graph, rng: random.Random,
                     mean_size: float = 3.0) -> List[Set[int]]:
    """Random valid partition: walk nodes in topological order; each node joins
    a random open predecessor group or starts a new one (paper §4.4.1)."""
    gid: Dict[int, int] = {}
    groups: List[Set[int]] = []
    p_join = 1.0 - 1.0 / max(mean_size, 1.0)
    for v in g.topo_order():
        cands = {gid[u] for u in g.preds(v) if u in gid}
        if cands and rng.random() < p_join:
            c = rng.choice(sorted(cands))
            groups[c].add(v)
            gid[v] = c
        else:
            gid[v] = len(groups)
            groups.append({v})
    return normalize(g, groups)


def evaluate_groups(
    g: Graph,
    groups: List[Set[int]],
    acc: AcceleratorConfig,
    out_tile: int = 1,
    repair: bool = True,
    ev: Optional["CachedEvaluator"] = None,
) -> Tuple[List[Set[int]], PlanCost]:
    """Evaluate (optionally repairing in-situ); returns (repaired groups, cost)."""
    from .cost import CachedEvaluator

    ev = ev or CachedEvaluator(g, out_tile=out_tile)
    if repair:
        groups = split_to_fit(g, groups, acc, out_tile=out_tile, ev=ev)
    plan = ev.plan(groups, acc)
    return groups, plan
