"""Reference executor for the consumption-centric scheme (validation only).

Executes a subgraph row-by-row with *actual data*, under hard per-tensor buffer
capacities equal to the derived allocations ``x``, and checks the paper's
claims mechanically:

* correctness  — every produced row equals the whole-tensor reference value,
* full reuse   — every external row is loaded from "DRAM" exactly once and no
                 intermediate row is ever recomputed,
* sufficiency  — with only ``x`` rows of buffer per tensor the schedule
                 completes without deadlock (tightness can be probed by
                 shrinking an allocation and expecting deadlock).

Nodes compute ``y[i] = tanh(b + sum_e dot(w_e, src_e[i*s : i*s+F]))`` over
their sliding in-edges (full edges contribute a whole-tensor reduction), which
makes row misindexing observable in the values.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .graph import FULL, SLIDING, Graph
from .tiling import SubgraphSchedule, derive_schedule


class DeadlockError(AssertionError):
    pass


@dataclass
class SimResult:
    max_occupancy: Dict[int, int]         # rows resident, max over time
    dram_loads: Dict[int, int]            # rows loaded per external tensor
    rounds: int
    updates: Dict[int, int]               # update count per internal node


class _Buffer:
    """Row buffer with a hard capacity and liveness-based eviction."""

    def __init__(self, capacity: int) -> None:
        self.capacity = capacity
        self.rows: Dict[int, float] = {}
        self.max_occ = 0
        self.head = 0  # next row index to produce / load

    def has_space(self) -> bool:
        return len(self.rows) < self.capacity

    def put(self, idx: int, val: float) -> None:
        if len(self.rows) >= self.capacity:
            raise DeadlockError(f"buffer overflow at capacity {self.capacity}")
        self.rows[idx] = val
        self.max_occ = max(self.max_occ, len(self.rows))

    def window(self, lo: int, hi: int) -> Optional[np.ndarray]:
        try:
            return np.array([self.rows[i] for i in range(lo, hi)])
        except KeyError:
            return None

    def evict_below(self, idx: int) -> None:
        for r in [r for r in self.rows if r < idx]:
            del self.rows[r]


def reference_forward(
    g: Graph, nodes: Set[int], rng: np.random.Generator
) -> Tuple[Dict[int, np.ndarray], Dict[Tuple[int, int], np.ndarray], Dict[int, float]]:
    """Whole-tensor reference; external inputs get random data."""
    ext = sorted({e.src for e in g.boundary_in(nodes)})
    vals: Dict[int, np.ndarray] = {}
    for t in ext:
        vals[t] = rng.normal(size=g.nodes[t].out_len)
    kernels: Dict[Tuple[int, int], np.ndarray] = {}
    bias: Dict[int, float] = {}
    for v in sorted(nodes):
        bias[v] = float(rng.normal())
        acc = np.full(g.nodes[v].out_len, bias[v])
        for e in g.in_edges(v):
            w = rng.normal(size=(e.F if e.kind == SLIDING else
                                 g.nodes[e.src].out_len))
            kernels[(e.src, v)] = w
            src = vals[e.src]
            if e.kind == FULL:
                acc = acc + float(np.dot(w, src))
            else:
                need = e.F + (g.nodes[v].out_len - 1) * e.s
                if need > len(src):
                    raise ValueError(
                        f"node {v}: out_len inconsistent with edge "
                        f"({e.src}->{v}, F={e.F}, s={e.s})"
                    )
                for i in range(g.nodes[v].out_len):
                    acc[i] += float(np.dot(w, src[i * e.s: i * e.s + e.F]))
        vals[v] = np.tanh(acc)
    return vals, kernels, bias


def simulate_subgraph(
    g: Graph,
    nodes: Set[int],
    schedule: Optional[SubgraphSchedule] = None,
    out_tile: int = 1,
    seed: int = 0,
    capacity_override: Optional[Dict[int, int]] = None,
    max_stall_rounds: int = 4,
) -> SimResult:
    """Run the capacity-constrained tiled execution; assert correctness."""
    sched = schedule or derive_schedule(g, nodes, out_tile=out_tile)
    rng = np.random.default_rng(seed)
    ref_vals, kernels, bias = reference_forward(g, nodes, rng)

    internal = sorted(nodes)
    ext = sorted({e.src for e in g.boundary_in(nodes)})
    cap = {t: sched.tensors[t].x for t in internal + ext}
    if capacity_override:
        cap.update(capacity_override)
    bufs: Dict[int, _Buffer] = {t: _Buffer(cap[t]) for t in internal + ext}
    loads: Dict[int, int] = {t: 0 for t in ext}
    loaded_once: Dict[int, Set[int]] = {t: set() for t in ext}
    produced_cnt: Dict[int, int] = {t: 0 for t in internal}
    updates: Dict[int, int] = {t: 0 for t in internal}
    recomputed = 0

    cons: Dict[int, List] = {t: [] for t in internal + ext}
    for e in g.edges:
        if e.dst in nodes and e.src in cons:
            cons[e.src].append(e)

    def consumer_low_water(tensor: int) -> int:
        """Smallest still-needed row index across internal consumers."""
        lo = None
        for e in cons[tensor]:
            nxt = bufs[e.dst].head
            if e.kind == FULL:
                need = 0 if nxt < g.nodes[e.dst].out_len else 10**18
            else:
                need = nxt * e.s
            lo = need if lo is None else min(lo, need)
        return 10**18 if lo is None else lo  # no consumer: immediate writeback

    def evict_all() -> None:
        for t in internal + ext:
            bufs[t].evict_below(consumer_low_water(t))

    def try_load_external(t: int, hi: int) -> bool:
        """Stream external rows up to (exclusive) ``hi``, evicting dead rows
        eagerly; returns False if capacity blocks the load."""
        b = bufs[t]
        hi = min(hi, g.nodes[t].out_len)
        while b.head < hi:
            if not b.has_space():
                b.evict_below(consumer_low_water(t))
                if not b.has_space():
                    return False
            r = b.head
            assert r not in loaded_once[t], f"external row {t}:{r} loaded twice"
            loaded_once[t].add(r)
            b.put(r, float(ref_vals[t][r]))
            loads[t] += 1
            b.head += 1
        return True

    def produce_one_update(v: int) -> int:
        """One update of node v: up to delta(v) rows, row-granular with eager
        eviction (consumers may lag producers within their x allocations; the
        delta phase alignment comes from the prologue, see tiling.py).
        Returns rows made (0 = stall)."""
        nonlocal recomputed
        b = bufs[v]
        out_len = g.nodes[v].out_len
        made = 0
        budget = min(sched.tensors[v].delta, out_len - b.head)
        while made < budget:
            i = b.head
            acc = bias[v]
            ok = True
            for e in g.in_edges(v):
                if e.kind == FULL:
                    lo, hi = 0, g.nodes[e.src].out_len
                else:
                    lo, hi = i * e.s, i * e.s + e.F
                if e.src in loads and not try_load_external(e.src, hi):
                    ok = False
                    break
                if bufs[e.src].window(lo, hi) is None:
                    ok = False
                    break
            if not ok:
                break
            if not b.has_space():
                b.evict_below(consumer_low_water(v))
                if not b.has_space():
                    break
            for e in g.in_edges(v):
                if e.kind == FULL:
                    lo, hi = 0, g.nodes[e.src].out_len
                else:
                    lo, hi = i * e.s, i * e.s + e.F
                seg = bufs[e.src].window(lo, hi)
                acc += float(np.dot(kernels[(e.src, v)], seg))
            val = float(np.tanh(acc))
            assert abs(val - ref_vals[v][i]) < 1e-9, (
                f"node {v} row {i}: {val} != ref {ref_vals[v][i]}"
            )
            b.put(i, val)
            b.head += 1
            made += 1
        if made:
            updates[v] += 1
            produced_cnt[v] += made
        return made

    total_target = sum(g.nodes[v].out_len for v in internal)
    rounds = 0
    stalls = 0
    while sum(produced_cnt.values()) < total_target:
        rounds += 1
        progress = 0
        for v in internal:
            progress += produce_one_update(v)
            evict_all()
        if progress == 0:
            stalls += 1
            if stalls >= max_stall_rounds:
                raise DeadlockError(
                    f"no progress after {rounds} rounds "
                    f"(produced {sum(produced_cnt.values())}/{total_target})"
                )
        else:
            stalls = 0

    assert recomputed == 0
    for v in internal:
        assert produced_cnt[v] == g.nodes[v].out_len
    for t, b in bufs.items():
        assert b.max_occ <= cap[t]
    return SimResult(
        max_occupancy={t: b.max_occ for t, b in bufs.items()},
        dram_loads=loads,
        rounds=rounds,
        updates=updates,
    )
