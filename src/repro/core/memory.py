"""Memory management for subgraph execution (paper §3.2, Fig. 7–8).

The global buffer is carved into logical per-tensor regions by a *buffer region
manager*: a 2N-deep register file holding (start, end) addresses for up to N
regions.  Each tensor gets a MAIN region (the sliding tile, ``x`` rows) and —
when the tile is narrower than the full feature-map width — a SIDE region
holding the horizontally-overlapping rows for reuse across the row loop.

In our row-granular model tiles span the full width (line-buffer style), so the
SIDE bytes are folded into MAIN for footprint purposes; the 2-D split is still
modelled so the region table and its area overhead match the paper's
demonstration (272 B table for N=64 regions, 17-bit addresses, 0.18% of a 1 MB
64-bit-wide buffer).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .graph import Graph
from .tiling import SubgraphSchedule, derive_schedule


@dataclass(frozen=True)
class Region:
    tensor: int
    start: int          # byte address in the global buffer
    end: int            # exclusive
    kind: str           # "MAIN" | "SIDE"

    @property
    def size(self) -> int:
        return self.end - self.start


@dataclass
class RegionTable:
    """The buffer region manager state: one (start, end) pair per region."""

    capacity_bytes: int
    max_regions: int = 64
    regions: List[Region] = field(default_factory=list)

    def allocate(self, tensor: int, size: int, kind: str = "MAIN") -> Region:
        if len(self.regions) >= 2 * self.max_regions:
            raise MemoryError(f"region table full (N={self.max_regions})")
        start = self.regions[-1].end if self.regions else 0
        if start + size > self.capacity_bytes:
            raise MemoryError(
                f"global buffer overflow: need {start + size} of "
                f"{self.capacity_bytes} bytes"
            )
        r = Region(tensor, start, start + size, kind)
        self.regions.append(r)
        return r

    @property
    def used_bytes(self) -> int:
        return self.regions[-1].end if self.regions else 0

    # -- hardware overhead (paper: 272 B register file, 0.18% area) --------
    def table_bytes(self) -> int:
        addr_bits = max(1, math.ceil(math.log2(max(self.capacity_bytes, 2))))
        # one start + one end address per region entry, N entries
        bits = 2 * self.max_regions * addr_bits
        return math.ceil(bits / 8)

    def area_overhead_fraction(self, sram_mm2_per_mb: float = 1.2,
                               regfile_mm2_per_kb: float = 0.012) -> float:
        """Rough silicon ratio of the region table vs the buffer itself."""
        buf_mm2 = (self.capacity_bytes / 2**20) * sram_mm2_per_mb
        tbl_mm2 = (self.table_bytes() / 1024) * regfile_mm2_per_kb
        return tbl_mm2 / max(buf_mm2, 1e-12)


@dataclass
class FootprintReport:
    total_bytes: int
    per_tensor: Dict[int, int]
    main_bytes: int
    side_bytes: int
    fits: bool


def side_rows(F: int, s: int) -> int:
    """Horizontally-overlapping rows reserved in the SIDE region (F > s)."""
    return max(0, F - s)


def subgraph_footprint(
    g: Graph,
    nodes: Set[int],
    schedule: Optional[SubgraphSchedule] = None,
    capacity_bytes: Optional[int] = None,
    out_tile: int = 1,
    tile_width_fraction: float = 1.0,
) -> FootprintReport:
    """Global-buffer bytes needed to execute ``nodes`` as one subgraph.

    ``tile_width_fraction`` < 1 models 2-D tiling where the MAIN tile covers a
    fraction of the row and the SIDE region holds the overlap rows of the full
    width; with the default (line-buffer tiles spanning the full width) SIDE
    is zero and MAIN is ``x`` full rows.
    """
    sched = schedule or derive_schedule(g, nodes, out_tile=out_tile)
    per: Dict[int, int] = {}
    main_total = 0
    side_total = 0
    for t, ts in sched.tensors.items():
        line = g.nodes[t].line_bytes
        main = ts.x * max(1, int(line * tile_width_fraction))
        side = 0
        if tile_width_fraction < 1.0:
            # max window among this tensor's consumers inside the subgraph
            fmax, smin = 0, 1
            for e in g.edges:
                if e.src == t and e.dst in nodes and e.kind == "sliding":
                    fmax, smin = max(fmax, e.F), max(1, e.s)
            side = side_rows(fmax, smin) * line
        per[t] = main + side
        main_total += main
        side_total += side
    total = main_total + side_total
    fits = capacity_bytes is None or total <= capacity_bytes
    return FootprintReport(total, per, main_total, side_total, fits)


def build_region_table(
    g: Graph,
    nodes: Set[int],
    capacity_bytes: int,
    max_regions: int = 64,
    out_tile: int = 1,
) -> RegionTable:
    """Compile-time layout: allocate MAIN (+SIDE) regions for every tensor."""
    sched = derive_schedule(g, nodes, out_tile=out_tile)
    table = RegionTable(capacity_bytes, max_regions)
    for t in sorted(sched.tensors):
        ts = sched.tensors[t]
        table.allocate(t, ts.x * g.nodes[t].line_bytes, "MAIN")
    return table
