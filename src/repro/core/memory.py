"""Memory management for subgraph execution (paper §3.2, Fig. 7–8).

The global buffer is carved into logical per-tensor regions by a *buffer region
manager*: a 2N-deep register file holding (start, end) addresses for up to N
regions.  Each tensor gets a MAIN region (the sliding tile, ``x`` rows) and —
when the tile is narrower than the full feature-map width — a SIDE region
holding the horizontally-overlapping rows for reuse across the row loop.

In our row-granular model tiles span the full width (line-buffer style), so the
SIDE bytes are folded into MAIN for footprint purposes; the 2-D split is still
modelled so the region table and its area overhead match the paper's
demonstration (272 B table for N=64 regions, 17-bit addresses, 0.18% of a 1 MB
64-bit-wide buffer).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Set, Tuple

from .graph import Graph
from .tiling import SubgraphSchedule, derive_schedule


@dataclass(frozen=True)
class Region:
    tensor: int
    start: int          # byte address in the global buffer
    end: int            # exclusive
    kind: str           # "MAIN" | "SIDE"

    @property
    def size(self) -> int:
        return self.end - self.start


@dataclass
class RegionTable:
    """The buffer region manager state: one (start, end) pair per region."""

    capacity_bytes: int
    max_regions: int = 64
    regions: List[Region] = field(default_factory=list)

    def allocate(self, tensor: int, size: int, kind: str = "MAIN") -> Region:
        if len(self.regions) >= 2 * self.max_regions:
            raise MemoryError(f"region table full (N={self.max_regions})")
        start = self.regions[-1].end if self.regions else 0
        if start + size > self.capacity_bytes:
            raise MemoryError(
                f"global buffer overflow: need {start + size} of "
                f"{self.capacity_bytes} bytes"
            )
        r = Region(tensor, start, start + size, kind)
        self.regions.append(r)
        return r

    @property
    def used_bytes(self) -> int:
        return self.regions[-1].end if self.regions else 0

    # -- hardware overhead (paper: 272 B register file, 0.18% area) --------
    def table_bytes(self) -> int:
        addr_bits = max(1, math.ceil(math.log2(max(self.capacity_bytes, 2))))
        # one start + one end address per region entry, N entries
        bits = 2 * self.max_regions * addr_bits
        return math.ceil(bits / 8)

    def area_overhead_fraction(self, sram_mm2_per_mb: float = 1.2,
                               regfile_mm2_per_kb: float = 0.012) -> float:
        """Rough silicon ratio of the region table vs the buffer itself."""
        buf_mm2 = (self.capacity_bytes / 2**20) * sram_mm2_per_mb
        tbl_mm2 = (self.table_bytes() / 1024) * regfile_mm2_per_kb
        return tbl_mm2 / max(buf_mm2, 1e-12)


@dataclass
class FootprintReport:
    total_bytes: int
    per_tensor: Dict[int, int]
    main_bytes: int
    side_bytes: int
    fits: bool


def side_rows(F: int, s: int) -> int:
    """Horizontally-overlapping rows reserved in the SIDE region (F > s)."""
    return max(0, F - s)


def subgraph_footprint(
    g: Graph,
    nodes: Set[int],
    schedule: Optional[SubgraphSchedule] = None,
    capacity_bytes: Optional[int] = None,
    out_tile: int = 1,
    tile_width_fraction: float = 1.0,
) -> FootprintReport:
    """Global-buffer bytes needed to execute ``nodes`` as one subgraph.

    ``tile_width_fraction`` < 1 models 2-D tiling where the MAIN tile covers a
    fraction of the row and the SIDE region holds the overlap rows of the full
    width; with the default (line-buffer tiles spanning the full width) SIDE
    is zero and MAIN is ``x`` full rows.
    """
    sched = schedule or derive_schedule(g, nodes, out_tile=out_tile)
    per: Dict[int, int] = {}
    main_total = 0
    side_total = 0
    for t, ts in sched.tensors.items():
        line = g.nodes[t].line_bytes
        main = ts.x * max(1, int(line * tile_width_fraction))
        side = 0
        if tile_width_fraction < 1.0:
            # max window among this tensor's consumers inside the subgraph
            fmax, smin = 0, 1
            for e in g.edges:
                if e.src == t and e.dst in nodes and e.kind == "sliding":
                    fmax, smin = max(fmax, e.F), max(1, e.s)
            side = side_rows(fmax, smin) * line
        per[t] = main + side
        main_total += main
        side_total += side
    total = main_total + side_total
    fits = capacity_bytes is None or total <= capacity_bytes
    return FootprintReport(total, per, main_total, side_total, fits)


@dataclass
class OccupancyTracker:
    """Time-stepped occupancy accounting over one subgraph's regions.

    Models the consumption-centric steady state: each tensor's resident
    rows grow as rows are produced (or streamed from DRAM) and are capped
    at the region allocation ``x`` — the eviction scheme frees any row all
    consumers are past, so a tensor never holds more than its allocation.
    Driven step-by-step by the trace simulator (:mod:`repro.sim`), which
    records ``resident_bytes`` per step and ``peak_bytes`` per subgraph;
    the peak is by construction bounded by the analytical footprint
    (:func:`subgraph_footprint`), and the cross-validation tests pin that.
    """

    caps_rows: Dict[int, int]          # region allocation x, in rows
    line_bytes: Dict[int, int]
    filled: Dict[int, int] = field(default_factory=dict)
    peak_bytes: int = 0

    @classmethod
    def from_schedule(cls, g: Graph,
                      sched: SubgraphSchedule) -> "OccupancyTracker":
        return cls(
            caps_rows={t: ts.x for t, ts in sched.tensors.items()},
            line_bytes={t: g.nodes[t].line_bytes for t in sched.tensors},
        )

    def advance(self, produced: Mapping[int, int]) -> int:
        """Account ``produced`` rows per tensor; returns bytes now resident."""
        for t, rows in produced.items():
            self.filled[t] = self.filled.get(t, 0) + rows
        occ = self.resident_bytes()
        self.peak_bytes = max(self.peak_bytes, occ)
        return occ

    def resident_bytes(self) -> int:
        return sum(
            min(rows, self.caps_rows.get(t, rows)) * self.line_bytes.get(t, 0)
            for t, rows in self.filled.items()
        )

    def resident_by_tensor(self) -> Dict[int, int]:
        """Per-tensor resident bytes (sums exactly to ``resident_bytes`` —
        the trace v3 ``occ_tensors`` timeline source)."""
        return {
            t: min(rows, self.caps_rows.get(t, rows))
            * self.line_bytes.get(t, 0)
            for t, rows in self.filled.items()
        }


def build_region_table(
    g: Graph,
    nodes: Set[int],
    capacity_bytes: int,
    max_regions: int = 64,
    out_tile: int = 1,
    schedule: Optional[SubgraphSchedule] = None,
) -> RegionTable:
    """Compile-time layout: allocate MAIN (+SIDE) regions for every tensor.

    ``schedule`` reuses an already-derived schedule (as
    :func:`subgraph_footprint` does) instead of re-deriving it.
    """
    sched = schedule or derive_schedule(g, nodes, out_tile=out_tile)
    table = RegionTable(capacity_bytes, max_regions)
    for t in sorted(sched.tensors):
        ts = sched.tensors[t]
        table.allocate(t, ts.x * g.nodes[t].line_bytes, "MAIN")
    return table
