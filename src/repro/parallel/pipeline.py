"""GPipe-style pipeline parallelism over a mesh axis (optional path).

The `pod` (or any) axis can be re-bound to pipeline stages: parameters are
sharded layer-group-wise across the stage axis, activations flow stage to
stage via ``jax.lax.ppermute`` inside ``shard_map``, and microbatches fill
the pipeline (bubble fraction (P-1)/(M+P-1)).

This module implements the schedule for a *stacked-stage* model: the caller
provides per-stage apply ``fn(stage_params, x) -> x`` where stage_params has
a leading stage axis sharded on the pipeline mesh axis.  Used by
launch/dryrun.py's --pipeline mode and tested on small meshes.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as PS


def pipeline_apply(
    fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,            # leaves [P_stages, ...] sharded on axis
    x: jnp.ndarray,               # [M_microbatches, mb, ...] (replicated in)
    mesh: Mesh,
    axis: str = "pod",
) -> jnp.ndarray:
    """Run M microbatches through P pipeline stages; returns outputs in
    microbatch order.  Implements the classic rotating-buffer GPipe loop:
    at tick t, stage s processes microbatch (t - s) if 0 <= t - s < M."""
    P = mesh.shape[axis]
    M = x.shape[0]

    def per_stage(params_local, x_all):
        # params_local: [1, ...] (this stage's slice); x_all: [M, mb, ...]
        params_local = jax.tree.map(lambda p: p[0], params_local)
        stage = lax.axis_index(axis)
        n_ticks = M + P - 1
        buf = jnp.zeros_like(x_all)                 # outputs (stage P-1 only)
        carry = jnp.zeros_like(x_all[0])            # inter-stage activation

        def tick(t, state):
            carry, buf = state
            mb_idx = t - stage
            # stage 0 ingests fresh microbatches; others use the carry
            inject = jnp.where(jnp.logical_and(stage == 0, mb_idx >= 0),
                               1, 0)
            x_in = jnp.where(inject,
                             x_all[jnp.clip(mb_idx, 0, M - 1)], carry)
            active = jnp.logical_and(mb_idx >= 0, mb_idx < M)
            y = fn(params_local, x_in)
            y = jnp.where(active, y, x_in)
            # last stage records its finished microbatch
            is_last = stage == P - 1
            buf = lax.cond(
                jnp.logical_and(active, is_last),
                lambda b: lax.dynamic_update_slice(
                    b, y[None], (jnp.clip(mb_idx, 0, M - 1),) +
                    (0,) * (b.ndim - 1)),
                lambda b: b, buf)
            # rotate activations to the next stage
            carry = lax.ppermute(y, axis,
                                 [(i, (i + 1) % P) for i in range(P)])
            return carry, buf

        _, buf = lax.fori_loop(0, n_ticks, tick, (carry, buf))
        # only stage P-1 holds real outputs; broadcast them to all stages
        buf = lax.psum(jnp.where(stage == P - 1, buf, jnp.zeros_like(buf)),
                       axis)
        return buf

    spec_params = jax.tree.map(lambda _: PS(axis), stage_params)
    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(spec_params, PS()),
        out_specs=PS(),
        check_rep=False,
    )(stage_params, x)


def pipeline_bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
