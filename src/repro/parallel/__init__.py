from .sharding import (
    LogicalRules,
    axis_rules,
    current_mesh,
    current_rules,
    logical_sharding,
    mesh_context,
    shard,
    spec_for,
)

__all__ = [k for k in dir() if not k.startswith("_")]
