"""Logical-axis sharding (flax-style rules, dependency-free).

Model code annotates tensors with *logical* axis names ("batch", "heads",
"embed", "expert", ...).  A rules table maps logical names to mesh axes; the
mapping differs per parallelism strategy (TP vs FSDP vs decode-SP) and is the
main lever the §Perf hillclimb turns.

Usage:
    with mesh_context(mesh, rules):
        y = shard(x, "batch", "seq", None)      # constraint inside jit
        s = logical_sharding(("vocab", "embed"))  # NamedSharding for params
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

Axes = Tuple[Optional[str], ...]
MeshAxis = Union[None, str, Tuple[str, ...]]
LogicalRules = Dict[str, MeshAxis]

_state = threading.local()

# default rules: single-pod (data, model) mesh, Megatron-style TP + FSDP
DEFAULT_RULES: LogicalRules = {
    "batch": ("pod", "data"),     # "pod" silently dropped if mesh lacks it
    "seq": None,
    "seq_kv": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "expert_cap": None,
    "fsdp": "data",               # second param axis: ZeRO-style shard
    "seq_res": None,              # block-boundary residual stream: map to
                                  # "model" for Megatron sequence parallelism
                                  # (GSPMD turns the TP all-reduce into
                                  # reduce-scatter + all-gather)
    "mamba_inner": "model",
    "lstm_inner": "model",
    "kv_lora": None,
    "conv": None,
    "layers": None,               # stacked-scan leading axis
}


def _get(name, default=None):
    return getattr(_state, name, default)


@contextmanager
def mesh_context(mesh: Optional[Mesh], rules: Optional[LogicalRules] = None):
    old_mesh, old_rules = _get("mesh"), _get("rules")
    _state.mesh = mesh
    _state.rules = dict(rules) if rules is not None else dict(DEFAULT_RULES)
    try:
        yield
    finally:
        _state.mesh = old_mesh
        _state.rules = old_rules


@contextmanager
def axis_rules(rules: LogicalRules):
    """Override only the rules (mesh unchanged)."""
    old = _get("rules")
    _state.rules = dict(rules)
    try:
        yield
    finally:
        _state.rules = old


def current_mesh() -> Optional[Mesh]:
    return _get("mesh")


def current_rules() -> LogicalRules:
    return _get("rules") or dict(DEFAULT_RULES)


def _mesh_axes(entry: MeshAxis, mesh: Mesh) -> MeshAxis:
    """Drop mesh axes that don't exist (e.g. 'pod' on a single-pod mesh)."""
    names = mesh.axis_names
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in names else None
    kept = tuple(a for a in entry if a in names)
    return kept if len(kept) > 1 else (kept[0] if kept else None)


def spec_for(axes: Sequence[Optional[str]],
             rules: Optional[LogicalRules] = None,
             mesh: Optional[Mesh] = None) -> PS:
    """PartitionSpec for a tuple of logical axis names."""
    mesh = mesh or current_mesh()
    rules = rules or current_rules()
    if mesh is None:
        return PS()
    used = set()
    parts = []
    for ax in axes:
        entry = _mesh_axes(rules.get(ax), mesh) if ax is not None else None
        # a mesh axis may appear at most once in a PartitionSpec
        if entry is not None:
            flat = (entry,) if isinstance(entry, str) else tuple(entry)
            flat = tuple(a for a in flat if a not in used)
            used.update(flat)
            entry = flat if len(flat) > 1 else (flat[0] if flat else None)
        parts.append(entry)
    return PS(*parts)


def logical_sharding(axes: Sequence[Optional[str]],
                     mesh: Optional[Mesh] = None,
                     rules: Optional[LogicalRules] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(axes, rules, mesh))


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes, mesh=mesh)))
