from .synthetic import DataConfig, PrefetchingLoader, SyntheticLM

__all__ = ["DataConfig", "PrefetchingLoader", "SyntheticLM"]
