"""Deterministic synthetic token pipeline.

Step-indexed (stateless) generation: batch ``i`` is a pure function of
(seed, step, shard), so elastic restarts replay the stream exactly — the
fault-tolerance contract (DESIGN.md §5).  A real-corpus loader would plug in
behind the same ``DataSource`` protocol.
"""

from __future__ import annotations

import threading
import queue as queue_mod
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # markov-chain-ish structure so the LM has something learnable
    n_patterns: int = 97


class SyntheticLM:
    """Learnable synthetic text: tokens follow a seeded affine recurrence
    ``t_{i+1} = (a * t_i + b) % vocab`` with per-sequence (a, b) drawn from a
    small pattern set — a few hundred steps of training measurably reduce
    loss (used by examples/train_tinylm.py)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.pat_a = rng.integers(1, cfg.vocab - 1, cfg.n_patterns)
        self.pat_b = rng.integers(0, cfg.vocab - 1, cfg.n_patterns)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        pat = rng.integers(0, cfg.n_patterns, B)
        a = self.pat_a[pat][:, None].astype(np.int64)
        b = self.pat_b[pat][:, None].astype(np.int64)
        t0 = rng.integers(0, cfg.vocab, (B, 1)).astype(np.int64)
        toks = np.empty((B, S), np.int64)
        toks[:, :1] = t0
        for i in range(1, S):
            toks[:, i: i + 1] = (a * toks[:, i - 1: i] + b) % cfg.vocab
        return {
            "tokens": toks.astype(np.int32),
            "loss_mask": np.ones((B, S), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchingLoader:
    """Background-thread prefetch (double buffering the host->device copy)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.step = start_step
        self.q: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            try:
                self.q.put(self.source.batch_at(s), timeout=0.5)
                s += 1
            except queue_mod.Full:
                continue

    def __next__(self):
        item = self.q.get()
        self.step += 1
        return item

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
