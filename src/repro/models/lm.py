"""LM drivers: decoder-only, enc-dec (whisper), VLM-backbone (llava).

Layers are scanned over the smallest repeating period of the block-spec
sequence (HLO stays O(period) — a 60-layer 236B model lowers as one scan body
plus remainder), with optional rematerialization per period.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import shard

from .blocks import block_apply, block_init, init_cache_for_block
from .config import ModelConfig
from .layers import Param, is_param, param_values, rmsnorm, rmsnorm_init, _init

REMAT_POLICIES = {
    "none": None,
    "full": "full",
    "dots": "dots",
}


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def _stack_params(trees: List[Any]):
    def stack(*leaves: Param) -> Param:
        return Param(jnp.stack([l.value for l in leaves]),
                     ("layers",) + tuple(leaves[0].axes))
    return jax.tree.map(stack, *trees, is_leaf=is_param)


def _layer_groups(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(prefix, period, reps, remainder) — see ModelConfig.layout()."""
    return cfg.layout()


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def lm_init(key, cfg: ModelConfig):
    dtype = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.n_layers + 4)
    specs = cfg.block_specs()
    pre, p, reps, rem = _layer_groups(cfg)

    params: Dict[str, Any] = {
        "embed": _init(keys[0], (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                       scale=1.0, dtype=dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = _init(keys[1], (cfg.d_model, cfg.vocab),
                               ("embed", "vocab"), dtype=dtype)
    if cfg.frontend != "none":
        # modality frontend STUB: a projection applied to precomputed
        # frame/patch embeddings supplied by input_specs()
        params["frontend_proj"] = _init(
            keys[2], (cfg.d_model, cfg.d_model), ("embed", None), dtype=dtype)

    if cfg.is_encdec:
        enc_keys = jax.random.split(keys[3], cfg.n_enc_layers)
        enc_spec = specs[0]
        params["encoder"] = _stack_params(
            [block_init(k, cfg, enc_spec, dtype) for k in enc_keys])
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
        dec_keys = jax.random.split(jax.random.fold_in(key, 999),
                                    cfg.n_layers)
        params["dec_cross"] = _stack_params(
            [_cross_block_init(k, cfg, dtype) for k in dec_keys])

    params["pre"] = {f"q{j}": block_init(keys[4 + j], cfg, specs[j], dtype)
                     for j in range(pre)}
    # scanned periods
    scan_params = {}
    for pos in range(p):
        trees = [block_init(keys[4 + pre + r * p + pos], cfg,
                            specs[pre + pos], dtype)
                 for r in range(reps)]
        scan_params[f"p{pos}"] = _stack_params(trees)
    params["scan"] = scan_params
    rest = {}
    for j in range(rem):
        li = pre + reps * p + j
        rest[f"r{j}"] = block_init(keys[4 + li], cfg, specs[li], dtype)
    params["rest"] = rest
    return params


def _cross_block_init(key, cfg: ModelConfig, dtype):
    from .layers import attention_init
    return {
        "norm": rmsnorm_init(cfg.d_model, dtype),
        "attn": attention_init(key, cfg, dtype),
    }


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    raise ValueError(cfg.remat)


def lm_apply(
    values,
    cfg: ModelConfig,
    tokens: jnp.ndarray,                    # [B, S_text]
    positions: Optional[jnp.ndarray] = None,
    extra_embeds: Optional[jnp.ndarray] = None,  # [B, S_img, d] frontend stub
    caches: Optional[Dict] = None,
    logits_dtype=jnp.float32,
):
    """Returns (logits [B,S,V], new_caches, aux_loss)."""
    cdtype = _dtype(cfg.compute_dtype)
    specs = cfg.block_specs()
    pre, p, reps, rem = _layer_groups(cfg)

    x = jnp.take(values["embed"], tokens, axis=0).astype(cdtype)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cdtype)
    if extra_embeds is not None:
        pe = extra_embeds.astype(cdtype) @ values["frontend_proj"].astype(cdtype)
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    x = shard(x, "batch", "seq", None)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def cast(tree):
        return jax.tree.map(lambda v: v.astype(cdtype)
                            if jnp.issubdtype(v.dtype, jnp.floating) else v,
                            tree)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = None if caches is None else {"pre": {}, "rest": {}}

    # ---- unrolled prefix (e.g. DeepSeek's first dense layer) --------------
    for j in range(pre):
        cache_j = None if caches is None else caches["pre"][f"q{j}"]
        x, nc, a = block_apply(cast(values["pre"][f"q{j}"]), cfg, specs[j],
                               x, positions, cache=cache_j)
        aux_total = aux_total + a
        if caches is not None:
            new_caches["pre"][f"q{j}"] = nc

    # ---- scanned periods ---------------------------------------------------
    scan_vals = cast(values["scan"])
    if caches is None:
        def period_body(x, layer_vals):
            aux = jnp.zeros((), jnp.float32)
            for pos in range(p):
                x, _, a = block_apply(layer_vals[f"p{pos}"], cfg,
                                      specs[pre + pos], x, positions)
                aux = aux + a
            return x, aux

        body = _maybe_remat(period_body, cfg)
        if reps:
            x, auxs = lax.scan(lambda c, lv: body(c, lv), x, scan_vals)
            aux_total = aux_total + auxs.sum()
    else:
        def period_body_c(x, inp):
            layer_vals, cache_slice = inp
            aux = jnp.zeros((), jnp.float32)
            new_slice = {}
            for pos in range(p):
                x, nc, a = block_apply(layer_vals[f"p{pos}"], cfg,
                                       specs[pre + pos], x, positions,
                                       cache=cache_slice[f"p{pos}"])
                new_slice[f"p{pos}"] = nc
                aux = aux + a
            return x, (new_slice, aux)

        if reps:
            x, (new_scan_caches, auxs) = lax.scan(
                period_body_c, x, (scan_vals, caches["scan"]))
            aux_total = aux_total + auxs.sum()
        else:
            new_scan_caches = caches["scan"]
        new_caches["scan"] = new_scan_caches

    # ---- unrolled remainder -------------------------------------------------
    for j in range(rem):
        li = pre + reps * p + j
        cache_j = None if caches is None else caches["rest"][f"r{j}"]
        x, nc, a = block_apply(cast(values["rest"][f"r{j}"]), cfg, specs[li],
                               x, positions, cache=cache_j)
        aux_total = aux_total + a
        if caches is not None:
            new_caches["rest"][f"r{j}"] = nc

    x = rmsnorm(values["final_norm"], x, cfg.norm_eps)
    head = (values["embed"].T if cfg.tie_embeddings else values["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdtype))
    if cfg.logit_softcap:
        cap = cfg.logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    logits = shard(logits.astype(logits_dtype), "batch", "seq", "vocab")
    return logits, new_caches, aux_total


# ---------------------------------------------------------------------------
# whisper-style enc-dec
# ---------------------------------------------------------------------------

def encdec_apply(
    values,
    cfg: ModelConfig,
    frames: jnp.ndarray,                    # [B, S_enc, d] precomputed (stub)
    tokens: jnp.ndarray,                    # [B, S_dec]
    positions: Optional[jnp.ndarray] = None,
    caches: Optional[Dict] = None,
    enc_out: Optional[jnp.ndarray] = None,  # reuse from prefill during decode
    logits_dtype=jnp.float32,
):
    """Returns (logits, new_caches, enc_out, aux)."""
    cdtype = _dtype(cfg.compute_dtype)
    specs = cfg.block_specs()
    B = tokens.shape[0]

    # --- encoder (bidirectional attention over frames) -------------------
    if enc_out is None:
        h = frames.astype(cdtype) @ values["frontend_proj"].astype(cdtype)
        h = shard(h, "batch", "seq", None)
        epos = jnp.broadcast_to(jnp.arange(h.shape[1])[None, :],
                                (B, h.shape[1]))
        enc_vals = jax.tree.map(lambda v: v.astype(cdtype)
                                if jnp.issubdtype(v.dtype, jnp.floating) else v,
                                values["encoder"])

        # bidirectional: emulate by attending with an all-true mask via
        # kv_source trick (see attention_apply: cross-attn mask is full)
        def enc_body_bidir(x, layer_vals):
            x, _, _ = block_apply(layer_vals, cfg, specs[0], x, epos,
                                  kv_source=x)
            return x, ()

        h, _ = lax.scan(enc_body_bidir, h, enc_vals)
        enc_out = rmsnorm(values["enc_norm"], h, cfg.norm_eps)

    # --- decoder: self-attn (cached) + cross-attn + ffn -------------------
    x = jnp.take(values["embed"], tokens, axis=0).astype(cdtype)
    S = x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = shard(x, "batch", "seq", None)

    dec_vals = jax.tree.map(lambda v: v.astype(cdtype)
                            if jnp.issubdtype(v.dtype, jnp.floating) else v,
                            values["scan"])
    cross_vals = jax.tree.map(lambda v: v.astype(cdtype)
                              if jnp.issubdtype(v.dtype, jnp.floating) else v,
                              values["dec_cross"])

    from .layers import attention_apply

    def dec_body(x, inp):
        if caches is None:
            layer_vals, cross = inp
            cache_slice = None
        else:
            layer_vals, cross, cache_slice = inp
        x, nc, _ = block_apply(layer_vals["p0"], cfg, specs[0], x, positions,
                               cache=(None if cache_slice is None
                                      else cache_slice["p0"]))
        hh = rmsnorm(cross["norm"], x, cfg.norm_eps)
        co, _ = attention_apply(cross["attn"], cfg, hh, positions,
                                kv_source=enc_out)
        x = x + co
        if caches is None:
            return x, ()
        return x, {"p0": nc}

    if caches is None:
        x, _ = lax.scan(dec_body, x, (dec_vals, cross_vals))
        new_caches = None
    else:
        x, new_scan = lax.scan(dec_body, x,
                               (dec_vals, cross_vals, caches["scan"]))
        new_caches = {"pre": {}, "scan": new_scan, "rest": {}}

    x = rmsnorm(values["final_norm"], x, cfg.norm_eps)
    head = (values["embed"].T if cfg.tie_embeddings else values["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cdtype))
    logits = shard(logits.astype(logits_dtype), "batch", "seq", "vocab")
    return logits, new_caches, enc_out, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    specs = cfg.block_specs()
    pre, p, reps, rem = _layer_groups(cfg)

    def stack_caches(pos):
        one = init_cache_for_block(cfg, specs[pre + pos], batch, max_len, dtype)
        return jax.tree.map(
            lambda v: jnp.broadcast_to(v, (reps,) + v.shape).copy(), one)

    return {
        "pre": {f"q{j}": init_cache_for_block(cfg, specs[j], batch, max_len,
                                              dtype)
                for j in range(pre)},
        "scan": ({f"p{pos}": stack_caches(pos) for pos in range(p)}
                 if reps else {}),
        "rest": {f"r{j}": init_cache_for_block(cfg, specs[pre + reps * p + j],
                                               batch, max_len, dtype)
                 for j in range(rem)},
    }


def cache_axes(cfg: ModelConfig):
    """Logical-axes tree parallel to init_caches (scan adds a layers dim)."""
    from .blocks import cache_axes_for_block

    specs = cfg.block_specs()
    pre, p, reps, rem = _layer_groups(cfg)

    def stacked(pos):
        one = cache_axes_for_block(cfg, specs[pre + pos])
        return jax.tree.map(lambda ax: ("layers",) + tuple(ax), one,
                            is_leaf=lambda x: isinstance(x, tuple))

    return {
        "pre": {f"q{j}": cache_axes_for_block(cfg, specs[j])
                for j in range(pre)},
        "scan": ({f"p{pos}": stacked(pos) for pos in range(p)}
                 if reps else {}),
        "rest": {f"r{j}": cache_axes_for_block(cfg, specs[pre + reps * p + j])
                 for j in range(rem)},
    }


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------

def lm_loss(values, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    """Next-token cross entropy.  batch: tokens [B,S], loss_mask [B,S],
    optional extra_embeds (frontend stub; prepended positions carry no loss)."""
    extra = batch.get("extra_embeds")
    if cfg.is_encdec:
        logits, _, _, aux = encdec_apply(values, cfg, batch["frames"],
                                         batch["tokens"])
    else:
        logits, _, aux = lm_apply(values, cfg, batch["tokens"],
                                  extra_embeds=extra)
        if extra is not None:
            logits = logits[:, extra.shape[1]:, :]
    tgt = batch["tokens"][:, 1:]
    lgt = logits[:, :-1, :].astype(jnp.float32)
    mask = batch["loss_mask"][:, 1:].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lgt, axis=-1)
    gold = jnp.take_along_axis(lgt, tgt[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    # z-loss stabilizer (PaLM): keeps logsumexp near 0
    zloss = 1e-4 * jnp.mean(jnp.square(logz) * mask)
    return loss + zloss + aux, {"loss": loss, "aux": aux,
                                "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}
