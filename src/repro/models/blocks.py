"""Transformer/SSM blocks: pre-norm mixer + pre-norm FFN/MoE, by BlockSpec."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import (
    ATTN,
    ATTN_LOCAL,
    ATTN_MLA,
    FFN_DENSE,
    FFN_MOE,
    FFN_MOE_RESIDUAL,
    FFN_NONE,
    MAMBA,
    MLSTM,
    SLSTM,
    BlockSpec,
    ModelConfig,
)
from .layers import (
    attention_apply,
    attention_init,
    ffn_apply,
    ffn_init,
    mamba_apply,
    mamba_init,
    mla_apply,
    mla_init,
    mlstm_apply,
    mlstm_init,
    moe_apply,
    moe_init,
    rmsnorm,
    rmsnorm_init,
    slstm_apply,
    slstm_init,
)


def block_init(key, cfg: ModelConfig, spec: BlockSpec, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if spec.mixer in (ATTN, ATTN_LOCAL):
        p["mixer"] = attention_init(k1, cfg, dtype)
    elif spec.mixer == ATTN_MLA:
        p["mixer"] = mla_init(k1, cfg, dtype)
    elif spec.mixer == MAMBA:
        p["mixer"] = mamba_init(k1, cfg, dtype)
    elif spec.mixer == MLSTM:
        p["mixer"] = mlstm_init(k1, cfg, dtype)
    elif spec.mixer == SLSTM:
        p["mixer"] = slstm_init(k1, cfg, dtype)
    else:
        raise ValueError(spec.mixer)

    if spec.ffn != FFN_NONE:
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
    if spec.ffn == FFN_DENSE:
        p["ffn"] = ffn_init(k2, cfg.d_model, cfg.d_ff, dtype)
    elif spec.ffn == FFN_MOE:
        p["moe"] = moe_init(k2, cfg, dtype)
    elif spec.ffn == FFN_MOE_RESIDUAL:
        p["moe"] = moe_init(k2, cfg, dtype)
        p["ffn"] = ffn_init(k3, cfg.d_model, cfg.d_ff, dtype)
    return p


def block_apply(
    params,
    cfg: ModelConfig,
    spec: BlockSpec,
    x,
    positions,
    cache: Optional[Dict] = None,
    kv_source: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x, new_cache_or_state, aux_loss)."""
    from repro.parallel.sharding import shard

    # Megatron-SP: the residual stream lives seq-sharded between blocks (a
    # no-op unless the "seq_res" rule maps to a mesh axis); the norm runs on
    # the shard, the mixer/FFN gather the sequence and their TP outputs
    # reduce-scatter back.
    x = shard(x, "batch", "seq_res", None)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    h = shard(h, "batch", None, None)
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer in (ATTN, ATTN_LOCAL):
        window = cfg.sliding_window if spec.mixer == ATTN_LOCAL else 0
        out, new_cache = attention_apply(params["mixer"], cfg, h, positions,
                                         window=window, cache=cache,
                                         kv_source=kv_source)
    elif spec.mixer == ATTN_MLA:
        out, new_cache = mla_apply(params["mixer"], cfg, h, positions,
                                   cache=cache)
    elif spec.mixer == MAMBA:
        out, new_cache = mamba_apply(params["mixer"], cfg, h, state=cache)
    elif spec.mixer == MLSTM:
        out, new_cache = mlstm_apply(params["mixer"], cfg, h, state=cache)
    elif spec.mixer == SLSTM:
        out, new_cache = slstm_apply(params["mixer"], cfg, h, state=cache)
    else:
        raise ValueError(spec.mixer)
    x = x + shard(out, "batch", "seq_res", None)

    if spec.ffn != FFN_NONE:
        h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        h = shard(h, "batch", None, None)
        if spec.ffn == FFN_DENSE:
            x = x + shard(ffn_apply(params["ffn"], h, cfg.act),
                          "batch", "seq_res", None)
        elif spec.ffn == FFN_MOE:
            mo, aux = moe_apply(params["moe"], cfg, h, cfg.act)
            x = x + shard(mo, "batch", "seq_res", None)
        elif spec.ffn == FFN_MOE_RESIDUAL:  # Arctic: dense residual || MoE
            mo, aux = moe_apply(params["moe"], cfg, h, cfg.act)
            x = x + shard(mo + ffn_apply(params["ffn"], h, cfg.act),
                          "batch", "seq_res", None)
    return x, new_cache, aux


def init_cache_for_block(cfg: ModelConfig, spec: BlockSpec, batch: int,
                         max_len: int, dtype=jnp.bfloat16) -> Optional[Dict]:
    """Decode-time cache/state skeleton for one layer."""
    if spec.mixer in (ATTN, ATTN_LOCAL):
        window = cfg.sliding_window if spec.mixer == ATTN_LOCAL else 0
        T = min(max_len, window) if window else max_len  # ring for local layers
        return {
            "k": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, T, cfg.n_kv_heads, cfg.v_dim), dtype),
            "pos": jnp.full((T,), -1, jnp.int32),
            "len": jnp.zeros((), jnp.int32),
        }
    if spec.mixer == ATTN_MLA:
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, 1, cfg.rope_head_dim), dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    if spec.mixer == MAMBA:
        di = cfg.mamba_expand * cfg.d_model
        return {
            "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
        }
    if spec.mixer == MLSTM:
        di = 2 * cfg.d_model
        dh = di // cfg.n_heads
        return {
            "C": jnp.zeros((batch, cfg.n_heads, dh, dh), jnp.float32),
            "N": jnp.zeros((batch, cfg.n_heads, dh), jnp.float32),
            "conv": jnp.zeros((batch, 3, di), dtype),
        }
    if spec.mixer == SLSTM:
        dh = cfg.d_model // cfg.n_heads
        z = jnp.zeros((batch, cfg.n_heads, dh), jnp.float32)
        return {"c": z, "n": z + 1e-6, "h": z, "m": z - 10.0}
    raise ValueError(spec.mixer)


def cache_axes_for_block(cfg: ModelConfig, spec: BlockSpec) -> Optional[Dict]:
    """Logical axes parallel to init_cache_for_block's value tree."""
    if spec.mixer in (ATTN, ATTN_LOCAL):
        return {
            "k": ("batch", "seq_kv", "kv_heads", None),
            "v": ("batch", "seq_kv", "kv_heads", None),
            "pos": ("seq_kv",),
            "len": (),
        }
    if spec.mixer == ATTN_MLA:
        return {
            "ckv": ("batch", "seq_kv", "kv_lora"),
            "k_rope": ("batch", "seq_kv", None, None),
            "len": (),
        }
    if spec.mixer == MAMBA:
        return {"conv": ("batch", None, "mamba_inner"),
                "ssm": ("batch", "mamba_inner", None)}
    if spec.mixer == MLSTM:
        return {"C": ("batch", None, None, None),
                "N": ("batch", None, None),
                "conv": ("batch", None, "lstm_inner")}
    if spec.mixer == SLSTM:
        ax = ("batch", None, None)
        return {"c": ax, "n": ax, "h": ax, "m": ax}
    raise ValueError(spec.mixer)
