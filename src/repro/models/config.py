"""Model configuration schema covering all 10 assigned architectures.

Layer structure is encoded per-layer as a :class:`BlockSpec` (mixer kind +
ffn kind); the model driver finds the smallest repeating period of the
block-spec sequence and scans over it (HLO stays O(period), essential for the
dry-run of 60-layer 236B-parameter configs).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Tuple

# mixer kinds
ATTN = "attn"            # global attention (GQA)
ATTN_LOCAL = "attn_local"  # sliding-window attention
ATTN_MLA = "attn_mla"    # multi-head latent attention (DeepSeek-V2)
MAMBA = "mamba"          # selective SSM
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block

# ffn kinds
FFN_DENSE = "dense"      # SwiGLU (or GELU) MLP
FFN_MOE = "moe"          # routed experts (+ optional shared experts)
FFN_MOE_RESIDUAL = "moe_residual"  # dense MLP in parallel with MoE (Arctic)
FFN_NONE = "none"        # block has no separate FFN (xLSTM)


@dataclass(frozen=True)
class BlockSpec:
    mixer: str
    ffn: str

    @property
    def code(self) -> str:
        return f"{self.mixer}/{self.ffn}"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads

    # attention
    rope_theta: float = 10_000.0
    sliding_window: int = 4096
    local_global_period: int = 0  # k: (k-1) local + 1 global per period
    qk_norm: bool = False
    logit_softcap: float = 0.0

    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0          # 0 -> d_head

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0
    moe_every: int = 1           # MoE on layers where idx % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # hybrid / ssm
    attn_every: int = 0          # jamba: attention on idx % attn_every == attn_offset
    attn_offset: int = 0
    slstm_every: int = 0         # xlstm: sLSTM on idx % slstm_every == slstm_offset
    slstm_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # enc-dec (whisper)
    is_encdec: bool = False
    n_enc_layers: int = 0

    # modality frontend stub
    frontend: str = "none"       # none | audio_frames | vision_patches
    n_frontend_tokens: int = 0

    # attention execution (consumption-centric chunking; 0 = always dense)
    attn_chunk: int = 1024

    # numerics / training
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    act: str = "silu"            # silu | gelu
    param_dtype: str = "float32"
    opt_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "none"          # none | full | offload-style policies

    # ----------------------------------------------------------------- #
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def v_dim(self) -> int:
        return self.v_head_dim or self.head_dim

    def mixer_kind(self, idx: int) -> str:
        if self.attn_every:  # hybrid (jamba): mostly mamba, periodic attention
            if idx % self.attn_every == self.attn_offset:
                return ATTN
            return MAMBA
        if self.family == "ssm":
            if self.slstm_every and idx % self.slstm_every == self.slstm_offset:
                return SLSTM
            return MLSTM
        if self.kv_lora_rank:
            return ATTN_MLA
        if self.local_global_period:
            k = self.local_global_period
            return ATTN if idx % k == k - 1 else ATTN_LOCAL
        return ATTN

    def ffn_kind(self, idx: int) -> str:
        if self.d_ff == 0 and not self.n_experts:
            return FFN_NONE
        if not self.n_experts:
            return FFN_DENSE
        if idx < self.first_k_dense:
            return FFN_DENSE
        if idx % self.moe_every == self.moe_offset:
            return (FFN_MOE_RESIDUAL
                    if self.family == "moe" and self.d_ff and self._arctic
                    else FFN_MOE)
        return FFN_DENSE

    @property
    def _arctic(self) -> bool:
        return "arctic" in self.name

    def block_specs(self) -> List[BlockSpec]:
        return [BlockSpec(self.mixer_kind(i), self.ffn_kind(i))
                for i in range(self.n_layers)]

    def period(self) -> int:
        """Smallest repeating period of the block-spec sequence."""
        return self.layout()[1]

    def layout(self) -> Tuple[int, int, int, int]:
        """(prefix, period, reps, remainder): ``prefix`` unrolled layers (e.g.
        DeepSeek's first dense layer), then ``reps`` scans over a
        ``period``-layer body, then ``remainder`` unrolled layers.  Chosen to
        minimize unrolled HLO (prefix + period + remainder)."""
        specs = [s.code for s in self.block_specs()]
        n = len(specs)

        def smallest_period(seq) -> int:
            m = len(seq)
            for p in range(1, m + 1):
                if all(seq[i] == seq[i % p] for i in range(m)):
                    return p
            return m

        best = None
        for f in range(min(n, 8)):  # prefixes beyond a few layers never help
            tail = specs[f:]
            if not tail:
                break
            p = smallest_period(tail)
            reps = len(tail) // p
            rem = len(tail) % p
            score = f + p + rem
            if best is None or score < best[0]:
                best = (score, f, p, reps, rem)
        _, f, p, reps, rem = best
        return f, p, reps, rem

    # -- parameter counting (for roofline MODEL_FLOPS) --------------------
    def param_count(self) -> int:
        return sum(self._layer_params(i) for i in range(self.n_layers)) + \
            self._embed_params()

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared only)."""
        total = self._embed_params()
        for i in range(self.n_layers):
            total += self._layer_params(i, active_only=True)
        return total

    def _embed_params(self) -> int:
        n = self.vocab * self.d_model
        if not self.tie_embeddings:
            n *= 2
        if self.is_encdec:
            n += self.n_frontend_tokens and 0
        return n

    def _mixer_params(self, kind: str) -> int:
        d = self.d_model
        if kind in (ATTN, ATTN_LOCAL):
            q = d * self.n_heads * self.head_dim
            kv = 2 * d * self.n_kv_heads * self.head_dim
            o = self.n_heads * self.v_dim * d
            return q + kv + o
        if kind == ATTN_MLA:
            qa = d * self.q_lora_rank if self.q_lora_rank else 0
            qb = (self.q_lora_rank or d) * self.n_heads * (
                self.head_dim + self.rope_head_dim)
            kva = d * (self.kv_lora_rank + self.rope_head_dim)
            kvb = self.kv_lora_rank * self.n_heads * (self.head_dim + self.v_dim)
            o = self.n_heads * self.v_dim * d
            return qa + qb + kva + kvb + o
        if kind == MAMBA:
            di = self.mamba_expand * d
            return (d * 2 * di + di * self.mamba_d_conv
                    + di * (2 * self.mamba_d_state + 2) + di * self.mamba_d_state
                    + di * d)
        if kind == MLSTM:
            di = 2 * d
            return d * 2 * di + 3 * di * di // 4 + di + di * 4 + di // 2 + di * d
        if kind == SLSTM:
            dh = d // max(self.n_heads, 1)
            rec = 4 * self.n_heads * dh * dh
            inp = 4 * d * d
            dff = max(128, ((int(d * 4 / 3) + 127) // 128) * 128)
            ffp = 3 * d * dff
            return rec + inp + ffp
        raise ValueError(kind)

    def _ffn_params(self, kind: str, active_only: bool = False) -> int:
        d = self.d_model
        dense = 3 * d * self.d_ff  # SwiGLU: up, gate, down
        if kind == FFN_NONE:
            return 0
        if kind == FFN_DENSE:
            return dense
        expert = 3 * d * self.d_ff_expert
        router = d * self.n_experts
        n_routed = self.top_k if active_only else self.n_experts
        moe = n_routed * expert + self.n_shared_experts * expert + router
        if kind == FFN_MOE_RESIDUAL:
            moe += dense
        return moe

    def _layer_params(self, idx: int, active_only: bool = False) -> int:
        return (self._mixer_params(self.mixer_kind(idx))
                + self._ffn_params(self.ffn_kind(idx), active_only)
                + 2 * self.d_model)  # norms

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_head=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=256,
        q_lora_rank=24 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        rope_head_dim=8 if cfg.kv_lora_rank else 64,
        v_head_dim=16 if cfg.v_head_dim else 0,
        n_experts=min(cfg.n_experts, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        d_ff_expert=32 if cfg.d_ff_expert else 0,
        sliding_window=16 if cfg.local_global_period else cfg.sliding_window,
        mamba_d_state=8,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_frontend_tokens=8 if cfg.n_frontend_tokens else 0,
        first_k_dense=min(cfg.first_k_dense, 1),
        param_dtype="float32",
        opt_dtype="float32",
        compute_dtype="float32",
    )
    kw.update(overrides)
    return cfg.with_(**kw)
