"""Functional layers for the model zoo (no flax — plain pytrees).

Every ``*_init`` returns a pytree whose leaves are :class:`Param` (value +
logical axes); ``*_apply`` consumes the matching *value* tree.  Sharding
annotations use logical names resolved through repro.parallel.sharding rules.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import shard

from .config import ModelConfig


class Param:
    """A parameter leaf: value + logical axes.  Registered as a pytree node
    with ``axes`` as static metadata, so trees of Params flow through
    jax.eval_shape / tree.map while the sharding annotation rides along —
    abstract init of the 480B configs never allocates."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[Optional[str], ...]):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        return f"Param({getattr(self.value, 'shape', self.value)}, {self.axes})"


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: Param(children[0], axes),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def param_values(tree):
    return jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)


def param_axes(tree):
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def _init(key, shape, axes, scale=None, dtype=jnp.float32) -> Param:
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    val = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    return Param(val.astype(dtype), axes)


def _zeros(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def _ones(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": _ones((d,), ("embed",), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, dh] (rotates the last dim); positions: [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                          # [dh/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..,S,1,dh/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, KV cache)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, h, kh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh, dv = cfg.head_dim, cfg.v_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, h, dh), ("embed", "heads", "head_dim"), dtype=dtype),
        "wk": _init(ks[1], (d, kh, dh), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wv": _init(ks[2], (d, kh, dv), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wo": _init(ks[3], (h, dv, d), ("heads", "head_dim", "embed"),
                    scale=1.0 / math.sqrt(h * dv), dtype=dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = rmsnorm_init(dh, dtype)
        p["knorm"] = rmsnorm_init(dh, dtype)
    return p


def _attend(q, k, v, mask, softcap: float = 0.0,
            scale: Optional[float] = None):
    """Dense path (short sequences / decode steps).
    q: [B,S,Kh,G,dh]  k: [B,T,Kh,dh]  v: [B,T,Kh,dv]  mask: [B?,S,T]."""
    scale = scale or 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out


def _pick_chunk(n: int, target: int, floor: int = 128) -> int:
    """Largest divisor of n that is <= target (0 if none >= floor)."""
    c = 0
    for d in range(1, int(math.isqrt(n)) + 1):
        if n % d == 0:
            for cand in (d, n // d):
                if floor <= cand <= target and cand > c:
                    c = cand
    return c


def _attend_chunked(q, k, v, qpos, kpos, causal: bool, window: int,
                    softcap: float, cq: int, ck: int,
                    scale: Optional[float] = None):
    """Online-softmax chunked attention — the consumption-centric scheme in
    portable jnp (on TPU the Pallas kernel in repro.kernels is the fused
    version; this path gives identical memory behaviour under XLA: the S x T
    score matrix never materializes, peak extra memory is B*cq*H*ck).

    q: [B,S,Kh,G,dh]  k: [B,T,Kh,dh]  v: [B,T,Kh,dv]
    qpos: [B,S]  kpos: [T]  ->  [B,S,Kh,G,dv]
    """
    B, S, K, G, dh = q.shape
    T = k.shape[1]
    dv = v.shape[-1]
    scale = scale or 1.0 / math.sqrt(dh)
    nq, nk = S // cq, T // ck

    qc = jnp.moveaxis(q.reshape(B, nq, cq, K, G, dh), 1, 0)
    qp = jnp.moveaxis(qpos.reshape(B, nq, cq), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, ck, K, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, K, dv), 1, 0)
    kp = kpos.reshape(nk, ck)

    def kv_block(st, blk):
        m, l, acc, qi, qpi = st
        kj, vj, kpj = blk
        s = jnp.einsum("bqkgd,btkd->bqkgt", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mask = (kpj >= 0)[None, None, :] & jnp.ones((B, cq, ck), bool)
        if causal:
            mask &= kpj[None, None, :] <= qpi[:, :, None]
        if window:
            mask &= kpj[None, None, :] > qpi[:, :, None] - window
        mask = mask[:, :, None, None, :]
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqkgt,btkv->bqkgv", p, vj.astype(jnp.float32))
        return (m_new, l, acc, qi, qpi), None

    kv_block = jax.checkpoint(kv_block)

    def q_block(_, blk):
        qi, qpi = blk
        m0 = jnp.full((B, cq, K, G), -1e30, jnp.float32)
        l0 = jnp.zeros((B, cq, K, G), jnp.float32)
        a0 = jnp.zeros((B, cq, K, G, dv), jnp.float32)
        (m, l, acc, _, _), _ = lax.scan(kv_block, (m0, l0, a0, qi, qpi),
                                        (kc, vc, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(v.dtype)

    _, out = lax.scan(q_block, None, (qc, qp))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, K, G, dv)


def _dispatch_attend(q, k, v, qpos, kpos, causal, window, softcap,
                     chunk: int, scale=None):
    """Choose chunked (long-seq training/prefill) vs dense attention.
    ``kpos`` [T] carries absolute key positions (-1 = empty ring slot)."""
    S, T = q.shape[1], k.shape[1]
    cq = _pick_chunk(S, chunk) if chunk else 0
    ck = _pick_chunk(T, max(chunk, 1) * 2) if chunk else 0
    if cq and ck and S >= chunk and T > ck:
        return _attend_chunked(q, k, v, qpos, kpos, causal, window, softcap,
                               cq, ck, scale)
    kp = kpos[None, :]
    mask = (kp >= 0)[:, None, :] & jnp.ones((1, S, T), bool)
    if causal:
        mask = mask & (kp[:, None, :] <= qpos[..., None])
    if window:
        mask = mask & (kp[:, None, :] > qpos[..., None] - window)
    return _attend(q, k, v, mask, softcap, scale)


def attention_apply(params, cfg: ModelConfig, x, positions,
                    window: int = 0, cache: Optional[Dict] = None,
                    kv_source: Optional[jnp.ndarray] = None):
    """Returns (out, new_cache).  ``cache``: {"k","v","len"} for decode;
    ``kv_source``: cross-attention memory (whisper decoder)."""
    B, S, D = x.shape
    h, kh, dh, dv = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.v_dim
    g = h // kh
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    src = x if kv_source is None else kv_source
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"])
    if cfg.qk_norm:
        q = rmsnorm(params["qnorm"], q, cfg.norm_eps)
        k = rmsnorm(params["knorm"], k, cfg.norm_eps)
    if kv_source is None:  # self-attention: rotary on q & k
        q = apply_rope(q, positions, cfg.rope_theta)
        kpos = positions if cache is None else positions  # same positions
        k = apply_rope(k, kpos, cfg.rope_theta)
    q = shard(q.reshape(B, S, kh, g, dh), "batch", "seq", "kv_heads", None, None)

    new_cache = None
    if cache is not None:
        # ring-buffer cache: write at len % T.  For global layers T = max_len
        # (never wraps); for sliding-window layers T = window, so the slot
        # being overwritten is exactly the key that just left the window.
        # Batch-uniform positions assumed for decode (positions[0]).
        T = cache["k"].shape[1]
        if S >= T:
            # prefilling more tokens than the ring holds (windowed layers):
            # only the last T keys can matter; slot order is irrelevant since
            # masking reads the absolute positions buffer
            k_w, v_w = k[:, -T:], v[:, -T:]
            pos_w = positions[0, -T:]
            slot = jnp.zeros((), jnp.int32)
        else:
            k_w, v_w, pos_w = k, v, positions[0]
            slot = cache["len"] % T
        ck = lax.dynamic_update_slice(cache["k"],
                                      k_w.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"],
                                      v_w.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
        cpos = lax.dynamic_update_slice(
            cache["pos"], pos_w.astype(jnp.int32), (slot,))
        new_cache = {"k": ck, "v": cv, "pos": cpos, "len": cache["len"] + S}
        if S >= T:
            # prefill: attend over the full in-flight keys (queries at early
            # positions need keys the ring has already dropped)
            k_att, v_att, kpos = k, v, positions[0]
        else:
            k_att, v_att, kpos = ck, cv, cpos
    else:
        k_att, v_att = k, v
        kpos = jnp.arange(k.shape[1])

    k_att = shard(k_att, "batch", "seq_kv", "kv_heads", None)
    v_att = shard(v_att, "batch", "seq_kv", "kv_heads", None)
    if kv_source is not None:  # cross-attention: full visibility
        mask = jnp.ones((1, S, k_att.shape[1]), dtype=bool)
        out = _attend(q, k_att, v_att, mask, cfg.logit_softcap)
    else:
        out = _dispatch_attend(q, k_att, v_att, positions, kpos,
                               causal=True, window=window,
                               softcap=cfg.logit_softcap,
                               chunk=cfg.attn_chunk)
    out = out.reshape(B, S, h, dv)
    out = jnp.einsum("bshv,hvd->bsd", out, params["wo"])
    return shard(out, "batch", "seq", None), new_cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    dh, dv, r = cfg.head_dim, cfg.v_dim, cfg.rope_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    if qr:
        p["wdq"] = _init(ks[0], (d, qr), ("embed", None), dtype=dtype)
        p["q_norm"] = rmsnorm_init(qr, dtype)
        p["wuq"] = _init(ks[1], (qr, h, dh + r), (None, "heads", "head_dim"),
                         dtype=dtype)
    else:
        p["wuq"] = _init(ks[1], (d, h, dh + r), ("embed", "heads", "head_dim"),
                         dtype=dtype)
    p["wdkv"] = _init(ks[2], (d, kvr + r), ("embed", "kv_lora"), dtype=dtype)
    p["kv_norm"] = rmsnorm_init(kvr, dtype)
    p["wukv"] = _init(ks[3], (kvr, h, dh + dv), ("kv_lora", "heads", "head_dim"),
                      dtype=dtype)
    p["wo"] = _init(ks[4], (h, dv, d), ("heads", "head_dim", "embed"),
                    scale=1.0 / math.sqrt(h * dv), dtype=dtype)
    return p


def mla_apply(params, cfg: ModelConfig, x, positions,
              cache: Optional[Dict] = None):
    """Latent attention; decode caches the compressed (c_kv, k_rope) pair —
    the memory saving that makes 128-head attention serveable."""
    B, S, D = x.shape
    h, dh, dv, r = cfg.n_heads, cfg.head_dim, cfg.v_dim, cfg.rope_head_dim
    kvr = cfg.kv_lora_rank
    # queries
    if cfg.q_lora_rank:
        cq = rmsnorm(params["q_norm"], x @ params["wdq"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wuq"])
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # compressed kv + shared rope key
    ckv_full = x @ params["wdkv"]                            # [B,S,kvr+r]
    ckv = rmsnorm(params["kv_norm"], ckv_full[..., :kvr], cfg.norm_eps)
    k_rope = apply_rope(ckv_full[..., kvr:][:, :, None, :], positions,
                        cfg.rope_theta)                      # [B,S,1,r]

    new_cache = None
    if cache is not None:
        idx = cache["len"]
        c_ckv = lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, idx, 0))
        c_kr = lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, idx, 0, 0))
        new_cache = {"ckv": c_ckv, "k_rope": c_kr, "len": idx + S}
        ckv, k_rope = c_ckv, c_kr
    T = ckv.shape[1]

    kv = jnp.einsum("btr,rhk->bthk", ckv, params["wukv"])
    k_nope, v = kv[..., :dh], kv[..., dh:]

    # fold the shared rope key into a single (dh + r)-dim head and reuse the
    # generic (chunked) attention path — MHA with Kh = h, G = 1.  With a
    # cache, slots beyond len hold zeros at kpos > qpos and mask out.
    scale = 1.0 / math.sqrt(dh + r)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, T, h, r))], axis=-1)
    qf = shard(qf, "batch", "seq", "heads", None, None)
    kf = shard(kf, "batch", "seq_kv", "heads", None)
    v = shard(v, "batch", "seq_kv", "heads", None)
    out = _dispatch_attend(qf, kf, v, positions, jnp.arange(T),
                           causal=True, window=0, softcap=0.0,
                           chunk=cfg.attn_chunk, scale=scale)
    out = out[:, :, :, 0, :]
    out = jnp.einsum("bshv,hvd->bsd", out, params["wo"])
    return shard(out, "batch", "seq", None), new_cache


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------

def ffn_init(key, d: int, dff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "wi": _init(ks[0], (d, dff), ("embed", "ff"), dtype=dtype),
        "wg": _init(ks[1], (d, dff), ("embed", "ff"), dtype=dtype),
        "wo": _init(ks[2], (dff, d), ("ff", "embed"), dtype=dtype),
    }


def ffn_apply(params, x, act: str = "silu"):
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = a(x @ params["wg"]) * (x @ params["wi"])
    h = shard(h, "batch", "seq", "ff")
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# MoE: sort-based capacity dispatch, expert-parallel friendly
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, e, dff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, e), ("embed", None), dtype=jnp.float32),
        "wi": _init(ks[1], (e, d, dff), ("expert", "fsdp", "ff"), dtype=dtype),
        "wg": _init(ks[2], (e, d, dff), ("expert", "fsdp", "ff"), dtype=dtype),
        "wo": _init(ks[3], (e, dff, d), ("expert", "ff", "fsdp"), dtype=dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = ffn_init(ks[4], d, cfg.d_ff_expert * cfg.n_shared_experts,
                               dtype)
    return p


def moe_apply(params, cfg: ModelConfig, x, act: str = "silu"):
    """x: [B, S, d].  Per-sequence groups; sort-based dispatch into an
    [B, E, C, d] buffer; grouped expert matmuls; combine with router weights.
    Returns (out, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * k * S / E))

    logits = (x.astype(jnp.float32) @ params["router"])      # [B,S,E]
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(gates, k)                         # [B,S,k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch-style)
    me = gates.mean(axis=(0, 1))                             # [E]
    ce = jax.nn.one_hot(topi, E).sum(axis=2).mean(axis=(0, 1))  # [E]
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef

    flat_e = topi.reshape(B, S * k)                          # [B, S*k]
    sort_idx = jnp.argsort(flat_e, axis=-1)                  # local per-seq sort
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    # rank within expert segment
    pos = jnp.arange(S * k)[None, :] - jax.vmap(
        lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    keep = pos < C
    dest = jnp.where(keep, sorted_e * C + pos, E * C)        # overflow -> E*C

    tok = sort_idx // k                                      # source token ids
    xs = jnp.take_along_axis(x, tok[..., None], axis=1)      # [B, S*k, d]
    ws = jnp.take_along_axis(topv.reshape(B, S * k), sort_idx, axis=-1)

    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda bf, dd, xx: bf.at[dd].add(xx))(buf, dest, xs)
    buf = buf[:, :-1].reshape(B, E, C, d)
    buf = shard(buf, "batch", "expert", None, None)

    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    h = a(jnp.einsum("becd,edf->becf", buf, params["wg"])) * \
        jnp.einsum("becd,edf->becf", buf, params["wi"])
    h = shard(h, "batch", "expert", None, "ff")
    y = jnp.einsum("becf,efd->becd", h, params["wo"])
    y = shard(y, "batch", "expert", None, None).reshape(B, E * C, d)

    yc = jnp.take_along_axis(
        jnp.pad(y, ((0, 0), (0, 1), (0, 0))),
        jnp.minimum(dest, E * C)[..., None], axis=1)
    yc = yc * (ws * keep).astype(y.dtype)[..., None]
    out = jnp.zeros((B, S, d), x.dtype)
    out = jax.vmap(lambda o, t, v: o.at[t].add(v))(out, tok, yc)

    if cfg.n_shared_experts:
        out = out + ffn_apply(params["shared"], x, act)
    return shard(out, "batch", "seq", None), aux


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — jamba's mixer
# ---------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dtr = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _init(ks[0], (d, 2 * di), ("embed", "mamba_inner"), dtype=dtype),
        "conv_w": _init(ks[1], (cfg.mamba_d_conv, di), ("conv", "mamba_inner"),
                        scale=0.5, dtype=dtype),
        "conv_b": _zeros((di,), ("mamba_inner",), dtype),
        "x_proj": _init(ks[2], (di, dtr + 2 * n), ("mamba_inner", None), dtype=dtype),
        "dt_proj": _init(ks[3], (dtr, di), (None, "mamba_inner"), dtype=dtype),
        "dt_bias": _zeros((di,), ("mamba_inner",), dtype),
        "A_log": Param(jnp.log(jnp.tile(jnp.arange(1., n + 1.), (di, 1))),
                       ("mamba_inner", None)),
        "D": _ones((di,), ("mamba_inner",), dtype),
        "out_proj": _init(ks[4], (di, d), ("mamba_inner", "embed"), dtype=dtype),
    }


def _causal_conv1d(u, w, b, state=None):
    """u: [B,S,di]; w: [K,di] depthwise.  state: [B,K-1,di] for decode."""
    K = w.shape[0]
    if state is not None:
        u_pad = jnp.concatenate([state.astype(u.dtype), u], axis=1)
        new_state = u_pad[:, -(K - 1):, :]
    else:
        u_pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = u_pad[:, -(K - 1):, :]
    out = sum(u_pad[:, i: i + u.shape[1], :] * w[i] for i in range(K))
    return out + b, new_state


def mamba_apply(params, cfg: ModelConfig, x, state: Optional[Dict] = None):
    """Returns (out, new_state); state = {"conv": [B,K-1,di], "ssm": [B,di,n]}."""
    B, S, d = x.shape
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dtr = max(1, math.ceil(d / 16))

    uz = x @ params["in_proj"]
    u, z = uz[..., :di], uz[..., di:]
    u = shard(u, "batch", "seq", "mamba_inner")
    u, conv_state = _causal_conv1d(u, params["conv_w"], params["conv_b"],
                                   None if state is None else state["conv"])
    u = jax.nn.silu(u)

    xdbc = u @ params["x_proj"]
    dt = jax.nn.softplus(xdbc[..., :dtr] @ params["dt_proj"] + params["dt_bias"])
    Bc = xdbc[..., dtr: dtr + n].astype(jnp.float32)         # [B,S,n]
    Cc = xdbc[..., dtr + n:].astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))        # [di,n]

    da = jnp.exp(dt.astype(jnp.float32)[..., None] * A)      # [B,S,di,n]
    db = (dt.astype(jnp.float32) * u.astype(jnp.float32))[..., None] * \
        Bc[:, :, None, :]                                    # [B,S,di,n]

    if state is None:
        def combine(a, b):
            (a1, b1), (a2, b2) = a, b
            return a2 * a1, a2 * b1 + b2
        _, hs = lax.associative_scan(combine, (da, db), axis=1)
        new_ssm = hs[:, -1]
    else:
        h0 = state["ssm"].astype(jnp.float32)
        def step(h, ab):
            a, b = ab
            h = a * h + b
            return h, h
        new_ssm, hs = lax.scan(step, h0,
                               (da.swapaxes(0, 1), db.swapaxes(0, 1)))
        hs = hs.swapaxes(0, 1)
    y = jnp.einsum("bsdn,bsn->bsd", hs, Cc)
    y = (y + u.astype(jnp.float32) * params["D"].astype(jnp.float32))
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    new_state = {"conv": conv_state.astype(x.dtype),
                 "ssm": new_ssm.astype(jnp.float32)}
    return shard(out, "batch", "seq", None), new_state


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, chunked parallel) + sLSTM (scalar, scan)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di = 2 * d
    ks = jax.random.split(key, 8)
    return {
        "up": _init(ks[0], (d, 2 * di), ("embed", "lstm_inner"), dtype=dtype),
        "conv_w": _init(ks[1], (4, di), ("conv", "lstm_inner"), scale=0.5,
                        dtype=dtype),
        "conv_b": _zeros((di,), ("lstm_inner",), dtype),
        "wq": _init(ks[2], (di, di), ("lstm_inner", None), dtype=dtype),
        "wk": _init(ks[3], (di, di), ("lstm_inner", None), dtype=dtype),
        "wv": _init(ks[4], (di, di), ("lstm_inner", None), dtype=dtype),
        "wif": _init(ks[5], (di, 2 * cfg.n_heads), ("lstm_inner", None),
                     scale=0.01, dtype=dtype),
        "skip": _ones((di,), ("lstm_inner",), dtype),  # learnable skip scale
        "down": _init(ks[7], (di, d), ("lstm_inner", "embed"), dtype=dtype),
        "out_norm": rmsnorm_init(di, dtype),
    }


def mlstm_apply(params, cfg: ModelConfig, x, state: Optional[Dict] = None,
                chunk: int = 256):
    """Chunked parallel mLSTM.  state = {"C": [B,H,dh,dh], "N": [B,H,dh],
    "conv": [B,3,di]} for decode."""
    B, S, d = x.shape
    H = cfg.n_heads
    di = 2 * d
    dh = di // H

    uz = x @ params["up"]
    u, z = uz[..., :di], uz[..., di:]
    c, conv_state = _causal_conv1d(u, params["conv_w"], params["conv_b"],
                                   None if state is None else state["conv"])
    c = jax.nn.silu(c)
    q = (c @ params["wq"]).reshape(B, S, H, dh).swapaxes(1, 2)  # [B,H,S,dh]
    k = (c @ params["wk"]).reshape(B, S, H, dh).swapaxes(1, 2) / math.sqrt(dh)
    v = (u @ params["wv"]).reshape(B, S, H, dh).swapaxes(1, 2)
    gates = u @ params["wif"]                                 # [B,S,2H]
    logi = jnp.clip(gates[..., :H], -12.0, 12.0).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(gates[..., H:].astype(jnp.float32) + 2.0)
    logi = logi.swapaxes(1, 2)                                # [B,H,S]
    logf = logf.swapaxes(1, 2)

    if state is not None:
        C0 = state["C"].astype(jnp.float32)
        N0 = state["N"].astype(jnp.float32)
    else:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        N0 = jnp.zeros((B, H, dh), jnp.float32)

    if S <= 4:  # single-step decode (unrolled)
        ys = []
        for t in range(S):
            f_t = jnp.exp(logf[:, :, t])[..., None, None]
            i_t = jnp.exp(logi[:, :, t])[..., None, None]
            kv = k[:, :, t, :, None].astype(jnp.float32) * \
                v[:, :, t, None, :].astype(jnp.float32)
            C0 = f_t * C0 + i_t * kv
            N0 = f_t[..., 0] * N0 + i_t[..., 0] * k[:, :, t].astype(jnp.float32)
            qt = q[:, :, t].astype(jnp.float32)
            num = jnp.einsum("bhd,bhdv->bhv", qt, C0)
            den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, N0))[..., None]
            ys.append(num / jnp.maximum(den, 1.0))
        y = jnp.stack(ys, axis=2)
        new_state = {"C": C0, "N": N0, "conv": conv_state}
    else:  # chunked parallel (training / prefill), seeded from the state
        nc = max(1, S // chunk)
        cs = S // nc
        qc = q.reshape(B, H, nc, cs, dh)
        kc = k.reshape(B, H, nc, cs, dh)
        vc = v.reshape(B, H, nc, cs, dh)
        lic = logi.reshape(B, H, nc, cs)
        lfc = logf.reshape(B, H, nc, cs)
        cum_f = jnp.cumsum(lfc, axis=-1)                      # within chunk
        tot_f = cum_f[..., -1]

        # intra-chunk: D[i,j] = exp(cum_f_i - cum_f_j + logi_j), j <= i
        dmat = cum_f[..., :, None] - cum_f[..., None, :] + lic[..., None, :]
        tri = jnp.tril(jnp.ones((cs, cs), bool))
        dmat = jnp.where(tri, dmat, -jnp.inf)
        att = jnp.einsum("bhnid,bhnjd->bhnij", qc.astype(jnp.float32),
                         kc.astype(jnp.float32)) * jnp.exp(dmat)
        y_intra = jnp.einsum("bhnij,bhnjd->bhnid", att, vc.astype(jnp.float32))
        den_intra = att.sum(-1)                               # q_i . n_vec (intra)

        # inter-chunk state scan
        decay_in = jnp.exp(tot_f[..., None] - cum_f + lic)    # [B,H,n,cs]
        kv_chunk = jnp.einsum("bhncd,bhncv,bhnc->bhndv",
                              kc.astype(jnp.float32), vc.astype(jnp.float32),
                              decay_in)
        n_chunk = jnp.einsum("bhncd,bhnc->bhnd", kc.astype(jnp.float32),
                             decay_in)

        def scan_fn(carry, inp):
            C_prev, N_prev = carry
            kv_c, n_c, tf = inp
            C_new = jnp.exp(tf)[..., None, None] * C_prev + kv_c
            N_new = jnp.exp(tf)[..., None] * N_prev + n_c
            return (C_new, N_new), (C_prev, N_prev)

        (Cl, Nl), (Cs_, Ns_) = lax.scan(
            scan_fn, (C0, N0),
            (kv_chunk.transpose(2, 0, 1, 3, 4), n_chunk.transpose(2, 0, 1, 3),
             tot_f.transpose(2, 0, 1)))
        Cs_ = Cs_.transpose(1, 2, 0, 3, 4)                    # [B,H,n,dh,dh]
        Ns_ = Ns_.transpose(1, 2, 0, 3)
        qdec = qc.astype(jnp.float32) * jnp.exp(cum_f)[..., None]
        y_inter = jnp.einsum("bhncd,bhndv->bhncv", qdec, Cs_)
        den_inter = jnp.einsum("bhncd,bhnd->bhnc", qdec, Ns_)

        num = y_intra + y_inter
        den = jnp.abs(den_intra + den_inter)[..., None]       # |q . n|
        y = (num / jnp.maximum(den, 1.0)).reshape(B, H, S, dh)
        new_state = {"C": Cl, "N": Nl, "conv": conv_state}

    y = y.swapaxes(1, 2).reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps)
    y = y + params["skip"] * c                                # learnable skip
    y = y * jax.nn.silu(z)
    out = y @ params["down"]
    return shard(out, "batch", "seq", None), new_state


def slstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dff = max(128, ((int(d * 4 / 3) + 127) // 128) * 128)  # shardable 4/3 GLU
    ks = jax.random.split(key, 5)
    return {
        "win": _init(ks[0], (d, 4 * d), ("embed", "lstm_inner"), dtype=dtype),
        "rrec": _init(ks[1], (H, dh, 4 * dh), (None, None, None),
                      scale=1.0 / math.sqrt(dh), dtype=dtype),
        "bias": _zeros((4 * d,), ("lstm_inner",), dtype),
        "out_norm": rmsnorm_init(d, dtype),
        "up": _init(ks[2], (d, 2 * dff), ("embed", "ff"), dtype=dtype),
        "down": _init(ks[3], (dff, d), ("ff", "embed"), dtype=dtype),
    }


def _slstm_cell(c, n, m, pre):
    """One stabilized sLSTM step (pre = Wx_t + h_{t-1} R already formed)."""
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
    zt = jnp.tanh(zi)
    ot = jax.nn.sigmoid(oi)
    log_f = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(log_f + m, ii)
    i_p = jnp.exp(ii - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * zt
    n_new = f_p * n + i_p
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return c_new, n_new, m_new, h_new


def _slstm_scan_plain(wx, rrec, c0, n0, h0, m0):
    """Reference scan (jax-AD'd): the weight gradient of ``rrec`` contracts
    the (sharded) batch axis INSIDE the time loop -> one all-reduce per step
    per layer under SPMD.  Kept for tests; training uses the custom-VJP
    version below."""
    def step(carry, wx_t):
        c, n, h, m = carry
        pre = wx_t + jnp.einsum("bhd,hdk->bhk", h, rrec)
        c, n, m, h = _slstm_cell(c, n, m, pre)
        return (c, n, h, m), h

    (cl, nl, hl, ml), hs = lax.scan(step, (c0, n0, h0, m0),
                                    wx.swapaxes(0, 1))
    return hs, (cl, nl, hl, ml)


@jax.custom_vjp
def _slstm_scan(wx, rrec, c0, n0, h0, m0):
    return _slstm_scan_plain(wx, rrec, c0, n0, h0, m0)


def _slstm_scan_fwd(wx, rrec, c0, n0, h0, m0):
    def step(carry, wx_t):
        c, n, h, m = carry
        pre = wx_t + jnp.einsum("bhd,hdk->bhk", h, rrec)
        c_new, n_new, m_new, h_new = _slstm_cell(c, n, m, pre)
        return (c_new, n_new, h_new, m_new), (h_new, c, n, m, h, pre)

    (cl, nl, hl, ml), ys = lax.scan(step, (c0, n0, h0, m0),
                                    wx.swapaxes(0, 1))
    hs, c_prev, n_prev, m_prev, h_prev, pres = ys
    return (hs, (cl, nl, hl, ml)), (rrec, c_prev, n_prev, m_prev, h_prev,
                                    pres)


def _slstm_scan_bwd(res, cots):
    """Deferred recurrent-weight gradient: the reverse scan only propagates
    state cotangents and EMITS dpre per step; the batch+time contraction for
    d(rrec) happens once afterwards (one all-reduce per layer instead of one
    per time step — the §Perf fix for recurrent archs)."""
    rrec, c_prev, n_prev, m_prev, h_prev, pres = res
    dhs, (dcl, dnl, dhl, dml) = cots

    def step(carry, inp):
        dc, dn, dh, dm = carry
        dh_out, c, n, m, pre = inp
        dh_tot = dh + dh_out
        _, cell_vjp = jax.vjp(_slstm_cell, c, n, m, pre)
        dc_p, dn_p, dm_p, dpre = cell_vjp((dc, dn, dm, dh_tot))
        dh_p = jnp.einsum("bhk,hdk->bhd", dpre, rrec)
        return (dc_p, dn_p, dh_p, dm_p), dpre

    (dc0, dn0, dh0, dm0), dpres = lax.scan(
        step, (dcl, dnl, dhl, dml),
        (dhs, c_prev, n_prev, m_prev, pres), reverse=True)
    dwx = dpres.swapaxes(0, 1)
    # ONE contraction over (time, batch) -> single all-reduce under SPMD
    drrec = jnp.einsum("sbhd,sbhk->hdk", h_prev, dpres)
    return dwx, drrec, dc0, dn0, dh0, dm0


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def slstm_apply(params, cfg: ModelConfig, x, state: Optional[Dict] = None):
    """Sequential scalar-memory LSTM with per-head recurrence + GLU out.
    state = {"c","n","h","m"} each [B,H,dh]."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    wx = (x @ params["win"] + params["bias"]).astype(jnp.float32)
    wx = wx.reshape(B, S, H, 4 * dh)

    if state is None:
        zeros = jnp.zeros((B, H, dh), jnp.float32)
        c0, n0, h0 = zeros, zeros + 1e-6, zeros
        m0 = jnp.zeros((B, H, dh), jnp.float32) - 10.0
    else:
        c0, n0 = state["c"], state["n"]
        h0, m0 = state["h"], state["m"]

    rrec = params["rrec"].astype(jnp.float32)
    hs, (cl, nl, hl, ml) = _slstm_scan(wx, rrec, c0, n0, h0, m0)
    y = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    y = rmsnorm(params["out_norm"], y, cfg.norm_eps)
    up = y @ params["up"]
    dff = params["down"].shape[0]
    y = jax.nn.gelu(up[..., :dff]) * up[..., dff:]
    out = y @ params["down"]
    new_state = {"c": cl, "n": nl, "h": hl, "m": ml}
    return shard(out, "batch", "seq", None), new_state
