from .config import BlockSpec, ModelConfig, reduced
from .layers import Param, is_param, param_axes, param_values, tree_cast
from .lm import cache_axes, encdec_apply, init_caches, lm_apply, lm_init, lm_loss

__all__ = [k for k in dir() if not k.startswith("_")]
