"""Model zoo: jax-free architecture configs + jax-backed layers/LMs.

``repro.models.config`` is pure dataclasses and is what the workload
resolver's ``tpu:`` scheme (via ``repro.core.tpu_adapter`` and
``repro.configs``) needs; the layer/LM names are lazy module attributes so
that resolving a ``tpu:`` workload — and the whole explore/evaluate path —
never pays, or depends on, the jax import.
"""

from .config import BlockSpec, ModelConfig, reduced

_LAYERS_EXPORTS = ("Param", "is_param", "param_axes", "param_values",
                   "tree_cast")
_LM_EXPORTS = ("cache_axes", "encdec_apply", "init_caches", "lm_apply",
               "lm_init", "lm_loss")


def __getattr__(name):
    if name in _LAYERS_EXPORTS:
        from . import layers

        return getattr(layers, name)
    if name in _LM_EXPORTS:
        from . import lm

        return getattr(lm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["BlockSpec", "ModelConfig", "reduced",
           *_LAYERS_EXPORTS, *_LM_EXPORTS]
