"""Render the dry-run/roofline markdown tables from runs/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report [--dir runs/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(directory: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def dryrun_table(rows: List[Dict], mesh: str) -> str:
    out = ["| arch | shape | status | bytes/dev (GiB) | compile (s) | "
           "collectives (GiB, wire) |",
           "|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | SKIP (documented)"
                       f" | - | - | - |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | - |")
            continue
        dev_bytes = (r.get("temp_size_in_bytes", 0)
                     + r.get("argument_size_in_bytes", 0))
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {fmt_bytes(dev_bytes)} | "
            f"{r.get('compile_s', 0):.0f} | "
            f"{r.get('coll_gbytes', 0):.2f} |")
    return "\n".join(out)


def roofline_table(rows: List[Dict], mesh: str = "pod16x16") -> str:
    out = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bound | MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != mesh or "bottleneck" not in r:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.2f} | "
            f"{r['t_memory_ms']:.2f} | {r['t_collective_ms']:.2f} | "
            f"{r['bottleneck']} | {r['flops_util']:.2f} | "
            f"{r['roofline_frac']:.3f} |")
    return "\n".join(out)


def pick_hillclimb(rows: List[Dict]) -> List[Dict]:
    """Worst roofline fraction, most collective-bound, most representative
    (largest fused-attention share: a long-seq train/prefill cell)."""
    ok = [r for r in rows if r.get("mesh") == "pod16x16"
          and "bottleneck" in r]
    if not ok:
        return []
    worst = min(ok, key=lambda r: r["roofline_frac"])
    coll = max(ok, key=lambda r: (r["t_collective_ms"]
                                  / max(max(r["t_compute_ms"],
                                            r["t_memory_ms"]), 1e-9)))
    rep = max((r for r in ok if r["kind"] in ("train", "prefill")),
              key=lambda r: r["hlo_gflops"], default=worst)
    picks, seen = [], set()
    for r, why in ((worst, "worst roofline fraction"),
                   (coll, "most collective-bound"),
                   (rep, "most representative of the technique")):
        key = (r["arch"], r["shape"])
        if key not in seen:
            seen.add(key)
            picks.append({**r, "why": why})
    return picks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    print("## Dry-run (single pod 16x16)\n")
    print(dryrun_table(rows, "pod16x16"))
    print("\n## Dry-run (multi-pod 2x16x16)\n")
    print(dryrun_table(rows, "pod2x16x16"))
    print("\n## Roofline (single pod)\n")
    print(roofline_table(rows))
    print("\n## Hillclimb picks\n")
    for p in pick_hillclimb(rows):
        print(f"- {p['arch']} x {p['shape']}: {p['why']} "
              f"(frac={p['roofline_frac']:.3f}, bound={p['bottleneck']})")


if __name__ == "__main__":
    main()
