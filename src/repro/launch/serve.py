"""Serving driver: batched generation over the model zoo.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 6 --prompt-len 12 --new-tokens 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import lm_init, param_values
from repro.serve import EncDecEngine, Request, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    values = param_values(lm_init(jax.random.PRNGKey(args.seed), cfg))
    rng = np.random.default_rng(args.seed)
    scfg = ServeConfig(max_batch=args.max_batch,
                       max_len=args.prompt_len + args.new_tokens + 8)

    t0 = time.time()
    if cfg.is_encdec:
        eng = EncDecEngine(cfg, values, scfg)
        frames = rng.normal(size=(args.requests, 16, cfg.d_model)) \
            .astype(np.float32)
        outs = eng.transcribe(frames, max_new_tokens=args.new_tokens)
        for i, o in enumerate(outs):
            print(f"req {i}: {o}")
    else:
        eng = ServeEngine(cfg, values, scfg)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, args.prompt_len)
                        .astype(np.int32),
                        max_new_tokens=args.new_tokens)
                for i in range(args.requests)]
        outs = eng.generate(reqs)
        for rid in sorted(outs):
            print(f"req {rid}: {outs[rid]}")
    dt = time.time() - t0
    total = args.requests * args.new_tokens
    print(f"{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s, "
          f"batch {args.max_batch})")


if __name__ == "__main__":
    main()
