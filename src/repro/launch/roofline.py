"""Roofline terms from compiled dry-run artifacts (no real hardware).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` gives per-device HLO flops/bytes; collective bytes are
not in cost_analysis, so we parse the post-SPMD HLO text and sum the shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighted by the wire cost of each primitive on a ring
(all-reduce moves ~2x its payload; all-gather/reduce-scatter ~1x; permute 1x).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%x = bf16[8,128,2048]{...} all-reduce(...)` — possibly tuple-typed
_SHAPE_RE = re.compile(r"(\w[\w\d]*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\(?)([^=]*?)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")

_WIRE_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Sum result-shape bytes of collective ops (wire-weighted), per kind."""
    per: Dict[str, int] = {}
    total = 0
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        kind = op.replace("-start", "")
        b = _shape_bytes(type_str)
        w = int(b * _WIRE_FACTOR.get(kind, 1.0))
        per[kind] = per.get(kind, 0) + w
        total += w
    return total, per


# --- trip-count-aware collective accounting --------------------------------
# lax.scan lowers to a while loop whose body is a separate HLO computation;
# collectives inside it execute trip-count times per step.  We split the HLO
# into computations, find `while` ops (condition/body refs), read the trip
# count from the condition's compare-against-constant, and multiply each
# computation's collective bytes by the product of its enclosing trip counts.

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALL_RE = re.compile(
    r"(?:to_apply|condition|body|branch_computations)=\{?%?([\w\.\-]+)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Computation name -> body text.  Headers look like
    ``%name (params...) -> type {`` or ``ENTRY %main.1 (...) -> ... {``."""
    comps: Dict[str, str] = {}
    name, buf, depth = None, [], 0
    for line in hlo_text.splitlines():
        if name is None:
            s = line.rstrip()
            if (s.endswith("{") and not line.startswith(" ")
                    and "->" in s and "(" in s):
                m = _COMP_HEAD_RE.match(s)
                if m:
                    name, buf = m.group(1), []
                    depth = s.count("{") - s.count("}")
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps[name] = "\n".join(buf)
            name = None
        else:
            buf.append(line)
    return comps


def collective_bytes_tripaware(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Collective bytes with while-loop bodies multiplied by trip counts
    (nested loops compose).  Falls back to plain counting on parse trouble."""
    comps = _split_computations(hlo_text)
    if not comps:
        return collective_bytes(hlo_text)

    # per-computation direct collective bytes
    direct: Dict[str, Dict[str, int]] = {}
    for name, body in comps.items():
        t, per = collective_bytes(body)
        direct[name] = per

    # while edges: parent comp -> (body comp, trip) — the trip count comes
    # from XLA's backend_config {"known_trip_count": {"n": "NN"}}
    body_trip: Dict[str, int] = {}
    parents: Dict[str, List[str]] = {}
    for name, body in comps.items():
        for line in body.splitlines():
            wm = _WHILE_RE.search(line)
            if wm:
                wbody = wm.group(2)
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                body_trip[wbody] = max(body_trip.get(wbody, 1), trip)
                parents.setdefault(wbody, []).append(name)
        for m in _CALL_RE.finditer(body):
            callee = m.group(1)
            if callee in comps:
                parents.setdefault(callee, []).append(name)

    entry = None
    for name in comps:
        if "main" in name or name.startswith("ENTRY"):
            entry = name
    # effective multiplier per computation = product of trips on the path
    # from entry (memoized DFS over the reversed call graph)
    memo: Dict[str, float] = {}

    def mult(name: str, depth=0) -> float:
        if depth > 50:
            return 1.0
        if name in memo:
            return memo[name]
        memo[name] = 1.0  # break cycles
        ps = parents.get(name, [])
        base = 1.0 if (not ps or name == entry) else max(
            mult(p, depth + 1) for p in ps)
        m = base * body_trip.get(name, 1)
        memo[name] = m
        return m

    per_total: Dict[str, int] = {}
    total = 0
    for name, per in direct.items():
        f = mult(name)
        for kind, b in per.items():
            w = int(b * f)
            per_total[kind] = per_total.get(kind, 0) + w
            total += w
    return total, per_total


def collective_breakdown(hlo_text: str, top: int = 8) -> List[Dict]:
    """Top collective-emitting ops with their trip multipliers — the §Perf
    profiling view ('lowered.as_text() is the profile')."""
    comps = _split_computations(hlo_text)
    body_trip: Dict[str, int] = {}
    parents: Dict[str, List[str]] = {}
    for name, body in comps.items():
        for line in body.splitlines():
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                body_trip[wm.group(2)] = max(body_trip.get(wm.group(2), 1),
                                             trip)
                parents.setdefault(wm.group(2), []).append(name)
        for m in _CALL_RE.finditer(body):
            if m.group(1) in comps:
                parents.setdefault(m.group(1), []).append(name)
    memo: Dict[str, float] = {}

    def mult(name: str, depth=0) -> float:
        if depth > 50 or name in memo:
            return memo.get(name, 1.0)
        memo[name] = 1.0
        ps = parents.get(name, [])
        base = max((mult(p, depth + 1) for p in ps), default=1.0)
        memo[name] = base * body_trip.get(name, 1)
        return memo[name]

    rows = []
    for name, body in comps.items():
        f = mult(name)
        for m in _OP_RE.finditer(body):
            kind = m.group(2).replace("-start", "")
            b = _shape_bytes(m.group(1))
            w = b * _WIRE_FACTOR.get(kind, 1.0)
            # grab metadata op_name if present on the line
            line = body[m.start(): body.find("\n", m.start())]
            nm = re.search(r'op_name="([^"]{0,120})', line)
            rows.append({
                "kind": kind, "bytes": int(b), "trips": int(f),
                "wire_total": int(w * f), "comp": name[:40],
                "op": nm.group(1) if nm else "",
            })
    rows.sort(key=lambda r: -r["wire_total"])
    return rows[:top]


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops: float               # per device
    hlo_bytes: float               # per device
    coll_bytes: float              # per device (wire-weighted)
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0       # 6*N*D global
    bytes_per_device: Optional[float] = None   # from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the bound step time: how close the
        step is to the pure-compute roofline."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        if t_bound <= 0:
            return 0.0
        useful = self.model_flops / self.n_devices / PEAK_FLOPS
        return useful / t_bound

    @property
    def flops_utilization(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS: fraction of compiled compute that is
        'useful' (catches remat/redundancy waste)."""
        if self.hlo_flops <= 0:
            return 0.0
        return self.model_flops / (self.hlo_flops * self.n_devices)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "devices": self.n_devices,
            "hlo_gflops": self.hlo_flops / 1e9,
            "hlo_gbytes": self.hlo_bytes / 1e9,
            "coll_gbytes": self.coll_bytes / 1e9,
            "t_compute_ms": self.t_compute * 1e3,
            "t_memory_ms": self.t_memory * 1e3,
            "t_collective_ms": self.t_collective * 1e3,
            "bottleneck": self.bottleneck,
            "model_gflops_global": self.model_flops / 1e9,
            "flops_util": self.flops_utilization,
            "roofline_frac": self.roofline_fraction,
            "coll_breakdown": self.coll_breakdown,
            "bytes_per_device": self.bytes_per_device,
        }


def analyze(arch: str, shape: str, mesh_name: str, n_devices: int,
            compiled, model_flops: float,
            extra_flops: float = 0.0, extra_bytes: float = 0.0,
            coll_multiplier: float = 1.0) -> RooflineReport:
    """``extra_*`` are the per-device scan trip-count corrections (see
    scan_correction); ``coll_multiplier`` scales collective bytes found
    inside scan bodies by the same reasoning (approximated by the caller)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):  # some backends return [dict]
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0)) + extra_flops
    byts = float(ca.get("bytes accessed", 0.0)) + extra_bytes
    text = compiled.as_text()
    if coll_multiplier == "tripaware":
        coll, breakdown = collective_bytes_tripaware(text)
    else:
        coll, breakdown = collective_bytes(text)
        coll = int(coll * coll_multiplier)
    mem = None
    try:
        m = compiled.memory_analysis()
        if m is not None:
            mem = float(getattr(m, "temp_size_in_bytes", 0)
                        + getattr(m, "argument_size_in_bytes", 0)
                        + getattr(m, "output_size_in_bytes", 0)
                        - getattr(m, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        hlo_flops=flops, hlo_bytes=byts, coll_bytes=float(coll),
        coll_breakdown=breakdown, model_flops=model_flops,
        bytes_per_device=mem,
    )


# ---------------------------------------------------------------------------
# scan trip-count correction
# ---------------------------------------------------------------------------
# XLA's module-level cost_analysis counts a while-loop (lax.scan) body ONCE
# regardless of trip count (verified in tests/test_roofline.py), so the raw
# numbers under-count the scanned layers by (reps - 1) bodies.  We report the
# raw numbers AND an additive correction from an analytic per-layer cost
# model; both appear in EXPERIMENTS.md §Roofline.

def _attn_token_flops(cfg, kv_len: int, kind: str) -> float:
    h, dh, dv = cfg.n_heads, cfg.head_dim, cfg.v_dim
    d = cfg.d_model
    if kind == "mla":
        r = cfg.rope_head_dim
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        proj = 2 * (d * qr + qr * h * (dh + r) + d * (kvr + r)
                    + kvr * h * (dh + dv) + h * dv * d)
        attn = 2 * h * ((dh + r) + dv) * kv_len
        return proj + attn
    kh = cfg.n_kv_heads
    proj = 2 * (d * h * dh + 2 * d * kh * dh + h * dv * d)
    attn = 2 * h * (dh + dv) * kv_len
    return proj + attn


def _mixer_token_flops(cfg, mixer: str, kv_len: int) -> float:
    d = cfg.d_model
    if mixer in ("attn",):
        return _attn_token_flops(cfg, kv_len, "gqa")
    if mixer == "attn_local":
        return _attn_token_flops(cfg, min(kv_len, cfg.sliding_window), "gqa")
    if mixer == "attn_mla":
        return _attn_token_flops(cfg, kv_len, "mla")
    if mixer == "mamba":
        di = cfg.mamba_expand * d
        n = cfg.mamba_d_state
        dtr = max(1, (d + 15) // 16)
        return 2 * (d * 2 * di + cfg.mamba_d_conv * di
                    + di * (dtr + 2 * n) + dtr * di + 5 * di * n + di * d)
    if mixer == "mlstm":
        di = 2 * d
        dh = di // cfg.n_heads
        chunk = 256
        return 2 * (d * 2 * di + 4 * di + 3 * di * di
                    + 2 * di * chunk + 2 * di * dh + di * d)
    if mixer == "slstm":
        dh = d // cfg.n_heads
        dff = int(d * 8 / 3)
        return 2 * (4 * d * d + 4 * cfg.n_heads * dh * dh + d * dff)
    raise ValueError(mixer)


def _ffn_token_flops(cfg, ffn: str) -> float:
    d = cfg.d_model
    dense = 2 * 3 * d * cfg.d_ff
    if ffn == "none":
        return 0.0
    if ffn == "dense":
        return dense
    routed = (cfg.capacity_factor * cfg.top_k + cfg.n_shared_experts) \
        * 2 * 3 * d * cfg.d_ff_expert + 2 * d * cfg.n_experts
    if ffn == "moe_residual":
        routed += dense
    return routed


def layer_flops(cfg, idx: int, tokens: int, kv_len: int, kind: str) -> float:
    spec = cfg.block_specs()[idx]
    per_tok = _mixer_token_flops(cfg, spec.mixer, kv_len) \
        + _ffn_token_flops(cfg, spec.ffn)
    mult = 3.0 if kind == "train" else 1.0            # fwd+bwd
    if kind == "train" and cfg.remat in ("full", "dots"):
        mult += 1.0                                    # recompute fwd
    return per_tok * tokens * mult


def _layer_param_bytes(cfg, idx: int) -> float:
    dt = 2 if cfg.param_dtype == "bfloat16" else 4
    return cfg._layer_params(idx) * dt


def layer_bytes(cfg, idx: int, tokens_local: int, kind: str) -> float:
    """Rough per-layer HBM bytes (global / n_devices applied by caller for
    params via sharding; here we return GLOBAL bytes assuming params are
    read once per device-group): weights read (+ grad write on train) +
    ~12 activation tensors r/w per token."""
    w = _layer_param_bytes(cfg, idx)
    acts = 12 * tokens_local * cfg.d_model * 2
    mult = 3.0 if kind == "train" else 1.0
    return w * mult + acts * mult


def scan_correction(cfg, kind: str, seq_len: int, global_batch: int,
                    n_devices: int) -> Tuple[float, float]:
    """(extra_flops, extra_bytes) PER DEVICE to add to cost_analysis numbers:
    (reps - 1) x scan-body cost (XLA counts the body once)."""
    pre, p, reps, rem = cfg.layout()
    if reps <= 1:
        return 0.0, 0.0
    if kind == "decode":
        tokens = global_batch
        kv = seq_len
    else:
        tokens = seq_len * global_batch
        kv = seq_len / 2  # causal average
    tokens_local = tokens / max(n_devices, 1)
    f = sum(layer_flops(cfg, pre + pos, tokens, kv, kind)
            for pos in range(p))
    b = sum(layer_bytes(cfg, pre + pos, tokens_local, kind)
            for pos in range(p))
    # params are sharded across the model axis (and fsdp): approximate the
    # per-device weight slice as 1/n_devices of global for flops; bytes use
    # tokens_local + per-device weight slice
    extra_flops = (reps - 1) * f / max(n_devices, 1)
    w_local = sum(_layer_param_bytes(cfg, pre + pos)
                  for pos in range(p)) / max(n_devices, 1)
    extra_bytes = (reps - 1) * (w_local * (3.0 if kind == "train" else 1.0)
                                + 12 * tokens_local * cfg.d_model * 2
                                * (3.0 if kind == "train" else 1.0))
    return extra_flops, extra_bytes


def model_flops_for(cfg, shape_kind: str, seq_len: int, global_batch: int,
                    tokens_override: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6*N*D (train: fwd+bwd over D tokens; prefill: 2*N*D;
    decode: 2*N_active*B tokens per step).  MoE: active params."""
    n_active = cfg.active_param_count()
    if tokens_override is not None:
        tokens = tokens_override
    elif shape_kind == "decode":
        tokens = global_batch           # one new token per sequence
    else:
        tokens = seq_len * global_batch
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n_active * tokens
