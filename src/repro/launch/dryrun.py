import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (arch x input shape x mesh) cell: build abstract parameters
(jax.eval_shape — no allocation), attach NamedShardings from the logical-axis
rules, lower + compile the real step function (train_step / prefill_step /
serve_step), print memory_analysis() (proves it fits) and cost_analysis()
(feeds §Roofline), and emit a JSON report.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out runs/dryrun

The XLA_FLAGS line above must precede every other import (jax locks the
device count on first backend init).
"""

import argparse
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs import ARCHS, SHAPES, get_config, skip_reason
from repro.configs.shapes import ShapeSpec
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import (
    cache_axes,
    encdec_apply,
    init_caches,
    is_param,
    lm_apply,
    lm_init,
    lm_loss,
    param_values,
)
from repro.models.config import ModelConfig
from repro.parallel.sharding import logical_sharding, mesh_context
from repro.train import AdamWConfig, adamw_init
from repro.train.trainstep import make_train_step

ENC_FRAMES = 1_500  # whisper encoder is architecturally capped at 1500 frames


# ---------------------------------------------------------------------------
# abstract trees + shardings
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    """Param tree of ShapeDtypeStructs (axes ride along as pytree aux)."""
    return jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))


def param_shardings(ptree, mesh):
    return jax.tree.map(lambda p: logical_sharding(p.axes, mesh),
                        ptree, is_leaf=is_param)


def _is_axes(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def axes_shardings(axes_tree, mesh):
    return jax.tree.map(lambda ax: logical_sharding(ax, mesh), axes_tree,
                        is_leaf=_is_axes)


# ---------------------------------------------------------------------------
# per-cell step functions + input specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """(batch SDS tree, batch sharding tree) for a train/prefill cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    batch: Dict[str, Any] = {}
    shards: Dict[str, Any] = {}

    def add(name, shp, dtype, axes):
        batch[name] = sds(shp, dtype)
        shards[name] = logical_sharding(axes, mesh)

    if cfg.is_encdec:
        add("frames", (B, ENC_FRAMES, cfg.d_model), jnp.float32,
            ("batch", None, None))
        add("tokens", (B, S), i32, ("batch", None))
        if shape.kind == "train":
            add("loss_mask", (B, S), jnp.float32, ("batch", None))
    elif cfg.frontend == "vision_patches":
        nf = cfg.n_frontend_tokens
        add("extra_embeds", (B, nf, cfg.d_model), jnp.float32,
            ("batch", None, None))
        add("tokens", (B, max(S - nf, 1)), i32, ("batch", None))
        if shape.kind == "train":
            add("loss_mask", (B, max(S - nf, 1)), jnp.float32, ("batch", None))
    else:
        add("tokens", (B, S), i32, ("batch", None))
        if shape.kind == "train":
            add("loss_mask", (B, S), jnp.float32, ("batch", None))
    return batch, shards


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        B = batch["tokens"].shape[0]
        caches = init_caches(cfg, B, max_len, jnp.bfloat16)
        if cfg.is_encdec:
            logits, caches, enc_out, _ = encdec_apply(
                params, cfg, batch["frames"], batch["tokens"], caches=caches)
            return logits[:, -1, :], caches, enc_out
        logits, caches, _ = lm_apply(
            params, cfg, batch["tokens"],
            extra_embeds=batch.get("extra_embeds"), caches=caches)
        return logits[:, -1, :], caches

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    if cfg.is_encdec:
        def serve_step(params, caches, tokens, positions, enc_out):
            logits, caches, _, _ = encdec_apply(
                params, cfg, None, tokens, positions=positions,
                caches=caches, enc_out=enc_out)
            return logits[:, -1, :], caches
        return serve_step

    def serve_step(params, caches, tokens, positions):
        logits, caches, _ = lm_apply(params, cfg, tokens,
                                     positions=positions, caches=caches)
        return logits[:, -1, :], caches

    return serve_step


# ---------------------------------------------------------------------------
# lowering per cell
# ---------------------------------------------------------------------------

def default_microbatches(cfg: ModelConfig, shape: ShapeSpec,
                         multi_pod: bool) -> int:
    """Baseline grad-accumulation: keep per-device microbatch ~8 sequences
    (4 for the 4k shapes of >30B models) so activations fit 16 GB HBM."""
    if shape.kind != "train":
        return 1
    data_ways = 32 if multi_pod else 16
    per_dev = max(1, shape.global_batch // data_ways)
    target = 4 if cfg.param_count() > 30e9 else 8
    return max(1, per_dev // target)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_overrides: Optional[Dict] = None,
               microbatches: Optional[int] = None,
               cfg_overrides: Optional[Dict] = None,
               verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.with_(**cfg_overrides)
    shape = SHAPES[shape_name]
    if microbatches is None:
        microbatches = default_microbatches(cfg, shape, multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    kind = shape.kind
    rkind = "decode_long" if (kind == "decode"
                              and shape.global_batch == 1) else kind
    rules = rules_for(cfg, rkind, rules_overrides)
    t0 = time.time()

    with mesh_context(mesh, rules):
        ptree = abstract_params(cfg)
        values = param_values(ptree)
        psh = param_shardings(ptree, mesh)

        if kind == "train":
            opt_cfg = AdamWConfig(state_dtype=cfg.opt_dtype)
            opt_sds = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), values)
            opt_sh = type(opt_sds)(
                step=NamedSharding(mesh, PS()),
                mu=param_shardings(ptree, mesh),
                nu=param_shardings(ptree, mesh))
            batch, bsh = batch_specs(cfg, shape, mesh)
            fn = make_train_step(cfg, opt_cfg, microbatches=microbatches)
            jitted = jax.jit(fn, in_shardings=(psh, opt_sh, bsh),
                             out_shardings=(psh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(values, opt_sds, batch)
        elif kind == "prefill":
            batch, bsh = batch_specs(cfg, shape, mesh)
            fn = make_prefill_step(cfg, max_len=shape.seq_len)
            jitted = jax.jit(fn, in_shardings=(psh, bsh))
            lowered = jitted.lower(values, batch)
        else:  # decode
            B, S = shape.global_batch, shape.seq_len
            caches_sds = jax.eval_shape(
                lambda: init_caches(cfg, B, S, jnp.bfloat16))
            csh = axes_shardings(cache_axes(cfg), mesh)
            tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            tsh = logical_sharding(("batch", None), mesh)
            fn = make_serve_step(cfg)
            if cfg.is_encdec:
                enc = jax.ShapeDtypeStruct((B, ENC_FRAMES, cfg.d_model),
                                           jnp.bfloat16)
                esh = logical_sharding(("batch", None, None), mesh)
                jitted = jax.jit(fn, in_shardings=(psh, csh, tsh, tsh, esh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(values, caches_sds, tok, pos, enc)
            else:
                jitted = jax.jit(fn, in_shardings=(psh, csh, tsh, tsh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(values, caches_sds, tok, pos)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    if verbose:
        print(f"[{arch} | {shape_name} | {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print("  memory_analysis:", mem)
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0] if ca else {}
        keys = ["flops", "bytes accessed", "utilization"]
        print("  cost_analysis:", {k: v for k, v in (ca or {}).items()
                                   if any(s in k for s in keys)})

    mf = roofline.model_flops_for(cfg, kind, shape.seq_len,
                                  shape.global_batch)
    # scan trip-count correction (XLA counts while bodies once; DESIGN.md §8)
    xf, xb = roofline.scan_correction(cfg, kind, shape.seq_len,
                                      shape.global_batch, mesh.devices.size)
    pre, p, reps, rem = cfg.layout()
    # collectives inside scan bodies execute trip-count times: counted via
    # trip-aware HLO parsing (roofline.collective_bytes_tripaware)
    coll_mult = "tripaware"
    rep = roofline.analyze(arch, shape_name, mesh_name,
                           mesh.devices.size, compiled, mf,
                           extra_flops=xf, extra_bytes=xb,
                           coll_multiplier=coll_mult)
    row = rep.row()
    row.update({
        "lower_s": t_lower,
        "compile_s": t_compile,
        "kind": kind,
        "rules": {k: str(v) for k, v in rules.items()},
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "microbatches": microbatches,
        "scan_correction_flops": xf,
        "scan_correction_bytes": xb,
        "coll_multiplier": coll_mult,
        "layout": [pre, p, reps, rem],
    })
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                row[attr] = int(v)
    return row


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell")
    ap.add_argument("--out", default=None, help="directory for JSON reports")
    ap.add_argument("--microbatches", type=int, default=None,
                    help="grad-accumulation steps (default: per-cell heuristic)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    if args.out:
        os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        tag = f"{arch}__{shape}__{mesh_name}"
        dest = os.path.join(args.out, f"{tag}.json") if args.out else None
        if dest and args.skip_existing and os.path.exists(dest):
            n_ok += 1
            continue
        reason = skip_reason(arch, shape)
        if reason:
            n_skip += 1
            row = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "skipped": reason}
            print(f"[{tag}] SKIP: {reason}")
        else:
            try:
                row = lower_cell(arch, shape, mp,
                                 microbatches=args.microbatches)
                n_ok += 1
            except Exception as e:  # report, keep going
                n_fail += 1
                row = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[{tag}] FAIL: {type(e).__name__}: {e}")
        if dest:
            with open(dest, "w") as f:
                json.dump(row, f, indent=1, default=str)
    print(f"dryrun: {n_ok} ok, {n_skip} skipped (documented), {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
