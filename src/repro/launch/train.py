"""End-to-end training driver (deliverable b).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir runs/tinyllama

Runs the full production loop on whatever devices exist (CPU here, a pod in
production): sharded params/opt via the same rules as the dry-run, the
deterministic data pipeline, checkpoint/restart, heartbeats + restart policy,
and optional simulated failures (--fail-at) to exercise the recovery path.
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import ARCHS, get_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import rules_for
from repro.models import is_param, lm_init, param_values
from repro.parallel.sharding import logical_sharding, mesh_context
from repro.runtime import (
    Decision,
    FaultConfig,
    HeartbeatMonitor,
    RestartPolicy,
    build_mesh,
    plan_mesh,
)
from repro.train import AdamWConfig, adamw_init
from repro.train.trainstep import make_train_step


def build_state(cfg, opt_cfg, mesh, seed=0):
    ptree = jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(seed), cfg))
    psh = jax.tree.map(lambda p: logical_sharding(p.axes, mesh), ptree,
                       is_leaf=is_param)
    init_fn = jax.jit(lambda k: param_values(lm_init(k, cfg)),
                      out_shardings=psh)
    values = init_fn(jax.random.PRNGKey(seed))
    opt = jax.jit(partial(adamw_init, cfg=opt_cfg))(values)
    return values, opt, psh


def run(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    n_dev = len(jax.devices())
    model_par = min(args.model_parallel, n_dev)
    plan = plan_mesh(n_dev - (n_dev % model_par), model_par)
    mesh = build_mesh(plan)
    rules = rules_for(cfg, "train")
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                          total_steps=args.steps,
                          state_dtype=cfg.opt_dtype)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))
    mgr = CheckpointManager(CheckpointConfig(
        directory=args.ckpt_dir, save_every=args.save_every,
        keep_last=2, async_save=True)) if args.ckpt_dir else None

    fault_cfg = FaultConfig()
    monitor = HeartbeatMonitor(fault_cfg, [f"host{i}" for i in
                                           range(max(1, n_dev // 8))])
    policy = RestartPolicy(fault_cfg)

    with mesh, mesh_context(mesh, rules):
        values, opt, psh = build_state(cfg, opt_cfg, mesh, args.seed)
        start = 0
        if mgr and mgr.latest_step() is not None:
            host = jax.tree.map(np.asarray, values)
            restored, meta = mgr.restore({"params": host, "opt": jax.tree.map(
                np.asarray, opt)})
            values = jax.tree.map(jnp.asarray, restored["params"])
            opt = jax.tree.map(jnp.asarray, restored["opt"])
            start = meta["step"]
            print(f"resumed from step {start}")

        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, microbatches=args.microbatches),
            donate_argnums=(0, 1))

        losses = []
        t0 = time.time()
        step = start
        while step < args.steps:
            batch = {k: jnp.asarray(v) for k, v in
                     data.batch_at(step).items()}
            if args.fail_at and step == args.fail_at:
                args.fail_at = 0
                print(f"[fault-injection] simulated step failure at {step}")
                decision = policy.decide(monitor, step_failed=True)
                print(f"[fault-injection] policy -> {decision.value}")
                if decision == Decision.RESTART_SAME and mgr:
                    latest = mgr.latest_step()
                    if latest is not None:
                        restored, meta = mgr.restore({
                            "params": jax.tree.map(np.asarray, values),
                            "opt": jax.tree.map(np.asarray, opt)})
                        values = jax.tree.map(jnp.asarray, restored["params"])
                        opt = jax.tree.map(jnp.asarray, restored["opt"])
                        step = meta["step"]
                        print(f"[fault-injection] restarted from {step}")
                        continue
            t_step = time.time()
            values, opt, metrics = step_fn(values, opt, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            for node in monitor.last_seen:
                monitor.heartbeat(node, time.time() - t_step)
            step += 1
            if mgr and mgr.should_save(step):
                mgr.save(step, {"params": values, "opt": opt})
            if step % args.log_every == 0 or step == args.steps:
                dt = time.time() - t0
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}  "
                      f"({dt / max(step - start, 1):.2f}s/step)")
        if mgr:
            mgr.save(args.steps, {"params": values, "opt": opt},
                     blocking=True)
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps": step - start}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a step failure at this step (tests recovery)")
    args = ap.parse_args()
    out = run(args)
    print(f"done: loss {out['first_loss']:.4f} -> {out['last_loss']:.4f} "
          f"over {out['steps']} steps")


if __name__ == "__main__":
    main()
