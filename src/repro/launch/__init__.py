"""Launchers: mesh construction, multi-pod dry-run, training, serving.

NOTE: repro.launch.dryrun must be imported/run as the FIRST thing in a fresh
process (it sets XLA_FLAGS before any jax initialization).
"""

from .mesh import MODEL_AXIS, make_production_mesh, rules_for

__all__ = ["MODEL_AXIS", "make_production_mesh", "rules_for"]
