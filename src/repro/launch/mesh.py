"""Production meshes + per-arch/per-cell sharding rules.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16, 16) = (data, model), 256 chips.
Multi-pod: (2, 16, 16) = (pod, data, model), 512 chips — the pod axis
composes with data parallelism (hierarchical gradient all-reduce) by default
and can be re-bound to pipeline stages via parallel/pipeline.py.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax

from repro.models.config import ModelConfig
from repro.parallel.sharding import DEFAULT_RULES, LogicalRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


MODEL_AXIS = 16  # TP/EP degree on the production meshes


def rules_for(cfg: ModelConfig, kind: str,
              overrides: Optional[LogicalRules] = None) -> LogicalRules:
    """Sharding rules per (arch, cell-kind).

    Baseline strategy (paper-faithful starting point, tuned in §Perf):
      * train/prefill: batch -> (pod, data); TP on heads/ff/vocab/experts;
        ZeRO on the second weight axis of experts (fsdp).
      * decode: additionally shard the KV cache sequence on `model` (the
        per-chip cache would not fit otherwise at 32k x 128).
    Archs whose head counts don't divide the 16-way model axis shard inner
    projection dims instead (xlstm) — see DESIGN.md §5.
    """
    rules = dict(DEFAULT_RULES)
    if kind == "decode":
        rules["seq_kv"] = "model"
    if kind in ("prefill", "decode"):
        rules["fsdp"] = None        # no ZeRO at inference; params TP-only
    # head-count divisibility fixes
    if cfg.n_heads % MODEL_AXIS != 0:
        rules["heads"] = None
    if cfg.n_kv_heads % MODEL_AXIS != 0:
        rules["kv_heads"] = None
    if cfg.n_experts and cfg.n_experts % MODEL_AXIS != 0:
        rules["expert"] = None
    if cfg.d_ff and cfg.d_ff % MODEL_AXIS != 0:
        rules["ff"] = None
    if cfg.vocab % MODEL_AXIS != 0:
        rules["vocab"] = None
    if (2 * cfg.mamba_expand * cfg.d_model) % MODEL_AXIS != 0:
        rules["mamba_inner"] = None
    if (4 * cfg.d_model) % MODEL_AXIS != 0:
        rules["lstm_inner"] = None
    # long-context decode with batch 1: spread the sequence over everything
    if kind == "decode_long":
        rules["seq_kv"] = ("data", "model")
        rules["batch"] = None
        rules["fsdp"] = None
    if overrides:
        rules.update(overrides)
    return rules
