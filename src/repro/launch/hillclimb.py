import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver (§Perf): named sharding/execution variants per
cell, re-lowered and re-analyzed; results land in runs/hillclimb/ and the
hypothesis -> change -> before/after log goes into EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch glm4-9b --shape train_4k --variants baseline,sp,fsdp,sp_fsdp
"""

import argparse
import json
import traceback
from typing import Dict, Optional

from repro.configs import ARCHS, SHAPES
from repro.launch.dryrun import lower_cell

# Each variant: logical-rule overrides (+ optional microbatches).
# Hypotheses documented in EXPERIMENTS.md §Perf.
VARIANTS: Dict[str, Dict] = {
    # paper-faithful baseline: Megatron TP + batch DP (+ZeRO on experts)
    "baseline": {},
    # Megatron sequence parallelism: activations sharded on `model` along
    # seq between blocks -> the per-layer activation all-reduce becomes
    # all-gather/reduce-scatter pairs (2x wire -> 1x) and activation memory
    # drops 16x
    "sp": {"rules": {"seq": "model"}},
    # ZeRO-dominant: drop tensor parallelism on heads/ff; shard the weights'
    # embed axis across `data` (all-gather params per layer, reduce-scatter
    # grads). Collective payload scales with params instead of activations.
    "fsdp": {"rules": {"heads": None, "kv_heads": None, "ff": None,
                       "embed": "data", "lstm_inner": None,
                       "mamba_inner": None}},
    # both: SP for activations + ZeRO for params
    "sp_fsdp": {"rules": {"seq": "model", "heads": None, "kv_heads": None,
                          "ff": None, "embed": "data", "lstm_inner": None,
                          "mamba_inner": None}},
    # re-enable head sharding for archs with head counts that don't divide
    # the 16-way model axis (GSPMD pads the uneven shard; beats 16x
    # replicated attention compute)
    "uneven_heads": {"rules": {"heads": "model"}},
    "uneven_heads_sp": {"rules": {"heads": "model", "seq": "model"}},
    # deeper grad accumulation (activation temps / step)
    "mb2x": {"microbatches": "2x"},
    # expert-parallel emphasis for MoE: experts on model, ffn dims free
    "ep_sp": {"rules": {"seq": "model", "ff": None, "expert": "model"}},
    # pure data parallelism (tiny models: TP collectives >> grad all-reduce)
    "dp_only": {"rules": {"heads": None, "kv_heads": None, "ff": None,
                          "vocab": None, "expert": None, "fsdp": None,
                          "lstm_inner": None, "mamba_inner": None}},
    # DP + ZeRO on weights (params sharded over data, no TP)
    "dp_zero": {"rules": {"heads": None, "kv_heads": None, "ff": None,
                          "vocab": None, "expert": None,
                          "lstm_inner": None, "mamba_inner": None,
                          "embed": "data"}},
    # remat policy: save matmul outputs (fewer bwd re-gathers, more memory)
    "remat_dots": {"cfg": {"remat": "dots"}},
    "fsdp_dots": {"rules": {"heads": None, "kv_heads": None, "ff": None,
                            "embed": "data", "lstm_inner": None,
                            "mamba_inner": None},
                  "cfg": {"remat": "dots"}},
    "uneven_heads_fsdp": {"rules": {"heads": "model", "kv_heads": None,
                                    "ff": None, "embed": "data"}},
    # real Megatron-SP: only the block-boundary residual stream is
    # seq-sharded; TP internals untouched -> AR becomes RS + AG
    "sp2": {"rules": {"seq_res": "model"}},
    "sp2_fsdp": {"rules": {"seq_res": "model", "heads": None,
                           "kv_heads": None, "ff": None, "embed": "data",
                           "lstm_inner": None, "mamba_inner": None}},
    # shard the head_dim instead of heads (divisible when heads aren't):
    # scores/psum over the sharded contraction
    "head_dim_tp": {"rules": {"heads": None, "kv_heads": None,
                              "head_dim": "model"}},
    "sp2_headdim": {"rules": {"seq_res": "model", "heads": None,
                              "kv_heads": None, "head_dim": "model"}},
}


def run_variant(arch: str, shape: str, variant: str, multi_pod: bool,
                out_dir: str) -> Dict:
    spec = VARIANTS[variant]
    mb = spec.get("microbatches")
    if mb == "2x":
        from repro.configs import get_config
        from repro.launch.dryrun import default_microbatches
        cfg = get_config(arch)
        mb = 2 * default_microbatches(cfg, SHAPES[shape], multi_pod)
    try:
        row = lower_cell(arch, shape, multi_pod,
                         rules_overrides=spec.get("rules"),
                         microbatches=mb,
                         cfg_overrides=spec.get("cfg"))
        row["variant"] = variant
    except Exception as e:
        row = {"arch": arch, "shape": shape, "variant": variant,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-3000:]}
        print(f"[{arch}|{shape}|{variant}] FAIL {row['error']}")
    os.makedirs(out_dir, exist_ok=True)
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    with open(os.path.join(out_dir,
                           f"{arch}__{shape}__{mesh}__{variant}.json"),
              "w") as f:
        json.dump(row, f, indent=1, default=str)
    return row


def summarize(rows) -> None:
    print(f"\n{'variant':16s} {'tC(ms)':>9s} {'tM(ms)':>9s} {'tX(ms)':>10s} "
          f"{'bound':>10s} {'frac':>6s} {'mem(GiB)':>9s}")
    for r in rows:
        if "error" in r:
            print(f"{r['variant']:16s} FAILED: {r['error'][:60]}")
            continue
        mem = (r.get("temp_size_in_bytes", 0)
               + r.get("argument_size_in_bytes", 0)) / 2**30
        print(f"{r['variant']:16s} {r['t_compute_ms']:9.1f} "
              f"{r['t_memory_ms']:9.1f} {r['t_collective_ms']:10.1f} "
              f"{r['bottleneck']:>10s} {r['roofline_frac']:6.3f} {mem:9.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--shape", choices=sorted(SHAPES), required=True)
    ap.add_argument("--variants", default="baseline,sp,fsdp,sp_fsdp")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="runs/hillclimb")
    args = ap.parse_args()
    rows = [run_variant(args.arch, args.shape, v, args.multi_pod, args.out)
            for v in args.variants.split(",")]
    summarize(rows)


if __name__ == "__main__":
    main()
