"""Batched ``finish_cost`` arithmetic as jit-compiled jnp / Pallas kernels.

The accelerator-resident half of the ``jax`` executor backend
(:class:`repro.core.engine.JaxExecutor`): a whole GA generation's distinct
``(structure, AcceleratorConfig)`` queries arrive as struct-of-arrays int64
buffers and the capacity / streaming / weight-sharing arithmetic of
:func:`repro.core.cost.finish_cost` runs as one device call.

Bitwise parity with the scalar kernel is the contract (the engine's guards
keep every lane below ``2**53`` / int64-product-safe, see
:func:`repro.core.engine.needs_scalar_fallback`), which pins the numerics:

* all integer work is int64 under ``jax.experimental.enable_x64`` (the
  context manager keeps x64 scoped to these calls — the rest of the repo's
  jax code stays in its default 32-bit world);
* the streaming block count mirrors ``_stream_single_layer`` exactly:
  ``ceil`` of a float64 true division, whose operands are exact below
  ``2**53`` and whose IEEE result is therefore identical to the scalar
  ``math.ceil(fp / glb)``.

Batches are padded to the next power of two so GA generations of drifting
size (cache warmth changes the miss count every round) reuse a handful of
compiled kernels instead of recompiling per shape; the arithmetic is
element-wise, so padding lanes can never perturb real lanes.

Two interchangeable variants, both validated by the differential-parity
suite (``tests/test_backend_parity.py``):

* :func:`_finish_jnp` — the default: the whole arithmetic as one jitted
  jnp expression.
* :func:`_finish_pallas` — the hot streaming-block sweep
  (``n_blocks`` / ``ema_w`` / capped footprint) as a Pallas kernel in the
  idiom of the other kernels in this package (interpret mode off-TPU),
  with the cheap mask algebra staying in jnp.  Selected by
  ``JaxExecutor(pallas=True)`` or ``$REPRO_JAX_PALLAS=1``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

# Pallas grid tile for the streaming-block sweep; a power of two so the
# pow2-padded batch is always an exact number of tiles
_STREAM_BLOCK = 256


def _finish_masks(fp, wr, w_total, single, glb, wbuf, shared,
                  n_blocks):
    """The mask algebra shared by both variants (pure jnp, element-wise).

    Mirrors ``finish_cost``'s branch structure: buffer overflow splits into
    infeasible (multi-node) vs streaming (single-node); separate-buffer
    weight overflow only ever invalidates multi-node subgraphs.
    """
    wbuf_cap = jnp.where(shared, glb, wbuf)
    overflow = jnp.where(shared, fp + wr > glb, fp > glb)
    infeasible_buf = overflow & ~single
    stream = overflow & single
    ema_w = jnp.where(stream, wr * n_blocks, w_total)
    fp_out = jnp.where(stream, jnp.minimum(fp, glb), fp)
    w_overflow = ~shared & ~single & ~infeasible_buf & (wr > wbuf_cap)
    feasible = ~(infeasible_buf | w_overflow)
    return ema_w, fp_out, infeasible_buf, w_overflow, stream, feasible


def _noc_bytes(share, ema_w):
    """§5.4.2 NoC charge, mirroring ``finish_cost``: every DRAM-loaded
    weight byte crosses the fabric to the ``share - 1`` peer cores.  The
    engine's guards bound ``share * w_total`` below ``2**31``, so the
    product stays int64-safe even for a streamed ``ema_w``."""
    return (share - 1) * ema_w


@jax.jit
def _finish_jnp(fp, w_total, single, glb, wbuf, shared, share):
    """Whole-batch ``finish_cost`` arithmetic as one jitted jnp expression."""
    wr = w_total // share
    # mirrors _stream_single_layer: math.ceil of a float64 true division
    n_blocks = jnp.maximum(
        jnp.ceil(fp / jnp.maximum(glb, 1)).astype(jnp.int64), 1)
    (ema_w, fp_out, infeasible_buf, w_overflow, stream,
     feasible) = _finish_masks(fp, wr, w_total, single, glb, wbuf, shared,
                               n_blocks)
    return (wr, n_blocks, ema_w, fp_out, _noc_bytes(share, ema_w),
            infeasible_buf, w_overflow, stream, feasible)


def _stream_blocks_kernel(fp_ref, glb_ref, wr_ref,
                          nb_ref, emaw_ref, fpcap_ref):
    """Pallas kernel: one tile of the single-layer streaming-block sweep.

    Computes, per lane: the row-block count (``ceil`` of the float64 true
    division, exactly as ``_stream_single_layer``), the re-streamed weight
    bytes ``wr * n_blocks``, and the buffer-capped footprint.  Whether a
    lane actually streams is decided by the jnp mask algebra outside — the
    kernel is pure arithmetic, so every lane computes unconditionally.
    """
    fp = fp_ref[...]
    glb = glb_ref[...]
    nb = jnp.maximum(jnp.ceil(fp / jnp.maximum(glb, 1)).astype(jnp.int64), 1)
    nb_ref[...] = nb
    emaw_ref[...] = wr_ref[...] * nb
    fpcap_ref[...] = jnp.minimum(fp, glb)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _finish_pallas(fp, w_total, single, glb, wbuf, shared, share,
                   interpret=True):
    """Variant routing the streaming-block sweep through the Pallas kernel."""
    n = fp.shape[0]
    block = min(_STREAM_BLOCK, n)  # both powers of two => exact tiling
    spec = pl.BlockSpec((block,), lambda i: (i,))
    wr = w_total // share
    nb, emaw_stream, fp_cap = pl.pallas_call(
        _stream_blocks_kernel,
        grid=(n // block,),
        in_specs=[spec, spec, spec],
        out_specs=(spec, spec, spec),
        out_shape=tuple(jax.ShapeDtypeStruct((n,), jnp.int64)
                        for _ in range(3)),
        interpret=interpret,
    )(fp, glb, wr)
    (ema_w, fp_out, infeasible_buf, w_overflow, stream,
     feasible) = _finish_masks(fp, wr, w_total, single, glb, wbuf, shared,
                               nb)
    # the mask algebra re-selects from the kernel's unconditional results
    ema_w = jnp.where(stream, emaw_stream, ema_w)
    fp_out = jnp.where(stream, fp_cap, fp_out)
    return (wr, nb, ema_w, fp_out, _noc_bytes(share, ema_w),
            infeasible_buf, w_overflow, stream, feasible)


def _pad_pow2(arr: np.ndarray, fill) -> np.ndarray:
    n = len(arr)
    m = 1
    while m < n:
        m *= 2
    if m == n:
        return arr
    out = np.full(m, fill, dtype=arr.dtype)
    out[:n] = arr
    return out


def finish_cost_batch(fp, w_total, single, glb, wbuf, shared, share,
                      use_pallas: bool = False) -> Tuple[np.ndarray, ...]:
    """Evaluate a batch of ``finish_cost`` queries on the jax device.

    Inputs are index-aligned equal-length arrays (int64 values, bool
    masks); every lane must already satisfy the engine's scalar-fallback
    guards.  Returns ``(wr, n_blocks, ema_w, fp_out, noc, infeasible_buf,
    w_overflow, stream, feasible)`` as NumPy arrays, bit-identical to the
    scalar kernel and to :class:`repro.core.engine.VectorExecutor`.
    """
    n = len(fp)
    if n == 0:
        empty_i = np.zeros(0, dtype=np.int64)
        empty_b = np.zeros(0, dtype=bool)
        return (empty_i,) * 5 + (empty_b,) * 4
    # pad to the next power of two: neutral lanes (glb/share=1 avoids any
    # divide-by-zero path) that the element-wise arithmetic cannot couple
    # into real lanes
    args = (
        _pad_pow2(np.asarray(fp, dtype=np.int64), 0),
        _pad_pow2(np.asarray(w_total, dtype=np.int64), 0),
        _pad_pow2(np.asarray(single, dtype=bool), False),
        _pad_pow2(np.asarray(glb, dtype=np.int64), 1),
        _pad_pow2(np.asarray(wbuf, dtype=np.int64), 1),
        _pad_pow2(np.asarray(shared, dtype=bool), False),
        _pad_pow2(np.asarray(share, dtype=np.int64), 1),
    )
    with enable_x64():
        jargs = tuple(jnp.asarray(a) for a in args)
        if use_pallas:
            # interpret everywhere but real TPUs, like the other kernels
            interpret = jax.default_backend() != "tpu"
            outs = _finish_pallas(*jargs, interpret=interpret)
        else:
            outs = _finish_jnp(*jargs)
        return tuple(np.asarray(o)[:n] for o in outs)
