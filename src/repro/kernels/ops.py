"""Jit'd public wrappers around the Pallas kernels with ref fallbacks."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .fused_ffn import fused_swiglu
from .rmsnorm import fused_rmsnorm


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "use_kernel"))
def attention(q, k, v, causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128,
              use_kernel: bool = True):
    if not use_kernel:
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("block_m", "block_f", "use_kernel"))
def swiglu(x, wg, wi, wo, block_m: int = 256, block_f: int = 512,
           use_kernel: bool = True):
    if not use_kernel:
        return ref.swiglu_ref(x, wg, wi, wo)
    return fused_swiglu(x, wg, wi, wo, block_m=block_m, block_f=block_f)


@partial(jax.jit, static_argnames=("eps", "block_m", "use_kernel"))
def rmsnorm(x, scale, eps: float = 1e-5, block_m: int = 256,
            use_kernel: bool = True):
    if not use_kernel:
        return ref.rmsnorm_ref(x, scale, eps)
    return fused_rmsnorm(x, scale, eps=eps, block_m=block_m)
