"""Fused RMSNorm kernel: one pass over each row block, fp32 statistics."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # [bm, d]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def fused_rmsnorm(
    x: jnp.ndarray,                  # [M, d]
    scale: jnp.ndarray,              # [d]
    eps: float = 1e-5,
    block_m: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    M, d = x.shape
    block_m = min(block_m, M)
    assert M % block_m == 0
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kernel = functools.partial(_rms_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, d), x.dtype),
        interpret=interpret,
    )(x, scale)
