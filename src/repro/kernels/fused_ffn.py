"""Fused SwiGLU FFN kernel: the paper's subgraph-in-buffer idea on the FFN
sub-DAG.  The [rows, d_ff] hidden activation (up to 2x d_ff floats/token —
the dominant intermediate of an LLM block) never leaves VMEM: each grid step
computes an [block_m, block_f] tile of silu(x@Wg) * (x@Wi) in scratch and
immediately folds it into the output accumulator via Wo.

Grid: (m_blocks, f_blocks) with f innermost sequential; the accumulator is
the MAIN region, weight tiles stream like the paper's input regions.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:  # pragma: no cover
    def _CompilerParams(**_kw):
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; incompatible jax version")


def _ffn_kernel(x_ref, wg_ref, wi_ref, wo_ref, o_ref, acc_ref, *, nf: int):
    fb = pl.program_id(1)

    @pl.when(fb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                    # [bm, d]
    wg = wg_ref[...].astype(jnp.float32)                  # [d, bf]
    wi = wi_ref[...].astype(jnp.float32)
    g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wi, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * u                                # [bm, bf] stays in VMEM
    wo = wo_ref[...].astype(jnp.float32)                  # [bf, d]
    acc_ref[...] += jax.lax.dot_general(h, wo, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(fb == nf - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_swiglu(
    x: jnp.ndarray,                  # [M, d]
    wg: jnp.ndarray,                 # [d, f]
    wi: jnp.ndarray,                 # [d, f]
    wo: jnp.ndarray,                 # [f, d]
    block_m: int = 256,
    block_f: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    M, d = x.shape
    f = wg.shape[1]
    assert wg.shape == (d, f) and wi.shape == (d, f) and wo.shape == (f, d)
    block_m = min(block_m, M)
    block_f = min(block_f, f)
    assert M % block_m == 0 and f % block_f == 0, (M, f, block_m, block_f)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nm, nf = M // block_m, f // block_f

    kernel = functools.partial(_ffn_kernel, nf=nf)
    return pl.pallas_call(
        kernel,
        grid=(nm, nf),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((d, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((block_f, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, wg, wi, wo)
