"""Pallas TPU kernels for the perf-critical sub-DAGs (DESIGN.md §6):
flash attention, fused SwiGLU FFN, fused RMSNorm — each with a pure-jnp
oracle in ref.py and interpret-mode validation in tests/test_kernels.py.

``finish_batch`` (imported as a submodule, not re-exported here) holds the
batched cost-kernel arithmetic behind the ``jax`` executor backend
(:mod:`repro.core.engine`); its oracle is the scalar
:func:`repro.core.cost.finish_cost` and its validation is the
differential-parity suite in tests/test_backend_parity.py."""

from .flash_attention import flash_attention
from .fused_ffn import fused_swiglu
from .ops import attention, rmsnorm, swiglu
from .rmsnorm import fused_rmsnorm

__all__ = ["attention", "flash_attention", "fused_rmsnorm", "fused_swiglu",
           "rmsnorm", "swiglu"]
