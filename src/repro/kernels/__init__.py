"""Pallas TPU kernels for the perf-critical sub-DAGs (DESIGN.md §6):
flash attention, fused SwiGLU FFN, fused RMSNorm — each with a pure-jnp
oracle in ref.py and interpret-mode validation in tests/test_kernels.py."""

from .flash_attention import flash_attention
from .fused_ffn import fused_swiglu
from .ops import attention, rmsnorm, swiglu
from .rmsnorm import fused_rmsnorm

__all__ = ["attention", "flash_attention", "fused_rmsnorm", "fused_swiglu",
           "rmsnorm", "swiglu"]
