"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, window: int = 0,
                  scale: Optional[float] = None):
    """q,k,v: [B, H, S, d] -> [B, H, S, d] (fp32 math)."""
    *_, S, d = q.shape
    scale = scale or 1.0 / math.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window:
        mask &= ki > qi - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(
        q.dtype)


def swiglu_ref(x, wg, wi, wo):
    """x: [M, d]; wg,wi: [d, f]; wo: [f, d] (fp32 accumulation)."""
    xf = x.astype(jnp.float32)
    h = jax.nn.silu(xf @ wg.astype(jnp.float32)) * (xf @ wi.astype(jnp.float32))
    return (h @ wo.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    """x: [M, d]; scale: [d]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype)
