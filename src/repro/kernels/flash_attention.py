"""Flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

This kernel *is* the paper's consumption-centric scheme specialized to
attention: the output tile (block_q rows) drives backward derivation of the
K/V tiles it consumes; the S x S score matrix — the production-centric
strawman — never exists in HBM.  The MAIN region is the (acc, m, l) VMEM
scratch; K/V blocks stream through like the paper's input-node regions.

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost and sequential
("arbitrary") so the online-softmax carry lives in VMEM scratch across kv
steps.  Causal/windowed masking is applied in-block; dead blocks (entirely
above the diagonal or outside the window) skip their compute via pl.when.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:  # pragma: no cover
    def _CompilerParams(**_kw):
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; incompatible jax version")

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int,
                 block_q: int, block_k: int, nk: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level liveness: skip blocks fully above the causal diagonal or
    # fully left of the sliding window
    live = jnp.bool_(True)
    if causal:
        live = (kb * block_k) <= (qb * block_q + block_q - 1)
    if window:
        live = jnp.logical_and(
            live, (kb * block_k + block_k - 1) > (qb * block_q - window))

    @pl.when(live)
    def _step():
        q_pos = qb * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        q = q_ref[0].astype(jnp.float32)                  # [bq, d]
        k = k_ref[0].astype(jnp.float32)                  # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)                  # [bk, d]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    causal: bool = True, window: int = 0,
    block_q: int = 128, block_k: int = 128,
    scale: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """q,k,v: [B, H, S, d] -> [B, H, S, d]."""
    B, H, S, d = q.shape
    assert k.shape == (B, H, S, d) and v.shape == (B, H, S, d)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    scale = scale or 1.0 / math.sqrt(d)
    nq, nk = S // block_q, S // block_k

    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * H, S, d)
    vf = v.reshape(B * H, S, d)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc  (MAIN region)
            pltpu.VMEM((block_q,), jnp.float32),     # running max m
            pltpu.VMEM((block_q,), jnp.float32),     # running denom l
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d)
