"""Checkpoint manager: retention, resume, async save, elastic resharding."""

from __future__ import annotations

import os
import shutil
import threading
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import numpy as np

from .io import checkpoint_steps, load_checkpoint, save_checkpoint


@dataclass
class CheckpointConfig:
    directory: str
    save_every: int = 100
    keep_last: int = 3
    keep_every: int = 0            # additionally keep every k-th (0 = off)
    async_save: bool = True


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self._pending: Optional[threading.Thread] = None
        os.makedirs(cfg.directory, exist_ok=True)

    # -- save --------------------------------------------------------------
    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.cfg.save_every == 0

    def save(self, step: int, tree, extra_meta: Optional[Dict] = None,
             blocking: Optional[bool] = None) -> None:
        """Device->host transfer happens synchronously (snapshot semantics);
        the file write runs on a background thread unless blocking."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            save_checkpoint(self.cfg.directory, step, host_tree, extra_meta)
            self._retain()

        if blocking or not self.cfg.async_save:
            work()
        else:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _retain(self):
        steps = checkpoint_steps(self.cfg.directory)
        keep = set(steps[-self.cfg.keep_last:])
        if self.cfg.keep_every:
            keep |= {s for s in steps if s % self.cfg.keep_every == 0}
        for s in steps:
            if s not in keep:
                shutil.rmtree(os.path.join(self.cfg.directory,
                                           f"step_{s:08d}"),
                              ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = checkpoint_steps(self.cfg.directory)
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None):
        return load_checkpoint(self.cfg.directory, step, template)


# ---------------------------------------------------------------------------
# elastic resharding: restore a checkpoint into a different mesh/device count
# ---------------------------------------------------------------------------

def reshard_to(tree, shardings):
    """Place host arrays according to new shardings (elastic restart after a
    mesh-shape change: the host holds full arrays, jax.device_put splits them
    for the new topology)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
        tree, shardings)
