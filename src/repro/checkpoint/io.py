"""Atomic sharded checkpoints (fault-tolerance substrate).

Layout:  <dir>/step_<N>/
            meta.json            — step, tree structure, shapes/dtypes, hash
            arrays_<k>.npz       — leaf shards (chunked to cap file size)
            _COMMITTED           — written last; a checkpoint without the
                                   marker is ignored (crash-safe)

Writes go to ``step_<N>.tmp.<pid>`` then ``os.rename`` (atomic on POSIX), so
a process killed mid-save can never corrupt the latest checkpoint — the
restart-safety property the runtime layer depends on.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MAX_SHARD_BYTES = 512 << 20


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return items, treedef


def save_checkpoint(directory: str, step: int, tree,
                    extra_meta: Optional[Dict] = None) -> str:
    """Blocking save; returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp.",
                           dir=directory)
    items, _ = _flatten(tree)
    # chunk leaves into npz shards
    shards: List[Dict[str, np.ndarray]] = [{}]
    sizes = [0]
    index: Dict[str, int] = {}
    for name, leaf in items:
        arr = np.asarray(leaf)
        if sizes[-1] + arr.nbytes > MAX_SHARD_BYTES and shards[-1]:
            shards.append({})
            sizes.append(0)
        shards[-1][name] = arr
        sizes[-1] += arr.nbytes
        index[name] = len(shards) - 1
    digest = hashlib.sha256()
    for i, shard in enumerate(shards):
        path = os.path.join(tmp, f"arrays_{i}.npz")
        np.savez(path, **shard)
        with open(path, "rb") as f:
            digest.update(f.read())
    meta = {
        "step": step,
        "index": index,
        "n_shards": len(shards),
        "leaves": {n: {"shape": list(np.asarray(l).shape),
                       "dtype": str(np.asarray(l).dtype)}
                   for n, l in items},
        "sha256": digest.hexdigest(),
        "extra": extra_meta or {},
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def checkpoint_steps(directory: str) -> List[int]:
    """Committed checkpoints, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp." not in name:
            if os.path.exists(os.path.join(directory, name, "_COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def load_checkpoint(directory: str, step: Optional[int] = None,
                    template=None, verify: bool = True):
    """Returns (tree, meta).  With ``template``, leaves are restored into the
    template's tree structure (and resharded to its shapes if the leading
    dimension layout changed — see manager.reshard)."""
    steps = checkpoint_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if verify:
        digest = hashlib.sha256()
        for i in range(meta["n_shards"]):
            with open(os.path.join(path, f"arrays_{i}.npz"), "rb") as f:
                digest.update(f.read())
        if digest.hexdigest() != meta["sha256"]:
            raise IOError(f"checkpoint {path} failed hash verification")
    arrays: Dict[str, np.ndarray] = {}
    for i in range(meta["n_shards"]):
        with np.load(os.path.join(path, f"arrays_{i}.npz")) as z:
            for k in z.files:
                arrays[k] = z[k]
    if template is None:
        return arrays, meta
    items, treedef = _flatten(template)
    leaves = []
    for name, tmpl_leaf in items:
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        leaves.append(arrays[name])
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, meta
