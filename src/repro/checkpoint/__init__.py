from .io import checkpoint_steps, load_checkpoint, save_checkpoint
from .manager import CheckpointConfig, CheckpointManager, reshard_to

__all__ = [k for k in dir() if not k.startswith("_")]
