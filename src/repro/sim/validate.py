"""Analytical <-> simulated cross-validation.

The trace simulator is a *lowering* of the analytical cost kernel, so for
every feasible plan the two must agree exactly:

* per subgraph, simulated DRAM bytes (external loads, output stores,
  weight first-load + re-streaming) equal the kernel's
  ``ema_in`` / ``ema_out`` / ``ema_w``,
* per subgraph, simulated NoC broadcast bytes equal the kernel's §5.4.2
  charge ``noc_bytes`` (and the step-level fabric traffic sums to the
  same total),
* the plan's simulated totals equal ``PlanCost.ema_total`` /
  ``PlanCost.noc_total`` byte-for-byte,
* the timeline's total duration equals ``PlanCost.latency_cycles`` plus
  the weight prologue (floating-point, checked to relative 1e-9).

Any drift means the simulator and the cost model disagree about what a
plan *does* — the golden workloads in ``tests/test_sim.py`` run this
check for every scheme's GA and greedy plans, which turns them into an
end-to-end oracle for the cost model.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set

from repro.core.cost import AcceleratorConfig, PlanCost
from repro.core.graph import Graph

from .trace import TrafficTrace, simulate_plan


@dataclass(frozen=True)
class SubgraphCheck:
    """One subgraph's analytical-vs-simulated byte comparison."""

    index: int
    nodes: tuple
    ema_in_analytical: int
    ema_in_simulated: int
    ema_out_analytical: int
    ema_out_simulated: int
    ema_w_analytical: int
    ema_w_simulated: int
    noc_analytical: int = 0
    noc_simulated: int = 0

    @property
    def ok(self) -> bool:
        return (self.ema_in_analytical == self.ema_in_simulated
                and self.ema_out_analytical == self.ema_out_simulated
                and self.ema_w_analytical == self.ema_w_simulated
                and self.noc_analytical == self.noc_simulated)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "index": self.index, "nodes": list(self.nodes), "ok": self.ok,
            "analytical": {"in": self.ema_in_analytical,
                           "out": self.ema_out_analytical,
                           "w": self.ema_w_analytical,
                           "noc": self.noc_analytical},
            "simulated": {"in": self.ema_in_simulated,
                          "out": self.ema_out_simulated,
                          "w": self.ema_w_simulated,
                          "noc": self.noc_simulated},
        }


@dataclass
class CrossValidationReport:
    """Whole-plan verdict plus the per-subgraph evidence."""

    checks: List[SubgraphCheck]
    total_analytical: int
    total_simulated: int
    latency_analytical: float       # PlanCost.latency_cycles
    latency_simulated: float        # trace total minus the weight prologue
    noc_analytical: int = 0         # PlanCost.noc_total (§5.4.2 charge)
    noc_simulated: int = 0          # step-level fabric traffic sum

    @property
    def bytes_ok(self) -> bool:
        return (self.total_analytical == self.total_simulated
                and self.noc_analytical == self.noc_simulated
                and all(c.ok for c in self.checks))

    @property
    def latency_ok(self) -> bool:
        return math.isclose(self.latency_analytical, self.latency_simulated,
                            rel_tol=1e-9, abs_tol=1e-6)

    @property
    def ok(self) -> bool:
        return self.bytes_ok and self.latency_ok

    def to_dict(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "total_analytical_bytes": self.total_analytical,
            "total_simulated_bytes": self.total_simulated,
            "noc_analytical_bytes": self.noc_analytical,
            "noc_simulated_bytes": self.noc_simulated,
            "latency_analytical_cycles": self.latency_analytical,
            "latency_simulated_cycles": self.latency_simulated,
            "subgraphs": [c.to_dict() for c in self.checks],
        }

    def summary(self) -> str:
        if self.ok:
            noc = (f" + NoC {self.noc_simulated} B"
                   if self.noc_simulated else "")
            return (f"cross-validation OK: simulated DRAM bytes == "
                    f"analytical EMA ({self.total_simulated} B over "
                    f"{len(self.checks)} subgraphs{noc})")
        bad = [c.index for c in self.checks if not c.ok]
        return (f"cross-validation FAILED: simulated {self.total_simulated} "
                f"B vs analytical {self.total_analytical} B "
                f"(mismatched subgraphs: {bad or 'totals/latency only'})")

    def raise_if_failed(self) -> None:
        if not self.ok:
            raise AssertionError(self.summary())


def cross_validate_trace(trace: TrafficTrace,
                         plan: Optional[PlanCost] = None,
                         ) -> CrossValidationReport:
    """Compare an existing trace against its (or a caller's) plan cost."""
    plan = plan if plan is not None else trace.plan
    if plan is None:
        raise ValueError("cross-validation needs the analytical PlanCost")
    if len(plan.subgraphs) != len(trace.subgraphs):
        raise ValueError(
            f"plan has {len(plan.subgraphs)} subgraphs but the trace has "
            f"{len(trace.subgraphs)}")
    checks = [
        SubgraphCheck(
            index=i, nodes=tuple(sc.nodes),
            ema_in_analytical=sc.ema_in, ema_in_simulated=sg.act_in,
            ema_out_analytical=sc.ema_out, ema_out_simulated=sg.act_out,
            ema_w_analytical=sc.ema_w,
            ema_w_simulated=sg.w_first + sg.w_stream,
            noc_analytical=sc.noc_bytes,
            noc_simulated=sg.noc_bytes,
        )
        for i, (sc, sg) in enumerate(zip(plan.subgraphs, trace.subgraphs))
    ]
    prologue = sum(s.cycles for s in trace.steps if s.subgraph < 0)
    return CrossValidationReport(
        checks=checks,
        total_analytical=plan.ema_total,
        total_simulated=sum(sg.dram_bytes for sg in trace.subgraphs),
        latency_analytical=plan.latency_cycles,
        latency_simulated=trace.total_cycles - prologue,
        # step-level fabric traffic (incl. the prologue broadcast) must sum
        # to the same §5.4.2 charge the per-subgraph checks compare
        noc_analytical=plan.noc_total,
        noc_simulated=trace.total_noc_bytes,
    )


def cross_validate(
    g: Graph,
    groups: Sequence[Set[int]],
    acc: AcceleratorConfig,
    out_tile: int = 1,
) -> CrossValidationReport:
    """Simulate ``groups`` and compare against the analytical kernel."""
    trace = simulate_plan(g, groups, acc, out_tile=out_tile)
    return cross_validate_trace(trace)
