"""Plan-level trace simulator: time-stepped DRAM traffic for whole plans.

Where :mod:`repro.core.simulate` validates one subgraph's row dataflow
with real data, this package executes an entire partition plan over time:
subgraphs in schedule order, tile/row granular, under the
consumption-centric memory-management scheme, with the next subgraph's
weights prefetched (double-buffered) beneath the current compute.  The
result is a :class:`TrafficTrace` — per-step DRAM bytes in/out, buffer
occupancy, and a derived :class:`BandwidthProfile` (peak, percentiles,
sustained) — plus a cross-validation layer asserting the simulated totals
equal the analytical kernel's EMA byte-for-byte.

Quickstart::

    from repro.api import build_workload
    from repro.core import AcceleratorConfig
    from repro.sim import cross_validate, simulate_plan

    g = build_workload("synthetic:layered:12?seed=1")
    groups = [{v} for v in range(g.n)]           # or a search result's plan
    trace = simulate_plan(g, groups, AcceleratorConfig())
    print(trace.bandwidth_profile())
    cross_validate(g, groups, AcceleratorConfig()).raise_if_failed()

CLI: ``python -m repro trace <workload-uri> [--out trace.json]``.
"""

from .bandwidth import (
    DEFAULT_PERCENTILES,
    BandwidthProfile,
    profile_from_steps,
)
from .lower import StepTraffic, SubgraphProgram, lower_plan, lower_subgraph
from .trace import (
    PROLOGUE,
    TRACE_FORMAT,
    TRACE_FORMAT_VERSION,
    SubgraphTrafficSummary,
    TraceStep,
    TrafficTrace,
    simulate_plan,
)
from .validate import (
    CrossValidationReport,
    SubgraphCheck,
    cross_validate,
    cross_validate_trace,
)

__all__ = [
    "BandwidthProfile",
    "CrossValidationReport",
    "DEFAULT_PERCENTILES",
    "PROLOGUE",
    "StepTraffic",
    "SubgraphCheck",
    "SubgraphProgram",
    "SubgraphTrafficSummary",
    "TRACE_FORMAT",
    "TRACE_FORMAT_VERSION",
    "TraceStep",
    "TrafficTrace",
    "cross_validate",
    "cross_validate_trace",
    "lower_plan",
    "lower_subgraph",
    "profile_from_steps",
    "simulate_plan",
]
