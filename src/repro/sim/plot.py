"""Bandwidth-over-time plotting for traces (``trace --plot out.png``).

matplotlib is an *optional* dependency, gated exactly like the jax
backend: :func:`plot_status` answers "could we plot?" without importing
anything heavy, and :func:`plot_bandwidth` raises a friendly
``RuntimeError`` (the CLI turns it into an exit-2 message) when the
library is absent.  Nothing else in the package imports matplotlib, so
every other subcommand works on a matplotlib-free install.
"""

from __future__ import annotations

import os
from typing import Any, List, Optional, Tuple

__all__ = ["plot_status", "plot_bandwidth"]


def plot_status() -> Tuple[bool, str]:
    """(available, reason-or-version) without rendering anything."""
    try:
        import matplotlib
    except ImportError as err:
        return False, (
            "trace --plot needs the optional matplotlib dependency "
            f"(pip install matplotlib): {err}")
    return True, f"matplotlib {matplotlib.__version__}"


def _series(trace: Any, bins: int) -> Tuple[
        List[float], List[float], List[float], List[float]]:
    """Resample the step timeline into ``bins`` equal time buckets.

    Returns (t_ms, dram_gbps, noc_gbps, occ_mb): bucket DRAM/NoC
    bandwidth is bucket bytes over bucket time; occupancy is the last
    step's total (act + weight) resident bytes in the bucket.
    """
    total_cycles = trace.total_cycles
    freq = trace.acc.freq_hz
    n = max(1, bins)
    width = total_cycles / n if total_cycles > 0 else 1.0
    dram = [0.0] * n
    noc = [0.0] * n
    occ = [0.0] * n
    occ_t = [-1.0] * n
    for s in trace.steps:
        # apportion a step's bytes over the buckets its duration spans
        b0 = min(n - 1, int(s.t_cycles / width))
        b1 = min(n - 1, int((s.t_cycles + s.cycles) / width)) if s.cycles \
            else b0
        span = b1 - b0 + 1
        for b in range(b0, b1 + 1):
            dram[b] += s.dram_bytes / span
            noc[b] += s.noc_bytes / span
        if s.t_cycles >= occ_t[b1]:
            occ_t[b1] = s.t_cycles
            occ[b1] = float(s.occ_act + s.occ_w)
    # carry occupancy forward through empty buckets
    last = 0.0
    for b in range(n):
        if occ_t[b] < 0:
            occ[b] = last
        last = occ[b]
    t_ms = [(b + 0.5) * width / freq * 1e3 for b in range(n)]
    secs = width / freq
    dram_gbps = [v / secs / 1e9 for v in dram]
    noc_gbps = [v / secs / 1e9 for v in noc]
    occ_mb = [v / 1e6 for v in occ]
    return t_ms, dram_gbps, noc_gbps, occ_mb


def plot_bandwidth(trace: Any, path: str, bins: int = 256,
                   title: Optional[str] = None) -> None:
    """Render DRAM/NoC bandwidth (and buffer occupancy) over time to
    ``path`` (format from the extension; Agg backend, no display)."""
    ok, why = plot_status()
    if not ok:
        raise RuntimeError(why)
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    t_ms, dram_gbps, noc_gbps, occ_mb = _series(trace, bins)
    prof = trace.bandwidth_profile()
    fig, (ax, ax2) = plt.subplots(
        2, 1, sharex=True, figsize=(10, 6),
        gridspec_kw={"height_ratios": [3, 1]})
    ax.step(t_ms, dram_gbps, where="mid", label="DRAM", lw=1.2)
    if any(noc_gbps):
        ax.step(t_ms, noc_gbps, where="mid", label="NoC broadcast", lw=1.0)
    for name, val, style in (
            ("p95", prof.percentiles["p95"] / 1e9, ":"),
            ("sustained", prof.sustained / 1e9, "--")):
        ax.axhline(val, ls=style, lw=0.8, color="gray")
        ax.annotate(f"{name} {val:.2f}", xy=(t_ms[-1], val),
                    fontsize=7, color="gray",
                    ha="right", va="bottom")
    ax.set_ylabel("bandwidth (GB/s)")
    ax.legend(loc="upper right", fontsize=8)
    ax.set_title(title or f"{trace.graph_name}: bandwidth over time "
                          f"({len(trace.steps)} steps)")
    ax2.step(t_ms, occ_mb, where="mid", color="tab:green", lw=1.0)
    ax2.set_ylabel("occupancy (MB)")
    ax2.set_xlabel("time (ms)")
    fig.tight_layout()
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    fig.savefig(path, dpi=120)
    plt.close(fig)
