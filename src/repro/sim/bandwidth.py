"""Bandwidth-requirement metrics derived from a traffic trace.

The paper's headline claims are byte counts *and* bandwidth requirements
(Fig. 3): a plan that moves the same bytes in shorter bursts needs a wider
DRAM interface.  From a step sequence this module derives

* ``peak``      — the largest per-step bandwidth (bytes/s),
* ``sustained`` — total bytes over total time,
* ``p50/p95/p99`` — time-weighted percentiles of per-step bandwidth, the
  statistic the ``bandwidth`` objective metric optimizes (the plan-level
  :meth:`~repro.core.cost.PlanCost.bandwidth_percentile` is this profile
  computed at one-segment-per-subgraph resolution).

Percentiles share :func:`repro.core.cost.time_weighted_percentile` with the
analytical layer so the two agree exactly at equal resolution.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Sequence, Tuple

from repro.core.cost import time_weighted_percentile

DEFAULT_PERCENTILES: Tuple[float, ...] = (50.0, 95.0, 99.0)


@dataclass(frozen=True)
class BandwidthProfile:
    """Bandwidth requirement statistics of one trace (bytes/s)."""

    peak: float
    sustained: float
    percentiles: Dict[str, float]       # {"p50": ..., "p95": ..., "p99": ...}
    total_bytes: int
    total_cycles: float

    def to_dict(self) -> Dict[str, float]:
        d = {"peak": self.peak, "sustained": self.sustained,
             "total_bytes": self.total_bytes,
             "total_cycles": self.total_cycles}
        d.update(self.percentiles)
        return d


def profile_from_steps(
    steps: Iterable[Tuple[int, float]],
    freq_hz: float,
    percentiles: Sequence[float] = DEFAULT_PERCENTILES,
    totals: Optional[Tuple[int, float]] = None,
) -> BandwidthProfile:
    """Build a profile from ``(dram_bytes, duration_cycles)`` steps.

    ``steps`` feeds the *requirement* statistics (peak, percentiles);
    ``totals`` optionally overrides ``(total_bytes, total_cycles)`` to
    additionally count phases excluded from those statistics — the weight
    prologue streams at the DRAM link rate with nothing to overlap, so its
    bandwidth is the interface rate by definition and would floor every
    plan's peak at that constant if it entered the max.  Zero-duration
    steps carry no time weight and are likewise excluded from statistics
    (their bytes still count toward totals).
    """
    items = list(steps)
    if totals is None:
        totals = (sum(b for b, _ in items), sum(c for _, c in items))
    total_bytes, total_cycles = totals
    pairs = [(b / c * freq_hz, c) for b, c in items if c > 0]
    peak = max((bw for bw, _ in pairs), default=0.0)
    sustained = (total_bytes / total_cycles * freq_hz
                 if total_cycles > 0 else 0.0)
    pcts = {f"p{int(p) if float(p).is_integer() else p}":
            time_weighted_percentile(pairs, p) for p in percentiles}
    return BandwidthProfile(peak=peak, sustained=sustained,
                            percentiles=pcts, total_bytes=total_bytes,
                            total_cycles=total_cycles)
