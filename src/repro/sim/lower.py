"""Lowering: one subgraph -> a row-granular step program.

The analytical kernel (:mod:`repro.core.cost`) gives a subgraph three
traffic sums (``ema_in``/``ema_out``/``ema_w``); the consumption-centric
schedule (:mod:`repro.core.tiling`) gives every resident tensor an update
quantum (``delta`` rows per update, ``upd_num`` updates per elementary
operation).  Lowering composes the two into a :class:`SubgraphProgram`: a
sequence of steps (one per elementary operation) that

* loads each external input tensor row-by-row at its scheduled rate,
* stores each output tensor row-by-row as it is produced,
* re-streams a single-layer subgraph's weights once per row-block sweep
  (block boundaries placed by the analytical block count), and
* accounts buffer occupancy through
  :class:`repro.core.memory.OccupancyTracker` under the ``RegionTable``
  region allocations.

Every byte apportioned across steps comes from an integer cumulative
split, so the per-subgraph sums reproduce the analytical EMA **exactly**
— the invariant :mod:`repro.sim.validate` asserts for whole plans.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.cost import (
    AcceleratorConfig,
    CostKernel,
    PlanCost,
    SubgraphCost,
    finish_cost,
)
from repro.core.graph import Graph
from repro.core.memory import OccupancyTracker, build_region_table
from repro.core.tiling import derive_schedule


@dataclass(frozen=True)
class StepTraffic:
    """DRAM traffic and state of one elementary operation (one step)."""

    act_in: int          # external activation bytes loaded this step
    act_out: int         # output activation bytes stored this step
    w_stream: int        # weight bytes re-streamed this step (block sweeps)
    macs: int            # MACs issued this step
    rows: int            # internal rows produced this step
    occ_act: int         # activation-buffer bytes resident at step end
    # per-tensor occupancy at step end: sorted (tensor id, bytes) pairs
    # summing exactly to occ_act (trace JSON v3 timelines)
    occ_tensors: Tuple[Tuple[int, int], ...] = ()


@dataclass(frozen=True)
class SubgraphProgram:
    """One subgraph lowered to a deterministic step sequence."""

    nodes: Tuple[int, ...]
    cost: SubgraphCost               # the analytical per-subgraph cost
    steps: Tuple[StepTraffic, ...]
    weight_first: int                # loaded before the subgraph starts
    weight_stream: int               # re-streamed during execution
    stream_blocks: int
    peak_occ_act: int
    footprint: int                   # analytical activation footprint
    region_count: Optional[int]      # RegionTable entries (None: streamed)
    region_table_bytes: Optional[int]
    # §5.4.2 weight broadcast over the core-to-core fabric: every DRAM-
    # loaded weight byte reaches the weight_share_cores - 1 peer cores
    # (== the analytical cost's noc_bytes; zero on a single core)
    noc_bytes: int = 0

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    @property
    def act_in_total(self) -> int:
        return sum(s.act_in for s in self.steps)

    @property
    def act_out_total(self) -> int:
        return sum(s.act_out for s in self.steps)

    @property
    def weight_total(self) -> int:
        return self.weight_first + self.weight_stream


def _even_split(total: int, n: int) -> List[int]:
    """Apportion ``total`` over ``n`` slots by cumulative integer rounding
    (sums exactly to ``total``; deterministic)."""
    if n <= 0:
        return []
    out, prev = [], 0
    for k in range(1, n + 1):
        cur = (total * k) // n
        out.append(cur - prev)
        prev = cur
    return out


def lower_subgraph(
    g: Graph,
    nodes: Set[int],
    acc: AcceleratorConfig,
    out_tile: int = 1,
    kernel: Optional[CostKernel] = None,
) -> SubgraphProgram:
    """Lower one subgraph to its step program (raises on infeasibility)."""
    fs = frozenset(nodes)
    kernel = kernel or CostKernel(g, out_tile=out_tile)
    st = kernel.structure(fs)
    sc = finish_cost(st, acc)
    if not sc.feasible:
        raise ValueError(
            f"cannot lower infeasible subgraph {sorted(nodes)}: {sc.reason}")
    sched = derive_schedule(g, set(nodes), out_tile=out_tile)
    brk = sc.traffic_breakdown()

    # rows each tensor gains per elementary operation, and how many ops the
    # slowest tensor needs to complete (>= the schedule's sink-driven count,
    # so every external load and output store finishes inside the program)
    rate = {t: max(1, ts.delta * ts.upd_num) for t, ts in
            sched.tensors.items()}
    n_steps = max(math.ceil(g.nodes[t].out_len / rate[t])
                  for t in sched.tensors)

    ext = sorted(t for t, ts in sched.tensors.items() if ts.external)
    outs = {e.src for e in g.boundary_out(nodes)}
    outs |= {v for v in nodes if g.nodes[v].is_output}
    outs = sorted(outs)
    internal = sorted(nodes)

    # weight re-streaming: block b of a single-layer sweep starts at the
    # step where its row block begins; block 0 is the prefetched first load
    stream_at: Dict[int, int] = {}
    if brk.stream_blocks > 1:
        per_block = brk.weight_stream // (brk.stream_blocks - 1)
        left = brk.weight_stream
        for b in range(1, brk.stream_blocks):
            k = (b * n_steps) // brk.stream_blocks
            bts = per_block if b < brk.stream_blocks - 1 else left
            stream_at[k] = stream_at.get(k, 0) + bts
            left -= bts

    rows_total = sum(g.nodes[v].out_len for v in internal)
    occ = OccupancyTracker.from_schedule(g, sched)
    filled: Dict[int, int] = {t: 0 for t in sched.tensors}
    steps: List[StepTraffic] = []
    rows_cum = 0
    macs_cum = 0
    for k in range(n_steps):
        produced: Dict[int, int] = {}
        for t in sched.tensors:
            inc = min(rate[t], g.nodes[t].out_len - filled[t])
            if inc > 0:
                produced[t] = inc
                filled[t] += inc
        act_in = sum(produced.get(t, 0) * g.nodes[t].line_bytes for t in ext)
        act_out = sum(produced.get(t, 0) * g.nodes[t].line_bytes
                      for t in outs)
        rows_k = sum(produced.get(v, 0) for v in internal)
        rows_cum += rows_k
        macs_next = (sc.macs * rows_cum) // max(rows_total, 1)
        occ_bytes = occ.advance(produced)
        occ_tensors = tuple(sorted(
            (t, b) for t, b in occ.resident_by_tensor().items() if b > 0))
        steps.append(StepTraffic(
            act_in=act_in, act_out=act_out,
            w_stream=stream_at.get(k, 0),
            macs=macs_next - macs_cum, rows=rows_k, occ_act=occ_bytes,
            occ_tensors=occ_tensors))
        macs_cum = macs_next

    # region-table layout (the paper's buffer region manager); a streamed
    # single layer deliberately exceeds the buffer, so it has no static
    # layout — the block sweep reuses one MAIN region
    region_count: Optional[int] = None
    region_bytes: Optional[int] = None
    try:
        table = build_region_table(g, set(nodes), acc.glb_bytes,
                                   out_tile=out_tile, schedule=sched)
        region_count = len(table.regions)
        region_bytes = table.table_bytes()
    except MemoryError:
        pass

    return SubgraphProgram(
        nodes=tuple(internal), cost=sc, steps=tuple(steps),
        weight_first=brk.weight_first, weight_stream=brk.weight_stream,
        stream_blocks=brk.stream_blocks, peak_occ_act=occ.peak_bytes,
        footprint=sc.footprint, region_count=region_count,
        region_table_bytes=region_bytes,
        noc_bytes=(acc.weight_share_cores - 1)
        * (brk.weight_first + brk.weight_stream))


def lower_plan(
    g: Graph,
    groups: Sequence[Set[int]],
    acc: AcceleratorConfig,
    out_tile: int = 1,
    kernel: Optional[CostKernel] = None,
) -> Tuple[List[SubgraphProgram], PlanCost]:
    """Lower a whole plan; returns the programs plus the analytical cost."""
    if not groups:
        raise ValueError("cannot lower an empty plan")
    kernel = kernel or CostKernel(g, out_tile=out_tile)
    programs = [lower_subgraph(g, set(s), acc, out_tile=out_tile,
                               kernel=kernel) for s in groups]
    plan = PlanCost(subgraphs=[p.cost for p in programs], acc=acc)
    return programs, plan
