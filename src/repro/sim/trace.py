"""The time-stepped plan executor: programs -> a :class:`TrafficTrace`.

Executes a lowered plan (:mod:`repro.sim.lower`) on a single timeline:

* a **prologue** loads the first subgraph's first weights — one explicit
  per-core DRAM stream segment per ``weight_share_cores`` core (§5.4.2),
* each subgraph runs its elementary operations in schedule order; while it
  computes, the *next* subgraph's first weight load streams in underneath
  (the paper's double-buffered weight prefetch, Fig. 3),
* single-layer block sweeps re-stream their weights at block boundaries,
* on a multi-core plan every DRAM-loaded weight byte is additionally
  broadcast to the ``weight_share_cores - 1`` peer cores over the NoC
  fabric (``noc_bytes`` rides on the step that loads the byte — the fabric
  is concurrent with the DRAM link, so it adds traffic, not time), and
  weight-buffer occupancy tracks the *per-core* residency
  (``weight_resident``), not the full weight bytes.

Time base: each subgraph's steps are scaled so their durations sum to the
analytical subgraph latency ``max(compute, IO)`` — the simulator is a
lowering of the cost model, not a second opinion on it, which is what
makes exact analytical<->simulated cross-validation possible (total DRAM
bytes match the kernel's EMA byte-for-byte, total cycles match
``PlanCost.latency_cycles`` plus the prologue).  Within a subgraph, step
durations are proportional to each step's own ``max(compute, IO)``, so
bursts (block reloads, ramp-up loads) are visible in the profile.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.cost import AcceleratorConfig, CostKernel, PlanCost
from repro.core.graph import Graph

from .bandwidth import DEFAULT_PERCENTILES, BandwidthProfile, \
    profile_from_steps
from .lower import _even_split, lower_plan

TRACE_FORMAT = "cocco-trace"
# v2: multi-core lowering — per-step/per-subgraph ``noc_bytes``, per-core
# prologue DRAM streams (``core``), and a top-level ``noc`` section with
# aggregate + per-link fabric profiles
# v3: per-tensor occupancy timelines — each compute step carries
# ``occ_tensors`` ([tensor id, bytes] pairs summing exactly to ``occ_act``;
# empty on prologue/weight-only steps)
TRACE_FORMAT_VERSION = 3

PROLOGUE = -1   # TraceStep.subgraph index of the initial weight load
WHOLE_CHIP = -1  # TraceStep.core for steps not tied to one core's stream


@dataclass(frozen=True)
class TraceStep:
    """One timeline step: traffic, duration, and buffer state."""

    subgraph: int        # plan index; PROLOGUE (-1) for the initial load
    step: int            # step index within the subgraph
    t_cycles: float      # start time
    cycles: float        # duration
    act_in: int          # external activation bytes loaded
    act_out: int         # activation bytes stored
    w_in: int            # weight bytes loaded (prefetch + stream)
    occ_act: int         # activation-buffer bytes resident at step end
    occ_w: int           # weight-buffer bytes resident at step end (per core)
    rows: int = 0
    macs: int = 0
    noc_bytes: int = 0   # weight bytes broadcast over the core-to-core fabric
    core: int = WHOLE_CHIP  # owning core of a per-core DRAM stream segment
    # v3: per-tensor activation occupancy at step end — sorted (tensor id,
    # bytes) pairs summing exactly to occ_act; empty on prologue steps
    occ_tensors: Tuple[Tuple[int, int], ...] = ()

    @property
    def dram_in(self) -> int:
        return self.act_in + self.w_in

    @property
    def dram_out(self) -> int:
        return self.act_out

    @property
    def dram_bytes(self) -> int:
        return self.dram_in + self.dram_out


@dataclass(frozen=True)
class SubgraphTrafficSummary:
    """Per-subgraph totals of a trace (the cross-validation unit)."""

    index: int
    nodes: Tuple[int, ...]
    act_in: int
    act_out: int
    w_first: int
    w_stream: int
    stream_blocks: int
    cycles: float
    n_steps: int
    peak_occ_act: int
    peak_occ_w: int
    footprint: int
    region_count: Optional[int]
    region_table_bytes: Optional[int]
    noc_bytes: int = 0   # broadcast bytes of this subgraph's own weights

    @property
    def dram_bytes(self) -> int:
        return self.act_in + self.act_out + self.w_first + self.w_stream


@dataclass
class TrafficTrace:
    """The simulator's output: a timeline plus per-subgraph totals."""

    graph_name: str
    acc: AcceleratorConfig
    groups: List[Tuple[int, ...]]
    out_tile: int
    steps: List[TraceStep]
    subgraphs: List[SubgraphTrafficSummary]
    plan: PlanCost = field(repr=False, default=None)  # analytical companion

    # -- totals ------------------------------------------------------------
    @property
    def total_dram_in(self) -> int:
        return sum(s.dram_in for s in self.steps)

    @property
    def total_dram_out(self) -> int:
        return sum(s.dram_out for s in self.steps)

    @property
    def total_dram_bytes(self) -> int:
        return self.total_dram_in + self.total_dram_out

    @property
    def total_cycles(self) -> float:
        return sum(s.cycles for s in self.steps)

    @property
    def total_noc_bytes(self) -> int:
        return sum(s.noc_bytes for s in self.steps)

    def noc_profile(
        self, percentiles: Sequence[float] = DEFAULT_PERCENTILES,
        links: int = 1,
    ) -> BandwidthProfile:
        """NoC-fabric requirement profile: aggregate (``links=1``) or
        per-link (``links=weight_share_cores`` — the rotation fabric is
        symmetric, so each link carries ``1/links`` of a step's broadcast
        bytes).  The prologue broadcast is excluded from the statistics but
        counts toward totals, mirroring :meth:`bandwidth_profile`."""
        def scaled(b):
            return b if links <= 1 else b / links
        return profile_from_steps(
            ((scaled(s.noc_bytes), s.cycles) for s in self.steps
             if s.subgraph >= 0),
            self.acc.freq_hz, percentiles,
            totals=(scaled(self.total_noc_bytes), self.total_cycles))

    def bandwidth_profile(
        self, percentiles: Sequence[float] = DEFAULT_PERCENTILES,
    ) -> BandwidthProfile:
        # prologue steps are link-bound by construction, so they are
        # excluded from the requirement statistics (peak/percentiles) but
        # still count toward totals and sustained bandwidth — mirroring
        # PlanCost.traffic_segments()/prologue_traffic()
        return profile_from_steps(
            ((s.dram_bytes, s.cycles) for s in self.steps
             if s.subgraph >= 0),
            self.acc.freq_hz, percentiles,
            totals=(self.total_dram_bytes, self.total_cycles))

    # -- serialization (the documented trace JSON schema) ------------------
    def to_dict(self, meta: Optional[Dict[str, Any]] = None,
                include_steps: bool = True) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "format": TRACE_FORMAT,
            "version": TRACE_FORMAT_VERSION,
            "graph": self.graph_name,
            "acc": asdict(self.acc),
            "out_tile": self.out_tile,
            "groups": [list(gr) for gr in self.groups],
            "totals": {
                "dram_in": self.total_dram_in,
                "dram_out": self.total_dram_out,
                "dram_bytes": self.total_dram_bytes,
                "noc_bytes": self.total_noc_bytes,
                "cycles": self.total_cycles,
            },
            "profile": self.bandwidth_profile().to_dict(),
            "noc": {
                "links": self.acc.weight_share_cores,
                "total_bytes": self.total_noc_bytes,
                "aggregate": self.noc_profile().to_dict(),
                "per_link": self.noc_profile(
                    links=self.acc.weight_share_cores).to_dict(),
            },
            "subgraphs": [asdict(sg) for sg in self.subgraphs],
        }
        if include_steps:
            d["steps"] = [asdict(s) for s in self.steps]
        if meta:
            d["meta"] = dict(meta)
        return d

    def to_json(self, meta: Optional[Dict[str, Any]] = None,
                include_steps: bool = True,
                indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(meta=meta,
                                       include_steps=include_steps),
                          indent=indent, sort_keys=True)


def _coalesce(steps: List[TraceStep], limit: int) -> List[TraceStep]:
    """Merge a subgraph's steps down to <= ``limit`` buckets (totals are
    preserved exactly; occupancy takes the bucket's last value)."""
    n = len(steps)
    if n <= limit:
        return steps
    out: List[TraceStep] = []
    start = 0
    for b in range(limit):
        end = ((b + 1) * n) // limit
        chunk = steps[start:end]
        if not chunk:
            continue
        out.append(TraceStep(
            subgraph=chunk[0].subgraph, step=b,
            t_cycles=chunk[0].t_cycles,
            cycles=sum(c.cycles for c in chunk),
            act_in=sum(c.act_in for c in chunk),
            act_out=sum(c.act_out for c in chunk),
            w_in=sum(c.w_in for c in chunk),
            occ_act=chunk[-1].occ_act, occ_w=chunk[-1].occ_w,
            rows=sum(c.rows for c in chunk),
            macs=sum(c.macs for c in chunk),
            noc_bytes=sum(c.noc_bytes for c in chunk),
            core=chunk[0].core,
            occ_tensors=chunk[-1].occ_tensors))
        start = end
    return out


def simulate_plan(
    g: Graph,
    groups: Sequence[Set[int]],
    acc: AcceleratorConfig,
    out_tile: int = 1,
    steps_per_subgraph: Optional[int] = None,
    kernel: Optional[CostKernel] = None,
) -> TrafficTrace:
    """Execute a partition plan on the simulated timeline.

    ``groups`` is the plan in execution order (any infeasible subgraph is
    a :class:`ValueError` — an infeasible plan has no timeline).
    ``steps_per_subgraph`` coalesces each subgraph's row-granular steps
    down to at most that many buckets; coalescing merges traffic and time,
    so every total (and the cross-validation) is resolution-independent.
    """
    programs, plan = lower_plan(g, groups, acc, out_tile=out_tile,
                                kernel=kernel)
    freq = acc.freq_hz
    bpc = acc.dram_bytes_per_cycle
    share = acc.weight_share_cores

    steps: List[TraceStep] = []
    summaries: List[SubgraphTrafficSummary] = []
    t = 0.0

    # prologue: the first subgraph's first weight load streams before any
    # compute — one explicit DRAM stream segment per core (§5.4.2: each
    # core pulls its own shard of the load; single-core plans keep the one
    # step of the v1 schema).  Weight occupancy is *per core*: it climbs by
    # cumulative integer scaling to exactly the per-core residency the
    # analytical kernel charges (``weight_resident``), not the full weight
    # bytes.  Every loaded byte is broadcast to the share - 1 peer cores.
    first0 = programs[0].weight_first
    resident0 = programs[0].cost.weight_resident
    if first0 > 0:
        cum = 0
        for c, shard in enumerate(_even_split(first0, share)):
            if shard <= 0:
                continue
            cum += shard
            cyc = shard / bpc
            steps.append(TraceStep(
                subgraph=PROLOGUE, step=c, t_cycles=t, cycles=cyc,
                act_in=0, act_out=0, w_in=shard, occ_act=0,
                occ_w=(cum * resident0) // first0,
                noc_bytes=(share - 1) * shard, core=c))
            t += cyc

    for i, prog in enumerate(programs):
        n = prog.n_steps
        nxt_first = (programs[i + 1].weight_first
                     if i + 1 < len(programs) else 0)
        nxt_resident = (programs[i + 1].cost.weight_resident
                        if i + 1 < len(programs) else 0)
        prefetch = _even_split(nxt_first, n)
        # raw per-step demand: max(compute, IO); then scale so the subgraph
        # occupies exactly its analytical latency on the timeline
        raw: List[float] = []
        for k, stp in enumerate(prog.steps):
            io = stp.act_in + stp.act_out + stp.w_stream + prefetch[k]
            raw.append(max(stp.macs / acc.macs_per_cycle, io / bpc))
        lat = prog.cost.latency_cycles(acc)
        raw_sum = sum(raw)
        if raw_sum > 0:
            durations = [r * lat / raw_sum for r in raw]
        else:
            # no per-step demand (e.g. a weight-only subgraph whose first
            # load happened in the previous prefetch window): spread the
            # analytical latency evenly so the timeline still spans it
            durations = [lat / n] * n

        own_w = prog.cost.weight_resident     # per-core resident own weights
        pre_cum = 0
        sub_steps: List[TraceStep] = []
        sub_t = t
        for k, stp in enumerate(prog.steps):
            pre_cum += prefetch[k]
            cyc = durations[k]
            w_in = stp.w_stream + prefetch[k]
            # prefetched weights occupy each core at its per-core share of
            # the next subgraph's residency (cumulative integer scaling
            # lands exactly on nxt_resident when the prefetch completes)
            occ_pre = ((pre_cum * nxt_resident) // nxt_first
                       if nxt_first > 0 else 0)
            sub_steps.append(TraceStep(
                subgraph=i, step=k, t_cycles=sub_t, cycles=cyc,
                act_in=stp.act_in, act_out=stp.act_out,
                w_in=w_in,
                occ_act=stp.occ_act, occ_w=own_w + occ_pre,
                rows=stp.rows, macs=stp.macs,
                noc_bytes=(share - 1) * w_in,
                occ_tensors=stp.occ_tensors))
            sub_t += cyc
        if steps_per_subgraph is not None:
            sub_steps = _coalesce(sub_steps, max(1, steps_per_subgraph))
        steps.extend(sub_steps)
        t += lat

        summaries.append(SubgraphTrafficSummary(
            index=i, nodes=prog.nodes,
            act_in=prog.act_in_total, act_out=prog.act_out_total,
            w_first=prog.weight_first, w_stream=prog.weight_stream,
            stream_blocks=prog.stream_blocks,
            cycles=lat, n_steps=len(sub_steps),
            peak_occ_act=prog.peak_occ_act,
            peak_occ_w=own_w + nxt_resident,
            footprint=prog.footprint,
            region_count=prog.region_count,
            region_table_bytes=prog.region_table_bytes,
            noc_bytes=prog.noc_bytes))

    return TrafficTrace(
        graph_name=g.name, acc=acc,
        groups=[tuple(sorted(s)) for s in groups],
        out_tile=out_tile, steps=steps, subgraphs=summaries, plan=plan)
