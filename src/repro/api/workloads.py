"""Workload resolver: ``workload:`` URIs -> Cocco :class:`~repro.core.graph.Graph`.

Every :class:`ExploreSpec` names its workload as a URI ``<scheme>:<rest>``
(a bare name is a back-compat alias for ``netlib:<name>``), and
:func:`build_workload` dispatches on an open scheme registry.  Built-ins:

* ``netlib:<model>`` — the paper's model zoo (:data:`repro.core.netlib.PAPER_MODELS`).
* ``tpu:<config>:<layer>[?tokens=N&tp=K]`` — one transformer block of a
  bundled :mod:`repro.configs` architecture, lowered through
  :func:`repro.core.tpu_adapter.build_block_graph` (rows = tokens); this
  makes the MoE/Mamba/ViT block graphs explorable by every strategy.
* ``synthetic:<kind>:<n>[?seed=S&...]`` — seeded random DAG generators
  (``layered`` | ``branchy`` | ``diamond`` | ``chain`` | ``pyramid``) for
  stress and fuzz workloads; deterministic in the URI, so fingerprints and
  store keys are stable across processes.
* ``file:<path>.json`` — import an external netlist in the documented Graph
  JSON format (:func:`repro.core.graph.graph_to_json` exports it).

``register_workload_scheme`` is open the same way ``register_strategy`` is:
downstream code can add a scheme and it becomes resolvable by
``run``/``compare``, the CLI, and the benchmarks without touching this
package.  Resolution is deterministic: one URI always builds the same graph
(same :func:`~repro.api.store.graph_fingerprint`), which is what lets the
spec-addressed :class:`~repro.api.store.ResultStore` replay any scheme's
results safely.
"""

from __future__ import annotations

import math
import random
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple
from urllib.parse import parse_qsl

from repro.core.graph import Graph, graph_from_json

# ---------------------------------------------------------------------------
# the scheme registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkloadScheme:
    """One registered URI scheme."""

    name: str
    build: Callable[[str, Dict[str, str]], Graph]   # (rest, params) -> Graph
    syntax: str                                     # e.g. "tpu:<config>:<layer>[?tokens=N]"
    description: str
    # display rows for `python -m repro workloads ls` (may be templates)
    list_fn: Optional[Callable[[], List[str]]] = None
    # concrete, resolvable URIs for `workloads ls --uris-only` (None when the
    # scheme's instances are not enumerable, e.g. file:)
    expand_fn: Optional[Callable[[], List[str]]] = None
    # False when the URI does not pin the graph's content (file: — the file
    # can change under an unchanged URI); the store layer then re-checks the
    # graph fingerprint before replaying an artifact
    stable: bool = True


_SCHEMES: Dict[str, WorkloadScheme] = {}


def register_workload_scheme(name: str, *, syntax: str, description: str,
                             list_fn: Optional[Callable[[], List[str]]] = None,
                             expand_fn: Optional[Callable[[], List[str]]] = None,
                             stable: bool = True):
    """Decorator: register ``fn(rest, params) -> Graph`` as scheme ``name``.

    ``rest`` is everything after ``<name>:`` up to the ``?``; ``params`` is
    the parsed query dict (string values; the builder coerces and must
    reject unknown keys so that two spellings of one workload cannot alias
    different graphs).  Pass ``stable=False`` when the URI alone does not
    pin the graph's content (e.g. a path whose file can change): the store
    layer then verifies the graph fingerprint before replaying artifacts.
    """

    def deco(fn: Callable[[str, Dict[str, str]], Graph]):
        _SCHEMES[name] = WorkloadScheme(name=name, build=fn, syntax=syntax,
                                        description=description,
                                        list_fn=list_fn, expand_fn=expand_fn,
                                        stable=stable)
        return fn

    return deco


def workload_schemes() -> List[WorkloadScheme]:
    return [_SCHEMES[k] for k in sorted(_SCHEMES)]


def parse_workload(uri: str) -> Tuple[str, str, Dict[str, str]]:
    """Split a workload URI into ``(scheme, rest, params)``.

    A bare name (no ``:``) aliases to ``netlib:<name>`` for back-compat
    with pre-resolver specs.  Unknown schemes and malformed query strings
    raise ``ValueError`` — this doubles as :class:`ExploreSpec`-time
    validation, so a typo fails at spec construction, not mid-search.
    """
    if not uri:
        raise ValueError("empty workload")
    if ":" not in uri:
        return "netlib", uri, {}
    scheme, rest = uri.split(":", 1)
    if scheme not in _SCHEMES:
        raise ValueError(
            f"unknown workload scheme {scheme!r} in {uri!r}; registered "
            f"schemes: {sorted(_SCHEMES)} (a bare name means netlib:<name>)")
    rest, _, query = rest.partition("?")
    params: Dict[str, str] = {}
    if query:
        try:
            pairs = parse_qsl(query, keep_blank_values=True,
                              strict_parsing=True)
        except ValueError as err:
            raise ValueError(f"bad workload query {query!r} in {uri!r}: "
                             f"{err}") from None
        for k, v in pairs:
            if k in params:
                raise ValueError(f"duplicate workload param {k!r} in {uri!r}")
            params[k] = v
    return scheme, rest, params


def validate_workload(uri: str) -> None:
    """Spec-construction-time validation: syntax only, no graph build, no
    file access.

    Registered schemes get their full URI syntax checked (malformed query
    strings fail here).  A ``prefix:`` that is *not* a registered scheme is
    accepted — it may be a free-form label for a custom graph passed via
    ``run(graph=...)``, and pre-resolver artifacts with such labels must
    keep deserializing.  Resolution (:func:`build_workload`) still rejects
    it with the full unknown-scheme message.
    """
    if not uri:
        raise ValueError("empty workload")
    if ":" in uri and uri.split(":", 1)[0] in _SCHEMES:
        parse_workload(uri)


def workload_is_stable(uri: str) -> bool:
    """True when the URI alone pins the graph content (every scheme except
    ``file:``-like ones).  Free-form labels count as stable: they resolve
    nowhere, so there is nothing to re-check."""
    if ":" not in uri:
        return True
    entry = _SCHEMES.get(uri.split(":", 1)[0])
    return entry.stable if entry is not None else True


def build_workload(uri: str) -> Graph:
    """Resolve a workload URI (or bare netlib name) to a graph."""
    scheme, rest, params = parse_workload(uri)
    try:
        return _SCHEMES[scheme].build(rest, params)
    except ModuleNotFoundError as err:
        raise RuntimeError(
            f"workload {uri!r} needs an optional dependency: {err}") from err


def list_workloads(scheme: Optional[str] = None,
                   concrete: bool = False) -> List[Tuple[str, str]]:
    """``(uri, note)`` rows for ``workloads ls``.

    Default: display rows, which may be compact templates
    (``tpu:<arch>:0..N``, ``synthetic:layered:<n>[?seed=S]``).  With
    ``concrete=True``, only URIs that :func:`build_workload` actually
    resolves are returned (schemes without enumerable instances contribute
    nothing) — the script-friendly ``workloads ls --uris-only`` contract.
    """
    if scheme is not None and scheme not in _SCHEMES:
        raise ValueError(f"unknown workload scheme {scheme!r}; registered "
                         f"schemes: {sorted(_SCHEMES)}")
    rows: List[Tuple[str, str]] = []
    for entry in workload_schemes():
        if scheme is not None and entry.name != scheme:
            continue
        if concrete:
            if entry.expand_fn is not None:
                rows.extend((uri, entry.description)
                            for uri in entry.expand_fn())
        elif entry.list_fn is None:
            rows.append((entry.syntax, entry.description))
        else:
            rows.extend((uri, entry.description) for uri in entry.list_fn())
    return rows


# ---------------------------------------------------------------------------
# shared param helpers (strict: unknown keys are an error, not a shrug)
# ---------------------------------------------------------------------------

def _int_param(params: Dict[str, str], key: str, default: int,
               minimum: int = 1) -> int:
    raw = params.pop(key, None)
    if raw is None:
        return default
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(f"workload param {key}={raw!r} is not an integer") \
            from None
    if value < minimum:
        raise ValueError(f"workload param {key}={value} must be >= {minimum}")
    return value


def _reject_extra_params(scheme: str, params: Dict[str, str]) -> None:
    if params:
        raise ValueError(
            f"unknown params {sorted(params)} for workload scheme "
            f"{scheme!r}")


# ---------------------------------------------------------------------------
# netlib: the paper zoo (bare names alias here)
# ---------------------------------------------------------------------------

def _list_netlib() -> List[str]:
    from repro.core import netlib

    return [f"netlib:{name}" for name in netlib.list_models()]


@register_workload_scheme(
    "netlib",
    syntax="netlib:<model>",
    description="paper model zoo (bare names alias to this scheme)",
    list_fn=_list_netlib,
    expand_fn=_list_netlib,
)
def _build_netlib(rest: str, params: Dict[str, str]) -> Graph:
    from repro.core import netlib

    _reject_extra_params("netlib", params)
    return netlib.build(rest)


# ---------------------------------------------------------------------------
# tpu: transformer block graphs of the bundled model configs
# ---------------------------------------------------------------------------

def _canonical_arch_key(name: str) -> str:
    return re.sub(r"[-_.]", "", name.lower())


def _resolve_arch(name: str) -> str:
    """Accept both registry spellings and separator-free aliases
    (``gemma3_4b`` == ``gemma3-4b``)."""
    from repro.configs import ARCHS

    if name in ARCHS:
        return name
    wanted = _canonical_arch_key(name)
    matches = [a for a in ARCHS if _canonical_arch_key(a) == wanted]
    if len(matches) == 1:
        return matches[0]
    raise ValueError(f"unknown tpu config {name!r}; known: {list(ARCHS)}")


def _list_tpu() -> List[str]:
    from repro.configs import ARCHS, get_config

    return [f"tpu:{arch}:0..{get_config(arch).n_layers - 1}"
            for arch in ARCHS]


def _expand_tpu() -> List[str]:
    from repro.configs import ARCHS, get_config

    return [f"tpu:{arch}:{layer}" for arch in ARCHS
            for layer in range(get_config(arch).n_layers)]


@register_workload_scheme(
    "tpu",
    syntax="tpu:<config>:<layer>[?tokens=N&tp=K]",
    description="one transformer block of a bundled model config "
                "(rows = tokens, TP-sharded)",
    list_fn=_list_tpu,
    expand_fn=_expand_tpu,
)
def _build_tpu(rest: str, params: Dict[str, str]) -> Graph:
    from repro.configs import get_config
    from repro.core.tpu_adapter import build_block_graph

    cfg_name, sep, layer_raw = rest.rpartition(":")
    if not sep:
        raise ValueError(
            f"tpu workload needs a layer index: tpu:<config>:<layer>, "
            f"got tpu:{rest!r}")
    try:
        layer_idx = int(layer_raw)
    except ValueError:
        raise ValueError(
            f"tpu layer index must be an integer, got {layer_raw!r}") \
            from None
    tokens = _int_param(params, "tokens", 8192)
    tp = _int_param(params, "tp", 16)
    _reject_extra_params("tpu", params)
    cfg = get_config(_resolve_arch(cfg_name))
    if not (0 <= layer_idx < cfg.n_layers):
        raise ValueError(
            f"layer {layer_idx} out of range for {cfg.name} "
            f"(0..{cfg.n_layers - 1})")
    return build_block_graph(cfg, layer_idx, tokens, tp_degree=tp)


# ---------------------------------------------------------------------------
# synthetic: seeded random DAG generators
# ---------------------------------------------------------------------------

def _mark_sinks_as_outputs(g: Graph) -> Graph:
    for v in g.sinks():
        g.nodes[v].is_output = True
    return g


def _random_node(g: Graph, rng: random.Random, name: str, rows: int) -> int:
    """One layer with randomized width/weights/compute (deterministic in rng)."""
    line = rng.choice((16, 32, 64, 128))
    wbytes = rng.choice((0, 256, 1024, 4096))
    macs = rows * line * rng.randint(1, 64)
    return g.add_node(name, rows, line, weight_bytes=wbytes, macs=macs)


def _gen_layered(n: int, seed: int, rows: int, width: int) -> Graph:
    """``width`` parallel lanes per rank; each node consumes 1-2 nodes of the
    previous rank and every producer keeps at least one consumer."""
    rng = random.Random(seed)
    g = Graph(f"synthetic:layered:{n}?seed={seed}")
    prev: List[int] = []
    made = 0
    while made < n:
        layer_w = 1 if not prev else min(width, n - made, rng.randint(1, width))
        layer = []
        for _ in range(layer_w):
            v = _random_node(g, rng, f"n{g.n}", rows)
            layer.append(v)
            made += 1
            for src in (rng.sample(prev, k=min(len(prev), rng.randint(1, 2)))
                        if prev else []):
                g.add_edge(src, v, F=1, s=1)
        # every producer of the previous rank must feed someone
        fed = {e.src for v in layer for e in g.in_edges(v)}
        for src in prev:
            if src not in fed:
                g.add_edge(src, rng.choice(layer), F=1, s=1)
        prev = layer
    return _mark_sinks_as_outputs(g)


def _gen_branchy(n: int, seed: int, rows: int) -> Graph:
    """RandWire-style irregular DAG: node ``i`` consumes 1-3 random nodes
    from a trailing locality window, so merge nodes of mixed fan-in appear."""
    rng = random.Random(seed)
    g = Graph(f"synthetic:branchy:{n}?seed={seed}")
    for i in range(n):
        v = _random_node(g, rng, f"n{i}", rows)
        if i == 0:
            continue
        lo = max(0, i - 8)
        k = min(i - lo, rng.randint(1, 3))
        for src in rng.sample(range(lo, i), k=k):
            g.add_edge(src, v, F=1, s=1)
    return _mark_sinks_as_outputs(g)


def _gen_diamond(n: int, seed: int, rows: int) -> Graph:
    """Residual/diamond chain: repeated ``x -> a -> b -> add(b, x)`` blocks,
    the shape the paper's multi-branch nets are made of."""
    rng = random.Random(seed)
    g = Graph(f"synthetic:diamond:{n}?seed={seed}")
    x = _random_node(g, rng, "stem", rows)
    while g.n < n:
        a = _random_node(g, rng, f"b{g.n}.a", rows)
        g.add_edge(x, a, F=1, s=1)
        if g.n < n:
            b = _random_node(g, rng, f"b{g.n}.b", rows)
            g.add_edge(a, b, F=1, s=1)
        else:
            b = a
        if g.n < n:
            add = g.add_node(f"b{g.n}.add", rows,
                             g.nodes[b].line_bytes, macs=2 * rows)
            g.add_edge(b, add, F=1, s=1)
            g.add_edge(x, add, F=1, s=1)
            x = add
        else:
            x = b
    return _mark_sinks_as_outputs(g)


def _gen_chain(n: int, seed: int, rows: int) -> Graph:
    """Plain chain with randomized sliding windows (F, s), exercising the
    backward row-derivation on heterogeneous strides."""
    rng = random.Random(seed)
    g = Graph(f"synthetic:chain:{n}?seed={seed}")
    prev = _random_node(g, rng, "n0", rows)
    cur_rows = rows
    for i in range(1, n):
        F, s = rng.choice(((1, 1), (1, 1), (3, 1), (3, 2), (2, 2)))
        out_rows = max(1, math.ceil(cur_rows / s))      # 'same' padding
        line = rng.choice((16, 32, 64, 128))
        v = g.add_node(f"n{i}", out_rows, line,
                       weight_bytes=rng.choice((0, 512, 2048)),
                       macs=out_rows * line * F)
        g.add_edge(prev, v, F=min(F, cur_rows), s=s)
        prev, cur_rows = v, out_rows
    return _mark_sinks_as_outputs(g)


def _gen_pyramid(n: int, seed: int, rows: int) -> Graph:
    """Stride pyramid with multi-input merges: rows halve level by level
    (non-uniform row counts across the graph), each level chains a few
    same-rate nodes, and merge nodes additionally consume a stride-matched
    skip edge from an *earlier* level — the mixed-rate fan-in shape the
    consumption-centric rate solver (tiling stage 3) has to balance."""
    rng = random.Random(seed)
    g = Graph(f"synthetic:pyramid:{n}?seed={seed}")
    cur_rows = max(rows, 2)
    prev = _random_node(g, rng, "p0.stem", cur_rows)
    levels: List[List[int]] = [[prev]]
    level_rows: List[int] = [cur_rows]
    while g.n < n:
        # new level: stride-2 downsample from the previous level's tail
        # (window F=s keeps f(k) = F + (k-1)s within the producer's rows)
        nxt_rows = max(1, cur_rows // 2)
        s_down = min(2, cur_rows)
        down = _random_node(g, rng, f"p{len(levels)}.down", nxt_rows)
        g.add_edge(prev, down, F=s_down, s=s_down)
        level = [down]
        prev, cur_rows = down, nxt_rows
        for _ in range(rng.randint(0, 2)):          # same-rate body nodes
            if g.n >= n:
                break
            v = _random_node(g, rng, f"p{len(levels)}.c{g.n}", cur_rows)
            g.add_edge(prev, v, F=1, s=1)
            level.append(v)
            prev = v
        if g.n < n:
            # multi-input merge: level tail + a skip from an earlier level,
            # stride chosen so the window stays inside the skip source
            merge = g.add_node(f"p{len(levels)}.merge", cur_rows,
                               g.nodes[prev].line_bytes,
                               macs=2 * cur_rows * g.nodes[prev].line_bytes)
            g.add_edge(prev, merge, F=1, s=1)
            j = rng.randrange(len(levels))
            src = rng.choice(levels[j])
            if cur_rows > 1:
                s_skip = min(2 ** (len(levels) - j),
                             max(1, (level_rows[j] - 1) // (cur_rows - 1)))
            else:
                s_skip = 1
            g.add_edge(src, merge, F=1, s=s_skip)
            level.append(merge)
            prev = merge
        levels.append(level)
        level_rows.append(cur_rows)
    return _mark_sinks_as_outputs(g)


_SYNTHETIC_KINDS = {
    "layered": _gen_layered,
    "branchy": _gen_branchy,
    "diamond": _gen_diamond,
    "chain": _gen_chain,
    "pyramid": _gen_pyramid,
}


def _list_synthetic() -> List[str]:
    return [f"synthetic:{kind}:<n>[?seed=S]" for kind in
            sorted(_SYNTHETIC_KINDS)]


@register_workload_scheme(
    "synthetic",
    syntax="synthetic:<kind>:<n>[?seed=S&rows=R&width=W]",
    description="seeded random DAG generators for stress/fuzz workloads",
    list_fn=_list_synthetic,
)
def _build_synthetic(rest: str, params: Dict[str, str]) -> Graph:
    kind, sep, n_raw = rest.partition(":")
    if not sep:
        raise ValueError(
            f"synthetic workload needs a node count: synthetic:<kind>:<n>, "
            f"got synthetic:{rest!r}")
    if kind not in _SYNTHETIC_KINDS:
        raise ValueError(f"unknown synthetic kind {kind!r}; known: "
                         f"{sorted(_SYNTHETIC_KINDS)}")
    try:
        n = int(n_raw)
    except ValueError:
        raise ValueError(f"synthetic node count must be an integer, "
                         f"got {n_raw!r}") from None
    if n < 2:
        raise ValueError(f"synthetic workload needs n >= 2, got {n}")
    seed = _int_param(params, "seed", 0, minimum=0)
    rows = _int_param(params, "rows", 32)
    kw = {}
    if kind == "layered":
        kw["width"] = _int_param(params, "width", 4)
    _reject_extra_params("synthetic", params)
    return _SYNTHETIC_KINDS[kind](n, seed, rows, **kw)


# ---------------------------------------------------------------------------
# file: external netlists in the documented Graph JSON format
# ---------------------------------------------------------------------------

@register_workload_scheme(
    "file",
    syntax="file:<path>.json",
    description="external netlist in the Graph JSON format "
                "(export with repro.core.graph.graph_to_json)",
    stable=False,   # the file can change under an unchanged URI
)
def _build_file(rest: str, params: Dict[str, str]) -> Graph:
    _reject_extra_params("file", params)
    path = Path(rest).expanduser()
    if not path.is_file():
        raise ValueError(f"workload file not found: {path}")
    try:
        return graph_from_json(path.read_text())
    except ValueError as err:
        raise ValueError(f"cannot load workload file {path}: {err}") from None
