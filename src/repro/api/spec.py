"""`ExploreSpec`: the single, serializable input to every search strategy.

A spec names a workload (a graph in :mod:`repro.core.netlib`), an
:class:`~repro.core.ga.Objective`, an :class:`~repro.core.ga.HWSpace`, a
sample budget, a seed, a strategy name, and that strategy's typed options —
replacing the old string-`mode`/`metric` + ``**ga_kw`` surface.  Specs are
frozen, compare by value, and round-trip losslessly through JSON, so a run
is reproducible from its serialized spec alone.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field, fields
from typing import Any, Dict, Optional, Tuple

from repro.core.cost import AcceleratorConfig
from repro.core.ga import HWSpace, Objective

from .registry import options_class_for


# ---------------------------------------------------------------------------
# per-strategy option blocks (typed replacements for **ga_kw)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GAOptions:
    """Cocco's genetic co-exploration (paper §4.3–4.4)."""

    population: int = 100
    tournament_k: int = 4
    crossover_frac: float = 0.5
    elite: int = 2
    log_populations: bool = False
    # names of registered strategies whose result groups seed the initial
    # population (paper §4.3 benefit 4, "flexible initialization")
    seed_from: Tuple[str, ...] = ()
    # store keys (64-hex, see `repro.api.store.spec_key`) of archived
    # ExploreResults whose groups also seed the initial population — the
    # warm-start path for FULL-budget sweeps from reduced-run artifacts
    # (`--seed-from-store` on the CLI).  Requires a store at run time; the
    # keys are part of the spec, so a warm-started run has its own address.
    seed_from_keys: Tuple[str, ...] = ()


@dataclass(frozen=True)
class GreedyOptions:
    """Halide-style greedy merging (paper §4.2.2)."""

    eval_budget: int = 30_000


@dataclass(frozen=True)
class DPOptions:
    """Irregular-NN DP over depth order (paper §4.2.3) — no knobs."""


@dataclass(frozen=True)
class EnumOptions:
    """Exact state-compression DP over ideals; budgeted (paper §4.2.1)."""

    state_budget: int = 2_000_000


@dataclass(frozen=True)
class SAOptions:
    """Simulated annealing over Cocco's mutation neighbourhood (§4.2.4)."""

    t0: float = 1.0
    t_end: float = 1e-3


@dataclass(frozen=True)
class TwoStepOptions:
    """RS+GA / GS+GA: capacity sampling then partition-only GA (§5.1.3)."""

    sampler: str = "random"          # "random" | "grid"
    capacity_samples: int = 10
    samples_per_capacity: int = 5_000


# ---------------------------------------------------------------------------
# (de)serialization helpers for the core value types
# ---------------------------------------------------------------------------

def acc_to_dict(acc: AcceleratorConfig) -> Dict[str, Any]:
    return asdict(acc)


def acc_from_dict(d: Dict[str, Any]) -> AcceleratorConfig:
    return AcceleratorConfig(**d)


def objective_to_dict(obj: Objective) -> Dict[str, Any]:
    return {"metric": obj.metric, "alpha": obj.alpha}


def objective_from_dict(d: Dict[str, Any]) -> Objective:
    return Objective(metric=d["metric"], alpha=d["alpha"])


def hw_to_dict(hw: HWSpace) -> Dict[str, Any]:
    d = {
        "mode": hw.mode,
        "base": acc_to_dict(hw.base),
        "glb_candidates": list(hw.glb_candidates),
        "wbuf_candidates": list(hw.wbuf_candidates),
        "shared_candidates": list(hw.shared_candidates),
    }
    # written only when the core axis is explored: the default () serializes
    # byte-identically to pre-core-axis specs, so store/zoo addresses
    # (spec_key hashes this dict) of existing artifacts stay valid
    if hw.core_candidates:
        d["core_candidates"] = list(hw.core_candidates)
    return d


def hw_from_dict(d: Dict[str, Any]) -> HWSpace:
    return HWSpace(
        mode=d["mode"],
        base=acc_from_dict(d["base"]),
        glb_candidates=tuple(d["glb_candidates"]),
        wbuf_candidates=tuple(d["wbuf_candidates"]),
        shared_candidates=tuple(d["shared_candidates"]),
        core_candidates=tuple(d.get("core_candidates", ())),
    )


def options_to_dict(options: Any) -> Optional[Dict[str, Any]]:
    return None if options is None else asdict(options)


def options_from_dict(strategy: str, d: Optional[Dict[str, Any]]) -> Any:
    cls = options_class_for(strategy)
    if cls is None:
        if d is not None:
            raise ValueError(
                f"cannot deserialize options for unregistered strategy "
                f"{strategy!r}; call register_strategy first")
        return None
    if d is None:
        return cls()
    kw = dict(d)
    # JSON turns tuples into lists; coerce back for tuple-defaulted fields
    for f in fields(cls):
        if isinstance(f.default, tuple) and isinstance(kw.get(f.name), list):
            kw[f.name] = tuple(kw[f.name])
    return cls(**kw)


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------

SPEC_VERSION = 1


@dataclass(frozen=True)
class ExploreSpec:
    """One fully-specified exploration run.

    ``workload`` is a workload URI resolved by
    :func:`repro.api.workloads.build_workload` — ``netlib:<model>`` (a bare
    name aliases here), ``tpu:<config>:<layer>``, ``synthetic:<kind>:<n>``,
    ``file:<path>.json``, or any scheme added via
    :func:`~repro.api.workloads.register_workload_scheme` — unless the
    caller passes an explicit graph to :func:`repro.api.run` (then it is a
    free-form label).  ``options`` is the registered strategy's typed option
    dataclass; ``None`` resolves to that strategy's defaults.
    """

    workload: str
    strategy: str = "ga"
    objective: Objective = Objective(metric="energy", alpha=None)
    hw: HWSpace = field(default_factory=HWSpace)
    sample_budget: int = 50_000
    seed: int = 0
    out_tile: int = 1
    options: Any = None

    def __post_init__(self) -> None:
        # Fail malformed workload URIs at spec construction, not mid-search.
        # Scheme-less names stay free-form (netlib aliases / custom-graph
        # labels); anything with a ``:`` must parse under a registered
        # scheme.  Syntax-only: no graph is built, no file is touched.
        from .workloads import validate_workload

        validate_workload(self.workload)
        if self.options is None:
            cls = options_class_for(self.strategy)
            if cls is not None:
                object.__setattr__(self, "options", cls())

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": SPEC_VERSION,
            "workload": self.workload,
            "strategy": self.strategy,
            "objective": objective_to_dict(self.objective),
            "hw": hw_to_dict(self.hw),
            "sample_budget": self.sample_budget,
            "seed": self.seed,
            "out_tile": self.out_tile,
            "options": options_to_dict(self.options),
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExploreSpec":
        return cls(
            workload=d["workload"],
            strategy=d["strategy"],
            objective=objective_from_dict(d["objective"]),
            hw=hw_from_dict(d["hw"]),
            sample_budget=d["sample_budget"],
            seed=d["seed"],
            out_tile=d.get("out_tile", 1),
            options=options_from_dict(d["strategy"], d.get("options")),
        )

    @classmethod
    def from_json(cls, data: str) -> "ExploreSpec":
        return cls.from_dict(json.loads(data))
