"""Unified exploration API: ``ExploreSpec`` -> strategy registry -> ``ExploreResult``.

One composable, serializable surface for every search method in the repo
(GA, greedy, DP, SA, two-step, exhaustive enumeration), every cost backend,
and every caller (benchmarks, examples, the ``python -m repro`` CLI, the
TPU planner).  Quickstart::

    from repro.api import ExploreSpec, run
    spec = ExploreSpec(workload="resnet50", strategy="ga", sample_budget=4000)
    print(run(spec).summary())

Specs and results round-trip losslessly through JSON
(``spec == ExploreSpec.from_json(spec.to_json())``), so any run can be
archived, shared, and reproduced bit-for-bit from its artifact.  Use
:func:`compare` to run several strategies on one spec with a shared cost
evaluator (``jobs=N`` fans them out over worker processes), a
:class:`ResultStore` to make re-runs of any already-searched spec instant,
and :func:`register_strategy` to plug in new methods.

Workloads are URIs resolved by :mod:`repro.api.workloads`
(``netlib:resnet50``, ``tpu:gemma3-4b:0``, ``synthetic:layered:24?seed=7``,
``file:graph.json``; bare names alias to ``netlib:``) — see
:func:`register_workload_scheme` to add a scheme, and
``python -m repro workloads ls`` to enumerate what resolves.
"""

from .registry import (
    Strategy,
    StrategyEntry,
    get_strategy,
    list_strategies,
    register_strategy,
)
from .spec import (
    DPOptions,
    EnumOptions,
    ExploreSpec,
    GAOptions,
    GreedyOptions,
    SAOptions,
    TwoStepOptions,
)
from .result import ExploreResult
from .store import (
    ResultStore,
    StoreEntry,
    StoreLockTimeout,
    StoreReadOnly,
    graph_fingerprint,
    spec_key,
)
from .strategies import active_store, compare, plan_tpu, run
from .workloads import (
    WorkloadScheme,
    build_workload,
    list_workloads,
    parse_workload,
    register_workload_scheme,
    workload_schemes,
)

__all__ = [
    "DPOptions",
    "EnumOptions",
    "ExploreResult",
    "ExploreSpec",
    "GAOptions",
    "GreedyOptions",
    "ResultStore",
    "SAOptions",
    "StoreEntry",
    "StoreLockTimeout",
    "StoreReadOnly",
    "Strategy",
    "StrategyEntry",
    "TwoStepOptions",
    "WorkloadScheme",
    "active_store",
    "build_workload",
    "compare",
    "get_strategy",
    "graph_fingerprint",
    "list_strategies",
    "list_workloads",
    "parse_workload",
    "plan_tpu",
    "register_strategy",
    "register_workload_scheme",
    "run",
    "spec_key",
    "workload_schemes",
]
