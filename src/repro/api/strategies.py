"""Built-in strategies + the ``run``/``compare`` entry points.

All six search methods from the paper's evaluation run under the same
registry and return :class:`ExploreResult`:

* ``ga``        — Cocco's genetic co-exploration (:func:`repro.core.ga.run_ga`)
* ``greedy``    — Halide-style greedy merging
* ``dp``        — Irregular-NN DP over depth order
* ``enum``      — exact (budgeted) enumeration over ideals
* ``sa``        — simulated annealing
* ``two_step``  — RS+GA / GS+GA decoupled capacity search

Fixed-hardware methods (``greedy``/``dp``/``enum``) evaluate at
``spec.hw.base`` regardless of the HW-space mode.
"""

from __future__ import annotations

import math
from dataclasses import replace
from typing import Iterable, List, Optional, Sequence, Set

from repro.core.baselines import (
    dp_partition,
    enumerate_partitions,
    greedy_partition,
    run_sa,
    run_two_step,
)
from repro.core.cost import CachedEvaluator, PlanCost
from repro.core.ga import SearchResult, run_ga
from repro.core.graph import Graph

from .registry import get_strategy, list_strategies, register_strategy
from .result import ExploreResult
from .spec import (
    DPOptions,
    EnumOptions,
    ExploreSpec,
    GAOptions,
    GreedyOptions,
    SAOptions,
    TwoStepOptions,
)


def build_workload(name: str) -> Graph:
    """Resolve a spec's workload name to a netlib graph."""
    from repro.core import netlib

    try:
        builder = netlib.PAPER_MODELS[name]
    except KeyError:
        raise ValueError(
            f"unknown workload {name!r}; known: {sorted(netlib.PAPER_MODELS)}"
        ) from None
    try:
        return builder()
    except ModuleNotFoundError as err:
        raise RuntimeError(
            f"workload {name!r} needs an optional dependency: {err}"
        ) from err


def run(spec: ExploreSpec, graph: Optional[Graph] = None,
        ev: Optional[CachedEvaluator] = None, **runtime) -> ExploreResult:
    """Run ``spec.strategy`` on ``spec`` and return an :class:`ExploreResult`.

    ``graph`` overrides workload-name resolution (for custom graphs);
    ``ev`` shares one :class:`CachedEvaluator` across calls (e.g. from
    :func:`compare`).  ``runtime`` carries non-serializable extras a strategy
    may accept (the GA takes ``init_groups``).
    """
    g = graph if graph is not None else build_workload(spec.workload)
    ev = ev or CachedEvaluator(g, out_tile=spec.out_tile)
    entry = get_strategy(spec.strategy)
    options = spec.options
    if options is None and entry.options_cls is not None:
        options = entry.options_cls()
    if entry.options_cls is not None and not isinstance(options,
                                                        entry.options_cls):
        raise TypeError(
            f"strategy {spec.strategy!r} expects options of type "
            f"{entry.options_cls.__name__}, got {type(options).__name__}"
        )
    result = entry.fn(spec, options, g, ev, **runtime)
    result.spec = spec
    result.meta.setdefault("graph", g.name)
    return result


def compare(spec: ExploreSpec, strategies: Optional[Iterable[str]] = None,
            graph: Optional[Graph] = None,
            ev: Optional[CachedEvaluator] = None) -> List[ExploreResult]:
    """Run several strategies on one spec, sharing a single evaluator.

    Strategies other than ``spec.strategy`` run with their default options.
    Returns results in the order given (rank by ``cost`` to get a table).
    """
    names = list(strategies) if strategies is not None else list_strategies()
    g = graph if graph is not None else build_workload(spec.workload)
    ev = ev or CachedEvaluator(g, out_tile=spec.out_tile)
    results = []
    for name in names:
        sub = spec if name == spec.strategy else replace(
            spec, strategy=name, options=None)
        results.append(run(sub, graph=g, ev=ev))
    return results


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _from_search(spec: ExploreSpec, res: SearchResult,
                 evaluations: int, **meta) -> ExploreResult:
    best = res.best
    return ExploreResult(
        workload=spec.workload,
        strategy=spec.strategy,
        groups=best.groups,
        acc=best.acc,
        plan=best.plan,
        cost=best.cost,
        objective=spec.objective,
        history=res.history,
        samples=res.samples,
        evaluations=evaluations,
        population_log=res.population_log,
        meta=dict(meta),
    )


def _fixed_point(spec: ExploreSpec, groups: Sequence[Set[int]],
                 plan: PlanCost, n_eval: int,
                 evaluations: int, **meta) -> ExploreResult:
    acc = spec.hw.base
    cost = spec.objective.cost(plan, acc)
    return ExploreResult(
        workload=spec.workload,
        strategy=spec.strategy,
        groups=[set(s) for s in groups],
        acc=acc,
        plan=plan,
        cost=cost,
        objective=spec.objective,
        history=[(max(n_eval, 1), cost)],
        samples=n_eval,
        evaluations=evaluations,
        meta=dict(meta),
    )


# ---------------------------------------------------------------------------
# built-in strategies
# ---------------------------------------------------------------------------

@register_strategy("ga", GAOptions)
def _strategy_ga(spec: ExploreSpec, opts: GAOptions, g: Graph,
                 ev: CachedEvaluator, init_groups=None) -> ExploreResult:
    ev0 = ev.evaluations
    seeds = [list(gr) for gr in init_groups] if init_groups else []
    for name in opts.seed_from:
        if name == spec.strategy:
            raise ValueError(
                f"seed_from cannot include the running strategy {name!r}")
        seeded = run(replace(spec, strategy=name, options=None),
                     graph=g, ev=ev)
        if seeded.groups:
            seeds.append(seeded.groups)
    res = run_ga(
        g, spec.objective, spec.hw,
        sample_budget=spec.sample_budget,
        population=opts.population,
        tournament_k=opts.tournament_k,
        crossover_frac=opts.crossover_frac,
        elite=opts.elite,
        seed=spec.seed,
        out_tile=spec.out_tile,
        init_groups=[[set(s) for s in gr] for gr in seeds] or None,
        log_populations=opts.log_populations,
        ev=ev,
    )
    return _from_search(spec, res, ev.evaluations - ev0,
                        seeded_from=list(opts.seed_from))


@register_strategy("greedy", GreedyOptions)
def _strategy_greedy(spec: ExploreSpec, opts: GreedyOptions, g: Graph,
                     ev: CachedEvaluator) -> ExploreResult:
    ev0 = ev.evaluations
    groups, plan, n_eval = greedy_partition(
        g, spec.hw.base, spec.objective, out_tile=spec.out_tile, ev=ev,
        eval_budget=opts.eval_budget)
    return _fixed_point(spec, groups, plan, n_eval, ev.evaluations - ev0)


@register_strategy("dp", DPOptions)
def _strategy_dp(spec: ExploreSpec, opts: DPOptions, g: Graph,
                 ev: CachedEvaluator) -> ExploreResult:
    ev0 = ev.evaluations
    groups, plan, n_eval = dp_partition(
        g, spec.hw.base, spec.objective, out_tile=spec.out_tile, ev=ev)
    return _fixed_point(spec, groups, plan, n_eval, ev.evaluations - ev0)


@register_strategy("enum", EnumOptions)
def _strategy_enum(spec: ExploreSpec, opts: EnumOptions, g: Graph,
                   ev: CachedEvaluator) -> ExploreResult:
    ev0 = ev.evaluations
    er = enumerate_partitions(
        g, spec.hw.base, spec.objective, out_tile=spec.out_tile,
        state_budget=opts.state_budget, ev=ev)
    meta = {"complete": er.complete, "states": er.states}
    if er.groups is None or er.plan is None:
        return ExploreResult(
            workload=spec.workload, strategy=spec.strategy, groups=[],
            acc=spec.hw.base, plan=None, cost=math.inf,
            objective=spec.objective, history=[], samples=er.states,
            evaluations=ev.evaluations - ev0, meta=meta)
    return _fixed_point(spec, er.groups, er.plan, er.states,
                        ev.evaluations - ev0, **meta)


@register_strategy("sa", SAOptions)
def _strategy_sa(spec: ExploreSpec, opts: SAOptions, g: Graph,
                 ev: CachedEvaluator) -> ExploreResult:
    ev0 = ev.evaluations
    res = run_sa(
        g, spec.objective, spec.hw, sample_budget=spec.sample_budget,
        t0=opts.t0, t_end=opts.t_end, seed=spec.seed,
        out_tile=spec.out_tile, ev=ev)
    return _from_search(spec, res, ev.evaluations - ev0)


@register_strategy("two_step", TwoStepOptions)
def _strategy_two_step(spec: ExploreSpec, opts: TwoStepOptions, g: Graph,
                       ev: CachedEvaluator) -> ExploreResult:
    res = run_two_step(
        g, spec.objective, spec.hw, sampler=opts.sampler,
        capacity_samples=opts.capacity_samples,
        samples_per_capacity=opts.samples_per_capacity,
        seed=spec.seed, out_tile=spec.out_tile)
    # two-step runs its own per-capacity evaluators; report their total
    return _from_search(spec, res, res.evaluations, sampler=opts.sampler)


# ---------------------------------------------------------------------------
# TPU planning (wraps the paper-faithful adapter)
# ---------------------------------------------------------------------------

def plan_tpu(arch: str, tokens: int = 8192, layer_idx: Optional[int] = None,
             sample_budget: int = 3_000, seed: int = 0):
    """Run Cocco as the TPU execution planner for one architecture.

    Thin wrapper over :func:`repro.core.tpu_adapter.plan_architecture` so
    callers (CLI ``plan-tpu``, examples) go through one surface.
    """
    from repro.configs import get_config
    from repro.core.tpu_adapter import plan_architecture

    cfg = get_config(arch)
    return plan_architecture(cfg, tokens_local=tokens, layer_idx=layer_idx,
                             sample_budget=sample_budget, seed=seed)
