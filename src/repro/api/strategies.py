"""Built-in strategies + the ``run``/``compare`` entry points.

All six search methods from the paper's evaluation run under the same
registry and return :class:`ExploreResult`:

* ``ga``        — Cocco's genetic co-exploration (:func:`repro.core.ga.run_ga`)
* ``greedy``    — Halide-style greedy merging
* ``dp``        — Irregular-NN DP over depth order
* ``enum``      — exact (budgeted) enumeration over ideals
* ``sa``        — simulated annealing
* ``two_step``  — RS+GA / GS+GA decoupled capacity search

Fixed-hardware methods (``greedy``/``dp``/``enum``) evaluate at
``spec.hw.base`` regardless of the HW-space mode.
"""

from __future__ import annotations

import contextvars
import math
import os
from concurrent.futures import ProcessPoolExecutor, as_completed
from contextlib import nullcontext
from dataclasses import replace
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple, Union

from repro.core.baselines import (
    dp_partition,
    enumerate_partitions,
    greedy_partition,
    run_sa,
    run_two_step,
)
from repro.core.cost import CachedEvaluator, PlanCost, SubgraphCost
from repro.core.ga import SearchResult, run_ga
from repro.core.graph import Graph
from repro.obs import recorder as obs

from .registry import get_strategy, list_strategies, register_strategy
from .result import ExploreResult
from .spec import (
    DPOptions,
    EnumOptions,
    ExploreSpec,
    GAOptions,
    GreedyOptions,
    SAOptions,
    TwoStepOptions,
)
from .store import ResultStore, graph_fingerprint, spec_key
from .workloads import build_workload  # re-export: the one resolution path


# The store of the innermost active run(), visible to strategies that launch
# nested sub-searches (GAOptions.seed_from baselines, seed_from_keys lookups)
# so those share — and populate — the same spec-addressed cache instead of
# re-searching their seeds on every sweep point.  A contextvar keeps it
# correct per-thread (the plan server runs searches on a worker pool).
_ACTIVE_STORE: contextvars.ContextVar[Optional[ResultStore]] = \
    contextvars.ContextVar("repro_active_store", default=None)


def active_store() -> Optional[ResultStore]:
    """The :class:`ResultStore` of the innermost in-flight :func:`run`,
    or ``None``.  For strategies that issue nested sub-searches."""
    return _ACTIVE_STORE.get()


def _make_evaluator(g: Graph, out_tile: int, eval_backend: Optional[str],
                    eval_jobs: int,
                    struct_cache_dir: Optional[str] = None) -> CachedEvaluator:
    """Build an evaluator whose executor matches the requested backend.

    ``struct_cache_dir`` (or ``$REPRO_STRUCT_CACHE_DIR``) attaches a
    disk-backed :class:`~repro.core.structcache.StructureCache` as the warm
    tier behind the in-memory canonical structure memo; unset means no
    filesystem traffic, exactly like the result store.
    """
    from repro.core.engine import make_executor

    cache_dir = struct_cache_dir or os.environ.get("REPRO_STRUCT_CACHE_DIR")
    struct_cache = None
    if cache_dir:
        from repro.core.structcache import StructureCache

        struct_cache = StructureCache(cache_dir)
    return CachedEvaluator(g, out_tile=out_tile,
                           executor=make_executor(eval_backend, eval_jobs),
                           struct_cache=struct_cache)


def _counters_delta(before: Dict[str, object],
                    after: Dict[str, object]) -> Dict[str, object]:
    """Numeric counter deltas (so a shared evaluator's prior activity does
    not leak into one run's profile); non-numeric fields pass through."""
    out: Dict[str, object] = {}
    for k, v in after.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            out[k] = v
        else:
            b = before.get(k, 0)
            out[k] = v - b if isinstance(b, (int, float)) else v
    return out


def run(spec: ExploreSpec, graph: Optional[Graph] = None,
        ev: Optional[CachedEvaluator] = None,
        store: Optional[ResultStore] = None,
        eval_backend: Optional[str] = None, eval_jobs: int = 1,
        profile: bool = False,
        struct_cache_dir: Optional[str] = None,
        **runtime) -> ExploreResult:
    """Run ``spec.strategy`` on ``spec`` and return an :class:`ExploreResult`.

    ``graph`` overrides workload-name resolution (for custom graphs);
    ``ev`` shares one :class:`CachedEvaluator` across calls (e.g. from
    :func:`compare`).  ``store`` consults a spec-addressed
    :class:`~repro.api.store.ResultStore` first and persists the result on a
    miss; it is bypassed when ``runtime`` extras are passed, because those
    are not part of the spec and the result would not be reproducible from
    its address.  ``runtime`` carries non-serializable extras a strategy may
    accept (the GA takes ``init_groups``).

    ``eval_backend``/``eval_jobs`` pick the evaluation-engine executor for
    batched in-strategy cost queries (``serial`` | ``process`` | ``vector``
    | ``jax``; ``eval_jobs > 1`` defaults the backend to ``process`` — see
    :mod:`repro.core.engine`).  Every backend returns identical results, so
    these are runtime knobs, deliberately *not* part of the spec (a stored
    artifact addresses what was searched, not how it was scheduled).  They
    apply when ``run`` builds the evaluator; a caller-provided ``ev`` keeps
    its own executor.

    ``result.evaluations`` is set here, uniformly for every strategy, to the
    number of *distinct* (subgraph, hardware-point) cost-model queries the
    strategy issued — see :class:`ExploreResult` for the exact semantics.

    ``profile=True`` attaches ``result.meta["profile"]``: the search's wall
    time plus the evaluator counter deltas it caused
    (:meth:`CachedEvaluator.counters` — structure raw/canonical/disk hits,
    misses, and ``derive_schedule`` seconds).  The profile is attached
    *after* the store write, so stored artifacts never embed timings and
    stay byte-stable across machines; a store hit returns the cached
    artifact without a profile (no search ran).  ``struct_cache_dir``
    (default ``$REPRO_STRUCT_CACHE_DIR``) adds a disk-backed warm tier for
    canonical structures when ``run`` builds the evaluator.
    """
    from .workloads import workload_is_stable

    use_store = store is not None and not runtime
    if use_store:
        cached = store.get(spec)
        if cached is not None:
            # Store keys carry no graph identity, so refuse another graph's
            # artifact: a custom graph= shares only the workload *label*
            # with the spec, and a non-stable workload URI (file: — the
            # file can change under an unchanged URI) must be re-resolved
            # and fingerprint-checked before its artifact replays.
            g_check = graph
            if g_check is None and not workload_is_stable(spec.workload):
                g_check = graph = build_workload(spec.workload)
            if (g_check is None
                    or cached.meta.get("graph_sha")
                    in (None, graph_fingerprint(g_check))):
                obs.add("store.hit")
                return cached
    if graph is not None:
        g = graph
    else:
        with obs.span("resolve-workload", workload=spec.workload):
            g = build_workload(spec.workload)
    created_ev = ev is None
    if created_ev:
        ev = _make_evaluator(g, spec.out_tile, eval_backend, eval_jobs,
                             struct_cache_dir)
    entry = get_strategy(spec.strategy)
    options = spec.options
    if options is None and entry.options_cls is not None:
        options = entry.options_cls()
    if entry.options_cls is not None and not isinstance(options,
                                                        entry.options_cls):
        raise TypeError(
            f"strategy {spec.strategy!r} expects options of type "
            f"{entry.options_cls.__name__}, got {type(options).__name__}"
        )
    # ``--profile`` is a thin view over the telemetry recorder: with no
    # ambient recorder installed, profiling brings its own (the strategy
    # span's duration *is* the reported wall time).  Telemetry never touches
    # the result — the profile dict is attached after the store write, and
    # counter deltas flow only into the recorder side-channel.
    rec = obs.current()
    if profile and not rec.enabled:
        rec = obs.Recorder()
    token = _ACTIVE_STORE.set(store if use_store else None)
    counters_before = ev.counters() if rec.enabled else None
    try:
        with ev.count_run() as touched, \
                (obs.recording(rec) if rec.enabled else nullcontext()), \
                rec.span(f"strategy:{spec.strategy}",
                         workload=spec.workload, strategy=spec.strategy,
                         budget=spec.sample_budget, seed=spec.seed) as sp:
            result = entry.fn(spec, options, g, ev, **runtime)
    finally:
        _ACTIVE_STORE.reset(token)
        if created_ev:
            ev.close()  # release executor pools; the cache dies with ev
    result.evaluations = len(touched)
    result.spec = spec
    result.meta.setdefault("graph", g.name)
    result.meta.setdefault("graph_sha", graph_fingerprint(g))
    if use_store:
        store.put(spec, result)
    if rec.enabled:
        prof = _counters_delta(counters_before, ev.counters())
        rec.merge_counters(prof, prefix="evaluator.")
        if profile:
            prof["wall_s"] = sp.dur_s
            result.meta["profile"] = prof
    return result


def _resolve_compare_specs(
    spec: ExploreSpec,
    strategies: Optional[Iterable[Union[str, ExploreSpec]]],
) -> List[ExploreSpec]:
    items = list(strategies) if strategies is not None else list_strategies()
    subs: List[ExploreSpec] = []
    for item in items:
        if isinstance(item, ExploreSpec):
            if (item.workload != spec.workload
                    or item.out_tile != spec.out_tile):
                raise ValueError(
                    "compare() spec items must share the primary spec's "
                    f"workload/out_tile; got {item.workload!r}/"
                    f"{item.out_tile} vs {spec.workload!r}/{spec.out_tile}")
            subs.append(item)
        else:
            subs.append(spec if item == spec.strategy
                        else replace(spec, strategy=item, options=None))
    return subs


def compare(spec: ExploreSpec,
            strategies: Optional[Iterable[Union[str, ExploreSpec]]] = None,
            graph: Optional[Graph] = None,
            ev: Optional[CachedEvaluator] = None,
            jobs: int = 1,
            store: Optional[ResultStore] = None,
            eval_backend: Optional[str] = None,
            eval_jobs: int = 1,
            struct_cache_dir: Optional[str] = None) -> List[ExploreResult]:
    """Run several strategies on one spec, sharing a single evaluator cache.

    ``strategies`` items are strategy names (run with their default options,
    except ``spec.strategy`` which keeps ``spec.options``) or fully-formed
    :class:`ExploreSpec` variants sharing the primary spec's workload (for
    per-strategy budgets/options, as the benchmarks do).  Returns results in
    the order given (rank by ``cost`` to get a table).

    ``jobs > 1`` runs the strategies in worker processes via
    :class:`~concurrent.futures.ProcessPoolExecutor`: each worker searches
    against a cold per-worker :class:`CachedEvaluator` whose entries are
    merged back into ``ev`` on join.  Because every strategy is
    deterministic given its spec and evaluation counts are cache-warmth
    independent, the parallel path returns bitwise-identical results to the
    serial path.  Strategies registered at import time (the built-ins, or
    anything importable from the worker) are supported; with the ``fork``
    start method (Linux default) runtime-registered strategies work too.
    When jax has been imported, workers start via ``forkserver`` instead
    (see :func:`repro.core.engine.pool_mp_context`) so no process forks a
    multithreaded jax runtime.

    ``store`` serves store hits in the parent without spawning a worker and
    persists every miss, so an interrupted comparison resumes where it
    stopped.

    ``eval_backend``/``eval_jobs`` select the evaluation-engine executor for
    *within-strategy* batches (a different axis than ``jobs``, which fans
    out whole strategies).  They configure the shared evaluator on the
    serial path; with ``jobs > 1`` each worker keeps the default serial
    executor — nesting process pools inside workers oversubscribes cores.

    ``struct_cache_dir`` (default ``$REPRO_STRUCT_CACHE_DIR``) attaches the
    disk-backed canonical structure cache; with ``jobs > 1`` each worker
    opens the same directory (writes are atomic, so sharing is safe) and
    additionally ships its in-memory canonical entries back on join
    (:meth:`CachedEvaluator.merge_structures`), mirroring the cost-memo
    merge.
    """
    subs = _resolve_compare_specs(spec, strategies)
    g = graph if graph is not None else build_workload(spec.workload)
    created_ev = ev is None
    if created_ev:
        ev = _make_evaluator(g, spec.out_tile, eval_backend, eval_jobs,
                             struct_cache_dir)
    try:
        if jobs and jobs > 1 and len(subs) > 1:
            return _compare_parallel(subs, g, ev, jobs, store,
                                     struct_cache_dir)
        return [run(sub, graph=g, ev=ev, store=store) for sub in subs]
    finally:
        if created_ev:
            ev.close()


def _compare_worker(
    spec_json: str, graph: Optional[Graph], store_dir: Optional[str],
    struct_cache_dir: Optional[str] = None,
) -> Tuple[ExploreResult, Dict[Tuple, SubgraphCost], Dict[Tuple, object]]:
    """Top-level (picklable) worker: run one spec on a cold evaluator.

    Returns the result plus the worker evaluator's memo table and its
    canonical structure table, so the parent can merge both
    (``CachedEvaluator.merge_cache`` / ``merge_structures``) and later
    serial runs still benefit from the work done in workers.
    """
    spec = ExploreSpec.from_json(spec_json)
    g = graph if graph is not None else build_workload(spec.workload)
    ev = _make_evaluator(g, spec.out_tile, None, 1, struct_cache_dir)
    worker_store = ResultStore(store_dir) if store_dir else None
    result = run(spec, graph=g, ev=ev, store=worker_store)
    return result, ev.cache_snapshot(), ev.structure_snapshot()


def _compare_parallel(subs: List[ExploreSpec], g: Graph,
                      ev: CachedEvaluator, jobs: int,
                      store: Optional[ResultStore],
                      struct_cache_dir: Optional[str] = None,
                      ) -> List[ExploreResult]:
    from repro.core.engine import pool_mp_context

    results: List[Optional[ExploreResult]] = [None] * len(subs)
    pending = list(range(len(subs)))
    if store is not None:
        g_sha = graph_fingerprint(g)
        missing = []
        for i in pending:
            cached = store.get(subs[i])
            if cached is not None and cached.meta.get("graph_sha") in (None,
                                                                       g_sha):
                results[i] = cached
            else:
                missing.append(i)
        pending = missing
    # identical specs in one batch (e.g. two searches that chose the same
    # hardware point) search once and share the result
    first_of: Dict[str, int] = {}
    duplicates: Dict[int, int] = {}
    unique = []
    for i in pending:
        key = spec_key(subs[i])
        if key in first_of:
            duplicates[i] = first_of[key]
        else:
            first_of[key] = i
            unique.append(i)
    if unique:
        store_dir = str(store.root) if store is not None else None
        with ProcessPoolExecutor(
                max_workers=min(jobs, len(unique)),
                mp_context=pool_mp_context()) as pool:
            futures = {
                pool.submit(_compare_worker, subs[i].to_json(), g, store_dir,
                            struct_cache_dir):
                i for i in unique
            }
            for fut in as_completed(futures):
                result, cache, structs = fut.result()
                results[futures[fut]] = result
                ev.merge_cache(cache)
                ev.merge_structures(structs)
    for i, j in duplicates.items():
        results[i] = results[j]
    return [r for r in results if r is not None]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _from_search(spec: ExploreSpec, res: SearchResult,
                 **meta) -> ExploreResult:
    # ``evaluations`` is left 0 here: run() overwrites it uniformly with the
    # distinct-query count of the whole strategy invocation
    best = res.best
    return ExploreResult(
        workload=spec.workload,
        strategy=spec.strategy,
        groups=best.groups,
        acc=best.acc,
        plan=best.plan,
        cost=best.cost,
        objective=spec.objective,
        history=res.history,
        samples=res.samples,
        population_log=res.population_log,
        meta=dict(meta),
    )


def _fixed_point(spec: ExploreSpec, groups: Sequence[Set[int]],
                 plan: PlanCost, n_eval: int, **meta) -> ExploreResult:
    acc = spec.hw.base
    cost = spec.objective.cost(plan, acc)
    return ExploreResult(
        workload=spec.workload,
        strategy=spec.strategy,
        groups=[set(s) for s in groups],
        acc=acc,
        plan=plan,
        cost=cost,
        objective=spec.objective,
        history=[(max(n_eval, 1), cost)],
        samples=n_eval,
        meta=dict(meta),
    )


# ---------------------------------------------------------------------------
# built-in strategies
# ---------------------------------------------------------------------------

def _store_seed_groups(opts: GAOptions, spec: ExploreSpec,
                       g: Graph) -> List[List[Set[int]]]:
    """Resolve ``opts.seed_from_keys`` against the active store: each key
    names an archived result (any strategy/budget) whose groups warm-start
    the population.  The archived partition must actually cover this graph,
    or a key pointing at a different workload would silently poison the
    initial population."""
    if not opts.seed_from_keys:
        return []
    store = active_store()
    if store is None:
        raise ValueError(
            "GAOptions.seed_from_keys needs a result store at run time "
            "(pass store= / --store-dir); keys cannot resolve without one")
    seeds: List[List[Set[int]]] = []
    every_node = set(range(g.n))
    for key in opts.seed_from_keys:
        seeded = store.get_by_key(key)
        if seeded is None:
            raise ValueError(
                f"seed_from_keys entry {key[:16]}... not found in "
                f"store[{store.root}] (run the reduced spec first, or check "
                f"`python -m repro store ls --json`)")
        covered = set().union(*seeded.groups) if seeded.groups else set()
        if covered != every_node:
            raise ValueError(
                f"seed_from_keys entry {key[:16]}... partitions workload "
                f"{seeded.workload!r}, which does not cover "
                f"{spec.workload!r} ({len(covered)} vs {g.n} nodes)")
        seeds.append(seeded.groups)
    return seeds


@register_strategy("ga", GAOptions)
def _strategy_ga(spec: ExploreSpec, opts: GAOptions, g: Graph,
                 ev: CachedEvaluator, init_groups=None) -> ExploreResult:
    seeds = [list(gr) for gr in init_groups] if init_groups else []
    for name in opts.seed_from:
        if name == spec.strategy:
            raise ValueError(
                f"seed_from cannot include the running strategy {name!r}")
        # Baseline seed searches always run (so the outer result's
        # `evaluations` stays independent of store warmth) but publish
        # write-through into the active store: the sweep's reduced baseline
        # specs become store hits for every later top-level run/compare.
        seeded = run(replace(spec, strategy=name, options=None),
                     graph=g, ev=ev)
        store = active_store()
        if (store is not None and seeded.spec is not None
                and seeded.spec not in store):
            store.put(seeded.spec, seeded)
        if seeded.groups:
            seeds.append(seeded.groups)
    seeds.extend(_store_seed_groups(opts, spec, g))
    res = run_ga(
        g, spec.objective, spec.hw,
        sample_budget=spec.sample_budget,
        population=opts.population,
        tournament_k=opts.tournament_k,
        crossover_frac=opts.crossover_frac,
        elite=opts.elite,
        seed=spec.seed,
        out_tile=spec.out_tile,
        init_groups=[[set(s) for s in gr] for gr in seeds] or None,
        log_populations=opts.log_populations,
        ev=ev,
    )
    return _from_search(spec, res, seeded_from=list(opts.seed_from),
                        seeded_from_keys=list(opts.seed_from_keys))


@register_strategy("greedy", GreedyOptions)
def _strategy_greedy(spec: ExploreSpec, opts: GreedyOptions, g: Graph,
                     ev: CachedEvaluator) -> ExploreResult:
    groups, plan, n_eval = greedy_partition(
        g, spec.hw.base, spec.objective, out_tile=spec.out_tile, ev=ev,
        eval_budget=opts.eval_budget)
    return _fixed_point(spec, groups, plan, n_eval)


@register_strategy("dp", DPOptions)
def _strategy_dp(spec: ExploreSpec, opts: DPOptions, g: Graph,
                 ev: CachedEvaluator) -> ExploreResult:
    groups, plan, n_eval = dp_partition(
        g, spec.hw.base, spec.objective, out_tile=spec.out_tile, ev=ev)
    return _fixed_point(spec, groups, plan, n_eval)


@register_strategy("enum", EnumOptions)
def _strategy_enum(spec: ExploreSpec, opts: EnumOptions, g: Graph,
                   ev: CachedEvaluator) -> ExploreResult:
    er = enumerate_partitions(
        g, spec.hw.base, spec.objective, out_tile=spec.out_tile,
        state_budget=opts.state_budget, ev=ev)
    meta = {"complete": er.complete, "states": er.states}
    if er.groups is None or er.plan is None:
        return ExploreResult(
            workload=spec.workload, strategy=spec.strategy, groups=[],
            acc=spec.hw.base, plan=None, cost=math.inf,
            objective=spec.objective, history=[], samples=er.states,
            meta=meta)
    return _fixed_point(spec, er.groups, er.plan, er.states, **meta)


@register_strategy("sa", SAOptions)
def _strategy_sa(spec: ExploreSpec, opts: SAOptions, g: Graph,
                 ev: CachedEvaluator) -> ExploreResult:
    res = run_sa(
        g, spec.objective, spec.hw, sample_budget=spec.sample_budget,
        t0=opts.t0, t_end=opts.t_end, seed=spec.seed,
        out_tile=spec.out_tile, ev=ev)
    return _from_search(spec, res)


@register_strategy("two_step", TwoStepOptions)
def _strategy_two_step(spec: ExploreSpec, opts: TwoStepOptions, g: Graph,
                       ev: CachedEvaluator) -> ExploreResult:
    # the shared evaluator now flows into the per-capacity inner GA runs, so
    # their queries are counted (and cached) like every other strategy's
    res = run_two_step(
        g, spec.objective, spec.hw, sampler=opts.sampler,
        capacity_samples=opts.capacity_samples,
        samples_per_capacity=opts.samples_per_capacity,
        seed=spec.seed, out_tile=spec.out_tile, ev=ev)
    return _from_search(spec, res, sampler=opts.sampler)


# ---------------------------------------------------------------------------
# TPU planning (wraps the paper-faithful adapter)
# ---------------------------------------------------------------------------

def plan_tpu(arch: str, tokens: int = 8192, layer_idx: Optional[int] = None,
             sample_budget: int = 3_000, seed: int = 0):
    """Run Cocco as the TPU execution planner for one architecture.

    Thin wrapper over :func:`repro.core.tpu_adapter.plan_architecture` so
    callers (CLI ``plan-tpu``, examples) go through one surface.
    """
    from repro.configs import get_config
    from repro.core.tpu_adapter import plan_architecture

    cfg = get_config(arch)
    return plan_architecture(cfg, tokens_local=tokens, layer_idx=layer_idx,
                             sample_budget=sample_budget, seed=seed)
