"""`ResultStore`: a spec-addressed, on-disk cache of :class:`ExploreResult`.

Every entry is one JSON artifact named by the SHA-256 of the canonical
serialization of its :class:`ExploreSpec` plus the strategy name, so a run is
addressed purely by *what was asked for*: re-invoking the same spec hits the
store and returns the archived result instantly instead of re-searching.
This is what lets ``python -m repro compare --store-dir ...`` and the
benchmark sweeps (`python -m benchmarks.run`) resume after an interrupt —
completed (workload, strategy, budget, seed, ...) points are replayed from
disk, and only the missing ones search.

Design notes:

* Keys are content hashes of ``ExploreSpec.to_dict()`` rendered as canonical
  JSON (sorted keys, minimal separators), so they are stable across
  processes, machines, and Python versions.
* Writes are atomic (temp file + ``os.replace``), so concurrent workers of a
  parallel ``compare`` may race on the same key and still leave a valid
  entry — both sides write equal bytes for a deterministic strategy.
* Reads are defensive: an entry that fails to parse, fails to validate,
  carries a different ``RESULT_VERSION``, or was written for a different
  spec (hash tampering, manual edits) is quarantined to
  ``<key>.json.corrupt`` and treated as a miss, after which the caller
  re-searches and overwrites it with a fresh artifact.
* The address covers the *spec*, not the code: artifacts written before an
  edit to the cost model or a strategy still hit afterwards.  Clear the
  store directory (or pass ``--no-store``) after changing search/cost
  code, the same way you would invalidate any other build cache.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional, Tuple, Union

from .result import RESULT_VERSION, ExploreResult
from .spec import ExploreSpec


def graph_fingerprint(g) -> str:
    """Cheap structural digest of a :class:`~repro.core.graph.Graph`.

    Stamped into stored results and checked on replay, so two different
    graphs sharing a workload label (custom graphs passed via ``graph=``)
    cannot serve each other's cached artifacts.
    """
    h = hashlib.sha256()
    for n in g.nodes:
        h.update(f"{n.idx},{n.out_len},{n.line_bytes},{n.weight_bytes},"
                 f"{n.macs},{n.is_output};".encode())
    for e in g.edges:
        h.update(f"{e.src},{e.dst},{e.F},{e.s},{e.kind};".encode())
    return h.hexdigest()


def spec_key(spec: ExploreSpec) -> str:
    """SHA-256 content hash addressing ``spec``'s result in a store.

    Hashes the canonical JSON of the spec (which embeds the strategy and its
    typed options) plus the strategy name as a domain separator.  Stable
    across processes: two workers hashing equal specs get equal keys.
    """
    canonical = json.dumps(spec.to_dict(), sort_keys=True,
                           separators=(",", ":"))
    h = hashlib.sha256()
    h.update(canonical.encode("utf-8"))
    h.update(b"\x00")
    h.update(spec.strategy.encode("utf-8"))
    return h.hexdigest()


@dataclass(frozen=True)
class StoreEntry:
    """One ``store ls`` row: artifact path, key, size, write time, labels."""

    path: Path
    key: str
    size: int
    mtime: float
    workload: str = ""
    strategy: str = ""


class ResultStore:
    """Directory of spec-addressed ``ExploreResult`` JSON artifacts."""

    def __init__(self, root: Union[str, Path]) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0

    # -- addressing -------------------------------------------------------
    def path_for(self, spec: ExploreSpec) -> Path:
        return self.root / f"{spec_key(spec)}.json"

    def __contains__(self, spec: ExploreSpec) -> bool:
        return self.path_for(spec).exists()

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*.json"))

    # -- read / write -----------------------------------------------------
    def get(self, spec: ExploreSpec) -> Optional[ExploreResult]:
        """Return the archived result for ``spec``, or ``None`` on a miss.

        A corrupt or mismatched entry is quarantined (renamed to
        ``*.json.corrupt``) and reported as a miss so the caller re-searches.
        """
        path = self.path_for(spec)
        try:
            payload = path.read_text()
        except OSError:
            self.misses += 1
            return None
        try:
            d = json.loads(payload)
            if d.get("version") != RESULT_VERSION:
                raise ValueError(
                    f"artifact version {d.get('version')!r} != "
                    f"{RESULT_VERSION} (written by an older layout)")
            result = ExploreResult.from_dict(d)
        except (ValueError, KeyError, TypeError) as err:
            self._quarantine(path, reason=str(err))
            self.misses += 1
            return None
        if result.spec is not None and result.spec != spec:
            self._quarantine(path, reason="stored spec != requested spec")
            self.misses += 1
            return None
        self.hits += 1
        return result

    def put(self, spec: ExploreSpec, result: ExploreResult) -> Path:
        """Atomically persist ``result`` under ``spec``'s key."""
        if result.spec is None:
            result.spec = spec
        path = self.path_for(spec)
        fd, tmp = tempfile.mkstemp(dir=self.root, prefix=".tmp-",
                                   suffix=".json")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(result.to_json(indent=2))
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    # -- maintenance ------------------------------------------------------
    def entries(self, peek: bool = True) -> List["StoreEntry"]:
        """Every artifact in the store, oldest mtime first (LRU order).

        With ``peek`` (the ``store ls`` path), ``workload``/``strategy``
        are best-effort reads from the artifact (empty strings for
        unreadable/corrupt entries); ``peek=False`` stays stat-only so
        ``gc``/``total_bytes`` never parse artifact JSON.
        """
        out: List[StoreEntry] = []
        for p in self.root.glob("*.json"):
            try:
                st = p.stat()
            except OSError:
                continue  # raced with a concurrent gc/clear
            workload = strategy = ""
            if peek:
                try:
                    d = json.loads(p.read_text())
                    workload = str(d.get("workload", ""))
                    strategy = str(d.get("strategy", ""))
                except (OSError, ValueError):
                    pass
            out.append(StoreEntry(path=p, key=p.stem, size=st.st_size,
                                  mtime=st.st_mtime, workload=workload,
                                  strategy=strategy))
        out.sort(key=lambda e: (e.mtime, e.key))
        return out

    def total_bytes(self) -> int:
        return sum(e.size for e in self.entries(peek=False))

    def gc(self, max_bytes: int) -> Tuple[int, int]:
        """Evict least-recently-written artifacts until the store holds at
        most ``max_bytes``.  Returns ``(entries_removed, bytes_freed)``.

        LRU by artifact mtime: a replayed spec does not refresh its mtime,
        so this is strictly write-recency — good enough for the sweep
        workloads the store serves (ROADMAP: cross-run eviction/GC).
        Quarantined ``*.json.corrupt`` files are always removed.
        """
        removed = freed = 0
        for p in self.root.glob("*.json.corrupt"):
            try:
                size = p.stat().st_size
                p.unlink()
                removed += 1
                freed += size
            except OSError:
                pass
        entries = self.entries(peek=False)
        total = sum(e.size for e in entries)
        for e in entries:
            if total <= max_bytes:
                break
            try:
                e.path.unlink()
            except OSError:
                continue  # another process beat us to it
            total -= e.size
            removed += 1
            freed += e.size
        return removed, freed

    def _quarantine(self, path: Path, reason: str) -> None:
        try:
            path.replace(path.with_suffix(".json.corrupt"))
        except OSError:
            pass  # another process may have quarantined/overwritten it

    def clear(self) -> int:
        """Delete every entry (incl. quarantined ones); returns the count."""
        n = 0
        for p in list(self.root.glob("*.json")) + \
                list(self.root.glob("*.json.corrupt")):
            try:
                p.unlink()
                n += 1
            except OSError:
                pass
        return n

    def stats(self) -> str:
        return (f"store[{self.root}]: {self.hits} hits, "
                f"{self.misses} misses, {len(self)} entries")
