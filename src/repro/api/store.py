"""`ResultStore`: a spec-addressed, concurrency-safe cache of results.

Every entry is one JSON artifact named by the SHA-256 of the canonical
serialization of its :class:`ExploreSpec` plus the strategy name, so a run is
addressed purely by *what was asked for*: re-invoking the same spec hits the
store and returns the archived result instantly instead of re-searching.
This is what lets ``python -m repro compare --store-dir ...``, the benchmark
sweeps (`python -m benchmarks.run`), the plan zoo (``python -m repro zoo``)
and the plan server (``python -m repro serve-plans``) resume after an
interrupt — completed (workload, strategy, budget, seed, ...) points are
replayed from disk, and only the missing ones search.

Design notes:

* Keys are content hashes of ``ExploreSpec.to_dict()`` rendered as canonical
  JSON (sorted keys, minimal separators), so they are stable across
  processes, machines, and Python versions.
* Writes are atomic (temp file + ``os.replace``), so concurrent workers of a
  parallel ``compare`` may race on the same key and still leave a valid
  entry — both sides write equal bytes for a deterministic strategy.  Temp
  files are dotfiles with a non-``.json`` suffix, so in-progress writes are
  invisible to ``entries()``/``gc()``/``__len__`` and a concurrent ``gc``
  can never evict (or a concurrent ``ls`` half-read) an entry mid-write.
* Reads are defensive: an entry that fails to parse, fails to validate,
  carries a different ``RESULT_VERSION``, or was written for a different
  spec (hash tampering, manual edits) is quarantined to
  ``<key>.json.corrupt`` and treated as a miss, after which the caller
  re-searches and overwrites it with a fresh artifact.  Quarantine re-checks
  that the on-disk bytes are still the bytes it read, so a concurrent
  writer's *fresh* artifact is never quarantined by a reader holding a
  stale corrupt payload.
* :meth:`exclusive` is a cross-process advisory lock (``O_CREAT | O_EXCL``
  lockfile with stale-lock recovery) serializing "search this spec" between
  processes: the plan server and the hammer tests use it so N concurrent
  identical requests — threads *or* processes — perform exactly one search.
* The address covers the *spec*, not the code: artifacts written before an
  edit to the cost model or a strategy still hit afterwards.  Clear the
  store directory (or pass ``--no-store``) after changing search/cost
  code, the same way you would invalidate any other build cache.
"""

from __future__ import annotations

import json
import os
import socket
import tempfile
import time
import uuid
from contextlib import contextmanager
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple, Union

import hashlib

from repro.obs import recorder as obs

from .result import RESULT_VERSION, ExploreResult
from .spec import ExploreSpec

#: seconds after which an abandoned temp/lock file (crashed writer) is
#: considered stale and reclaimable by ``gc()`` / ``exclusive()``
STALE_AFTER_S = 600.0


def graph_fingerprint(g) -> str:
    """Cheap structural digest of a :class:`~repro.core.graph.Graph`.

    Stamped into stored results and checked on replay, so two different
    graphs sharing a workload label (custom graphs passed via ``graph=``)
    cannot serve each other's cached artifacts.
    """
    h = hashlib.sha256()
    for n in g.nodes:
        h.update(f"{n.idx},{n.out_len},{n.line_bytes},{n.weight_bytes},"
                 f"{n.macs},{n.is_output};".encode())
    for e in g.edges:
        h.update(f"{e.src},{e.dst},{e.F},{e.s},{e.kind};".encode())
    return h.hexdigest()


def spec_key(spec: ExploreSpec) -> str:
    """SHA-256 content hash addressing ``spec``'s result in a store.

    Hashes the canonical JSON of the spec (which embeds the strategy and its
    typed options) plus the strategy name as a domain separator.  Stable
    across processes: two workers hashing equal specs get equal keys.
    """
    canonical = json.dumps(spec.to_dict(), sort_keys=True,
                           separators=(",", ":"))
    h = hashlib.sha256()
    h.update(canonical.encode("utf-8"))
    h.update(b"\x00")
    h.update(spec.strategy.encode("utf-8"))
    return h.hexdigest()


class StoreLockTimeout(RuntimeError):
    """``exclusive()`` could not acquire the per-key lock in time."""


class StoreReadOnly(RuntimeError):
    """A mutating operation was attempted on a read-only store (zoo mount)."""


@dataclass(frozen=True)
class StoreEntry:
    """One ``store ls`` row: artifact path, key, size, write time, labels."""

    path: Path
    key: str
    size: int
    mtime: float
    workload: str = ""
    strategy: str = ""


class ResultStore:
    """Directory of spec-addressed ``ExploreResult`` JSON artifacts.

    ``read_only=True`` mounts an existing directory (e.g. a precomputed plan
    zoo) as a pure read-through tier: ``get`` never quarantines, and every
    mutating method (``put``/``gc``/``clear``/``exclusive``) raises
    :class:`StoreReadOnly`.
    """

    def __init__(self, root: Union[str, Path],
                 read_only: bool = False) -> None:
        self.root = Path(root)
        self.read_only = read_only
        if read_only:
            if not self.root.is_dir():
                raise FileNotFoundError(
                    f"read-only store directory does not exist: {self.root}")
        else:
            self.root.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.writes = 0
        self.quarantined = 0

    # -- addressing -------------------------------------------------------
    def path_for(self, spec: ExploreSpec) -> Path:
        return self.root / f"{spec_key(spec)}.json"

    def __contains__(self, spec: ExploreSpec) -> bool:
        return self.path_for(spec).exists()

    def __len__(self) -> int:
        return sum(1 for _ in self._artifacts())

    def _artifacts(self) -> Iterator[Path]:
        """Finished artifacts only: dotfiles (in-progress ``.tmp-*`` writes,
        ``.<key>.lock`` lockfiles) never count as entries."""
        for p in self.root.glob("*.json"):
            if not p.name.startswith("."):
                yield p

    # -- read / write -----------------------------------------------------
    def get(self, spec: ExploreSpec) -> Optional[ExploreResult]:
        """Return the archived result for ``spec``, or ``None`` on a miss.

        A corrupt or mismatched entry is quarantined (renamed to
        ``*.json.corrupt``) and reported as a miss so the caller re-searches.
        """
        return self._load(self.path_for(spec), expect_spec=spec)

    def get_by_key(self, key: str) -> Optional[ExploreResult]:
        """Load an artifact by its raw store key (``--seed-from-store``,
        zoo verification).  Validates that the artifact's embedded spec
        actually hashes to ``key``, so a hand-renamed file cannot be served
        under a foreign address."""
        path = self.root / f"{key}.json"
        res = self._load(path, expect_spec=None)
        if res is None:
            return None
        if res.spec is not None and spec_key(res.spec) != key:
            self._quarantine(path, reason="stored spec does not hash to key",
                             expected_payload=None)
            self.misses += 1
            self.hits -= 1
            return None
        return res

    def resolve_key(self, prefix: str) -> str:
        """Expand a unique key prefix (≥ 8 hex chars) to the full key."""
        if len(prefix) < 8:
            raise ValueError(
                f"store key prefix {prefix!r} too short (need >= 8 chars)")
        matches = [p.stem for p in self._artifacts()
                   if p.stem.startswith(prefix)]
        if not matches:
            raise KeyError(f"no store entry matches key prefix {prefix!r} "
                           f"in {self.root}")
        if len(matches) > 1:
            raise KeyError(f"store key prefix {prefix!r} is ambiguous "
                           f"({len(matches)} matches)")
        return matches[0]

    def _load(self, path: Path,
              expect_spec: Optional[ExploreSpec]) -> Optional[ExploreResult]:
        try:
            payload = path.read_bytes()
        except OSError:
            self.misses += 1
            obs.add("result_store.miss")
            return None
        try:
            d = json.loads(payload)
            if d.get("version") != RESULT_VERSION:
                raise ValueError(
                    f"artifact version {d.get('version')!r} != "
                    f"{RESULT_VERSION} (written by an older layout)")
            result = ExploreResult.from_dict(d)
        except (ValueError, KeyError, TypeError) as err:
            self._quarantine(path, reason=str(err), expected_payload=payload)
            self.misses += 1
            return None
        if (expect_spec is not None and result.spec is not None
                and result.spec != expect_spec):
            self._quarantine(path, reason="stored spec != requested spec",
                             expected_payload=payload)
            self.misses += 1
            obs.add("result_store.miss")
            return None
        self.hits += 1
        obs.add("result_store.hit")
        return result

    def put(self, spec: ExploreSpec, result: ExploreResult) -> Path:
        """Atomically persist ``result`` under ``spec``'s key.

        The temp file is a dotfile with a ``.tmp`` suffix, so a concurrent
        ``gc()``/``entries()``/``ls`` never sees (or evicts) the write in
        progress; ``os.replace`` publishes it in one step.
        """
        self._require_writable("put")
        if result.spec is None:
            result.spec = spec
        path = self.path_for(spec)
        fd, tmp = tempfile.mkstemp(dir=self.root, prefix=".tmp-",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(result.to_json(indent=2))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.writes += 1
        obs.add("result_store.write")
        return path

    # -- cross-process locking --------------------------------------------
    def lock_path(self, key: str) -> Path:
        return self.root / f".{key}.lock"

    @contextmanager
    def exclusive(self, spec_or_key: Union[ExploreSpec, str],
                  timeout: Optional[float] = None,
                  stale_after: float = STALE_AFTER_S,
                  poll: float = 0.02):
        """Cross-process advisory lock for one store key.

        ``O_CREAT | O_EXCL`` on ``.<key>.lock`` is atomic on every local
        filesystem, so at most one process holds the lock; others spin until
        it is released (or ``timeout`` elapses -> :class:`StoreLockTimeout`).
        A lock older than ``stale_after`` seconds (crashed holder) is
        reclaimed via rename-to-unique-then-unlink, so two waiters cannot
        both "steal" it and stomp each other's fresh lock.

        Use it to serialize *searching* a spec across processes::

            if (res := store.get(spec)) is None:
                with store.exclusive(spec):
                    res = store.get(spec)          # another process won
                    if res is None:
                        res = run(spec)            # exactly one search
                        store.put(spec, res)
        """
        self._require_writable("exclusive")
        key = (spec_or_key if isinstance(spec_or_key, str)
               else spec_key(spec_or_key))
        lock = self.lock_path(key)
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            try:
                fd = os.open(lock, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                try:
                    os.write(fd, f"{os.getpid()}@{socket.gethostname()} "
                                 f"{time.time():.3f}\n".encode())
                finally:
                    os.close(fd)
                break
            except FileExistsError:
                self._reclaim_stale_lock(lock, stale_after)
                if deadline is not None and time.monotonic() > deadline:
                    raise StoreLockTimeout(
                        f"could not acquire store lock {lock} within "
                        f"{timeout:.1f}s (held by: "
                        f"{self._lock_holder(lock)})") from None
                time.sleep(poll)
        try:
            yield
        finally:
            try:
                os.unlink(lock)
            except OSError:
                pass    # reclaimed as stale by someone else; already gone

    def _lock_holder(self, lock: Path) -> str:
        try:
            return lock.read_text().strip() or "?"
        except OSError:
            return "?"

    def _reclaim_stale_lock(self, lock: Path, stale_after: float) -> None:
        try:
            age = time.time() - lock.stat().st_mtime
        except OSError:
            return      # released while we looked: retry the open
        if age <= stale_after:
            return
        # rename first so only one waiter wins the reclaim; the loser's
        # rename fails ENOENT and it simply retries the O_EXCL open
        grave = lock.with_name(f"{lock.name}.stale-{uuid.uuid4().hex}")
        try:
            os.rename(lock, grave)
            os.unlink(grave)
        except OSError:
            pass

    # -- maintenance ------------------------------------------------------
    def entries(self, peek: bool = True) -> List["StoreEntry"]:
        """Every artifact in the store, oldest mtime first (LRU order).

        With ``peek`` (the ``store ls`` path), ``workload``/``strategy``
        are best-effort reads from the artifact (empty strings for
        unreadable/corrupt entries); ``peek=False`` stays stat-only so
        ``gc``/``total_bytes`` never parse artifact JSON.
        """
        out: List[StoreEntry] = []
        for p in self._artifacts():
            try:
                st = p.stat()
            except OSError:
                continue  # raced with a concurrent gc/clear
            workload = strategy = ""
            if peek:
                try:
                    d = json.loads(p.read_text())
                    workload = str(d.get("workload", ""))
                    strategy = str(d.get("strategy", ""))
                except (OSError, ValueError):
                    pass
            out.append(StoreEntry(path=p, key=p.stem, size=st.st_size,
                                  mtime=st.st_mtime, workload=workload,
                                  strategy=strategy))
        out.sort(key=lambda e: (e.mtime, e.key))
        return out

    def total_bytes(self) -> int:
        return sum(e.size for e in self.entries(peek=False))

    def _sweep_debris(self, stale_after: float) -> Tuple[int, int]:
        """Remove quarantined artifacts plus *stale* temp/lock files left by
        crashed writers.  Fresh dotfiles (an in-progress ``put``, a held
        lock) are never touched."""
        removed = freed = 0
        now = time.time()
        for p in list(self.root.glob("*.corrupt")) \
                + list(self.root.glob(".tmp-*")) \
                + list(self.root.glob(".*.lock")) \
                + list(self.root.glob(".*.lock.stale-*")):
            try:
                st = p.stat()
            except OSError:
                continue
            if p.name.startswith(".") and now - st.st_mtime <= stale_after:
                continue        # live write / held lock
            try:
                p.unlink()
            except OSError:
                continue
            removed += 1
            freed += st.st_size
        return removed, freed

    def gc(self, max_bytes: int,
           stale_after: float = STALE_AFTER_S) -> Tuple[int, int]:
        """Evict least-recently-written artifacts until the store holds at
        most ``max_bytes``.  Returns ``(entries_removed, bytes_freed)``.

        LRU by artifact mtime: a replayed spec does not refresh its mtime,
        so this is strictly write-recency — good enough for the sweep
        workloads the store serves (ROADMAP: cross-run eviction/GC).
        Quarantined ``*.json.corrupt`` files are always removed; temp/lock
        debris from crashed writers is removed once older than
        ``stale_after`` seconds (in-progress writes are dotfiles that never
        appear as entries, so gc cannot evict an entry mid-write).
        """
        self._require_writable("gc")
        removed, freed = self._sweep_debris(stale_after)
        entries = self.entries(peek=False)
        total = sum(e.size for e in entries)
        for e in entries:
            if total <= max_bytes:
                break
            try:
                e.path.unlink()
            except OSError:
                continue  # another process beat us to it
            total -= e.size
            removed += 1
            freed += e.size
        return removed, freed

    def _quarantine(self, path: Path, reason: str,
                    expected_payload: Optional[bytes]) -> None:
        """Move a bad artifact aside — but only if it is still the bad
        artifact.  A concurrent writer may have already replaced the entry
        with a fresh valid one; re-reading and comparing to the payload we
        judged corrupt keeps us from quarantining their good write (the
        remaining read-compare-rename window is narrow and loses at most a
        cache entry, never correctness: a quarantined entry just
        re-searches)."""
        if self.read_only:
            return
        if expected_payload is not None:
            try:
                if path.read_bytes() != expected_payload:
                    return      # someone already overwrote it with new bytes
            except OSError:
                return          # already quarantined/evicted elsewhere
        try:
            path.replace(path.with_suffix(".json.corrupt"))
            self.quarantined += 1
        except OSError:
            pass  # another process may have quarantined/overwritten it

    def clear(self) -> int:
        """Delete every entry (incl. quarantined ones); returns the count."""
        self._require_writable("clear")
        n = 0
        for p in list(self._artifacts()) + list(self.root.glob("*.corrupt")):
            try:
                p.unlink()
                n += 1
            except OSError:
                pass
        self._sweep_debris(stale_after=0.0)
        return n

    def _require_writable(self, op: str) -> None:
        if self.read_only:
            raise StoreReadOnly(
                f"store[{self.root}] is mounted read-only; {op}() is not "
                f"allowed (zoo tiers are immutable — rebuild with "
                f"`python -m repro zoo build`)")

    # -- metrics ----------------------------------------------------------
    def counters(self) -> Dict[str, int]:
        """Session counters + current on-disk shape, for ``/stats``."""
        entries = self.entries(peek=False)
        return {
            "hits": self.hits,
            "misses": self.misses,
            "writes": self.writes,
            "quarantined": self.quarantined,
            "entries": len(entries),
            "bytes": sum(e.size for e in entries),
            "read_only": self.read_only,
        }

    def stats(self) -> str:
        return (f"store[{self.root}]: {self.hits} hits, "
                f"{self.misses} misses, {len(self)} entries")
