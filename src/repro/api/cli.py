"""``python -m repro`` — run explorations from the command line.

Subcommands:

* ``explore``   — run one strategy on one workload; print the summary and
                  optionally write the spec/result as JSON artifacts.
* ``compare``   — run several strategies on the same spec (one shared cost
                  evaluator, optionally ``--jobs N`` worker processes) and
                  print a ranked table.
* ``trace``     — search a plan (or load one with ``--plan``), execute it on
                  the time-stepped trace simulator (:mod:`repro.sim`), print
                  the bandwidth profile + analytical/simulated
                  cross-validation, and optionally export the trace JSON.
* ``workloads`` — ``ls`` every resolvable workload URI (scheme registry:
                  ``netlib:`` / ``tpu:`` / ``synthetic:`` / ``file:``);
                  ``--json`` emits a machine-readable listing for tooling.
* ``store``     — ``ls`` the spec-addressed result store (``--json`` for a
                  machine-readable listing), or ``gc`` it down to a byte cap
                  (LRU by artifact mtime).
* ``plan-tpu``  — Cocco as the TPU execution planner for a model config.
* ``serve-plans`` — long-running HTTP plan server over a result store
                  (``POST /plan`` with an ExploreSpec JSON body; hits replay
                  in milliseconds, misses search once with in-flight
                  deduplication).  ``--stats`` / ``--request`` are the
                  client modes.  See ``docs/serving.md``.
* ``zoo``       — ``build`` the precomputed plan zoo (resumable grid sweep
                  into a store directory), ``ls`` grid coverage, ``verify``
                  replay integrity of every artifact.

``--workload`` takes a URI (a bare name is ``netlib:<name>``): e.g.
``netlib:resnet50``, ``tpu:gemma3-4b:0?tokens=4096``,
``synthetic:layered:24?seed=7``, ``file:my_net.json``.

``--store-dir`` (or ``$REPRO_STORE_DIR``) points both ``explore`` and
``compare`` at a spec-addressed result store: a spec that was already
searched replays its archived result instantly instead of re-searching.
``--eval-jobs N`` / ``--eval-backend`` parallelize cost evaluation *within*
one strategy through the evaluation engine (``repro.core.engine``:
``serial`` | ``process`` | ``vector`` | ``jax``); every backend returns
bit-identical results, so they are pure runtime knobs (``jax`` batches
whole GA generations onto the accelerator and needs the optional jax
dependency).

``explore --profile`` prints where the search spent its time (wall vs
``derive_schedule`` seconds) and the structure-cache counters (raw /
canonical / disk hits vs misses).  ``--struct-cache-dir`` (or
``$REPRO_STRUCT_CACHE_DIR``) adds a disk-backed warm cache of canonical
subgraph structures shared across runs and worker processes — gated like
the result store: unset means no filesystem traffic.

Examples::

    python -m repro explore --workload resnet50 --strategy ga \
        --metric energy --alpha 0.002 --hw-mode shared --budget 4000 \
        --eval-jobs 4
    python -m repro workloads ls --scheme tpu
    python -m repro explore --workload "tpu:gemma3-4b:0?tokens=4096" \
        --strategy ga --budget 2000
    python -m repro compare --workload "synthetic:layered:24?seed=7" \
        --strategies greedy,dp,ga --jobs 4 --store-dir runs/store
    python -m repro store gc --store-dir runs/store --max-bytes 100000000
    python -m repro trace "synthetic:layered:24?seed=7" --strategy greedy \
        --out runs/trace.json
    python -m repro workloads ls --json
    python -m repro plan-tpu --arch glm4-9b --samples 2000
    python -m repro zoo build --zoo-dir runs/zoo --budget 2000
    python -m repro serve-plans --store-dir runs/store --zoo-dir runs/zoo
    python -m repro serve-plans --stats --url http://127.0.0.1:8787
    python -m repro explore --workload resnet50 --strategy ga \
        --budget 20000 --store-dir runs/store --seed-from-store 1a2b3c4d
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import replace
from typing import Any, Dict, List, Optional

from repro.core.cost import METRICS
from repro.core.ga import HWSpace, Objective

from .registry import list_strategies, options_class_for
from .result import ExploreResult
from .spec import ExploreSpec
from .store import ResultStore
from .strategies import compare, plan_tpu, run


def _parse_opt_overrides(pairs: List[str]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"--opt expects KEY=VALUE, got {pair!r}")
        key, raw = pair.split("=", 1)
        try:
            out[key] = json.loads(raw)
        except json.JSONDecodeError:
            out[key] = raw
    return out


def _apply_seed_from_store(args: argparse.Namespace,
                           spec: ExploreSpec) -> ExploreSpec:
    """Resolve ``--seed-from-store KEY`` prefixes against the store and
    inject them as ``options.seed_from_keys`` (GA warm-starting from
    archived reduced-budget results)."""
    prefixes = getattr(args, "seed_from_store", None) or []
    if not prefixes:
        return spec
    if args.spec:
        raise SystemExit(
            "--seed-from-store cannot be combined with --spec; set "
            "options.seed_from_keys inside the spec file instead")
    if spec.options is None or not hasattr(spec.options, "seed_from_keys"):
        raise SystemExit(
            "--seed-from-store needs a strategy that supports "
            f"seed_from_keys (ga), not {spec.strategy!r}")
    store = _store_from_args(args)
    if store is None:
        raise SystemExit(
            "--seed-from-store resolves keys against a store: pass "
            "--store-dir (or set $REPRO_STORE_DIR), without --no-store")
    keys = tuple(k if len(k) == 64 else store.resolve_key(k)
                 for k in prefixes)
    return replace(spec, options=replace(spec.options,
                                         seed_from_keys=keys))


def _spec_from_args(args: argparse.Namespace) -> ExploreSpec:
    if args.spec:
        with open(args.spec) as f:
            return _apply_seed_from_store(
                args, ExploreSpec.from_json(f.read()))
    if not args.workload:
        raise SystemExit("either --spec or --workload is required")
    opts_cls = options_class_for(args.strategy)
    if opts_cls is None:
        raise SystemExit(
            f"unknown strategy {args.strategy!r}; "
            f"registered: {', '.join(list_strategies())}")
    options = opts_cls(**_parse_opt_overrides(args.opt))
    cores = getattr(args, "cores", None)
    try:
        core_candidates = tuple(
            int(c) for c in cores.split(",") if c.strip()) if cores else ()
    except ValueError:
        raise SystemExit(f"--cores expects comma-separated integers, "
                         f"got {cores!r}")
    spec = ExploreSpec(
        workload=args.workload,
        strategy=args.strategy,
        objective=Objective(metric=args.metric, alpha=args.alpha),
        hw=HWSpace(mode=args.hw_mode, core_candidates=core_candidates),
        sample_budget=args.budget,
        seed=args.seed,
        out_tile=args.out_tile,
        options=options,
    )
    return _apply_seed_from_store(args, spec)


def _write_file(path: str, payload: str) -> None:
    """Write an artifact, creating parent directories (the documented
    quickstarts use paths like runs/trace.json on fresh checkouts)."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        f.write(payload)


def _maybe_save(path: Optional[str], payload: str) -> None:
    if path:
        _write_file(path, payload)


def _store_from_args(args: argparse.Namespace) -> Optional[ResultStore]:
    """Resolve --store-dir / --no-store / $REPRO_STORE_DIR to a store."""
    if args.no_store:
        return None
    store_dir = args.store_dir or os.environ.get("REPRO_STORE_DIR")
    return ResultStore(store_dir) if store_dir else None


def _result_row(res: ExploreResult) -> Dict[str, str]:
    plan = res.plan
    return {
        "strategy": res.strategy,
        "cost": f"{res.cost:.4g}",
        "EMA_MB": f"{plan.ema_total/1e6:.2f}" if plan else "-",
        "energy_mJ": f"{plan.energy_pj/1e9:.3f}" if plan else "-",
        "subgraphs": str(res.n_subgraphs),
        "samples": str(res.samples),
        "evals": str(res.evaluations),
    }


def _print_table(rows: List[Dict[str, str]]) -> None:
    cols = ["rank"] + list(rows[0].keys()) if rows else []
    table = [dict(rank=str(i + 1), **r) for i, r in enumerate(rows)]
    widths = {c: max(len(c), *(len(r[c]) for r in table)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in table:
        print("  ".join(r[c].ljust(widths[c]) for c in cols))


def _print_profile(res: ExploreResult) -> None:
    prof = res.meta.get("profile")
    if prof is None:
        print("  profile: store hit — no search ran")
        return
    wall = prof.get("wall_s", 0.0)
    derive = prof.get("structure_derive_s", 0.0)
    pct = 100.0 * derive / wall if wall > 0 else 0.0
    canon = "on" if prof.get("canonical") else "off"
    print(f"  profile: wall {wall:.2f}s, derive_schedule {derive:.2f}s "
          f"({pct:.0f}% of wall) over {prof.get('structure_misses', 0)} "
          f"structure misses (canonical memo {canon})")
    disk = ""
    if "structure_disk_writes" in prof:
        disk = (f", {prof.get('structure_disk_hits', 0)} disk hits / "
                f"{prof['structure_disk_writes']} writes")
    print(f"           structure hits: "
          f"{prof.get('structure_raw_hits', 0)} raw, "
          f"{prof.get('structure_canon_hits', 0)} canonical{disk}; "
          f"{prof.get('evaluations', 0)} cost evals / "
          f"{prof.get('lookups', 0)} lookups")


def cmd_explore(args: argparse.Namespace) -> int:
    spec = _spec_from_args(args)
    _maybe_save(args.save_spec, spec.to_json(indent=2))
    store = _store_from_args(args)
    rec = None
    if args.telemetry:
        from repro.obs import Recorder, recording

        rec = Recorder()
        with recording(rec):
            res = run(spec, store=store, eval_backend=args.eval_backend,
                      eval_jobs=args.eval_jobs, profile=args.profile,
                      struct_cache_dir=args.struct_cache_dir)
    else:
        res = run(spec, store=store, eval_backend=args.eval_backend,
                  eval_jobs=args.eval_jobs, profile=args.profile,
                  struct_cache_dir=args.struct_cache_dir)
    print(res.summary())
    if rec is not None:
        from repro.obs import (
            chrome_trace_doc,
            recorder_events,
            write_chrome_trace,
        )

        doc = chrome_trace_doc(
            recorder_events(rec), counters=rec.counters,
            meta={"kind": "search", "workload": spec.workload,
                  "strategy": spec.strategy, "seed": spec.seed})
        write_chrome_trace(args.telemetry, doc)
        print(f"  telemetry written to {args.telemetry} "
              f"({len(rec.spans)} spans; open in ui.perfetto.dev)")
    if res.history:
        print(f"  converged: cost {res.history[0][1]:.4g} -> "
              f"{res.history[-1][1]:.4g} over {res.samples} samples "
              f"({res.evaluations} cost-model evals)")
    if args.profile:
        _print_profile(res)
    if store is not None:
        print(f"  {store.stats()}")
    _maybe_save(args.out, res.to_json(indent=2))
    if args.out:
        print(f"  result written to {args.out}")
    return 0


def cmd_compare(args: argparse.Namespace) -> int:
    spec = _spec_from_args(args)
    _maybe_save(args.save_spec, spec.to_json(indent=2))
    names = [s.strip() for s in args.strategies.split(",") if s.strip()]
    if not names:
        raise SystemExit("--strategies needs at least one strategy name")
    store = _store_from_args(args)
    results = compare(spec, names, jobs=args.jobs, store=store,
                      eval_backend=args.eval_backend,
                      eval_jobs=args.eval_jobs,
                      struct_cache_dir=args.struct_cache_dir)
    ranked = sorted(results, key=lambda r: r.cost)
    _print_table([_result_row(r) for r in ranked])
    best = ranked[0]
    print(f"\nbest: {best.summary()}")
    if store is not None:
        print(store.stats())
    _maybe_save(args.out,
                json.dumps([r.to_dict() for r in ranked], indent=2))
    return 0


def _store_for_maintenance(args: argparse.Namespace) -> ResultStore:
    store_dir = args.store_dir or os.environ.get("REPRO_STORE_DIR")
    if not store_dir:
        raise SystemExit(
            "store maintenance needs --store-dir (or $REPRO_STORE_DIR)")
    return ResultStore(store_dir)


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024 or unit == "GB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GB"


def cmd_store_ls(args: argparse.Namespace) -> int:
    import datetime

    store = _store_for_maintenance(args)
    entries = store.entries()
    total = sum(e.size for e in entries)
    if args.json:
        # machine-readable contract for tooling: full keys, raw sizes and
        # mtimes, LRU order (oldest first) — same rows `store gc` walks
        doc = {
            "root": str(store.root),
            "count": len(entries),
            "total_bytes": total,
            "entries": [{
                "key": e.key,
                "workload": e.workload or None,
                "strategy": e.strategy or None,
                "size": e.size,
                "mtime": e.mtime,
            } for e in entries],
        }
        print(json.dumps(doc, indent=2, sort_keys=True))
        return 0
    rows = [{
        "key": e.key[:16],
        "workload": e.workload or "?",
        "strategy": e.strategy or "?",
        "size": _fmt_bytes(e.size),
        "mtime": datetime.datetime.fromtimestamp(e.mtime)
                 .strftime("%Y-%m-%d %H:%M:%S"),
    } for e in entries]
    if rows:
        _print_table(rows)
    print(f"\n{len(entries)} entries, {_fmt_bytes(total)} in {store.root}")
    return 0


def cmd_store_gc(args: argparse.Namespace) -> int:
    store = _store_for_maintenance(args)
    removed, freed = store.gc(args.max_bytes)
    print(f"store[{store.root}]: evicted {removed} entries "
          f"({_fmt_bytes(freed)}), {_fmt_bytes(store.total_bytes())} of "
          f"{_fmt_bytes(args.max_bytes)} cap in use")
    return 0


def cmd_workloads_ls(args: argparse.Namespace) -> int:
    from .workloads import list_workloads, workload_schemes

    if args.json:
        # machine-readable contract for tooling: every "workloads" entry is
        # a concrete URI the resolver accepts (templates never appear here)
        doc = {
            "schemes": [{
                "name": s.name,
                "syntax": s.syntax,
                "description": s.description,
                "stable": s.stable,
            } for s in workload_schemes()
                if args.scheme in (None, s.name)],
            "workloads": [{
                "uri": uri,
                "scheme": uri.split(":", 1)[0],
                "description": note,
            } for uri, note in list_workloads(args.scheme, concrete=True)],
        }
        print(json.dumps(doc, indent=2, sort_keys=True))
        return 0
    # --uris-only is the script-friendly contract: every printed line is a
    # concrete URI that `explore --workload <line>` resolves; the default
    # view may show compact templates (tpu:<arch>:0..N) alongside the table
    rows = list_workloads(args.scheme, concrete=args.uris_only)
    if not args.uris_only:
        _print_table([{
            "scheme": s.name,
            "syntax": s.syntax,
            "description": s.description,
        } for s in workload_schemes()])
        print()
    for uri, _note in rows:
        print(uri)
    return 0


def cmd_trace(args: argparse.Namespace) -> int:
    from repro.sim import cross_validate_trace, simulate_plan

    from .workloads import build_workload

    if getattr(args, "uri", None):
        if args.workload and args.workload != args.uri:
            raise SystemExit(
                f"trace: conflicting workloads {args.uri!r} (positional) "
                f"and {args.workload!r} (--workload); pass one")
        args.workload = args.uri
    if args.plan:
        if args.workload or args.spec:
            raise SystemExit(
                "trace: --plan replays an archived result (with its own "
                "workload); it cannot be combined with a workload URI or "
                "--spec")
        with open(args.plan) as f:
            res = ExploreResult.from_json(f.read())
        workload, strategy = res.workload, res.strategy
        seed = res.spec.seed if res.spec else 0
        out_tile = res.spec.out_tile if res.spec else 1
    else:
        spec = _spec_from_args(args)
        store = _store_from_args(args)
        res = run(spec, store=store, eval_backend=args.eval_backend,
                  eval_jobs=args.eval_jobs,
                  struct_cache_dir=args.struct_cache_dir)
        workload, strategy = spec.workload, spec.strategy
        seed, out_tile = spec.seed, spec.out_tile
    if not res.groups or res.plan is None:
        raise RuntimeError(
            f"{workload}[{strategy}] found no feasible plan to trace")
    g = build_workload(workload)
    trace = simulate_plan(g, res.groups, res.acc, out_tile=out_tile,
                          steps_per_subgraph=args.steps_per_subgraph)
    report = cross_validate_trace(trace, res.plan)
    prof = trace.bandwidth_profile()
    print(f"{workload}[{strategy}]: {len(res.groups)} subgraphs, "
          f"{len(trace.steps)} trace steps over "
          f"{trace.total_cycles:.0f} cycles")
    print(f"  DRAM traffic: {trace.total_dram_in / 1e6:.2f} MB in, "
          f"{trace.total_dram_out / 1e6:.2f} MB out")
    print(f"  bandwidth: peak={prof.peak / 1e9:.2f} GB/s  "
          f"p99={prof.percentiles['p99'] / 1e9:.2f}  "
          f"p95={prof.percentiles['p95'] / 1e9:.2f}  "
          f"p50={prof.percentiles['p50'] / 1e9:.2f}  "
          f"sustained={prof.sustained / 1e9:.2f} GB/s")
    if trace.total_noc_bytes:
        links = res.acc.weight_share_cores
        agg = trace.noc_profile()
        link = trace.noc_profile(links=links)
        print(f"  NoC broadcast: {trace.total_noc_bytes / 1e6:.2f} MB over "
              f"{links} links; aggregate "
              f"peak={agg.peak / 1e9:.2f} GB/s "
              f"p95={agg.percentiles['p95'] / 1e9:.2f}; per-link "
              f"peak={link.peak / 1e9:.2f} GB/s "
              f"p95={link.percentiles['p95'] / 1e9:.2f}")
    print(f"  {report.summary()}")
    if args.out:
        meta = {"workload": workload, "strategy": strategy, "seed": seed,
                "validation": report.to_dict()}
        _write_file(args.out,
                    trace.to_json(meta=meta,
                                  include_steps=not args.no_steps) + "\n")
        print(f"  trace written to {args.out}")
    if args.perfetto:
        from repro.obs import chrome_trace_doc, traffic_events, \
            write_chrome_trace

        doc = chrome_trace_doc(
            traffic_events(trace),
            meta={"kind": "traffic", "workload": workload,
                  "strategy": strategy, "seed": seed})
        write_chrome_trace(args.perfetto, doc)
        print(f"  perfetto timeline written to {args.perfetto} "
              f"(open in ui.perfetto.dev)")
    if args.plot:
        from repro.sim.plot import plot_bandwidth

        plot_bandwidth(trace, args.plot,
                       title=f"{workload}[{strategy}]: bandwidth over time")
        print(f"  bandwidth plot written to {args.plot}")
    if not report.ok:
        raise RuntimeError(report.summary())
    return 0


def cmd_plan_tpu(args: argparse.Namespace) -> int:
    from repro.configs import ARCHS

    archs = [args.arch] if args.arch else list(ARCHS)
    for arch in archs:
        plan = plan_tpu(arch, tokens=args.tokens, layer_idx=args.layer,
                        sample_budget=args.samples, seed=args.seed)
        print(plan.summary())
    return 0


def cmd_serve_plans(args: argparse.Namespace) -> int:
    from repro.serve.plans import (
        PlanServer,
        PlanService,
        fetch_stats,
        request_plan,
    )

    if args.stats or args.request:
        # client modes: talk to an already-running server and exit
        url = args.url or f"http://{args.host}:{args.port}"
        if args.stats:
            print(json.dumps(fetch_stats(url), indent=2, sort_keys=True))
            return 0
        with open(args.request) as f:
            spec = ExploreSpec.from_json(f.read())
        doc = request_plan(url, spec, timeout=args.timeout)
        res = ExploreResult.from_dict(doc["result"])
        print(res.summary())
        print(f"  served_from={doc['served_from']} deduped={doc['deduped']} "
              f"latency={doc['latency_ms']:.1f}ms key={doc['key'][:16]}")
        return 0
    store_dir = args.store_dir or os.environ.get("REPRO_STORE_DIR")
    if not store_dir:
        raise SystemExit(
            "serve-plans needs --store-dir (or $REPRO_STORE_DIR)")
    store = ResultStore(store_dir)
    zoo_dir = args.zoo_dir or os.environ.get("REPRO_ZOO_DIR")
    zoo = ResultStore(zoo_dir, read_only=True) if zoo_dir else None
    service = PlanService(store, zoo=zoo, workers=args.workers,
                          eval_backend=args.eval_backend,
                          eval_jobs=args.eval_jobs)
    server = PlanServer((args.host, args.port), service,
                        quiet=not args.verbose)
    if args.port_file:
        _write_file(args.port_file, server.url + "\n")
    zoo_note = f", zoo={zoo.root} ({len(zoo)} plans)" if zoo else ""
    print(f"serve-plans: listening on {server.url} "
          f"(store={store.root}{zoo_note}, workers={service.workers})",
          flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    return 0


def _zoo_dir_from_args(args: argparse.Namespace) -> str:
    return args.zoo_dir or os.environ.get("REPRO_ZOO_DIR") or "runs/zoo"


def _parse_objectives(raw: str) -> List[Any]:
    """``"ema,energy:0.002"`` -> ``[("ema", None), ("energy", 0.002)]``."""
    out = []
    for item in raw.split(","):
        item = item.strip()
        if not item:
            continue
        if ":" in item:
            metric, alpha = item.split(":", 1)
            out.append((metric, float(alpha)))
        else:
            out.append((item, None))
    return out


def _zoo_grid(args: argparse.Namespace) -> List[ExploreSpec]:
    from repro.serve.zoo import zoo_specs

    workloads = ([w.strip() for w in args.workloads.split(",") if w.strip()]
                 if args.workloads else None)
    strategies = [s.strip() for s in args.strategies.split(",") if s.strip()]
    specs = zoo_specs(workloads=workloads, strategies=strategies,
                      objectives=_parse_objectives(args.objectives),
                      budget=args.budget, seed=args.seed)
    if args.limit is not None:
        specs = specs[:args.limit]
    return specs


def _objective_label(spec: ExploreSpec) -> str:
    return spec.objective.metric + (
        "" if spec.objective.alpha is None else f":{spec.objective.alpha:g}")


def cmd_zoo_build(args: argparse.Namespace) -> int:
    from repro.api.store import spec_key
    from repro.serve.zoo import build_zoo

    specs = _zoo_grid(args)
    if args.dry_run:
        _print_table([{
            "workload": s.workload,
            "strategy": s.strategy,
            "objective": _objective_label(s),
            "budget": str(s.sample_budget),
            "key": spec_key(s)[:16],
        } for s in specs])
        print(f"\n{len(specs)} zoo specs (dry run; nothing built)")
        return 0
    store = ResultStore(_zoo_dir_from_args(args))
    report = build_zoo(store, specs, progress=print)
    print(f"zoo[{store.root}]: {report.built} built, {report.replayed} "
          f"already archived, {report.failed} failed "
          f"({len(store)} artifacts, {_fmt_bytes(store.total_bytes())})")
    return 1 if report.failed else 0


def cmd_zoo_ls(args: argparse.Namespace) -> int:
    from repro.serve.zoo import zoo_coverage

    zoo_dir = _zoo_dir_from_args(args)
    store = (ResultStore(zoo_dir, read_only=True)
             if os.path.isdir(zoo_dir) else None)
    rows = zoo_coverage(store, _zoo_grid(args))
    archived = sum(r["status"] == "archived" for r in rows)
    if args.json:
        print(json.dumps({
            "zoo_dir": zoo_dir,
            "archived": archived,
            "missing": len(rows) - archived,
            "rows": rows,
        }, indent=2, sort_keys=True))
        return 0
    if rows:
        _print_table(rows)
    print(f"\nzoo[{zoo_dir}]: {archived}/{len(rows)} grid points archived")
    return 0


def cmd_zoo_verify(args: argparse.Namespace) -> int:
    from repro.serve.zoo import verify_zoo

    store = ResultStore(_zoo_dir_from_args(args), read_only=True)
    problems = verify_zoo(store, rebuild_graphs=not args.no_rebuild)
    if problems:
        for problem in problems:
            print(f"FAIL {problem}")
        print(f"zoo[{store.root}]: {len(problems)} problems in "
              f"{len(store)} artifacts")
        return 1
    print(f"zoo[{store.root}]: {len(store)} artifacts verified clean")
    return 0


def _add_spec_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--spec", help="load an ExploreSpec JSON file "
                                  "(overrides the flags below)")
    p.add_argument("--workload",
                   help="workload URI: netlib:<model> (bare names alias "
                        "here), tpu:<config>:<layer>[?tokens=N&tp=K], "
                        "synthetic:<kind>:<n>[?seed=S], file:<path>.json; "
                        "see `repro workloads ls`")
    p.add_argument("--strategy", default="ga",
                   help=f"one of: {', '.join(list_strategies())}")
    p.add_argument("--metric", default="ema", choices=list(METRICS))
    p.add_argument("--alpha", type=float, default=None,
                   help="Formula-2 weight (None => partition-only Formula 1)")
    p.add_argument("--hw-mode", default="fixed",
                   choices=["fixed", "separate", "shared"])
    p.add_argument("--cores", default=None, metavar="N[,N...]",
                   help="comma-separated weight-share core counts to "
                        "co-explore (HWSpace.core_candidates), e.g. "
                        "--cores 1,2,4; omit to keep the core count fixed "
                        "at the base config's value")
    p.add_argument("--budget", type=int, default=5_000,
                   help="sample budget for search strategies")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out-tile", type=int, default=1)
    p.add_argument("--opt", action="append", default=[], metavar="KEY=VALUE",
                   help="strategy option override, e.g. --opt population=40")
    p.add_argument("--save-spec", metavar="PATH",
                   help="write the resolved ExploreSpec JSON here")
    p.add_argument("--store-dir", metavar="DIR",
                   default=None,
                   help="spec-addressed result store: re-running an "
                        "already-searched spec replays the archived result "
                        "(default: $REPRO_STORE_DIR if set)")
    p.add_argument("--no-store", action="store_true",
                   help="ignore --store-dir/$REPRO_STORE_DIR and always "
                        "search from scratch")
    p.add_argument("--seed-from-store", action="append", default=[],
                   metavar="KEY",
                   help="seed the GA population from this archived result's "
                        "groups (full store key or a unique >= 8-char "
                        "prefix; repeatable; needs a store and strategy ga "
                        "— warm-start FULL-budget sweeps from reduced runs)")
    p.add_argument("--eval-jobs", type=int, default=1,
                   help="evaluation-engine workers for batched cost queries "
                        "within one strategy (results are identical to "
                        "serial evaluation)")
    p.add_argument("--eval-backend", default=None, metavar="NAME",
                   help="evaluation-engine executor: serial | process | "
                        "vector | jax (default: process when --eval-jobs "
                        "> 1, else serial; jax needs the optional jax "
                        "dependency and is checked up front)")
    p.add_argument("--struct-cache-dir", metavar="DIR", default=None,
                   help="disk-backed warm cache for canonical subgraph "
                        "structures, shared across runs and worker "
                        "processes (default: $REPRO_STRUCT_CACHE_DIR if "
                        "set; unset means no filesystem traffic)")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro",
        description="Cocco hardware-mapping co-exploration (unified API)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    pe = sub.add_parser("explore", help="run one strategy on one workload")
    _add_spec_args(pe)
    pe.add_argument("--out", metavar="PATH",
                    help="write the ExploreResult JSON here")
    pe.add_argument("--profile", action="store_true",
                    help="print a search profile: wall time, "
                         "derive_schedule seconds, and structure-cache "
                         "hit/miss counters (raw / canonical / disk)")
    pe.add_argument("--telemetry", metavar="PATH",
                    help="record the search's span tree + counters and "
                         "write a Chrome/Perfetto trace-event JSON here "
                         "(open in ui.perfetto.dev; results are identical "
                         "with or without recording)")
    pe.set_defaults(fn=cmd_explore)

    pc = sub.add_parser("compare",
                        help="run several strategies on one spec, ranked")
    _add_spec_args(pc)
    pc.add_argument("--strategies", default="greedy,dp,ga",
                    help="comma-separated strategy names")
    pc.add_argument("--jobs", type=int, default=1,
                    help="run strategies in N worker processes "
                         "(results are identical to the serial path)")
    pc.add_argument("--out", metavar="PATH",
                    help="write all ExploreResult JSONs here (a list)")
    pc.set_defaults(fn=cmd_compare)

    ptr = sub.add_parser(
        "trace",
        help="simulate a plan's DRAM traffic over time "
             "(repro.sim trace simulator)")
    ptr.add_argument("uri", nargs="?", default=None,
                     help="workload URI (positional alias for --workload)")
    _add_spec_args(ptr)
    ptr.add_argument("--plan", metavar="PATH",
                     help="trace an archived ExploreResult JSON instead of "
                          "searching for a plan first")
    ptr.add_argument("--steps-per-subgraph", type=int, default=None,
                     metavar="N",
                     help="coalesce each subgraph's row-granular steps to "
                          "at most N buckets (totals are preserved; "
                          "default: full row resolution)")
    ptr.add_argument("--out", metavar="PATH",
                     help="write the trace JSON here (cocco-trace format)")
    ptr.add_argument("--no-steps", action="store_true",
                     help="omit the per-step timeline from --out JSON "
                          "(totals, profile, and per-subgraph rows stay)")
    ptr.add_argument("--perfetto", metavar="PATH",
                     help="write the timeline as Chrome/Perfetto "
                          "trace-event JSON (steps as duration events on "
                          "per-core tracks, DRAM/NoC bytes as counter "
                          "tracks; open in ui.perfetto.dev)")
    ptr.add_argument("--plot", metavar="PATH",
                     help="render a bandwidth-over-time plot (PNG/SVG by "
                          "extension; needs the optional matplotlib "
                          "dependency)")
    ptr.set_defaults(fn=cmd_trace)

    pw = sub.add_parser("workloads",
                        help="list resolvable workload URIs")
    wsub = pw.add_subparsers(dest="workloads_cmd", required=True)
    pwl = wsub.add_parser("ls", help="schemes + every enumerable workload")
    pwl.add_argument("--scheme", default=None,
                     help="limit to one scheme (netlib, tpu, synthetic, "
                          "file, or a registered custom scheme)")
    pwl.add_argument("--uris-only", action="store_true",
                     help="print only concrete, resolvable URIs — every "
                          "line works as --workload (script-friendly; "
                          "no scheme table, no templates)")
    pwl.add_argument("--json", action="store_true",
                     help="machine-readable output: {schemes, workloads} "
                          "with concrete URIs only (for tooling)")
    pwl.set_defaults(fn=cmd_workloads_ls)

    ps = sub.add_parser("store",
                        help="inspect / garbage-collect a result store")
    store_sub = ps.add_subparsers(dest="store_cmd", required=True)
    psl = store_sub.add_parser("ls", help="list store entries (LRU first)")
    psl.add_argument("--store-dir", default=None,
                     help="store directory (default: $REPRO_STORE_DIR)")
    psl.add_argument("--json", action="store_true",
                     help="machine-readable output: {root, count, "
                          "total_bytes, entries:[{key, workload, strategy, "
                          "size, mtime}]} with full keys (for tooling)")
    psl.set_defaults(fn=cmd_store_ls)
    psg = store_sub.add_parser(
        "gc", help="evict least-recently-written entries down to a size cap")
    psg.add_argument("--store-dir", default=None,
                     help="store directory (default: $REPRO_STORE_DIR)")
    psg.add_argument("--max-bytes", type=int, required=True,
                     help="keep at most this many bytes of artifacts")
    psg.set_defaults(fn=cmd_store_gc)

    pt = sub.add_parser("plan-tpu",
                        help="Cocco as the TPU execution planner")
    pt.add_argument("--arch", default=None,
                    help="model config name (default: all)")
    pt.add_argument("--tokens", type=int, default=8192)
    pt.add_argument("--layer", type=int, default=None)
    pt.add_argument("--samples", type=int, default=2_000)
    pt.add_argument("--seed", type=int, default=0)
    pt.set_defaults(fn=cmd_plan_tpu)

    from repro.serve.zoo import DEFAULT_BUDGET

    psp = sub.add_parser(
        "serve-plans",
        help="HTTP plan server over a result store (docs/serving.md)")
    psp.add_argument("--host", default="127.0.0.1")
    psp.add_argument("--port", type=int, default=8787,
                     help="bind port (0 lets the OS pick; see --port-file)")
    psp.add_argument("--store-dir", default=None,
                     help="read-write result store every search publishes "
                          "to (default: $REPRO_STORE_DIR)")
    psp.add_argument("--zoo-dir", default=None,
                     help="mount a prebuilt plan zoo as a read-only "
                          "read-through tier (default: $REPRO_ZOO_DIR)")
    psp.add_argument("--workers", type=int, default=2,
                     help="search worker threads (hits never queue behind "
                          "them)")
    psp.add_argument("--eval-jobs", type=int, default=1,
                     help="evaluation-engine workers per search")
    psp.add_argument("--eval-backend", default=None, metavar="NAME",
                     help="evaluation-engine executor per search (serial | "
                          "process | vector | jax)")
    psp.add_argument("--port-file", metavar="PATH",
                     help="write the bound URL here once listening "
                          "(CI/scripts; pairs with --port 0)")
    psp.add_argument("--verbose", action="store_true",
                     help="log each HTTP request")
    psp.add_argument("--stats", action="store_true",
                     help="client mode: print a running server's /stats "
                          "JSON and exit")
    psp.add_argument("--request", metavar="SPEC.json",
                     help="client mode: POST this ExploreSpec file to a "
                          "running server, print the plan summary")
    psp.add_argument("--url", default=None,
                     help="server URL for --stats/--request "
                          "(default: http://HOST:PORT)")
    psp.add_argument("--timeout", type=float, default=600.0,
                     help="client-mode request timeout in seconds")
    psp.set_defaults(fn=cmd_serve_plans)

    def _add_zoo_grid_args(p: argparse.ArgumentParser) -> None:
        p.add_argument("--zoo-dir", default=None,
                       help="zoo directory (default: $REPRO_ZOO_DIR, "
                            "else runs/zoo)")
        p.add_argument("--workloads", default=None,
                       help="comma-separated workload URIs (default: every "
                            "netlib: model + the curated tpu: blocks)")
        p.add_argument("--strategies", default="greedy,ga",
                       help="comma-separated strategies")
        p.add_argument("--objectives", default="ema,energy:0.002",
                       help="comma-separated metric[:alpha] objectives")
        p.add_argument("--budget", type=int, default=DEFAULT_BUDGET,
                       help="sample budget per grid point")
        p.add_argument("--seed", type=int, default=0)
        p.add_argument("--limit", type=int, default=None, metavar="N",
                       help="only the first N grid points (smoke/CI)")

    pz = sub.add_parser(
        "zoo", help="build / inspect / verify the precomputed plan zoo")
    zsub = pz.add_subparsers(dest="zoo_cmd", required=True)
    pzb = zsub.add_parser(
        "build",
        help="archive every grid point into the zoo store (resumable: "
             "already-archived specs replay instead of re-searching)")
    _add_zoo_grid_args(pzb)
    pzb.add_argument("--dry-run", action="store_true",
                     help="print the grid (workload/strategy/objective/key) "
                          "without building anything")
    pzb.set_defaults(fn=cmd_zoo_build)
    pzl = zsub.add_parser("ls", help="grid coverage: archived vs missing")
    _add_zoo_grid_args(pzl)
    pzl.add_argument("--json", action="store_true",
                     help="machine-readable coverage rows")
    pzl.set_defaults(fn=cmd_zoo_ls)
    pzv = zsub.add_parser(
        "verify",
        help="replay-integrity check of every artifact in the zoo")
    pzv.add_argument("--zoo-dir", default=None,
                     help="zoo directory (default: $REPRO_ZOO_DIR, "
                          "else runs/zoo)")
    pzv.add_argument("--no-rebuild", action="store_true",
                     help="skip re-resolving workload URIs (faster; still "
                          "checks parse/spec-hash/re-scored cost)")
    pzv.set_defaults(fn=cmd_zoo_verify)

    args = ap.parse_args(argv)
    backend = getattr(args, "eval_backend", None)
    if backend is not None:
        # pre-flight: fail with the engine's friendly message (unknown name
        # lists the valid backends; an unavailable jax reports the import
        # failure) before any search work starts
        from repro.core.engine import backend_status

        ok, why = backend_status(backend)
        if not ok:
            print(f"error: {why}", file=sys.stderr)
            return 2
    try:
        return args.fn(args)
    except (KeyError, ValueError, TypeError, OSError, RuntimeError) as err:
        # user-input errors (unknown workload, bad option key, missing spec
        # file, absent optional dep) -> clean message, nonzero exit
        print(f"error: {err}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
