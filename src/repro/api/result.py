"""`ExploreResult`: the common, serializable output of every strategy.

Superset of the legacy ``CoccoResult``: groups, hardware point, plan,
scalar cost, convergence history, sample/evaluation counts, the per-strategy
metadata (``meta``), and the originating :class:`ExploreSpec` — so a result
written to JSON is a self-contained, reproducible artifact.
"""

from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.cost import AcceleratorConfig, PlanCost, SubgraphCost
from repro.core.ga import Objective

from .spec import (
    ExploreSpec,
    acc_from_dict,
    acc_to_dict,
    objective_from_dict,
    objective_to_dict,
)

RESULT_VERSION = 1


def plan_to_dict(plan: Optional[PlanCost]) -> Optional[Dict[str, Any]]:
    if plan is None:
        return None
    return {
        "acc": acc_to_dict(plan.acc),
        "subgraphs": [asdict(s) for s in plan.subgraphs],
    }


def plan_from_dict(d: Optional[Dict[str, Any]]) -> Optional[PlanCost]:
    if d is None:
        return None
    subs = [SubgraphCost(**{**s, "nodes": tuple(s["nodes"])})
            for s in d["subgraphs"]]
    return PlanCost(subgraphs=subs, acc=acc_from_dict(d["acc"]))


@dataclass
class ExploreResult:
    """What :func:`repro.api.run` returns for every strategy.

    Field semantics worth pinning down:

    * ``samples`` — how many candidate *plans* the strategy considered (GA/SA
      genomes, greedy merge attempts, enum states, ...); the x-axis of
      ``history``.
    * ``evaluations`` — how many **distinct** cost-model queries the run
      issued: unique (subgraph node-set, hardware-point) pairs sent to the
      :class:`~repro.core.cost.CachedEvaluator`, *including* nested
      sub-searches (a ``seed_from`` GA's baseline runs, ``two_step``'s
      per-capacity inner GAs).  Distinct queries — not raw cache misses — so
      the number does not depend on evaluator cache warmth: a strategy
      reports the same ``evaluations`` whether it ran alone, after other
      strategies on a shared evaluator (serial :func:`repro.api.compare`),
      or in a cold worker process (``compare(jobs=N)``).  A run replayed
      from a :class:`~repro.api.store.ResultStore` returns the archived
      result unchanged, so this field then reports the original search's
      count even though no new evaluation happened.
    * ``cost`` — ``objective.cost(plan, acc)``; ``math.inf`` when no feasible
      plan was found (then ``plan`` is ``None``).
    """

    workload: str
    strategy: str
    groups: List[Set[int]]
    acc: AcceleratorConfig
    plan: Optional[PlanCost]
    cost: float
    objective: Objective
    history: List[Tuple[int, float]]
    samples: int
    evaluations: int = 0
    population_log: List = field(default_factory=list)
    meta: Dict[str, Any] = field(default_factory=dict)
    spec: Optional[ExploreSpec] = None

    @property
    def n_subgraphs(self) -> int:
        return len(self.groups)

    @property
    def feasible(self) -> bool:
        return self.plan is not None and self.plan.feasible

    def summary(self) -> str:
        if self.plan is None:
            return (f"{self.workload}[{self.strategy}]: no plan "
                    f"(meta={self.meta})")
        bw = self.plan.avg_bandwidth() / 1e9
        return (
            f"{self.workload}[{self.strategy}]: {self.n_subgraphs} subgraphs | "
            f"cost={self.cost:.4g} | EMA={self.plan.ema_total/1e6:.2f} MB | "
            f"energy={self.plan.energy_pj/1e9:.3f} mJ | "
            f"avg BW={bw:.2f} GB/s | "
            f"GLB={self.acc.glb_bytes//1024}KB"
            + ("" if self.acc.shared else
               f" WBUF={self.acc.wbuf_bytes//1024}KB")
        )

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": RESULT_VERSION,
            "workload": self.workload,
            "strategy": self.strategy,
            "groups": [sorted(s) for s in self.groups],
            "acc": acc_to_dict(self.acc),
            "plan": plan_to_dict(self.plan),
            # strict-JSON safe: math.inf (e.g. a budget-exceeded enum run)
            # serializes as null; from_dict maps it back
            "cost": self.cost if math.isfinite(self.cost) else None,
            "objective": objective_to_dict(self.objective),
            "history": [list(h) for h in self.history],
            "samples": self.samples,
            "evaluations": self.evaluations,
            "population_log": [[list(p) for p in gen]
                               for gen in self.population_log],
            "meta": self.meta,
            "spec": self.spec.to_dict() if self.spec is not None else None,
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExploreResult":
        return cls(
            workload=d["workload"],
            strategy=d["strategy"],
            groups=[set(s) for s in d["groups"]],
            acc=acc_from_dict(d["acc"]),
            plan=plan_from_dict(d.get("plan")),
            cost=d["cost"] if d["cost"] is not None else math.inf,
            objective=objective_from_dict(d["objective"]),
            history=[tuple(h) for h in d["history"]],
            samples=d["samples"],
            evaluations=d.get("evaluations", 0),
            population_log=[[tuple(p) for p in gen]
                            for gen in d.get("population_log", [])],
            meta=d.get("meta", {}),
            spec=(ExploreSpec.from_dict(d["spec"])
                  if d.get("spec") is not None else None),
        )

    @classmethod
    def from_json(cls, data: str) -> "ExploreResult":
        return cls.from_dict(json.loads(data))
