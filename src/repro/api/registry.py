"""Strategy registry: one namespace under which every search method runs.

A strategy is a callable ``fn(spec, options, graph, ev, **runtime) ->
ExploreResult`` registered under a short name together with its typed
options dataclass.  ``register_strategy`` is open: downstream code can add
new methods and they become visible to ``run``/``compare`` and the CLI
without touching this package.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Protocol, runtime_checkable


@runtime_checkable
class Strategy(Protocol):
    """Shape of a registered strategy runner."""

    def __call__(self, spec: Any, options: Any, graph: Any, ev: Any,
                 **runtime: Any) -> Any: ...


@dataclass(frozen=True)
class StrategyEntry:
    name: str
    fn: Callable
    options_cls: Optional[type]


_STRATEGIES: Dict[str, StrategyEntry] = {}


def register_strategy(name: str, options_cls: Optional[type] = None):
    """Decorator: register ``fn`` as strategy ``name``.

    ``options_cls`` is the frozen dataclass of per-strategy knobs; it is
    what ``ExploreSpec.options`` defaults to and what JSON deserialization
    instantiates for this strategy.
    """

    def deco(fn: Callable) -> Callable:
        _STRATEGIES[name] = StrategyEntry(name=name, fn=fn,
                                          options_cls=options_cls)
        return fn

    return deco


def get_strategy(name: str) -> StrategyEntry:
    try:
        return _STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: {sorted(_STRATEGIES)}"
        ) from None


def list_strategies() -> List[str]:
    return sorted(_STRATEGIES)


def options_class_for(name: str) -> Optional[type]:
    entry = _STRATEGIES.get(name)
    return entry.options_cls if entry else None
