"""The plan zoo: precomputed ``ExploreResult`` archives for common specs.

``python -m repro zoo build`` sweeps a curated grid — every ``netlib:``
model × a curated set of ``tpu:`` block workloads × standard objectives ×
a couple of strategies — through a :class:`ResultStore`, so the artifacts
are plain spec-addressed store entries.  That makes the build *resumable*
(already-archived specs replay instead of re-searching; interrupt and
re-run freely) and the zoo directly mountable by the plan server
(``serve-plans --zoo-dir``) as a read-only read-through tier: common
requests are answered from disk in milliseconds and never search.

``zoo ls`` reports grid coverage (which points are archived vs missing);
``zoo verify`` checks replay integrity of every artifact in the directory:
it must parse, its embedded spec must hash to its filename, its workload
must still resolve to the graph it was searched on (fingerprint check), and
its recorded cost must equal re-scoring its plan under its objective.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.api.result import ExploreResult
from repro.api.spec import ExploreSpec, GAOptions
from repro.api.store import ResultStore, graph_fingerprint, spec_key
from repro.api.workloads import build_workload, list_workloads
from repro.core.ga import HWSpace, Objective

# Curated tpu: block workloads: one representative decode/prefill block per
# covered architecture family (dense GQA, MoE, SSM, enc-dec).  Layer 0 at a
# production-ish token count; the full per-layer sweep stays a user-driven
# `zoo build --workloads` away.
CURATED_TPU_WORKLOADS: Tuple[str, ...] = (
    "tpu:gemma3-4b:0?tokens=4096",
    "tpu:glm4-9b:0?tokens=4096",
    "tpu:tinyllama-1.1b:0?tokens=4096",
    "tpu:whisper-base:0?tokens=1500",
)

#: standard objectives: partition-only EMA (Formula 1) and the paper's
#: energy co-objective (Formula 2, alpha=0.002)
STANDARD_OBJECTIVES: Tuple[Tuple[str, Optional[float]], ...] = (
    ("ema", None),
    ("energy", 0.002),
)

STANDARD_STRATEGIES: Tuple[str, ...] = ("greedy", "ga")

#: reduced default budget: the zoo is a serving cache, not the paper sweep;
#: rebuild with --budget for FULL-quality plans
DEFAULT_BUDGET = 2_000


def default_zoo_workloads() -> List[str]:
    """Every ``netlib:`` model plus the curated ``tpu:`` blocks."""
    netlib = [uri for uri, _ in list_workloads("netlib", concrete=True)]
    return netlib + list(CURATED_TPU_WORKLOADS)


def zoo_specs(workloads: Optional[Sequence[str]] = None,
              strategies: Sequence[str] = STANDARD_STRATEGIES,
              objectives: Sequence[Tuple[str, Optional[float]]]
              = STANDARD_OBJECTIVES,
              budget: int = DEFAULT_BUDGET,
              seed: int = 0,
              hw_mode: str = "fixed") -> List[ExploreSpec]:
    """The zoo grid as concrete :class:`ExploreSpec` rows (deterministic
    order: workload-major, then objective, then strategy)."""
    specs: List[ExploreSpec] = []
    for workload in (workloads if workloads is not None
                     else default_zoo_workloads()):
        for metric, alpha in objectives:
            for strategy in strategies:
                specs.append(ExploreSpec(
                    workload=workload,
                    strategy=strategy,
                    objective=Objective(metric=metric, alpha=alpha),
                    hw=HWSpace(mode=hw_mode),
                    sample_budget=budget,
                    seed=seed,
                    options=(GAOptions(population=50)
                             if strategy == "ga" else None),
                ))
    return specs


@dataclass
class ZooBuildReport:
    """What one ``zoo build`` pass did."""

    built: int = 0          # searched + archived this pass
    replayed: int = 0       # already archived (resume hit)
    failed: int = 0
    errors: List[str] = None  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if self.errors is None:
            self.errors = []

    @property
    def total(self) -> int:
        return self.built + self.replayed + self.failed


def build_zoo(store: ResultStore, specs: Sequence[ExploreSpec],
              progress: Optional[Callable[[str], None]] = None,
              ) -> ZooBuildReport:
    """Archive every spec into ``store`` (resumable: store hits skip).

    Uses :func:`repro.serve.plans.resolve_plan`, so concurrent builders
    sharing one directory cooperate through the store's per-key lock
    instead of double-searching.
    """
    from .plans import resolve_plan

    say = progress or (lambda _msg: None)
    report = ZooBuildReport()
    for i, spec in enumerate(specs):
        label = f"[{i + 1}/{len(specs)}] {spec.workload} " \
                f"{spec.strategy}/{spec.objective.metric}"
        try:
            res, source = resolve_plan(spec, store=store)
        except (ValueError, KeyError, RuntimeError) as err:
            report.failed += 1
            report.errors.append(f"{label}: {err}")
            say(f"{label}: FAILED ({err})")
            continue
        if source == "search":
            report.built += 1
            say(f"{label}: built (cost={res.cost:.4g})")
        else:
            report.replayed += 1
            say(f"{label}: archived (replayed, cost={res.cost:.4g})")
    return report


def zoo_coverage(store: Optional[ResultStore], specs: Sequence[ExploreSpec]
                 ) -> List[Dict[str, str]]:
    """One row per grid point: archived or missing (for ``zoo ls``).
    ``store=None`` (the zoo directory does not exist yet) marks every
    point missing."""
    rows = []
    for spec in specs:
        key = spec_key(spec)
        present = (store is not None
                   and (store.root / f"{key}.json").exists())
        rows.append({
            "workload": spec.workload,
            "strategy": spec.strategy,
            "objective": spec.objective.metric
            + ("" if spec.objective.alpha is None
               else f":{spec.objective.alpha:g}"),
            "budget": str(spec.sample_budget),
            "key": key[:16],
            "status": "archived" if present else "missing",
        })
    return rows


def verify_zoo(store: ResultStore,
               rebuild_graphs: bool = True) -> List[str]:
    """Replay-integrity check of every artifact in the zoo directory.

    Returns a list of problems (empty == everything verifies):

    * the artifact parses as a current-version ``ExploreResult`` and its
      embedded spec hashes to its filename (spec-addressing intact);
    * with ``rebuild_graphs`` (default), the workload URI still resolves to
      a graph with the archived ``graph_sha`` (the plan still applies to
      what the URI builds today);
    * the archived scalar cost equals re-scoring the archived plan under
      the archived objective (the replay really is the search's answer).
    """
    problems: List[str] = []
    fingerprints: Dict[str, str] = {}
    for entry in store.entries(peek=False):
        name = entry.path.name
        try:
            res = ExploreResult.from_json(entry.path.read_text())
        except (ValueError, KeyError, TypeError) as err:
            problems.append(f"{name}: unreadable artifact ({err})")
            continue
        if res.spec is None:
            problems.append(f"{name}: artifact has no embedded spec")
            continue
        if spec_key(res.spec) != entry.key:
            problems.append(
                f"{name}: embedded spec hashes to "
                f"{spec_key(res.spec)[:16]}..., not its filename")
            continue
        if res.plan is not None:
            recost = res.objective.cost(res.plan, res.acc)
            if recost != res.cost:
                problems.append(
                    f"{name}: archived cost {res.cost!r} != re-scored "
                    f"plan cost {recost!r}")
        if rebuild_graphs:
            sha = res.meta.get("graph_sha")
            if sha is not None:
                uri = res.spec.workload
                try:
                    if uri not in fingerprints:
                        fingerprints[uri] = graph_fingerprint(
                            build_workload(uri))
                except (ValueError, KeyError, RuntimeError) as err:
                    problems.append(
                        f"{name}: workload {uri!r} no longer resolves "
                        f"({err})")
                    continue
                if fingerprints[uri] != sha:
                    problems.append(
                        f"{name}: workload {uri!r} now builds a different "
                        f"graph than the archived plan was searched on")
    return problems
