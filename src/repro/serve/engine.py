"""Batched serving engine: prefill + decode over the model zoo.

Design: requests are grouped by prompt length into batches (static batching
with length bucketing); each group is prefilled in one batched forward that
also populates the caches, then decoded synchronously.  The cache pytree
(models.init_caches) is batch-synchronized — one write position per layer —
which is exactly what the ring-buffer/SSM caches support.  Per-slot cache
lengths (paged attention / continuous batching) are a documented §Perf
extension, not needed for the dry-run cells.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec_apply, init_caches, lm_apply
from repro.models.config import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # [S] int32
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    cache_dtype: Any = jnp.float32
    greedy: bool = True


class ServeEngine:
    """Length-bucketed batch serving for decoder-only archs."""

    def __init__(self, cfg: ModelConfig, values, scfg: ServeConfig):
        if cfg.is_encdec:
            raise NotImplementedError("use EncDecEngine for whisper")
        self.cfg = cfg
        self.scfg = scfg
        self.values = values
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self._decode_fn)

    def _prefill_fn(self, values, caches, tokens):
        B, P = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(P)[None, :], (B, P))
        logits, caches, _ = lm_apply(values, self.cfg, tokens,
                                     positions=pos, caches=caches)
        return logits[:, -1, :], caches

    def _decode_fn(self, values, caches, tokens, positions):
        logits, caches, _ = lm_apply(values, self.cfg, tokens,
                                     positions=positions, caches=caches)
        return logits[:, -1, :], caches

    def _generate_group(self, group: List[Request]) -> None:
        B = len(group)
        P = len(group[0].prompt)
        caches = init_caches(self.cfg, B, self.scfg.max_len,
                             self.scfg.cache_dtype)
        tokens = jnp.asarray(np.stack([r.prompt for r in group]), jnp.int32)
        logits, caches = self._prefill(self.values, caches, tokens)
        steps = max(r.max_new_tokens for r in group)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for t in range(steps):
            for i, r in enumerate(group):
                if len(r.generated) < r.max_new_tokens:
                    r.generated.append(int(cur[i]))
            if t == steps - 1 or P + t + 1 >= self.scfg.max_len:
                break
            pos = jnp.full((B, 1), P + t, jnp.int32)
            logits, caches = self._decode(self.values, caches,
                                          cur[:, None], pos)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Length-bucketed batched generation."""
        by_len: Dict[int, List[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        for _, reqs in sorted(by_len.items()):
            for i in range(0, len(reqs), self.scfg.max_batch):
                self._generate_group(reqs[i: i + self.scfg.max_batch])
        return {r.rid: r.generated for r in requests}


class EncDecEngine:
    """Whisper-style: encode frames once, decode tokens against the memory."""

    def __init__(self, cfg: ModelConfig, values, scfg: ServeConfig):
        assert cfg.is_encdec
        self.cfg = cfg
        self.scfg = scfg
        self.values = values
        self._step = jax.jit(self._step_fn)

    def _step_fn(self, values, caches, frames, tokens, positions, enc_out):
        logits, caches, enc_out, _ = encdec_apply(
            values, self.cfg, frames, tokens, positions=positions,
            caches=caches, enc_out=enc_out)
        return logits[:, -1, :], caches, enc_out

    def transcribe(self, frames: np.ndarray, bos: int = 1,
                   max_new_tokens: int = 16) -> List[List[int]]:
        B = frames.shape[0]
        caches = init_caches(self.cfg, B, self.scfg.max_len,
                             self.scfg.cache_dtype)
        frames = jnp.asarray(frames)
        cur = jnp.full((B, 1), bos, jnp.int32)
        enc_out = None
        out = [[] for _ in range(B)]
        for t in range(max_new_tokens):
            pos = jnp.full((B, 1), t, jnp.int32)
            logits, caches, enc_out = self._step(self.values, caches, frames,
                                                 cur, pos, enc_out)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            for i in range(B):
                out[i].append(int(cur[i, 0]))
        return out
