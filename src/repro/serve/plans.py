"""Planning-as-a-service: a concurrent plan server over the result store.

``python -m repro serve-plans`` turns the one-shot spec→strategy→result
pipeline into a long-running service: clients POST an :class:`ExploreSpec`
as JSON and get back the archived (or freshly searched) `ExploreResult`.
The serving stack is three read-through tiers:

1. **zoo** — an optional read-only directory of precomputed artifacts
   (``python -m repro zoo build``); common requests never search.
2. **store** — the read-write spec-addressed :class:`ResultStore`; every
   search is published here, so a repeated request replays in milliseconds.
3. **search** — a bounded worker pool running the actual strategy, with
   per-spec **in-flight deduplication** (N concurrent identical requests
   share one search; the other N-1 "join" the winner's future) and **warm
   evaluator reuse** (requests for the same workload fingerprint share one
   :class:`CachedEvaluator`, so repeat searches start cache-hot).

Cross-process safety comes from :meth:`ResultStore.exclusive`: a search
first takes the per-key lockfile, re-checks the store (another process may
have won), and only then searches — so N identical requests across threads
*and* processes perform exactly one search.  All counters (hits, misses,
dedup joins, per-tier latency) are exposed at ``GET /stats`` (JSON) and
``GET /metrics`` (Prometheus text exposition; per-tier latency
histograms from :mod:`repro.obs.metrics`).

Protocol (JSON over HTTP, stdlib ``ThreadingHTTPServer`` — no new deps):

* ``POST /plan`` — body is an ``ExploreSpec`` JSON document (the exact
  ``ExploreSpec.to_dict()`` format; ``--save-spec`` writes one).  Response:
  ``{"ok": true, "key": <spec key>, "served_from": "zoo"|"store"|"search",
  "deduped": bool, "latency_ms": float, "result": <ExploreResult dict>}``.
  Malformed specs get ``400 {"ok": false, "error": ...}``; search failures
  get ``500``.
* ``GET /stats`` — server + store + zoo counters (schema in
  ``docs/serving.md``).
* ``GET /metrics`` — the same counters as Prometheus text format 0.0.4
  (reference table in ``docs/observability.md``).
* ``GET /healthz`` — liveness probe, ``{"ok": true}``.

See ``docs/serving.md`` for the full protocol and the zoo layout.
"""

from __future__ import annotations

import json
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple
from urllib import request as _urlrequest

from repro.api.result import ExploreResult
from repro.api.spec import ExploreSpec
from repro.api.store import ResultStore, graph_fingerprint, spec_key
from repro.api.strategies import run
from repro.api.workloads import build_workload, workload_is_stable
from repro.obs.metrics import Histogram, render_metrics

PROTOCOL_VERSION = 1

Searcher = Callable[[ExploreSpec], ExploreResult]


# ---------------------------------------------------------------------------
# tiered resolution (also the cross-process building block: the zoo builder
# and the multi-process hammer tests call this directly, no HTTP involved)
# ---------------------------------------------------------------------------

def _validated_get(tier: Optional[ResultStore],
                   spec: ExploreSpec) -> Optional[ExploreResult]:
    """A store hit, with the fingerprint revalidation :func:`repro.api.run`
    applies: a non-stable workload URI (``file:`` — the file can change
    under an unchanged URI) is re-resolved and its graph digest checked
    before the artifact replays."""
    if tier is None:
        return None
    cached = tier.get(spec)
    if cached is None:
        return None
    if not workload_is_stable(spec.workload):
        g = build_workload(spec.workload)
        if cached.meta.get("graph_sha") not in (None, graph_fingerprint(g)):
            return None
    return cached


def resolve_plan(spec: ExploreSpec,
                 store: Optional[ResultStore] = None,
                 zoo: Optional[ResultStore] = None,
                 searcher: Optional[Searcher] = None,
                 lock_timeout: Optional[float] = None,
                 ) -> Tuple[ExploreResult, str]:
    """Resolve one spec through the zoo → store → search tiers.

    Returns ``(result, served_from)`` with ``served_from`` one of ``"zoo"``,
    ``"store"``, ``"search"``.  The search path holds the store's per-key
    cross-process lock and re-checks the store inside it, so concurrent
    resolvers of the same spec — in any number of processes — perform
    exactly one search; the losers replay the winner's artifact.
    """
    search = searcher if searcher is not None else (lambda s: run(s))
    hit = _validated_get(zoo, spec)
    if hit is not None:
        return hit, "zoo"
    if store is None:
        return search(spec), "search"
    hit = _validated_get(store, spec)
    if hit is not None:
        return hit, "store"
    with store.exclusive(spec, timeout=lock_timeout):
        hit = _validated_get(store, spec)
        if hit is not None:
            return hit, "store"         # another process searched first
        res = search(spec)
        store.put(spec, res)
    return res, "search"


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------

@dataclass
class PlanResponse:
    """One fulfilled ``/plan`` request."""

    result: ExploreResult
    key: str
    served_from: str        # "zoo" | "store" | "search"
    deduped: bool
    latency_ms: float

    def to_dict(self) -> Dict[str, Any]:
        return {
            "key": self.key,
            "served_from": self.served_from,
            "deduped": self.deduped,
            "latency_ms": round(self.latency_ms, 3),
            "result": self.result.to_dict(),
        }


class _WarmEvaluator:
    """One cached evaluator + the mutex serializing searches through it
    (CachedEvaluator's run-scope bookkeeping is not reentrant across
    threads; different workloads still search fully in parallel)."""

    def __init__(self, ev) -> None:
        self.ev = ev
        self.lock = threading.Lock()


class PlanService:
    """The transport-independent core of the plan server.

    ``plan(spec)`` blocks until the spec is served: hits return synchronously
    from the zoo/store tiers, misses are funneled through a bounded
    ``ThreadPoolExecutor`` with in-flight request deduplication.  The HTTP
    layer (:class:`PlanServer`) is a thin shell over this class, which is
    also usable fully in-process (tests, ``examples/serve_lm.py``).
    """

    def __init__(self, store: ResultStore,
                 zoo: Optional[ResultStore] = None,
                 workers: int = 2,
                 eval_backend: Optional[str] = None,
                 eval_jobs: int = 1,
                 max_warm_evaluators: int = 8,
                 lock_timeout: Optional[float] = None) -> None:
        self.store = store
        self.zoo = zoo
        self.workers = max(1, workers)
        self.eval_backend = eval_backend
        self.eval_jobs = eval_jobs
        self.max_warm_evaluators = max(1, max_warm_evaluators)
        self.lock_timeout = lock_timeout
        self.started = time.time()
        self._pool = ThreadPoolExecutor(
            max_workers=self.workers, thread_name_prefix="plan-search")
        self._lock = threading.Lock()
        self._inflight: Dict[str, Future] = {}
        self._evaluators: "OrderedDict[Tuple[str, int], _WarmEvaluator]" = \
            OrderedDict()
        self._closed = False
        # counters (all mutated under self._lock)
        self.requests = 0
        self.searches = 0
        self.store_hits = 0
        self.zoo_hits = 0
        self.dedup_joins = 0
        self.errors = 0
        # per-tier cumulative latency histograms (seconds, repro.obs) —
        # they replace the old sliding _LatencyWindow, so quantiles no
        # longer forget samples past a 512-entry deque
        self._latency = {tier: Histogram()
                         for tier in ("zoo", "store", "search")}

    # -- request path -----------------------------------------------------
    def plan(self, spec: ExploreSpec) -> PlanResponse:
        """Serve one spec (blocking).  Thread-safe: this is what each HTTP
        handler thread calls."""
        if self._closed:
            raise RuntimeError("PlanService is closed")
        t0 = time.perf_counter()
        key = spec_key(spec)
        with self._lock:
            self.requests += 1
        # fast path: zoo/store hits answer synchronously (milliseconds, even
        # while every pool worker is busy searching something else)
        hit = self._lookup(spec)
        if hit is not None:
            result, source = hit
            return self._done(result, key, source, False, t0)
        with self._lock:
            fut = self._inflight.get(key)
            deduped = fut is not None
            if deduped:
                self.dedup_joins += 1
            else:
                fut = self._pool.submit(self._fulfil, spec, key)
                self._inflight[key] = fut
        try:
            result, source = fut.result()
        except Exception:
            with self._lock:
                self.errors += 1
            raise
        return self._done(result, key, source, deduped, t0)

    def _lookup(self, spec: ExploreSpec
                ) -> Optional[Tuple[ExploreResult, str]]:
        hit = _validated_get(self.zoo, spec)
        if hit is not None:
            return hit, "zoo"
        hit = _validated_get(self.store, spec)
        if hit is not None:
            return hit, "store"
        return None

    def _fulfil(self, spec: ExploreSpec,
                key: str) -> Tuple[ExploreResult, str]:
        """Pool worker: tiered resolve under the cross-process lock, with a
        warm evaluator for the spec's workload."""
        try:
            return resolve_plan(spec, store=self.store, zoo=self.zoo,
                                searcher=self._search,
                                lock_timeout=self.lock_timeout)
        finally:
            with self._lock:
                self._inflight.pop(key, None)

    def _search(self, spec: ExploreSpec) -> ExploreResult:
        g = build_workload(spec.workload)
        warm = self._warm_evaluator(g, spec.out_tile)
        with warm.lock:
            res = run(spec, graph=g, ev=warm.ev)
        with self._lock:
            self.searches += 1
        return res

    def _warm_evaluator(self, g, out_tile: int) -> _WarmEvaluator:
        from repro.core.cost import CachedEvaluator
        from repro.core.engine import make_executor

        key = (graph_fingerprint(g), out_tile)
        with self._lock:
            warm = self._evaluators.get(key)
            if warm is None:
                warm = _WarmEvaluator(CachedEvaluator(
                    g, out_tile=out_tile,
                    executor=make_executor(self.eval_backend,
                                           self.eval_jobs)))
                self._evaluators[key] = warm
            self._evaluators.move_to_end(key)
            # LRU-evict cold evaluators (skip any mid-search: its searcher
            # holds the warm lock and will simply be dropped next time)
            while len(self._evaluators) > self.max_warm_evaluators:
                for k in list(self._evaluators):
                    if k != key and not self._evaluators[k].lock.locked():
                        self._evaluators.pop(k).ev.close()
                        break
                else:
                    break
        return warm

    def _done(self, result: ExploreResult, key: str, source: str,
              deduped: bool, t0: float) -> PlanResponse:
        dt = time.perf_counter() - t0
        with self._lock:
            if source == "zoo":
                self.zoo_hits += 1
            elif source == "store":
                self.store_hits += 1
            self._latency[source].observe(dt)
        return PlanResponse(result=result, key=key, served_from=source,
                            deduped=deduped, latency_ms=dt * 1e3)

    # -- introspection ----------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """The ``GET /stats`` document (schema: ``docs/serving.md``)."""
        with self._lock:
            server = {
                "version": PROTOCOL_VERSION,
                "uptime_s": round(time.time() - self.started, 3),
                "workers": self.workers,
                "requests": self.requests,
                "searches": self.searches,
                "store_hits": self.store_hits,
                "zoo_hits": self.zoo_hits,
                "dedup_joins": self.dedup_joins,
                "errors": self.errors,
                "in_flight": len(self._inflight),
                "warm_evaluators": len(self._evaluators),
                "latency_ms": {tier: h.snapshot_ms()
                               for tier, h in self._latency.items()},
            }
        return {
            "ok": True,
            "server": server,
            "store": self.store.counters(),
            "zoo": self.zoo.counters() if self.zoo is not None else None,
        }

    def metrics_text(self) -> str:
        """The ``GET /metrics`` document: Prometheus text format 0.0.4.

        Same counters as :meth:`stats`, but in the standard exposition
        so any Prometheus-compatible scraper can poll the server; the
        per-tier latency *histograms* carry the full distribution (the
        JSON view only shows interpolated p50/p95).
        """
        # store counters walk the artifact directory — gather them before
        # taking the service lock
        tiers: List[Tuple[str, ResultStore]] = [("store", self.store)]
        if self.zoo is not None:
            tiers.append(("zoo", self.zoo))
        store_counts = [(name, st.counters()) for name, st in tiers]
        with self._lock:
            lab = lambda tier: {"tier": tier}
            served: List[Tuple[Optional[Mapping[str, str]], object]] = [
                (lab("zoo"), self.zoo_hits),
                (lab("store"), self.store_hits),
                (lab("search"), self.searches),
            ]
            families = [
                ("repro_plan_requests_total", "counter",
                 "Plan requests received.", [(None, self.requests)]),
                ("repro_plan_served_total", "counter",
                 "Plan responses by serving tier.", served),
                ("repro_plan_request_latency_seconds", "histogram",
                 "Plan request latency by serving tier.",
                 [(lab(t), h) for t, h in self._latency.items()]),
                ("repro_plan_dedup_joins_total", "counter",
                 "Requests that joined an in-flight identical search.",
                 [(None, self.dedup_joins)]),
                ("repro_plan_errors_total", "counter",
                 "Plan requests that raised.", [(None, self.errors)]),
                ("repro_plan_inflight_searches", "gauge",
                 "Searches currently in flight (dedup table size).",
                 [(None, len(self._inflight))]),
                ("repro_plan_warm_evaluators", "gauge",
                 "Warm evaluators resident in the LRU.",
                 [(None, len(self._evaluators))]),
                ("repro_plan_warm_evaluators_limit", "gauge",
                 "Warm-evaluator LRU capacity.",
                 [(None, self.max_warm_evaluators)]),
                ("repro_plan_workers", "gauge",
                 "Search worker pool size.", [(None, self.workers)]),
                ("repro_plan_uptime_seconds", "gauge",
                 "Seconds since the service started.",
                 [(None, round(time.time() - self.started, 3))]),
            ]
            for metric, mtype, help_text in (
                    ("repro_store_hits_total", "counter", "Store hits."),
                    ("repro_store_misses_total", "counter",
                     "Store misses."),
                    ("repro_store_writes_total", "counter",
                     "Store writes."),
                    ("repro_store_quarantined_total", "counter",
                     "Artifacts quarantined on load."),
                    ("repro_store_entries", "gauge",
                     "Artifacts currently in the store."),
                    ("repro_store_bytes", "gauge",
                     "Bytes of artifacts currently in the store."),
            ):
                key = metric.replace("repro_store_", "").replace(
                    "_total", "")
                families.append((metric, mtype, help_text, [
                    (lab(name), counts[key])
                    for name, counts in store_counts]))
            return render_metrics(families)

    def close(self) -> None:
        self._closed = True
        self._pool.shutdown(wait=True)
        with self._lock:
            evs, self._evaluators = list(self._evaluators.values()), \
                OrderedDict()
        for warm in evs:
            warm.ev.close()


# ---------------------------------------------------------------------------
# HTTP shell
# ---------------------------------------------------------------------------

class _PlanRequestHandler(BaseHTTPRequestHandler):
    server_version = f"repro-serve-plans/{PROTOCOL_VERSION}"
    protocol_version = "HTTP/1.1"

    @property
    def service(self) -> PlanService:
        return self.server.service            # type: ignore[attr-defined]

    def log_message(self, fmt: str, *args) -> None:
        if not getattr(self.server, "quiet", True):
            BaseHTTPRequestHandler.log_message(self, fmt, *args)

    def _send(self, code: int, doc: Dict[str, Any]) -> None:
        payload = json.dumps(doc).encode()
        self._send_raw(code, payload, "application/json")

    def _send_raw(self, code: int, payload: bytes,
                  content_type: str) -> None:
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        self.wfile.write(payload)

    def do_GET(self) -> None:                                   # noqa: N802
        path = self.path.rstrip("/") or "/"
        if path == "/stats":
            self._send(200, self.service.stats())
        elif path == "/metrics":
            self._send_raw(200, self.service.metrics_text().encode(),
                           "text/plain; version=0.0.4; charset=utf-8")
        elif path == "/healthz":
            self._send(200, {"ok": True})
        elif path == "/":
            self._send(200, {
                "ok": True,
                "service": "repro-serve-plans",
                "version": PROTOCOL_VERSION,
                "endpoints": {
                    "POST /plan": "body: ExploreSpec JSON -> "
                                  "{ok, key, served_from, deduped, "
                                  "latency_ms, result}",
                    "GET /stats": "server + store + zoo counters",
                    "GET /metrics": "Prometheus text-format counters",
                    "GET /healthz": "liveness probe",
                },
            })
        else:
            self._send(404, {"ok": False, "error": f"no route {self.path}"})

    def do_POST(self) -> None:                                  # noqa: N802
        if self.path.rstrip("/") != "/plan":
            self._send(404, {"ok": False, "error": f"no route {self.path}"})
            return
        try:
            length = int(self.headers.get("Content-Length") or 0)
            spec = ExploreSpec.from_json(
                self.rfile.read(length).decode("utf-8"))
        except (ValueError, KeyError, TypeError, UnicodeDecodeError) as err:
            self._send(400, {"ok": False, "error": f"bad spec: {err}"})
            return
        try:
            resp = self.service.plan(spec)
        except Exception as err:        # search/store failure -> 500
            self._send(500, {"ok": False,
                             "error": f"{type(err).__name__}: {err}"})
            return
        self._send(200, {"ok": True, **resp.to_dict()})


class PlanServer(ThreadingHTTPServer):
    """``ThreadingHTTPServer`` bound to a :class:`PlanService`.

    Bind with port 0 to let the OS pick; ``server_address`` then reports
    the real port.  ``daemon_threads`` so a hung client cannot block
    shutdown.
    """

    daemon_threads = True

    def __init__(self, address: Tuple[str, int], service: PlanService,
                 quiet: bool = True) -> None:
        super().__init__(address, _PlanRequestHandler)
        self.service = service
        self.quiet = quiet

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"

    def close(self) -> None:
        self.shutdown()
        self.server_close()
        self.service.close()


def serve_in_thread(service: PlanService, host: str = "127.0.0.1",
                    port: int = 0) -> PlanServer:
    """Start a :class:`PlanServer` on a daemon thread (tests, examples)."""
    server = PlanServer((host, port), service)
    thread = threading.Thread(target=server.serve_forever,
                              name="plan-server", daemon=True)
    thread.start()
    return server


# ---------------------------------------------------------------------------
# client helpers (stdlib urllib; used by the CLI, CI smoke, and examples)
# ---------------------------------------------------------------------------

def request_plan(url: str, spec: ExploreSpec,
                 timeout: float = 600.0) -> Dict[str, Any]:
    """POST ``spec`` to a running plan server; returns the response doc
    (with ``result`` left as a plain dict — ``ExploreResult.from_dict`` it
    if you need the object)."""
    req = _urlrequest.Request(
        url.rstrip("/") + "/plan",
        data=spec.to_json().encode(),
        headers={"Content-Type": "application/json"},
        method="POST")
    with _urlrequest.urlopen(req, timeout=timeout) as resp:
        return json.loads(resp.read().decode())


def fetch_stats(url: str, timeout: float = 30.0) -> Dict[str, Any]:
    """GET a running plan server's ``/stats`` document."""
    with _urlrequest.urlopen(url.rstrip("/") + "/stats",
                             timeout=timeout) as resp:
        return json.loads(resp.read().decode())


def fetch_metrics(url: str, timeout: float = 30.0) -> str:
    """GET a running plan server's ``/metrics`` text exposition."""
    with _urlrequest.urlopen(url.rstrip("/") + "/metrics",
                             timeout=timeout) as resp:
        return resp.read().decode()
