from .engine import EncDecEngine, Request, ServeConfig, ServeEngine

__all__ = ["EncDecEngine", "Request", "ServeConfig", "ServeEngine"]
