"""Serving layer: the LM batch engine (jax) + the plan server (stdlib).

``repro.serve.engine`` needs jax and the model zoo; the plan server
(``plans``/``zoo``) is pure stdlib over ``repro.api``.  The engine names
are lazy module attributes so that ``python -m repro serve-plans`` (and the
plan-server tests) never pay — or depend on — the jax import.
"""

_ENGINE_EXPORTS = ("EncDecEngine", "Request", "ServeConfig", "ServeEngine")


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from . import engine

        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


from .plans import (
    PlanResponse,
    PlanServer,
    PlanService,
    fetch_stats,
    request_plan,
    resolve_plan,
    serve_in_thread,
)
from .zoo import (
    ZooBuildReport,
    build_zoo,
    default_zoo_workloads,
    verify_zoo,
    zoo_coverage,
    zoo_specs,
)

__all__ = [
    "EncDecEngine",
    "PlanResponse",
    "PlanServer",
    "PlanService",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "ZooBuildReport",
    "build_zoo",
    "default_zoo_workloads",
    "fetch_stats",
    "request_plan",
    "resolve_plan",
    "serve_in_thread",
    "verify_zoo",
    "zoo_coverage",
    "zoo_specs",
]
