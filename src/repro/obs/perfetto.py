"""Chrome/Perfetto trace-event JSON exporters.

Two renderers onto the same target format (the Trace Event Format's JSON
object flavor, which https://ui.perfetto.dev opens directly):

* :func:`recorder_events` — a search run's span tree as nested "X"
  (complete) duration events on one track, with the recorder's timed
  samples (per-generation best/mean cost, population diversity) as "C"
  counter tracks.
* :func:`traffic_events` — a sim ``TrafficTrace`` timeline: steps as
  duration events on per-core tracks (prologue DRAM stream shards land on
  their owning core's track, compute steps on the whole-chip track) and
  DRAM/NoC bytes as counter tracks.  The time base converts simulated
  cycles to microseconds at the accelerator's clock, so the Perfetto
  ruler reads as real time on the modeled part.

Both return plain event dicts; :func:`chrome_trace_doc` wraps them in the
documented ``{"traceEvents": [...]}`` envelope.  Timestamps are
microseconds (the format's unit).
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from .recorder import Recorder

__all__ = [
    "recorder_events",
    "traffic_events",
    "chrome_trace_doc",
    "write_chrome_trace",
]

TELEMETRY_FORMAT = "cocco-telemetry"
TELEMETRY_FORMAT_VERSION = 1

_SEARCH_PID = 1
_SIM_PID = 2


def _meta(pid: int, tid: Optional[int], name: str, label: str
          ) -> Dict[str, Any]:
    ev: Dict[str, Any] = {"ph": "M", "pid": pid, "name": name,
                          "args": {"name": label}, "ts": 0}
    if tid is not None:
        ev["tid"] = tid
    return ev


def recorder_events(rec: Recorder, pid: int = _SEARCH_PID
                    ) -> List[Dict[str, Any]]:
    """Render a :class:`Recorder` as trace events (spans + counters)."""
    events: List[Dict[str, Any]] = [
        _meta(pid, None, "process_name", "search"),
        _meta(pid, 1, "thread_name", "spans"),
    ]
    for sp in rec.spans:
        args = {k: v for k, v in sp.attrs.items()
                if isinstance(v, (int, float, str, bool))}
        events.append({
            "name": sp.name, "ph": "X", "pid": pid, "tid": 1,
            "ts": round(sp.t0_s * 1e6, 3),
            "dur": round(max(sp.dur_s, 0.0) * 1e6, 3),
            "args": args,
        })
    for name, t_s, value in rec.samples:
        events.append({
            "name": name, "ph": "C", "pid": pid, "tid": 1,
            "ts": round(t_s * 1e6, 3),
            "args": {"value": value},
        })
    return events


def traffic_events(trace: Any, pid: int = _SIM_PID,
                   max_counter_steps: int = 4096) -> List[Dict[str, Any]]:
    """Render a ``repro.sim.trace.TrafficTrace`` as trace events.

    Per-core DRAM stream segments (``step.core >= 0``) get one track per
    core; whole-chip steps share track 0.  DRAM and NoC bytes become
    counter tracks sampled at each step start.  ``max_counter_steps``
    bounds counter-event volume on row-granular traces (duration events
    are always emitted one per step).
    """
    scale = 1e6 / trace.acc.freq_hz  # cycles -> microseconds
    events: List[Dict[str, Any]] = [
        _meta(pid, None, "process_name", f"sim:{trace.graph_name}"),
        _meta(pid, 0, "thread_name", "chip"),
    ]
    cores = sorted({s.core for s in trace.steps if s.core >= 0})
    for c in cores:
        events.append(_meta(pid, c + 1, "thread_name",
                            f"core{c} DRAM stream"))
    stride = max(1, len(trace.steps) // max_counter_steps)
    for i, stp in enumerate(trace.steps):
        name = ("prologue" if stp.subgraph < 0
                else f"sg{stp.subgraph}.step{stp.step}")
        tid = stp.core + 1 if stp.core >= 0 else 0
        events.append({
            "name": name, "ph": "X", "pid": pid, "tid": tid,
            "ts": round(stp.t_cycles * scale, 3),
            "dur": round(stp.cycles * scale, 3),
            "args": {"subgraph": stp.subgraph, "step": stp.step,
                     "rows": stp.rows, "macs": stp.macs},
        })
        if i % stride == 0:
            ts = round(stp.t_cycles * scale, 3)
            events.append({
                "name": "DRAM bytes", "ph": "C", "pid": pid, "tid": 0,
                "ts": ts,
                "args": {"in": stp.dram_in, "out": stp.dram_out},
            })
            events.append({
                "name": "NoC bytes", "ph": "C", "pid": pid, "tid": 0,
                "ts": ts, "args": {"broadcast": stp.noc_bytes},
            })
            events.append({
                "name": "occupancy", "ph": "C", "pid": pid, "tid": 0,
                "ts": ts, "args": {"act": stp.occ_act, "w": stp.occ_w},
            })
    return events


def chrome_trace_doc(events: List[Dict[str, Any]],
                     counters: Optional[Dict[str, float]] = None,
                     meta: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Any]:
    """Wrap events in the trace-event JSON object envelope.  Extra keys
    (``format``/``counters``/``meta``) are ignored by viewers but make the
    export self-describing for ``scripts/check_telemetry_schema.py``."""
    doc: Dict[str, Any] = {
        "format": TELEMETRY_FORMAT,
        "version": TELEMETRY_FORMAT_VERSION,
        "displayTimeUnit": "ms",
        "traceEvents": events,
    }
    if counters:
        doc["counters"] = dict(sorted(counters.items()))
    if meta:
        doc["meta"] = dict(meta)
    return doc


def write_chrome_trace(path: str, doc: Dict[str, Any]) -> None:
    import os

    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
