"""Structured telemetry recorder: nested spans, counters, timed samples.

The recorder is the single substrate every layer emits into: the search
front door (``resolve-workload`` / ``strategy:<name>`` spans), the GA loop
(per-generation spans plus best/mean/diversity samples), the batched
engine (``evaluate_batch`` / executor submit+join spans, scalar-fallback
counters), the partition repair loop, and the structure-memo tiers.

Design constraints (the hard invariant carried from PRs 7-9):

* **Side-channel only.**  Nothing here ever touches an ``ExploreResult``
  or a stored artifact; exporters write to a *separate* file.
* **Near-zero when disabled.**  The ambient recorder defaults to a
  shared :class:`NullRecorder` whose ``span()`` hands back one reusable
  no-op context manager and whose ``add``/``sample`` are empty method
  calls — no clock reads, no allocation, no branches beyond a
  ``ContextVar`` lookup.
* **Ambient, not threaded through signatures.**  A ``ContextVar`` holds
  the active recorder (the same pattern ``strategies._ACTIVE_STORE``
  uses), so deep call sites (``CachedEvaluator``, ``split_to_fit_batch``)
  emit without plumbing a recorder argument through every layer.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Span",
    "Recorder",
    "NullRecorder",
    "current",
    "enabled",
    "recording",
    "span",
    "add",
    "sample",
]


@dataclass
class Span:
    """One timed region.  ``parent`` indexes into ``Recorder.spans``
    (-1 for roots); ``t0_s``/``dur_s`` are seconds relative to the
    recorder's epoch on the monotonic clock."""

    index: int
    parent: int
    name: str
    t0_s: float
    dur_s: float = 0.0
    attrs: Dict[str, Any] = field(default_factory=dict)


class _NullSpan:
    """Shared, stateless no-op context manager."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """Disabled recorder: every operation is a constant-time no-op."""

    enabled = False

    __slots__ = ()

    def span(self, name: str, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def add(self, name: str, value: float = 1) -> None:
        pass

    def sample(self, name: str, value: float) -> None:
        pass

    def merge_counters(self, mapping: Dict[str, Any],
                       prefix: str = "") -> None:
        pass


class _SpanCtx:
    """Context manager for one live span on a real :class:`Recorder`."""

    __slots__ = ("_rec", "_name", "_attrs", "_span")

    def __init__(self, rec: "Recorder", name: str,
                 attrs: Dict[str, Any]) -> None:
        self._rec = rec
        self._name = name
        self._attrs = attrs
        self._span: Optional[Span] = None

    def __enter__(self) -> Span:
        self._span = self._rec._open(self._name, self._attrs)
        return self._span

    def __exit__(self, *exc: Any) -> bool:
        assert self._span is not None
        self._rec._close(self._span)
        return False


class Recorder:
    """Collects spans, counters, and timestamped samples for one run.

    Spans are appended in *entry* order (a pre-order walk of the tree),
    so ``spans[i].parent < i`` always holds and exporters can render the
    tree in a single pass.  A recorder is single-threaded by design: the
    ambient ``ContextVar`` keeps concurrent server searches isolated.
    """

    enabled = True

    def __init__(self) -> None:
        self._epoch = time.perf_counter()
        self.spans: List[Span] = []
        self.counters: Dict[str, float] = {}
        # (series name, t_s relative to epoch, value)
        self.samples: List[Tuple[str, float, float]] = []
        self._stack: List[int] = []

    # -- span lifecycle -------------------------------------------------
    def span(self, name: str, **attrs: Any) -> _SpanCtx:
        return _SpanCtx(self, name, attrs)

    def _open(self, name: str, attrs: Dict[str, Any]) -> Span:
        parent = self._stack[-1] if self._stack else -1
        sp = Span(index=len(self.spans), parent=parent, name=name,
                  t0_s=time.perf_counter() - self._epoch, attrs=attrs)
        self.spans.append(sp)
        self._stack.append(sp.index)
        return sp

    def _close(self, sp: Span) -> None:
        sp.dur_s = time.perf_counter() - self._epoch - sp.t0_s
        # tolerate exceptions unwinding through several spans at once
        while self._stack and self._stack[-1] >= sp.index:
            self._stack.pop()

    # -- counters and samples -------------------------------------------
    def add(self, name: str, value: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + value

    def sample(self, name: str, value: float) -> None:
        self.samples.append(
            (name, time.perf_counter() - self._epoch, float(value)))

    def merge_counters(self, mapping: Dict[str, Any],
                       prefix: str = "") -> None:
        """Fold a flat dict of numeric counters (e.g. the evaluator's
        ``counters()`` output) into this recorder, skipping non-numeric
        entries."""
        for key, val in mapping.items():
            if isinstance(val, bool) or not isinstance(val, (int, float)):
                continue
            self.add(prefix + key, val)

    # -- views ----------------------------------------------------------
    def span_tree(self) -> List[Dict[str, Any]]:
        """Nested ``{"name": ..., "children": [...]}`` view, for tests
        that pin tree *shape* without depending on timings."""
        nodes: List[Dict[str, Any]] = [
            {"name": sp.name, "children": []} for sp in self.spans]
        roots: List[Dict[str, Any]] = []
        for sp, node in zip(self.spans, nodes):
            if sp.parent < 0:
                roots.append(node)
            else:
                nodes[sp.parent]["children"].append(node)
        return roots

    def to_dict(self) -> Dict[str, Any]:
        return {
            "spans": [
                {"index": sp.index, "parent": sp.parent, "name": sp.name,
                 "t0_s": sp.t0_s, "dur_s": sp.dur_s, "attrs": sp.attrs}
                for sp in self.spans],
            "counters": dict(self.counters),
            "samples": [
                {"name": n, "t_s": t, "value": v}
                for n, t, v in self.samples],
        }


_NULL = NullRecorder()
_ACTIVE: ContextVar[Any] = ContextVar("repro_obs_recorder", default=_NULL)


def current() -> Any:
    """The ambient recorder (a :class:`NullRecorder` when disabled)."""
    return _ACTIVE.get()


def enabled() -> bool:
    return _ACTIVE.get().enabled


@contextmanager
def recording(rec: Recorder) -> Iterator[Recorder]:
    """Install *rec* as the ambient recorder for the enclosed block."""
    token = _ACTIVE.set(rec)
    try:
        yield rec
    finally:
        _ACTIVE.reset(token)


def span(name: str, **attrs: Any) -> Any:
    """Open a span on the ambient recorder (no-op when disabled)."""
    return _ACTIVE.get().span(name, **attrs)


def add(name: str, value: float = 1) -> None:
    _ACTIVE.get().add(name, value)


def sample(name: str, value: float) -> None:
    _ACTIVE.get().sample(name, value)
