"""Prometheus-style metric primitives and text exposition (format 0.0.4).

Zero-dependency building blocks for the plan server's ``/metrics``
endpoint.  :class:`Histogram` replaces the old ``_LatencyWindow``: where
the window silently dropped samples past its 512-entry deque and served
quantiles over whatever happened to remain, the histogram is cumulative
over the process lifetime — every observation lands in a bucket, and
exact ``count`` / ``sum`` / ``max`` ride alongside so the back-compat
``/stats`` view keeps its mean and max exact (quantiles become the usual
Prometheus bucket interpolation).
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = ["DEFAULT_LATENCY_BUCKETS", "Histogram", "render_metrics"]

# Latency bucket upper bounds in *seconds*, spanning sub-millisecond
# zoo hits through multi-second cold searches.  +Inf is implicit.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
)


class Histogram:
    """Cumulative histogram with exact count/sum/max side-channels."""

    __slots__ = ("bounds", "bucket_counts", "count", "total", "max")

    def __init__(self, buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS
                 ) -> None:
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds = bounds
        # per-bucket (non-cumulative) counts; index len(bounds) == +Inf
        self.bucket_counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.max = 0.0

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if value > self.max:
            self.max = value
        for i, bound in enumerate(self.bounds):
            if value <= bound:
                self.bucket_counts[i] += 1
                return
        self.bucket_counts[-1] += 1

    def cumulative(self) -> List[Tuple[float, int]]:
        """``[(le, cumulative_count), ...]`` ending with (+Inf, count)."""
        out: List[Tuple[float, int]] = []
        running = 0
        for bound, n in zip(self.bounds, self.bucket_counts):
            running += n
            out.append((bound, running))
        out.append((math.inf, self.count))
        return out

    def quantile(self, q: float) -> float:
        """Prometheus-style bucket-interpolated quantile estimate."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        running = 0
        lo = 0.0
        for bound, n in zip(self.bounds, self.bucket_counts):
            if n and running + n >= rank:
                frac = (rank - running) / n
                return lo + (bound - lo) * frac
            running += n
            lo = bound
        # rank falls in the +Inf bucket: best estimate is the exact max
        return self.max

    def snapshot_ms(self) -> Dict[str, float]:
        """Back-compat ``/stats`` view (same keys as the old window)."""
        mean = self.total / self.count if self.count else 0.0
        return {
            "count": self.count,
            "mean_ms": round(mean * 1e3, 3),
            "max_ms": round(self.max * 1e3, 3),
            "p50_ms": round(self.quantile(0.50) * 1e3, 3),
            "p95_ms": round(self.quantile(0.95) * 1e3, 3),
        }


def _fmt_value(v: float) -> str:
    if isinstance(v, float) and math.isinf(v):
        return "+Inf"
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


def _fmt_labels(labels: Optional[Mapping[str, str]]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in sorted(labels.items()))
    return "{%s}" % inner


def render_metrics(families: Iterable[Tuple[str, str, str, List[Tuple[
        Optional[Mapping[str, str]], object]]]]) -> str:
    """Render metric families as Prometheus text exposition 0.0.4.

    Each family is ``(name, type, help, samples)`` where ``type`` is one
    of ``counter`` / ``gauge`` / ``histogram``.  For scalar families each
    sample is ``(labels_or_None, number)``; for histograms each sample is
    ``(labels_or_None, Histogram)`` and expands into the conventional
    ``_bucket{le=...}`` / ``_sum`` / ``_count`` series.
    """
    lines: List[str] = []
    for name, mtype, help_text, samples in families:
        lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {mtype}")
        for labels, value in samples:
            if mtype == "histogram":
                assert isinstance(value, Histogram)
                base = dict(labels or {})
                for le, cum in value.cumulative():
                    blabels = dict(base)
                    blabels["le"] = _fmt_value(le)
                    lines.append(f"{name}_bucket{_fmt_labels(blabels)} {cum}")
                lines.append(
                    f"{name}_sum{_fmt_labels(base)} "
                    f"{_fmt_value(value.total)}")
                lines.append(
                    f"{name}_count{_fmt_labels(base)} {value.count}")
            else:
                lines.append(
                    f"{name}{_fmt_labels(labels)} {_fmt_value(value)}")
    return "\n".join(lines) + "\n"
