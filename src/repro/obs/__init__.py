"""Unified telemetry: spans + counters + exporters (docs/observability.md).

One substrate for every layer's runtime visibility:

* :mod:`repro.obs.recorder` — the ambient :class:`Recorder` (nested
  spans, counters, timed samples) with a near-zero disabled path.
* :mod:`repro.obs.perfetto` — Chrome/Perfetto trace-event JSON export of
  a recorder or a sim ``TrafficTrace``.
* :mod:`repro.obs.metrics` — Prometheus-style histograms and the text
  exposition the plan server's ``/metrics`` endpoint serves.

The hard invariant: telemetry is side-channel only.  Results and stored
artifacts are byte-identical whether a recorder is installed or not.
"""

from .metrics import DEFAULT_LATENCY_BUCKETS, Histogram, render_metrics
from .perfetto import (
    TELEMETRY_FORMAT,
    TELEMETRY_FORMAT_VERSION,
    chrome_trace_doc,
    recorder_events,
    traffic_events,
    write_chrome_trace,
)
from .recorder import (
    NullRecorder,
    Recorder,
    Span,
    add,
    current,
    enabled,
    recording,
    sample,
    span,
)

__all__ = [
    "DEFAULT_LATENCY_BUCKETS",
    "Histogram",
    "render_metrics",
    "TELEMETRY_FORMAT",
    "TELEMETRY_FORMAT_VERSION",
    "chrome_trace_doc",
    "recorder_events",
    "traffic_events",
    "write_chrome_trace",
    "NullRecorder",
    "Recorder",
    "Span",
    "add",
    "current",
    "enabled",
    "recording",
    "sample",
    "span",
]
