"""jamba-v0.1-52b [arXiv:2403.19887]: 32L d=4096, Mamba+attn 1:7 interleave,
MoE 16e top-2 every other layer, GQA kv=8, vocab 65536."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=65_536,
    d_head=128,
    attn_every=8,                 # 1 attention : 7 mamba
    attn_offset=4,
    n_experts=16,
    top_k=2,
    d_ff_expert=14_336,
    moe_every=2,                  # MoE every other layer
    moe_offset=1,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    tie_embeddings=False,
    param_dtype="bfloat16",
    opt_dtype="bfloat16",
    remat="full",
)

SMOKE = reduced(CONFIG, attn_every=2, attn_offset=1, moe_every=2, moe_offset=0,
                n_layers=4)
