"""deepseek-v2-236b [arXiv:2405.04434]: 60L d=5120 128H MLA (kv_lora=512,
q_lora=1536, rope head 64), 2 shared + 160 routed experts top-6, first layer
dense (d_ff 12288), expert d_ff=1536, vocab 102400."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12_288,                  # dense layers (first_k_dense)
    vocab=102_400,
    d_head=128,                   # nope head dim
    v_head_dim=128,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1536,
    first_k_dense=1,
    moe_every=1,
    moe_offset=0,
    tie_embeddings=False,
    param_dtype="bfloat16",
    opt_dtype="bfloat16",
    remat="full",
)

SMOKE = reduced(CONFIG, n_heads=4, n_kv_heads=4)
