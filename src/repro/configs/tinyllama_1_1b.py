"""tinyllama-1.1b [arXiv:2401.02385]: llama2-arch, 22L d=2048 32H GQA kv=4."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32_000,
    d_head=64,
    rope_theta=10_000.0,
    tie_embeddings=False,
    remat="full",
)

SMOKE = reduced(CONFIG)
