"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: 35L d=7168 56H GQA kv=8,
128 experts top-2 (d_ff 4864) + dense residual MLP in parallel."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,                    # the parallel dense residual MLP
    vocab=32_000,
    d_head=128,
    n_experts=128,
    top_k=2,
    d_ff_expert=4864,
    moe_every=1,
    moe_offset=0,
    tie_embeddings=False,
    param_dtype="bfloat16",
    opt_dtype="bfloat16",
    remat="full",
)

SMOKE = reduced(CONFIG)
