"""gemma3-4b [hf:google/gemma-3]: 34L d=2560 8H GQA kv=4, 5:1 local:global
sliding window (1024), 128k context, qk-norm, 262k vocab."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10_240,
    vocab=262_144,
    d_head=256,
    rope_theta=1_000_000.0,
    local_global_period=6,        # 5 local + 1 global
    sliding_window=1024,
    qk_norm=True,
    tie_embeddings=True,
    act="gelu",
    remat="full",
)

SMOKE = reduced(CONFIG, local_global_period=2, n_layers=4)
