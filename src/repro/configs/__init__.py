"""Architecture registry: the 10 assigned configs (--arch <id>)."""

from . import (
    arctic_480b,
    deepseek_v2_236b,
    gemma3_4b,
    glm4_9b,
    granite_3_8b,
    jamba_v0_1_52b,
    llava_next_34b,
    tinyllama_1_1b,
    whisper_base,
    xlstm_350m,
)
from .shapes import LONG_CONTEXT_OK, SHAPES, ShapeSpec, cells_for, skip_reason

_MODULES = {
    "whisper-base": whisper_base,
    "tinyllama-1.1b": tinyllama_1_1b,
    "glm4-9b": glm4_9b,
    "gemma3-4b": gemma3_4b,
    "granite-3-8b": granite_3_8b,
    "xlstm-350m": xlstm_350m,
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "arctic-480b": arctic_480b,
    "llava-next-34b": llava_next_34b,
}

ARCHS = sorted(_MODULES)


def get_config(name: str, smoke: bool = False):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.CONFIG
