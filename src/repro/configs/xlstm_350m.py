"""xlstm-350m [arXiv:2405.04517]: 24L d=1024 4H, sLSTM + mLSTM blocks
(3 mLSTM : 1 sLSTM interleave; d_ff=0 — projections live inside the blocks)."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    slstm_every=4,
    slstm_offset=3,
    tie_embeddings=True,
    remat="full",
)

SMOKE = reduced(CONFIG, d_model=64, n_heads=2)
