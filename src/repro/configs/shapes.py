"""Assigned input-shape grid (seq_len x global_batch) and per-arch cell rules.

``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a KV
cache/state of seq_len); ``train_4k`` lowers ``train_step``; ``prefill_32k``
lowers ``prefill_step``.  ``long_500k`` runs only for sub-quadratic archs
(see DESIGN.md §4): xlstm (SSM state), jamba (hybrid), gemma3 (5:1 sliding
window); it is N/A for the pure full-attention archs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing)
LONG_CONTEXT_OK = {"xlstm-350m", "jamba-v0.1-52b", "gemma3-4b"}


def cells_for(arch: str) -> List[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_OK:
        out.append("long_500k")
    return out


def skip_reason(arch: str, shape: str) -> str:
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return ("N/A: pure full-attention arch — 500k prefill is quadratic "
                "(DESIGN.md §4)")
    return ""
