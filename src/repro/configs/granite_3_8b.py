"""granite-3-8b [hf:ibm-granite/granite-3.0]: 40L d=4096 32H GQA kv=8."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12_800,
    vocab=49_155,
    d_head=128,
    rope_theta=10_000.0,
    tie_embeddings=True,
    remat="full",
)

SMOKE = reduced(CONFIG)
