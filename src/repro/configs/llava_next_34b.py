"""llava-next-34b [hf:llava-hf/llava-v1.6]: 60L d=7168 56H GQA kv=8 backbone
(Yi-34B-class); anyres vision tiling is a STUB — input_specs() supplies
precomputed patch embeddings (up to 2880 tokens) prepended to the text."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20_480,
    vocab=64_000,
    d_head=128,
    rope_theta=5_000_000.0,
    frontend="vision_patches",
    n_frontend_tokens=2_880,
    tie_embeddings=False,
    param_dtype="bfloat16",
    opt_dtype="bfloat16",
    remat="full",
)

SMOKE = reduced(CONFIG)
