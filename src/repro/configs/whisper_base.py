"""whisper-base [arXiv:2212.04356]: enc-dec, 6+6L, d=512, 8H MHA, ff=2048.
Audio conv frontend is a STUB: input_specs() supplies precomputed frame
embeddings (see DESIGN.md §4)."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51_865,
    d_head=64,
    is_encdec=True,
    n_enc_layers=6,
    frontend="audio_frames",
    n_frontend_tokens=1_500,
    tie_embeddings=True,
    act="gelu",
    remat="full",
)

SMOKE = reduced(CONFIG)
