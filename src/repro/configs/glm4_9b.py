"""glm4-9b [hf:THUDM/glm-4-9b]: 40L d=4096 32H GQA kv=2, RoPE, vocab 151552."""

from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13_696,
    vocab=151_552,
    d_head=128,
    rope_theta=10_000.0,
    tie_embeddings=False,
    remat="full",
)

SMOKE = reduced(CONFIG)
