from .elastic import MeshPlan, build_mesh, plan_mesh, rescale_batch, shrink_after_failure
from .fault import (
    Decision,
    FaultConfig,
    HeartbeatMonitor,
    NodeState,
    RestartPolicy,
    mitigate_stragglers,
)

__all__ = [k for k in dir() if not k.startswith("_")]
