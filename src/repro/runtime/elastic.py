"""Elastic scaling: recompute the mesh from surviving devices and reshard.

On failure, the coordinator (a) drops dead hosts, (b) picks the largest
(data', model') grid that the survivors support while preserving the model
axis (TP degree must divide attention heads / expert count — resharding the
model axis would change per-op tile shapes), (c) restores the latest
checkpoint into the new shardings (checkpoint.manager.reshard_to), and
(d) replays the data stream from the checkpoint step (data is step-indexed).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_mesh(n_devices: int, model_parallel: int,
              multi_pod: bool = False, pod_size: int = 256) -> MeshPlan:
    """Largest mesh using <= n_devices with a fixed model axis."""
    if n_devices < model_parallel:
        raise ValueError("fewer devices than the model-parallel degree")
    if multi_pod and n_devices >= 2 * pod_size:
        pods = n_devices // pod_size
        data = pod_size // model_parallel
        return MeshPlan((pods, data, model_parallel),
                        ("pod", "data", "model"))
    data = n_devices // model_parallel
    return MeshPlan((data, model_parallel), ("data", "model"))


def shrink_after_failure(current: MeshPlan, lost_devices: int) -> MeshPlan:
    """Elastic contraction: keep the model axis, shrink data (and pods)."""
    surviving = current.n_devices - lost_devices
    model = current.shape[-1]
    multi = len(current.shape) == 3
    if multi:
        pod_size = current.shape[1] * current.shape[2]
        if surviving >= 2 * pod_size:
            return plan_mesh(surviving, model, multi_pod=True,
                             pod_size=pod_size)
    data = max(1, surviving // model)
    return MeshPlan((data, model), ("data", "model"))


def build_mesh(plan: MeshPlan,
               devices: Optional[Sequence] = None) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    need = plan.n_devices
    if len(devices) < need:
        raise ValueError(f"need {need} devices, have {len(devices)}")
    import numpy as np
    arr = np.array(devices[:need]).reshape(plan.shape)
    return Mesh(arr, plan.axis_names)


def rescale_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-replica batch constant; shrink the global batch with the
    data axis (documented alternative: keep global batch and raise
    microbatching — see launch/train.py --keep-global-batch)."""
    per = global_batch // old_data
    return per * new_data
