"""Fault tolerance: heartbeats, straggler mitigation, restart policy.

On a real cluster each host runs a :class:`HeartbeatMonitor` fed by the
training loop; the coordinator applies :class:`RestartPolicy` to decide
between (a) in-place retry, (b) checkpoint-restart on the same topology,
(c) elastic restart on the survivors (see elastic.py).  The logic is
topology-agnostic and fully unit-testable on CPU; only the transport (here:
in-process callables; on a pod: GRPC/coordination-service) is swappable.
"""

from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional


class NodeState(str, Enum):
    HEALTHY = "healthy"
    SLOW = "slow"
    DEAD = "dead"


@dataclass
class FaultConfig:
    heartbeat_interval_s: float = 10.0
    dead_after_missed: int = 3
    straggler_factor: float = 2.0      # step time > factor * median => SLOW
    straggler_window: int = 20
    max_restarts_per_hour: int = 6


class HeartbeatMonitor:
    """Tracks per-node liveness + step-time distribution."""

    def __init__(self, cfg: FaultConfig, nodes: List[str],
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.last_seen: Dict[str, float] = {n: clock() for n in nodes}
        self.step_times: Dict[str, List[float]] = {n: [] for n in nodes}

    def heartbeat(self, node: str, step_time_s: Optional[float] = None):
        self.last_seen[node] = self.clock()
        if step_time_s is not None:
            w = self.step_times.setdefault(node, [])
            w.append(step_time_s)
            del w[: -self.cfg.straggler_window]

    def state(self, node: str) -> NodeState:
        silence = self.clock() - self.last_seen[node]
        if silence > self.cfg.dead_after_missed * self.cfg.heartbeat_interval_s:
            return NodeState.DEAD
        times = self.step_times.get(node) or []
        other_medians = [statistics.median(v)
                         for n, v in self.step_times.items()
                         if n != node and v]
        if times and other_medians:
            med = statistics.median(other_medians)
            if med > 0 and statistics.median(times) > \
                    self.cfg.straggler_factor * med:
                return NodeState.SLOW
        return NodeState.HEALTHY

    def survey(self) -> Dict[str, NodeState]:
        return {n: self.state(n) for n in self.last_seen}

    def dead_nodes(self) -> List[str]:
        return [n for n, s in self.survey().items() if s == NodeState.DEAD]

    def stragglers(self) -> List[str]:
        return [n for n, s in self.survey().items() if s == NodeState.SLOW]


class Decision(str, Enum):
    CONTINUE = "continue"
    EXCLUDE_AND_RESTART = "exclude_and_restart"   # elastic: drop dead nodes
    RESTART_SAME = "restart_same"                 # transient failure
    HALT = "halt"                                 # restart budget exhausted


@dataclass
class RestartPolicy:
    cfg: FaultConfig
    restart_times: List[float] = field(default_factory=list)
    clock: Callable[[], float] = time.monotonic

    def _budget_ok(self) -> bool:
        now = self.clock()
        self.restart_times = [t for t in self.restart_times if now - t < 3600]
        return len(self.restart_times) < self.cfg.max_restarts_per_hour

    def decide(self, monitor: HeartbeatMonitor,
               step_failed: bool = False) -> Decision:
        dead = monitor.dead_nodes()
        if not dead and not step_failed:
            return Decision.CONTINUE
        if not self._budget_ok():
            return Decision.HALT
        self.restart_times.append(self.clock())
        if dead:
            return Decision.EXCLUDE_AND_RESTART
        return Decision.RESTART_SAME


def mitigate_stragglers(monitor: HeartbeatMonitor,
                        data_assignment: Dict[str, int]) -> Dict[str, int]:
    """Rebalance per-node microbatch counts away from stragglers (simple
    work-stealing: each straggler sheds one unit to the fastest node)."""
    out = dict(data_assignment)
    slow = monitor.stragglers()
    if not slow:
        return out
    healthy = [n for n, s in monitor.survey().items()
               if s == NodeState.HEALTHY]
    if not healthy:
        return out
    fastest = min(
        healthy,
        key=lambda n: (statistics.median(monitor.step_times[n])
                       if monitor.step_times.get(n) else float("inf")))
    for s in slow:
        if out.get(s, 0) > 1:
            out[s] -= 1
            out[fastest] = out.get(fastest, 0) + 1
    return out
