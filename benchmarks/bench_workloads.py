"""Workload-family sweep: GA vs greedy vs DP external traffic across all
four workload-URI schemes (`netlib:` / `tpu:` / `synthetic:` / `file:`).

The paper evaluates Cocco on its six netlists; this sweep stresses the same
search strategies on every *family* the workload resolver can name — a CNN
netlist, a TPU transformer block, and seeded synthetic DAGs — plus a
`file:` import round-tripped through the Graph JSON format (the bench
exports one of the synthetic graphs and re-resolves it from disk, so the
import path is exercised end to end).

Emits ``workloads.<family>.<strategy>,us,EMA=..`` rows; like every
partition benchmark it runs through :func:`common.compare_cached`, so
``--store-dir`` makes re-runs instant and ``--jobs`` fans strategies out.
"""

from __future__ import annotations

from pathlib import Path

from repro.api import ExploreSpec, GAOptions, build_workload
from repro.core.ga import HWSpace, Objective
from repro.core.graph import graph_to_json

from .common import POPULATION, Timer, compare_cached, emit, fmt_mb

STRATEGIES = ["ga", "greedy", "dp"]

# one representative per scheme; budgets stay reduced-mode friendly
WORKLOADS = [
    ("netlib", "netlib:resnet50"),
    ("tpu", "tpu:gemma3-4b:0?tokens=2048"),
    ("synthetic_layered", "synthetic:layered:24?seed=7"),
    ("synthetic_branchy", "synthetic:branchy:24?seed=3"),
]


def _file_workload() -> str:
    """Export a synthetic graph to Graph JSON and resolve it back via file:."""
    out = Path("runs") / "bench" / "workload_diamond.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(graph_to_json(build_workload("synthetic:diamond:16?seed=5")))
    return f"file:{out}"


def main(budget: int = 2_000) -> None:
    for family, uri in WORKLOADS + [("file", _file_workload())]:
        spec = ExploreSpec(
            workload=uri,
            strategy="ga",
            objective=Objective(metric="ema", alpha=None),
            hw=HWSpace(mode="fixed"),
            sample_budget=budget,
            seed=0,
            options=GAOptions(population=min(POPULATION, 40)),
        )
        t = Timer()
        results = compare_cached(spec, STRATEGIES)
        per_strategy = t.us / max(len(results), 1)
        for res in results:
            ema = res.plan.ema_total if res.plan is not None else float("inf")
            emit(f"workloads.{family}.{res.strategy}", per_strategy,
                 f"EMA={fmt_mb(ema)}")


if __name__ == "__main__":
    from .common import configure

    configure()
    main()
