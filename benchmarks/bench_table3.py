"""Paper Table 3: multi-core weight sharing x batch size, energy/latency/
per-core buffer size under the energy-capacity co-opt configuration.

Trends validated: (a) 1 -> 2 cores usually costs energy (NoC overhead),
(b) per-core capacity drops with more cores, (c) latency grows sub-linearly
with batch, (d) energy per batch amortizes weight traffic."""

from __future__ import annotations

from typing import Dict

from repro.api import ExploreSpec, GAOptions
from repro.core import AcceleratorConfig, HWSpace, Objective
from repro.core.netlib import build

from .common import (
    COOPT_MODELS,
    COOPT_SAMPLES,
    POPULATION,
    Timer,
    compare_cached,
    emit,
)

CORES = (1, 2, 4)
BATCHES = (1, 2, 8)


def table3_metrics(plan, acc: AcceleratorConfig, n: int, b: int) -> Dict:
    """Energy(mJ)/latency(ms) for n weight-sharing cores and batch b.
    Weights load from DRAM once per subgraph (reused across the batch) and
    rotate across cores over the crossbar; activations scale with b.  The
    crossbar broadcast is the cost model's own §5.4.2 charge
    (``SubgraphCost.noc_bytes`` == ``(n - 1) * ema_w`` since the specs set
    ``weight_share_cores=n``), not a benchmark-side re-derivation."""
    e_glb = acc.sram_pj_per_byte(acc.glb_bytes)
    energy_pj = 0.0
    lat_cycles = 0.0
    for s in plan.subgraphs:
        acts = s.ema_in + s.ema_out
        w = s.ema_w
        energy_pj += (w * acc.e_dram_pj_per_byte
                      + b * acts * acc.e_dram_pj_per_byte
                      + b * s.glb_access_bytes * e_glb
                      + b * s.macs * acc.e_mac_pj
                      + s.noc_bytes * acc.e_noc_pj_per_byte)
        compute = b * s.macs / (acc.macs_per_cycle * n)
        io = (w + b * acts) / acc.dram_bytes_per_cycle
        lat_cycles += max(compute, io)
    return {"energy_mj": energy_pj / 1e9,
            "latency_ms": lat_cycles / acc.freq_hz * 1e3}


def run_all(samples: int = COOPT_SAMPLES) -> Dict:
    out = {}
    for name in COOPT_MODELS:
        g = build(name)
        # one spec per core count; the batch is store-addressed and runs in
        # parallel under --jobs
        specs = [
            ExploreSpec(
                workload=name,
                strategy="ga",
                objective=Objective(metric="energy", alpha=0.002),
                hw=HWSpace(mode="shared",
                           base=AcceleratorConfig(shared=True,
                                                  weight_share_cores=n,
                                                  n_cores=n)),
                sample_budget=max(samples // 2, 1000),
                seed=0,
                options=GAOptions(population=POPULATION),
            )
            for n in CORES
        ]
        rows = {}
        for n, res in zip(CORES, compare_cached(specs[0], specs, graph=g)):
            for b in BATCHES:
                m = table3_metrics(res.plan, res.acc, n, b)
                m["size_kb"] = res.acc.glb_bytes // 1024
                rows[(n, b)] = m
        out[name] = rows
    return out


def main() -> None:
    res = run_all()
    for name, rows in res.items():
        t = Timer()
        e11, e21 = rows[(1, 1)]["energy_mj"], rows[(2, 1)]["energy_mj"]
        l11, l18 = rows[(1, 1)]["latency_ms"], rows[(1, 8)]["latency_ms"]
        s1, s4 = rows[(1, 1)]["size_kb"], rows[(4, 1)]["size_kb"]
        emit(f"table3.{name}", t.us,
             f"E(1c)={e11:.2f}mJ E(2c)={e21:.2f}mJ | "
             f"lat b1={l11:.2f}ms b8={l18:.2f}ms "
             f"(x{l18 / max(l11, 1e-9):.1f} sub-linear<8) | "
             f"size 1c={s1}KB 4c={s4}KB")


if __name__ == "__main__":
    main()
