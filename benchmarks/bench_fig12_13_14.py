"""Paper Fig. 12 (sample-efficiency curves), Fig. 13 (population
distribution over generations), Fig. 14 (alpha sweep: capacity vs energy).

Cocco, SA, and the two-step schemes all run as registry strategies on one
shared-buffer ExploreSpec per model; every run goes through the sweep-wide
result store (resumable) and each model's strategy batch fans out over
``--jobs`` worker processes."""

from __future__ import annotations

import json
import os
from dataclasses import replace
from typing import Dict, List

from repro.api import ExploreSpec, GAOptions, TwoStepOptions
from repro.core import HWSpace, Objective
from repro.core.netlib import build

from .common import (
    COOPT_SAMPLES,
    POPULATION,
    Timer,
    compare_cached,
    emit,
    run_cached,
)

FIG12_MODELS = ["resnet50", "googlenet", "randwire_a"]
ALPHAS = [0.0005, 0.002, 0.008, 0.032]
OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "runs/bench")


def downsample(history: List, n: int = 200) -> List:
    if len(history) <= n:
        return [list(h) for h in history]
    step = len(history) / n
    return [list(history[int(i * step)]) for i in range(n)]


def coopt_spec(name: str, samples: int, alpha: float = 0.002) -> ExploreSpec:
    return ExploreSpec(
        workload=name,
        strategy="ga",
        objective=Objective(metric="energy", alpha=alpha),
        hw=HWSpace(mode="shared"),
        sample_budget=samples,
        seed=0,
        options=GAOptions(population=POPULATION),
    )


def run_fig12(samples: int = COOPT_SAMPLES) -> Dict:
    out = {}
    for name in FIG12_MODELS:
        g = build(name)
        spec = coopt_spec(name, samples)
        two_step = {
            tag: replace(spec, strategy="two_step",
                         options=TwoStepOptions(
                             sampler=sampler, capacity_samples=4,
                             samples_per_capacity=max(samples // 4, 500)))
            for tag, sampler in (("rs_ga", "random"), ("gs_ga", "grid"))
        }
        batch = compare_cached(
            spec,
            [spec, replace(spec, strategy="sa", options=None),
             two_step["rs_ga"], two_step["gs_ga"]],
            graph=g)
        cocco, sa, rs, gs = batch
        out[name] = {"cocco": downsample(cocco.history),
                     "sa": downsample(sa.history),
                     "rs_ga": downsample(rs.history),
                     "gs_ga": downsample(gs.history)}
    return out


def run_fig13(samples: int = COOPT_SAMPLES) -> Dict:
    spec = replace(coopt_spec("resnet50", samples),
                   options=GAOptions(population=POPULATION,
                                     log_populations=True))
    res = run_cached(spec)
    return {"resnet50": [[list(p) for p in gen]
                         for gen in res.population_log[:20]]}


def run_fig14(samples: int = COOPT_SAMPLES) -> Dict:
    out = {}
    for name in ("resnet50", "googlenet", "randwire_a", "nasnet"):
        g = build(name)
        specs = [coopt_spec(name, max(samples // 2, 1000), alpha=alpha)
                 for alpha in ALPHAS]
        rows = []
        for alpha, res in zip(ALPHAS,
                              compare_cached(specs[0], specs, graph=g)):
            rows.append({"alpha": alpha,
                         "capacity_kb": res.acc.glb_bytes // 1024,
                         "energy_pj": res.plan.energy_pj})
        base = rows[0]["energy_pj"]
        for r in rows:
            r["energy_norm"] = r["energy_pj"] / base
        out[name] = rows
    return out


def main() -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    t = Timer()
    f12 = run_fig12()
    with open(os.path.join(OUT_DIR, "fig12_curves.json"), "w") as f:
        json.dump(f12, f)
    for name, curves in f12.items():
        finals = {k: v[-1][1] for k, v in curves.items()}
        best = min(finals.values())
        emit(f"fig12.{name}", t.us,
             " ".join(f"{k}={v / best:.3f}x" for k, v in finals.items()))

    t = Timer()
    f13 = run_fig13()
    with open(os.path.join(OUT_DIR, "fig13_population.json"), "w") as f:
        json.dump(f13, f)
    gens = f13["resnet50"]
    if gens:
        first = sum(p[2] for p in gens[0]) / len(gens[0])
        last = sum(p[2] for p in gens[-1]) / len(gens[-1])
        emit("fig13.resnet50", t.us,
             f"pop_mean_cost gen0={first:.3e} genN={last:.3e} "
             f"centralized={last < first}")

    t = Timer()
    f14 = run_fig14()
    with open(os.path.join(OUT_DIR, "fig14_alpha.json"), "w") as f:
        json.dump(f14, f)
    for name, rows in f14.items():
        caps = [r["capacity_kb"] for r in rows]
        ens = [r["energy_norm"] for r in rows]
        emit(f"fig14.{name}", t.us,
             f"alpha {ALPHAS[0]}->{ALPHAS[-1]}: capacity {caps[0]}KB->"
             f"{caps[-1]}KB energy {ens[0]:.2f}->{ens[-1]:.2f}")


if __name__ == "__main__":
    main()
