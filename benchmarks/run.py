# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig11,...]
        [--store-dir runs/store] [--jobs N] [--no-store]
        [--eval-jobs N] [--eval-backend serial|process|vector]

Reduced sample budgets by default (REPRO_BENCH_FULL=1 for the paper's
400k/50k budgets).  Emits `name,us_per_call,derived` CSV rows.

``--store-dir`` (default ``runs/store``, or ``$REPRO_STORE_DIR``) keeps a
spec-addressed cache of every search the partition benchmarks perform, so an
interrupted sweep — or a re-run to re-plot — replays finished specs from disk
instead of re-searching; ``--no-store`` disables it.  ``--jobs N`` runs
independent strategies of one benchmark point in N worker processes.
"""

from __future__ import annotations

import argparse
import os
import time
import traceback

from . import (
    bench_fig3,
    bench_fig11,
    bench_fig12_13_14,
    bench_kernels,
    bench_roofline,
    bench_serve,
    bench_table3,
    bench_tables12,
    bench_trace,
    bench_workloads,
)

BENCHES = {
    "fig3": bench_fig3.main,
    "fig11": bench_fig11.main,
    "tables12": bench_tables12.main,
    "fig12_13_14": bench_fig12_13_14.main,
    "table3": bench_table3.main,
    "workloads": bench_workloads.main,
    "trace": bench_trace.main,
    "serve": bench_serve.main,
    "kernels": bench_kernels.main,
    "roofline": bench_roofline.main,
}


def main() -> None:
    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--store-dir",
                    default=os.environ.get("REPRO_STORE_DIR", "runs/store"),
                    help="spec-addressed result store for resumable sweeps "
                         "(default: runs/store)")
    ap.add_argument("--no-store", action="store_true",
                    help="always search from scratch")
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker processes for independent strategy runs")
    ap.add_argument("--eval-jobs", type=int, default=1,
                    help="evaluation-engine workers for batched cost "
                         "queries within one strategy")
    ap.add_argument("--eval-backend", default=None,
                    choices=["serial", "process", "vector"],
                    help="evaluation-engine executor (default: process "
                         "when --eval-jobs > 1, else serial)")
    args = ap.parse_args()
    common.configure(store_dir=None if args.no_store else args.store_dir,
                     jobs=args.jobs, eval_jobs=args.eval_jobs,
                     eval_backend=args.eval_backend)
    names = list(BENCHES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.time()
        try:
            BENCHES[name]()
        except Exception as e:
            failures += 1
            print(f"{name}.ERROR,{(time.time() - t0) * 1e6:.0f},"
                  f"{type(e).__name__}: {e}")
            traceback.print_exc()
        print(f"{name}.total,{(time.time() - t0) * 1e6:.0f},done")
    if common.STORE is not None:
        print(f"# {common.STORE.stats()}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
