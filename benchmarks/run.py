# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig11,...]
        [--store-dir runs/store] [--jobs N] [--no-store]
        [--eval-jobs N] [--eval-backend serial|process|vector|jax]

Reduced sample budgets by default (REPRO_BENCH_FULL=1 for the paper's
400k/50k budgets).  Emits `name,us_per_call,derived` CSV rows.

``--store-dir`` (default ``runs/store``, or ``$REPRO_STORE_DIR``) keeps a
spec-addressed cache of every search the partition benchmarks perform, so an
interrupted sweep — or a re-run to re-plot — replays finished specs from disk
instead of re-searching; ``--no-store`` disables it.  ``--jobs N`` runs
independent strategies of one benchmark point in N worker processes.
"""

from __future__ import annotations

import argparse
import importlib
import os
import time
import traceback

# bench name -> module (imported at dispatch time: the kernel/serve/roofline
# benches need jax, and a lazy registry keeps --help and the cost-model
# benches working without it)
BENCHES = {
    "fig3": "bench_fig3",
    "fig11": "bench_fig11",
    "tables12": "bench_tables12",
    "fig12_13_14": "bench_fig12_13_14",
    "table3": "bench_table3",
    "workloads": "bench_workloads",
    "trace": "bench_trace",
    "engine": "bench_engine",
    "serve": "bench_serve",
    "kernels": "bench_kernels",
    "roofline": "bench_roofline",
}


def _bench_main(name: str):
    module = importlib.import_module(f"benchmarks.{BENCHES[name]}")
    return module.main


def main() -> None:
    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--store-dir",
                    default=os.environ.get("REPRO_STORE_DIR", "runs/store"),
                    help="spec-addressed result store for resumable sweeps "
                         "(default: runs/store)")
    ap.add_argument("--no-store", action="store_true",
                    help="always search from scratch")
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker processes for independent strategy runs")
    ap.add_argument("--eval-jobs", type=int, default=1,
                    help="evaluation-engine workers for batched cost "
                         "queries within one strategy")
    ap.add_argument("--eval-backend", default=None,
                    help="evaluation-engine executor: serial | process | "
                         "vector | jax (default: process when "
                         "--eval-jobs > 1, else serial)")
    args = ap.parse_args()
    if args.eval_backend is not None:
        from repro.core.engine import backend_status

        ok, why = backend_status(args.eval_backend)
        if not ok:
            raise SystemExit(f"error: {why}")
    common.configure(store_dir=None if args.no_store else args.store_dir,
                     jobs=args.jobs, eval_jobs=args.eval_jobs,
                     eval_backend=args.eval_backend)
    names = list(BENCHES) if not args.only else args.only.split(",")
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"error: unknown bench {unknown}; "
                         f"valid: {', '.join(BENCHES)}")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.time()
        try:
            _bench_main(name)()
        except Exception as e:
            failures += 1
            print(f"{name}.ERROR,{(time.time() - t0) * 1e6:.0f},"
                  f"{type(e).__name__}: {e}")
            traceback.print_exc()
        print(f"{name}.total,{(time.time() - t0) * 1e6:.0f},done")
    if common.STORE is not None:
        print(f"# {common.STORE.stats()}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
