"""Kernel micro-bench: wall time of the Pallas kernels (interpret mode on
CPU — correctness/structure, not TPU latency) vs the jnp reference, plus the
derived FLOP counts that feed the §Roofline compute term."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention, fused_rmsnorm, fused_swiglu
from repro.kernels import ref

from .common import Timer, emit


def timeit(fn, *args, n=3):
    fn(*args)  # warm up / compile
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n * 1e6


def main() -> None:
    key = jax.random.PRNGKey(0)
    B, H, S, d = 1, 4, 512, 64
    q, k, v = (jax.random.normal(kk, (B, H, S, d), jnp.float32)
               for kk in jax.random.split(key, 3))
    flops_attn = 4 * B * H * S * S * d

    us_kernel = timeit(lambda *a: flash_attention(*a, interpret=True), q, k, v)
    us_ref = timeit(jax.jit(ref.attention_ref), q, k, v)
    emit("kernel.flash_attention", us_kernel,
         f"ref_us={us_ref:.0f} gflop={flops_attn / 1e9:.2f} "
         f"interp_overhead={us_kernel / max(us_ref, 1):.1f}x")

    M, dm, f = 256, 128, 512
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, dm))
    wg = jax.random.normal(ks[1], (dm, f)) / jnp.sqrt(dm)
    wi = jax.random.normal(ks[2], (dm, f)) / jnp.sqrt(dm)
    wo = jax.random.normal(ks[3], (f, dm)) / jnp.sqrt(f)
    us_kernel = timeit(lambda *a: fused_swiglu(*a, interpret=True),
                       x, wg, wi, wo)
    us_ref = timeit(jax.jit(ref.swiglu_ref), x, wg, wi, wo)
    emit("kernel.fused_swiglu", us_kernel,
         f"ref_us={us_ref:.0f} gflop={6 * M * dm * f / 1e9:.3f}")

    scale = jnp.ones(dm)
    us_kernel = timeit(lambda *a: fused_rmsnorm(*a, interpret=True), x, scale)
    us_ref = timeit(jax.jit(ref.rmsnorm_ref), x, scale)
    emit("kernel.fused_rmsnorm", us_kernel, f"ref_us={us_ref:.0f}")


if __name__ == "__main__":
    main()
