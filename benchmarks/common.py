"""Shared benchmark utilities.

Budgets default to a reduced mode so `python -m benchmarks.run` finishes on a
laptop; set REPRO_BENCH_FULL=1 to use the paper's sample counts (400k
partition / 50k co-opt samples).
"""

from __future__ import annotations

import os
import time
from contextlib import contextmanager
from typing import Dict, List

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

PARTITION_SAMPLES = 400_000 if FULL else 2_500
COOPT_SAMPLES = 50_000 if FULL else 1_500
POPULATION = 500 if FULL else 40
GREEDY_EVALS = 10**9 if FULL else 5_000
ENUM_STATES = 2_000_000 if FULL else 60_000

SMALL_MODELS = ["vgg16", "resnet50", "googlenet", "nasnet"]
LARGE_MODELS = ["resnet152", "transformer", "gpt", "randwire_a", "randwire_b"]
COOPT_MODELS = ["resnet50", "googlenet", "randwire_a", "nasnet"]


class Timer:
    def __init__(self):
        self.t0 = time.time()

    @property
    def us(self) -> float:
        return (time.time() - self.t0) * 1e6


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.0f},{derived}")


def fmt_mb(x: float) -> str:
    return f"{x / 1e6:.2f}MB"
