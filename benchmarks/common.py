"""Shared benchmark utilities.

Budgets default to a reduced mode so `python -m benchmarks.run` finishes on a
laptop; set REPRO_BENCH_FULL=1 to use the paper's sample counts (400k
partition / 50k co-opt samples).

The partition benchmarks run every search through :func:`run_cached` /
:func:`compare_cached`, which honor the orchestrator's ``--store-dir`` /
``--jobs`` / ``--no-store`` flags (see :func:`configure`): with a store
configured, an interrupted sweep resumes from the already-searched specs
instead of re-searching them, and independent strategy runs fan out over
worker processes.
"""

from __future__ import annotations

import os
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Sequence, Union

from repro.api import ExploreResult, ExploreSpec, ResultStore
from repro.api import compare as api_compare
from repro.api import run as api_run

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"

# process-wide sweep configuration, set once by benchmarks.run (or by tests)
STORE: Optional[ResultStore] = None
JOBS: int = 1
EVAL_JOBS: int = 1
EVAL_BACKEND: Optional[str] = None


def configure(store_dir: Optional[str] = None, jobs: int = 1,
              eval_jobs: int = 1,
              eval_backend: Optional[str] = None) -> None:
    """Point every subsequent run_cached/compare_cached at one store/pool.

    ``jobs`` fans out whole strategies; ``eval_jobs``/``eval_backend``
    parallelize cost evaluation *within* one strategy through the
    evaluation engine (`repro.core.engine`: serial | process | vector |
    jax) — results are identical either way, so both axes are safe under
    the result store.
    """
    global STORE, JOBS, EVAL_JOBS, EVAL_BACKEND
    STORE = ResultStore(store_dir) if store_dir else None
    JOBS = max(1, jobs)
    EVAL_JOBS = max(1, eval_jobs)
    EVAL_BACKEND = eval_backend


def new_evaluator(g, out_tile: int = 1):
    """A `CachedEvaluator` wired to the sweep-wide evaluation backend."""
    from repro.core.cost import CachedEvaluator
    from repro.core.engine import make_executor

    return CachedEvaluator(g, out_tile=out_tile,
                           executor=make_executor(EVAL_BACKEND, EVAL_JOBS))


def run_cached(spec: ExploreSpec, graph=None, ev=None) -> ExploreResult:
    """`repro.api.run` against the sweep-wide result store."""
    return api_run(spec, graph=graph, ev=ev, store=STORE,
                   eval_jobs=EVAL_JOBS, eval_backend=EVAL_BACKEND)


def compare_cached(spec: ExploreSpec,
                   strategies: Sequence[Union[str, ExploreSpec]],
                   graph=None, ev=None) -> List[ExploreResult]:
    """`repro.api.compare` with the sweep-wide store and process pool."""
    return api_compare(spec, strategies, graph=graph, ev=ev,
                       jobs=JOBS, store=STORE,
                       eval_jobs=EVAL_JOBS, eval_backend=EVAL_BACKEND)

PARTITION_SAMPLES = 400_000 if FULL else 2_500
COOPT_SAMPLES = 50_000 if FULL else 1_500
POPULATION = 500 if FULL else 40
GREEDY_EVALS = 10**9 if FULL else 5_000
ENUM_STATES = 2_000_000 if FULL else 60_000

SMALL_MODELS = ["vgg16", "resnet50", "googlenet", "nasnet"]
LARGE_MODELS = ["resnet152", "transformer", "gpt", "randwire_a", "randwire_b"]
COOPT_MODELS = ["resnet50", "googlenet", "randwire_a", "nasnet"]


class Timer:
    def __init__(self):
        self.t0 = time.time()

    @property
    def us(self) -> float:
        return (time.time() - self.t0) * 1e6


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.0f},{derived}")


def fmt_mb(x: float) -> str:
    return f"{x / 1e6:.2f}MB"
