"""Paper Fig. 3: fusing layers into subgraphs (L = 1, 3, 5) cuts external
memory access by 42-75% and average bandwidth by 27-68% on the 2 TOPS
accelerator (1 MB GLB + 1.125 MB WBUF)."""

from __future__ import annotations

from typing import Dict, List, Set

from repro.core import AcceleratorConfig, CachedEvaluator
from repro.core.baselines import _depth_order
from repro.core.netlib import build
from repro.core.partition import split_to_fit

from .common import SMALL_MODELS, Timer, emit


def fused_partition(g, L: int, acc, ev) -> List[Set[int]]:
    """Consecutive depth-order runs of L layers, split in-situ to fit."""
    order = _depth_order(g)
    groups = []
    for i in range(0, len(order), L):
        seg = set(order[i: i + L])
        comps = g.weakly_connected_components(seg)
        groups.extend(comps)
    return split_to_fit(g, groups, acc, ev=ev)


def run() -> Dict:
    acc = AcceleratorConfig()
    out = {}
    for name in SMALL_MODELS:
        g = build(name)
        ev = CachedEvaluator(g)
        rows = {}
        for L in (1, 3, 5):
            groups = fused_partition(g, L, acc, ev)
            plan = ev.plan(groups, acc)
            rows[L] = {
                "ema_mb": plan.ema_total / 1e6,
                "avg_bw_gbs": plan.avg_bandwidth() / 1e9,
                "peak_bw_gbs": plan.peak_bandwidth() / 1e9,
                "subgraphs": len(groups),
            }
        base = rows[1]
        for L in (3, 5):
            rows[L]["ema_reduction_%"] = 100 * (1 - rows[L]["ema_mb"]
                                                / base["ema_mb"])
            rows[L]["bw_reduction_%"] = 100 * (1 - rows[L]["avg_bw_gbs"]
                                               / base["avg_bw_gbs"])
        out[name] = rows
    return out


def main() -> None:
    t = Timer()
    res = run()
    for name, rows in res.items():
        d = (f"L3 ema -{rows[3]['ema_reduction_%']:.0f}% "
             f"bw -{rows[3]['bw_reduction_%']:.0f}% | "
             f"L5 ema -{rows[5]['ema_reduction_%']:.0f}% "
             f"bw -{rows[5]['bw_reduction_%']:.0f}%")
        emit(f"fig3.{name}", t.us, d)


if __name__ == "__main__":
    main()
