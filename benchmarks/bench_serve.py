"""Plan-server latency: cold search vs store hit vs zoo hit vs dedup join.

The serving claim is quantitative — a repeated request must be answered
from the store/zoo tiers orders of magnitude faster than the search that
produced it, and N concurrent identical requests must cost one search.
This bench measures exactly that, in-process (no HTTP, so the numbers are
the service's own overhead, not socket noise):

* ``serve.cold.<w>``   — first request: full search through the pool
  (``searches=1`` derived).
* ``serve.store_hit.<w>`` — identical request again, mean per-call over
  repeats (derived: speedup vs cold).
* ``serve.zoo_hit.<w>``   — same spec served from a read-only zoo mount.
* ``serve.dedup.<w>``     — N threads hammer one *cold* spec; derived
  reports searches (must be 1) and joins (must be N-1).

Emits ``name,us_per_call,derived`` CSV like every other bench.
"""

from __future__ import annotations

import tempfile
import threading
import time
from pathlib import Path

from repro.api import ExploreSpec, ResultStore
from repro.core.ga import HWSpace, Objective
from repro.serve.plans import PlanService

from .common import Timer, emit

WORKLOADS = [
    ("layered24", "synthetic:layered:24?seed=7"),
    ("gemma3", "tpu:gemma3-4b:0?tokens=2048"),
]

HIT_REPEATS = 50
DEDUP_FANOUT = 8


def _spec(uri: str, seed: int = 0) -> ExploreSpec:
    return ExploreSpec(workload=uri, strategy="greedy",
                       objective=Objective(metric="ema", alpha=None),
                       hw=HWSpace(mode="fixed"),
                       sample_budget=2_000, seed=seed)


def main() -> None:
    for name, uri in WORKLOADS:
        root = Path(tempfile.mkdtemp(prefix=f"bench-serve-{name}-"))
        svc = PlanService(ResultStore(root / "store"), workers=2)
        try:
            spec = _spec(uri)
            t = Timer()
            svc.plan(spec)
            cold_us = t.us
            emit(f"serve.cold.{name}", cold_us,
                 f"searches={svc.searches}")

            t = Timer()
            for _ in range(HIT_REPEATS):
                svc.plan(spec)
            hit_us = t.us / HIT_REPEATS
            emit(f"serve.store_hit.{name}", hit_us,
                 f"speedup={cold_us / max(hit_us, 1e-9):.0f}x")

            # zoo tier: mount the store we just filled as a read-only zoo
            zoo_svc = PlanService(ResultStore(root / "fresh"),
                                  zoo=ResultStore(root / "store",
                                                  read_only=True))
            try:
                zoo_svc.plan(spec)          # warm the mount's first stat
                t = Timer()
                for _ in range(HIT_REPEATS):
                    zoo_svc.plan(spec)
                emit(f"serve.zoo_hit.{name}", t.us / HIT_REPEATS,
                     f"zoo_hits={zoo_svc.zoo_hits}")
            finally:
                zoo_svc.close()

            # dedup: N concurrent requests for one cold spec, one search
            fresh = _spec(uri, seed=1)
            barrier = threading.Barrier(DEDUP_FANOUT)

            def hammer():
                barrier.wait()
                svc.plan(fresh)

            threads = [threading.Thread(target=hammer)
                       for _ in range(DEDUP_FANOUT)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            us = (time.perf_counter() - t0) * 1e6 / DEDUP_FANOUT
            emit(f"serve.dedup.{name}", us,
                 f"fanout={DEDUP_FANOUT} searches={svc.searches - 1} "
                 f"joins={svc.dedup_joins}")
        finally:
            svc.close()


if __name__ == "__main__":
    from .common import configure

    configure(store_dir=None)
    main()
