"""Evaluation-engine backend microbench: generation-shaped miss batches.

Times exactly the work the executor seam sees during a GA generation —
``finish_cost`` over a batch of distinct ``(structure, hardware-point)``
queries whose structure half is already memoized — for every backend that
resolves on this machine.  This isolates the batched arithmetic from graph
analysis, so the rows answer "which backend should ``--eval-backend`` use
here?" directly.

Emits ``engine.<workload>.<backend>.b<batch>,us,x<speedup>`` rows where
``us`` is per-batch wall time (best of ``REPEATS`` after a warm-up that
also pays any jit compilation) and the derived column is the speedup over
the serial scalar loop.  The jax rows are skipped — with a note, not an
error — when jax is not installed.

The ``structure.*`` rows cover the other half: a cold kernel deriving a
GA-shaped corpus of distinct node sets, with the canonical content-
fingerprint memo off vs on (``REPRO_STRUCT_CANON`` / ``CostKernel
(canonical=...)``).  The derived column reports derivations/canonical
hits, and the ``canon_on`` row's speedup is the structure-half win the
memo buys on that workload.
"""

from __future__ import annotations

import random
import time

from repro.api import build_workload
from repro.core import CostKernel, HWSpace
from repro.core.engine import make_executor
from repro.core.partition import random_partition

from .common import FULL, emit

WORKLOADS = [("resnet50", "netlib:resnet50"),
             ("layered24", "synthetic:layered:24?seed=7")]
BATCHES = [64, 512, 4096] if FULL else [64, 512]
BACKENDS = ["serial", "vector", "jax"]
REPEATS = 5


def _queries(g, n: int):
    """n distinct generation-shaped queries: random partitions x sampled
    hardware points (the co-exploration miss pattern)."""
    rng = random.Random(7)
    hw = HWSpace(mode="separate")
    out = []
    while len(out) < n:
        acc = hw.sample(rng)
        for s in random_partition(g, rng, mean_size=rng.uniform(1.5, 6.0)):
            out.append((frozenset(s), acc))
    return out[:n]


def _time_batch(ex, kernel, queries) -> float:
    ex.evaluate(kernel, queries)            # warm-up: structure memo + jit
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.time()
        ex.evaluate(kernel, queries)
        best = min(best, (time.time() - t0) * 1e6)
    return best


def _node_corpus(g, n_parts: int):
    """Distinct node sets from GA-shaped random partitions (the repeated
    isomorphic shapes are the canonical memo's target)."""
    rng = random.Random(7)
    seen, out = set(), []
    for _ in range(n_parts):
        for s in random_partition(g, rng, mean_size=rng.uniform(1.5, 6.0)):
            fs = frozenset(s)
            if fs not in seen:
                seen.add(fs)
                out.append(fs)
    return out


def bench_structures() -> None:
    """Cold-kernel structure derivation, canonical memo off vs on."""
    n_parts = 48 if FULL else 16
    for wname, uri in WORKLOADS:
        g = build_workload(uri)
        sets = _node_corpus(g, n_parts)
        base_us = None
        for label, canonical in (("off", False), ("on", True)):
            best, counts = float("inf"), ""
            for _ in range(REPEATS):
                kernel = CostKernel(g, canonical=canonical)
                t0 = time.time()
                for fs in sets:
                    kernel.structure(fs)
                best = min(best, (time.time() - t0) * 1e6)
                counts = (f"{kernel.structure_misses}derive/"
                          f"{kernel.structure_canon_hits}hit")
            if label == "off":
                base_us = best
            speedup = base_us / best if base_us else 1.0
            emit(f"structure.{wname}.canon_{label}.s{len(sets)}", best,
                 f"x{speedup:.2f},{counts}")


def main() -> None:
    from repro.core.engine import backend_status

    bench_structures()
    for wname, uri in WORKLOADS:
        g = build_workload(uri)
        for n in BATCHES:
            queries = _queries(g, n)
            base_us = None
            for backend in BACKENDS:
                ok, why = backend_status(backend)
                if not ok:
                    emit(f"engine.{wname}.{backend}.b{n}", 0.0, "skipped")
                    continue
                ex = make_executor(backend, 1)
                try:
                    us = _time_batch(ex, CostKernel(g), queries)
                finally:
                    ex.close()
                if backend == "serial":
                    base_us = us
                speedup = base_us / us if base_us else 1.0
                emit(f"engine.{wname}.{backend}.b{n}", us, f"x{speedup:.2f}")


if __name__ == "__main__":
    main()
