"""Trace-simulator sweep: strategies ranked by *simulated* peak bandwidth
across all four workload-URI schemes.

The analytical kernel ranks plans by EMA bytes; this bench re-ranks the
same searches by what the time-stepped trace simulator (:mod:`repro.sim`)
says about their bandwidth requirement — peak and p95 of the per-step
DRAM bandwidth — and cross-validates every simulated plan against the
analytical EMA on the way (a failed cross-validation is a bench error,
not a silent wrong number).

Emits ``trace.<family>.<rank>.<strategy>,us,peak=..`` rows where ``us`` is
the simulation time per plan and ``rank`` orders strategies by simulated
peak bandwidth (1 = lowest requirement, the paper's "lower bandwidth"
claim).  Runs through :func:`common.compare_cached`, so ``--store-dir``
replays the searches and only the simulation re-runs.
"""

from __future__ import annotations

from pathlib import Path

from repro.api import ExploreSpec, GAOptions, build_workload
from repro.core.ga import HWSpace, Objective
from repro.core.graph import graph_to_json
from repro.sim import cross_validate_trace, simulate_plan

from .common import POPULATION, Timer, compare_cached, emit

STRATEGIES = ["ga", "greedy", "dp", "sa"]

# one representative per workload-URI scheme (file: is exported on demand)
WORKLOADS = [
    ("netlib", "netlib:resnet50"),
    ("tpu", "tpu:gemma3-4b:0?tokens=2048"),
    ("synthetic", "synthetic:pyramid:24?seed=7"),
]


def _file_workload() -> str:
    out = Path("runs") / "bench" / "trace_diamond.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(graph_to_json(build_workload("synthetic:diamond:16?seed=5")))
    return f"file:{out}"


def main(budget: int = 2_000) -> None:
    for family, uri in WORKLOADS + [("file", _file_workload())]:
        spec = ExploreSpec(
            workload=uri,
            strategy="ga",
            objective=Objective(metric="ema", alpha=None),
            hw=HWSpace(mode="fixed"),
            sample_budget=budget,
            seed=0,
            options=GAOptions(population=min(POPULATION, 40)),
        )
        g = build_workload(uri)
        results = [r for r in compare_cached(spec, STRATEGIES, graph=g)
                   if r.plan is not None and r.plan.feasible]
        ranked = []
        for res in results:
            t = Timer()
            trace = simulate_plan(g, res.groups, res.acc,
                                  steps_per_subgraph=64)
            us = t.us
            report = cross_validate_trace(trace, res.plan)
            if not report.ok:
                raise AssertionError(
                    f"{family}/{res.strategy}: {report.summary()}")
            prof = trace.bandwidth_profile()
            ranked.append((prof.peak, prof, res, us))
        ranked.sort(key=lambda r: r[0])
        for rank, (peak, prof, res, us) in enumerate(ranked, start=1):
            emit(f"trace.{family}.{rank}.{res.strategy}", us,
                 f"peak={peak / 1e9:.2f}GB/s "
                 f"p95={prof.percentiles['p95'] / 1e9:.2f} "
                 f"sustained={prof.sustained / 1e9:.2f} "
                 f"EMA={prof.total_bytes / 1e6:.2f}MB xval=ok")


if __name__ == "__main__":
    from .common import configure

    configure()
    main()
