"""Roofline table from the dry-run artifacts (deliverable g).

Reads runs/dryrun/*.json (produced by `python -m repro.launch.dryrun --all
--out runs/dryrun`); if absent, runs two representative cells in a fresh
subprocess (the 512-device XLA flag must be set before jax init, so the
dry-run can never run inside this process)."""

from __future__ import annotations

import glob
import json
import os
import subprocess
import sys
from typing import Dict, List

from .common import Timer, emit

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "runs/dryrun2")
FALLBACK_CELLS = [("tinyllama-1.1b", "train_4k"), ("xlstm-350m", "decode_32k")]


def load_rows() -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def ensure_rows() -> List[Dict]:
    rows = load_rows()
    if rows:
        return rows
    os.makedirs(DRYRUN_DIR, exist_ok=True)
    for arch, shape in FALLBACK_CELLS:
        subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", "single", "--out", DRYRUN_DIR],
            check=False,
            env={**os.environ,
                 "PYTHONPATH": os.environ.get("PYTHONPATH", "src")},
        )
    return load_rows()


def main() -> None:
    t = Timer()
    rows = ensure_rows()
    ok = [r for r in rows if "bottleneck" in r]
    skipped = [r for r in rows if "skipped" in r]
    failed = [r for r in rows if "error" in r]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        emit(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}", t.us,
             f"tC={r['t_compute_ms']:.2f}ms tM={r['t_memory_ms']:.2f}ms "
             f"tX={r['t_collective_ms']:.2f}ms bound={r['bottleneck']} "
             f"frac={r['roofline_frac']:.3f} util={r['flops_util']:.3f}")
    emit("roofline.summary", t.us,
         f"{len(ok)} cells ok, {len(skipped)} documented skips, "
         f"{len(failed)} failed")


if __name__ == "__main__":
    main()
