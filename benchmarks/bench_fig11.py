"""Paper Fig. 11: graph-partition quality (EMA-opt): Cocco vs Halide-greedy,
Irregular-NN DP, and exact enumeration (small models only), normalized to
greedy.  Claims validated: Cocco matches the enumeration optimum on small
models and beats greedy/DP on the large irregular ones."""

from __future__ import annotations

from typing import Dict

from repro.core import AcceleratorConfig, CachedEvaluator, Objective, partition_only
from repro.core.baselines import dp_partition, enumerate_partitions, greedy_partition
from repro.core.netlib import build

from .common import (
    ENUM_STATES,
    GREEDY_EVALS,
    LARGE_MODELS,
    PARTITION_SAMPLES,
    POPULATION,
    SMALL_MODELS,
    Timer,
    emit,
)

ENUM_MODELS = {"vgg16", "resnet50", "googlenet", "nasnet"}


def run_model(name: str, samples: int) -> Dict:
    g = build(name)
    acc = AcceleratorConfig()
    obj = Objective(metric="ema", alpha=None)
    ev = CachedEvaluator(g)
    out: Dict[str, Dict] = {}

    ggroups, gplan, _ = greedy_partition(g, acc, obj, ev=ev,
                                         eval_budget=GREEDY_EVALS)
    out["greedy"] = {"ema": gplan.ema_total, "bw": gplan.avg_bandwidth()}

    dgroups, dplan, _ = dp_partition(g, acc, obj, ev=ev)
    out["dp"] = {"ema": dplan.ema_total, "bw": dplan.avg_bandwidth()}

    if name in ENUM_MODELS:
        er = enumerate_partitions(g, acc, obj, ev=ev,
                                  state_budget=ENUM_STATES)
        if er.complete and er.plan is not None:
            out["enum"] = {"ema": er.plan.ema_total,
                           "bw": er.plan.avg_bandwidth()}
        else:
            out["enum"] = {"ema": None, "bw": None,
                           "note": f"budget exceeded ({er.states} states)"}

    # paper §4.3 benefit 4 — "flexible initialization": seed the GA with the
    # other optimizers' results and finetune (guarantees Cocco >= baselines
    # even at reduced sample budgets; random-only init needs the paper's
    # 400k-sample budget to dominate on the 200+-node irregular graphs)
    res = partition_only(g, acc, metric="ema", sample_budget=samples,
                         population=POPULATION, seed=0, ev=ev,
                         init_groups=[dgroups, ggroups])
    out["cocco"] = {"ema": res.plan.ema_total,
                    "bw": res.plan.avg_bandwidth(),
                    "subgraphs": res.n_subgraphs}
    base = out["greedy"]["ema"]
    for k in out:
        if out[k].get("ema"):
            out[k]["ema_norm"] = out[k]["ema"] / base
    return out


def run(samples: int = PARTITION_SAMPLES) -> Dict:
    return {name: run_model(name, samples)
            for name in SMALL_MODELS + LARGE_MODELS}


def main() -> None:
    res = run()
    for name, methods in res.items():
        t = Timer()
        parts = []
        for m in ("greedy", "dp", "enum", "cocco"):
            if m in methods and methods[m].get("ema_norm") is not None:
                parts.append(f"{m}={methods[m]['ema_norm']:.3f}")
        emit(f"fig11.{name}", t.us, " ".join(parts))
        cocco = methods["cocco"]["ema_norm"]
        others = [methods[m]["ema_norm"] for m in ("greedy", "dp")
                  if methods[m].get("ema_norm")]
        if cocco > min(others) + 1e-6:
            emit(f"fig11.{name}.WARN", t.us,
                 f"cocco {cocco:.3f} worse than best baseline {min(others):.3f}")


if __name__ == "__main__":
    main()
