"""Paper Fig. 11: graph-partition quality (EMA-opt): Cocco vs Halide-greedy,
Irregular-NN DP, and exact enumeration (small models only), normalized to
greedy.  Claims validated: Cocco matches the enumeration optimum on small
models and beats greedy/DP on the large irregular ones.

All methods run through the unified exploration API as one spec batch per
model (`compare_cached`): every leg is a fully-specified ExploreSpec, so the
sweep is spec-addressed in the result store and resumable, and the legs fan
out over worker processes under ``--jobs``."""

from __future__ import annotations

from dataclasses import replace
from typing import Dict

from repro.api import (
    EnumOptions,
    ExploreSpec,
    GAOptions,
    GreedyOptions,
)
from repro.core import AcceleratorConfig, HWSpace, Objective
from repro.core.netlib import build

from .common import (
    ENUM_STATES,
    GREEDY_EVALS,
    LARGE_MODELS,
    PARTITION_SAMPLES,
    POPULATION,
    SMALL_MODELS,
    Timer,
    compare_cached,
    emit,
    new_evaluator,
)

ENUM_MODELS = {"vgg16", "resnet50", "googlenet", "nasnet"}


def run_model(name: str, samples: int) -> Dict:
    g = build(name)
    ev = new_evaluator(g)
    base = ExploreSpec(
        workload=name,
        objective=Objective(metric="ema", alpha=None),
        hw=HWSpace(mode="fixed", base=AcceleratorConfig()),
        sample_budget=samples,
        seed=0,
    )
    specs = [
        replace(base, strategy="greedy",
                options=GreedyOptions(eval_budget=GREEDY_EVALS)),
        replace(base, strategy="dp", options=None),
    ]
    if name in ENUM_MODELS:
        specs.append(replace(base, strategy="enum",
                             options=EnumOptions(state_budget=ENUM_STATES)))
    # paper §4.3 benefit 4 — "flexible initialization": seed the GA with the
    # other optimizers' results and finetune.  seed_from keeps the seeding
    # inside the spec, so this leg is store-addressable like the rest; the
    # seeds re-run dp/greedy with *default* options, which in reduced mode
    # are >= this benchmark's budgets (so Cocco >= both baselines below and
    # the WARN never fires).  In FULL mode the reported greedy is unbounded
    # while the seed greedy is budget-capped — there the GA's own 400k
    # samples, not the seed, carry the paper's claim, and the WARN check
    # still guards the result.
    specs.append(replace(base, strategy="ga",
                         options=GAOptions(population=POPULATION,
                                           seed_from=("dp", "greedy"))))
    try:
        results = {r.strategy: r for r in compare_cached(base, specs,
                                                         graph=g, ev=ev)}
    finally:
        ev.close()  # release --eval-jobs worker pools between models

    out: Dict[str, Dict] = {}
    greedy = results["greedy"]
    out["greedy"] = {"ema": greedy.plan.ema_total,
                     "bw": greedy.plan.avg_bandwidth()}
    dp = results["dp"]
    out["dp"] = {"ema": dp.plan.ema_total, "bw": dp.plan.avg_bandwidth()}
    if name in ENUM_MODELS:
        er = results["enum"]
        if er.meta["complete"] and er.plan is not None:
            out["enum"] = {"ema": er.plan.ema_total,
                           "bw": er.plan.avg_bandwidth()}
        else:
            out["enum"] = {"ema": None, "bw": None,
                           "note": f"budget exceeded ({er.meta['states']} states)"}
    cocco = results["ga"]
    out["cocco"] = {"ema": cocco.plan.ema_total,
                    "bw": cocco.plan.avg_bandwidth(),
                    "subgraphs": cocco.n_subgraphs}
    base_ema = out["greedy"]["ema"]
    for k in out:
        if out[k].get("ema"):
            out[k]["ema_norm"] = out[k]["ema"] / base_ema
    return out


def run_all(samples: int = PARTITION_SAMPLES) -> Dict:
    return {name: run_model(name, samples)
            for name in SMALL_MODELS + LARGE_MODELS}


def main() -> None:
    res = run_all()
    for name, methods in res.items():
        t = Timer()
        parts = []
        for m in ("greedy", "dp", "enum", "cocco"):
            if m in methods and methods[m].get("ema_norm") is not None:
                parts.append(f"{m}={methods[m]['ema_norm']:.3f}")
        emit(f"fig11.{name}", t.us, " ".join(parts))
        cocco = methods["cocco"]["ema_norm"]
        others = [methods[m]["ema_norm"] for m in ("greedy", "dp")
                  if methods[m].get("ema_norm")]
        if cocco > min(others) + 1e-6:
            emit(f"fig11.{name}.WARN", t.us,
                 f"cocco {cocco:.3f} worse than best baseline {min(others):.3f}")


if __name__ == "__main__":
    main()
