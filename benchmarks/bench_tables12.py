"""Paper Tables 1-2: hardware-mapping co-exploration with separate / shared
buffers.  Methods: fixed-HW (S/M/L) + partition-only, two-step RS+GA / GS+GA,
co-opt SA and Cocco.  Cost = Formula 2 (BUF_SIZE + alpha * energy),
alpha = 0.002, energy metric.  Claim: co-opt (Cocco) <= two-step <= fixed.

Every method is a registry strategy on the same ExploreSpec family, with one
shared CachedEvaluator per model.  Each model runs as two spec batches
(the HW searches, then the partition-only final-cost runs at every chosen
hardware point), so the whole table is store-addressed/resumable and
parallel under ``--jobs``."""

from __future__ import annotations

from dataclasses import replace
from typing import Dict

from repro.api import ExploreSpec, GAOptions, TwoStepOptions
from repro.core import AcceleratorConfig, HWSpace, Objective
from repro.core.netlib import build

from .common import (
    COOPT_MODELS,
    COOPT_SAMPLES,
    POPULATION,
    Timer,
    compare_cached,
    emit,
    new_evaluator,
)

KB = 1024
ALPHA = 0.002

FIXED = {
    "separate": {"S": (512 * KB, 576 * KB), "M": (1024 * KB, 1152 * KB),
                 "L": (2048 * KB, 2304 * KB)},
    "shared": {"S": (576 * KB, 0), "M": (1152 * KB, 0), "L": (2304 * KB, 0)},
}


def part_spec(g, acc, samples) -> ExploreSpec:
    """Paper §5.3.1: after choosing HW, run partition-only at that point."""
    return ExploreSpec(
        workload=g.name,
        strategy="ga",
        objective=Objective(metric="energy", alpha=None),
        hw=HWSpace(mode="fixed", base=acc),
        sample_budget=samples,
        seed=1,
        options=GAOptions(population=POPULATION),
    )


def run_model(name: str, mode: str, samples: int) -> Dict:
    g = build(name)
    ev = new_evaluator(g)
    try:
        return _run_model(g, ev, mode, samples)
    finally:
        ev.close()  # release --eval-jobs worker pools between models


def _run_model(g, ev, mode: str, samples: int) -> Dict:
    coopt = ExploreSpec(
        workload=g.name,
        strategy="ga",
        objective=Objective(metric="energy", alpha=ALPHA),
        hw=HWSpace(mode=mode),
        sample_budget=samples,
        seed=4,
        options=GAOptions(population=POPULATION),
    )
    part_budget = max(samples // 2, 1000)

    # phase 1: the hardware searches (two-step x2, SA, Cocco) as one batch
    search_specs = {
        "rs_ga": replace(coopt, strategy="two_step", seed=2,
                         options=TwoStepOptions(
                             sampler="random", capacity_samples=4,
                             samples_per_capacity=max(samples // 4, 500))),
        "gs_ga": replace(coopt, strategy="two_step", seed=2,
                         options=TwoStepOptions(
                             sampler="grid", capacity_samples=4,
                             samples_per_capacity=max(samples // 4, 500))),
        "sa": replace(coopt, strategy="sa", seed=3, options=None),
        "cocco": coopt,
    }
    searched = dict(zip(search_specs,
                        compare_cached(coopt, list(search_specs.values()),
                                       graph=g, ev=ev)))

    # phase 2: Formula-2 final cost at every chosen hardware point
    accs = {
        f"fixed_{tag}": AcceleratorConfig(glb_bytes=a, wbuf_bytes=w,
                                          shared=(mode == "shared"))
        for tag, (a, w) in FIXED[mode].items()
    }
    accs.update({tag: res.acc for tag, res in searched.items()})
    final_specs = [part_spec(g, acc, part_budget) for acc in accs.values()]
    finals = dict(zip(accs, compare_cached(final_specs[0], final_specs,
                                           graph=g, ev=ev)))

    out: Dict[str, Dict] = {}
    for tag, acc in accs.items():
        out[tag] = {
            "glb_kb": acc.glb_bytes // KB,
            "wbuf_kb": acc.wbuf_bytes // KB,
            "cost": acc.buf_size_total + ALPHA * finals[tag].plan.energy_pj,
        }
    return out


def run_all(mode: str, samples: int = COOPT_SAMPLES) -> Dict:
    return {m: run_model(m, mode, samples) for m in COOPT_MODELS}


def main() -> None:
    for mode, table in (("separate", "table1"), ("shared", "table2")):
        res = run_all(mode)
        for name, methods in res.items():
            t = Timer()
            best_base = min(v["cost"] for k, v in methods.items()
                            if k != "cocco")
            c = methods["cocco"]["cost"]
            emit(f"{table}.{name}", t.us,
                 f"cocco={c:.3e} best_baseline={best_base:.3e} "
                 f"improvement={(1 - c / best_base) * 100:.1f}% "
                 f"size={methods['cocco']['glb_kb']}KB+"
                 f"{methods['cocco']['wbuf_kb']}KB")


if __name__ == "__main__":
    main()
