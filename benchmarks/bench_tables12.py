"""Paper Tables 1-2: hardware-mapping co-exploration with separate / shared
buffers.  Methods: fixed-HW (S/M/L) + partition-only, two-step RS+GA / GS+GA,
co-opt SA and Cocco.  Cost = Formula 2 (BUF_SIZE + alpha * energy),
alpha = 0.002, energy metric.  Claim: co-opt (Cocco) <= two-step <= fixed.

Every method is a registry strategy on the same ExploreSpec family, with one
shared CachedEvaluator per model."""

from __future__ import annotations

from dataclasses import replace
from typing import Dict

from repro.api import ExploreSpec, GAOptions, TwoStepOptions, run
from repro.core import AcceleratorConfig, CachedEvaluator, HWSpace, Objective
from repro.core.netlib import build

from .common import COOPT_MODELS, COOPT_SAMPLES, POPULATION, Timer, emit

KB = 1024
ALPHA = 0.002

FIXED = {
    "separate": {"S": (512 * KB, 576 * KB), "M": (1024 * KB, 1152 * KB),
                 "L": (2048 * KB, 2304 * KB)},
    "shared": {"S": (576 * KB, 0), "M": (1152 * KB, 0), "L": (2304 * KB, 0)},
}


def final_cost(g, acc, ev, samples) -> float:
    """Paper §5.3.1: after choosing HW, run partition-only and report
    Formula-2 cost at that hardware point."""
    spec = ExploreSpec(
        workload=g.name,
        strategy="ga",
        objective=Objective(metric="energy", alpha=None),
        hw=HWSpace(mode="fixed", base=acc),
        sample_budget=samples,
        seed=1,
        options=GAOptions(population=POPULATION),
    )
    res = run(spec, graph=g, ev=ev)
    return acc.buf_size_total + ALPHA * res.plan.energy_pj


def run_model(name: str, mode: str, samples: int) -> Dict:
    g = build(name)
    ev = CachedEvaluator(g)
    coopt = ExploreSpec(
        workload=name,
        strategy="ga",
        objective=Objective(metric="energy", alpha=ALPHA),
        hw=HWSpace(mode=mode),
        sample_budget=samples,
        seed=4,
        options=GAOptions(population=POPULATION),
    )
    out: Dict[str, Dict] = {}
    part_budget = max(samples // 2, 1000)

    for tag, (a, w) in FIXED[mode].items():
        acc = AcceleratorConfig(glb_bytes=a, wbuf_bytes=w,
                                shared=(mode == "shared"))
        out[f"fixed_{tag}"] = {
            "glb_kb": a // KB, "wbuf_kb": w // KB,
            "cost": final_cost(g, acc, ev, part_budget),
        }

    for tag, sampler in (("rs_ga", "random"), ("gs_ga", "grid")):
        res = run(replace(coopt, strategy="two_step", seed=2,
                          options=TwoStepOptions(
                              sampler=sampler, capacity_samples=4,
                              samples_per_capacity=max(samples // 4, 500))),
                  graph=g)
        acc = res.acc
        out[tag] = {"glb_kb": acc.glb_bytes // KB,
                    "wbuf_kb": acc.wbuf_bytes // KB,
                    "cost": final_cost(g, acc, ev, part_budget)}

    res = run(replace(coopt, strategy="sa", seed=3, options=None),
              graph=g, ev=ev)
    out["sa"] = {"glb_kb": res.acc.glb_bytes // KB,
                 "wbuf_kb": res.acc.wbuf_bytes // KB,
                 "cost": final_cost(g, res.acc, ev, part_budget)}

    cres = run(coopt, graph=g, ev=ev)
    out["cocco"] = {"glb_kb": cres.acc.glb_bytes // KB,
                    "wbuf_kb": cres.acc.wbuf_bytes // KB,
                    "cost": final_cost(g, cres.acc, ev, part_budget)}
    return out


def run_all(mode: str, samples: int = COOPT_SAMPLES) -> Dict:
    return {m: run_model(m, mode, samples) for m in COOPT_MODELS}


def main() -> None:
    for mode, table in (("separate", "table1"), ("shared", "table2")):
        res = run_all(mode)
        for name, methods in res.items():
            t = Timer()
            best_base = min(v["cost"] for k, v in methods.items()
                            if k != "cocco")
            c = methods["cocco"]["cost"]
            emit(f"{table}.{name}", t.us,
                 f"cocco={c:.3e} best_baseline={best_base:.3e} "
                 f"improvement={(1 - c / best_base) * 100:.1f}% "
                 f"size={methods['cocco']['glb_kb']}KB+"
                 f"{methods['cocco']['wbuf_kb']}KB")


if __name__ == "__main__":
    main()
