"""Roofline machinery: HLO collective parsing, XLA scan-once behaviour
(the documented basis for the trip-count correction), report math."""

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import roofline


HLO_SAMPLE = """
ENTRY %main {
  %p0 = bf16[16,512]{1,0} parameter(0)
  %ar = bf16[16,512]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[64,128]{1,0} all-gather(%p0), dimensions={0}
  %rs = bf16[8,512]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(%ag), source_target_pairs={{0,1}}
  %a2a = bf16[2,2]{1,0} all-to-all(%rs), dimensions={0}
}
"""


def test_collective_parser_finds_all_kinds():
    total, per = roofline.collective_bytes(HLO_SAMPLE)
    assert set(per) == {"all-reduce", "all-gather", "reduce-scatter",
                        "collective-permute", "all-to-all"}
    # all-reduce is wire-weighted 2x
    assert per["all-reduce"] == 2 * 16 * 512 * 2
    assert per["all-gather"] == 64 * 128 * 4
    assert per["reduce-scatter"] == 8 * 512 * 2
    assert total == sum(per.values())


def test_parser_ignores_non_collectives():
    text = "%d = f32[128,128]{1,0} dot(%a, %b)"
    total, per = roofline.collective_bytes(text)
    assert total == 0 and per == {}


def test_xla_counts_scan_body_once():
    """The premise of the trip-count correction: module-level cost analysis
    does not multiply while-loop bodies by trip count."""
    w = jnp.ones((64, 64))

    def loop(n):
        def f(x):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y
        return jax.jit(f).lower(jnp.ones((64, 64))).compile()

    ca2 = loop(2).cost_analysis()
    ca8 = loop(8).cost_analysis()
    if isinstance(ca2, list):
        ca2, ca8 = ca2[0], ca8[0]
    f2, f8 = ca2.get("flops", 0), ca8.get("flops", 0)
    assert f2 == f8, "XLA now multiplies trip counts: drop scan_correction"


def test_scan_correction_positive_for_scanned_arch():
    cfg = get_config("glm4-9b")
    xf, xb = roofline.scan_correction(cfg, "train", 4096, 256, 256)
    assert xf > 0 and xb > 0
    pre, p, reps, rem = cfg.layout()
    # correction carries (reps-1) bodies: at least that multiple of one body
    one_layer = roofline.layer_flops(cfg, pre, 4096 * 256, 2048, "train") / 256
    assert xf == pytest.approx((reps - 1) * one_layer, rel=1e-6)


def test_report_terms_and_bottleneck():
    rep = roofline.RooflineReport(
        arch="a", shape="s", mesh="m", n_devices=256,
        hlo_flops=197e12 * 0.1,         # 100 ms of compute? no: 0.1 s
        hlo_bytes=819e9 * 0.01,
        coll_bytes=50e9 * 0.002,
        model_flops=197e12 * 0.05 * 256,
    )
    assert rep.t_compute == pytest.approx(0.1)
    assert rep.t_memory == pytest.approx(0.01)
    assert rep.t_collective == pytest.approx(0.002)
    assert rep.bottleneck == "compute"
    assert rep.roofline_fraction == pytest.approx(0.5)
    assert rep.flops_utilization == pytest.approx(0.5)


def test_model_flops_moe_uses_active_params():
    cfg = get_config("deepseek-v2-236b")
    full = cfg.param_count()
    active = cfg.active_param_count()
    assert active < full / 3
    mf = roofline.model_flops_for(cfg, "train", 4096, 256)
    assert mf == pytest.approx(6.0 * active * 4096 * 256)
