"""The telemetry layer's hard invariant and its exporters.

The invariant (ISSUE: observability): telemetry is a *side channel*.
With a recorder installed or absent, every strategy returns a bitwise
identical ``ExploreResult``, golden artifacts stay byte-identical, and
the store writes the same bytes.  On top of that: the recorder's span
tree has a pinned shape for a seeded GA run, the Perfetto exporters emit
schema-valid Chrome trace-event JSON, and the plan server's ``/metrics``
endpoint serves parseable Prometheus text whose counters are monotone.
"""

import json
import math
import sys
import urllib.request
from pathlib import Path

import pytest

from repro.api import ExploreSpec, GAOptions, run
from repro.api.store import ResultStore, spec_key
from repro.obs import (
    Histogram,
    NullRecorder,
    Recorder,
    chrome_trace_doc,
    recorder_events,
    render_metrics,
    traffic_events,
)
from repro.obs import recorder as obs
from repro.serve.plans import PlanService, fetch_metrics, serve_in_thread
from test_golden_workloads import canonical_dict, golden_path, golden_spec

REPO_ROOT = Path(__file__).resolve().parents[1]

ALL_STRATEGIES = ("dp", "enum", "ga", "greedy", "sa", "two_step")


def small_spec(strategy: str, **kw) -> ExploreSpec:
    kw.setdefault("workload", "synthetic:chain:6?seed=1")
    kw.setdefault("sample_budget", 200)
    kw.setdefault("seed", 0)
    return ExploreSpec(strategy=strategy, **kw)


def ga_spec() -> ExploreSpec:
    return ExploreSpec(workload="synthetic:layered:10?seed=2",
                       strategy="ga", sample_budget=150, seed=0,
                       options=GAOptions(population=10))


def validate_telemetry(doc):
    sys.path.insert(0, str(REPO_ROOT / "scripts"))
    try:
        from check_telemetry_schema import validate_telemetry_dict
    finally:
        sys.path.pop(0)
    return validate_telemetry_dict(doc)


# ---------------------------------------------------------------------------
# recorder primitives
# ---------------------------------------------------------------------------

def test_spans_nest_and_appear_in_entry_order():
    rec = Recorder()
    with rec.span("a"):
        with rec.span("b", k=1):
            rec.add("hits")
        with rec.span("c"):
            pass
    assert [sp.name for sp in rec.spans] == ["a", "b", "c"]
    assert [sp.parent for sp in rec.spans] == [-1, 0, 0]
    assert all(sp.parent < sp.index for sp in rec.spans)
    assert all(sp.dur_s >= 0 for sp in rec.spans)
    assert rec.spans[1].attrs == {"k": 1}
    assert rec.counters == {"hits": 1}
    assert rec.span_tree() == [
        {"name": "a", "children": [
            {"name": "b", "children": []},
            {"name": "c", "children": []},
        ]}]


def test_span_stack_unwinds_through_exceptions():
    rec = Recorder()
    with pytest.raises(RuntimeError):
        with rec.span("outer"):
            with rec.span("inner"):
                raise RuntimeError("boom")
    with rec.span("after"):
        pass
    assert rec.spans[-1].name == "after"
    assert rec.spans[-1].parent == -1     # stack fully unwound


def test_merge_counters_skips_non_numeric_and_bools():
    rec = Recorder()
    rec.merge_counters({"n": 2, "flag": True, "name": "x", "f": 1.5},
                       prefix="ev.")
    assert rec.counters == {"ev.n": 2, "ev.f": 1.5}


def test_null_recorder_is_inert_and_ambient_by_default():
    assert isinstance(obs.current(), NullRecorder)
    assert not obs.enabled()
    with obs.span("nothing", k=1):
        obs.add("x")
        obs.sample("y", 2.0)
    rec = Recorder()
    with obs.recording(rec):
        assert obs.current() is rec
        with obs.span("real"):
            obs.add("x")
    assert not obs.enabled()
    assert [sp.name for sp in rec.spans] == ["real"]
    assert rec.counters == {"x": 1}


# ---------------------------------------------------------------------------
# histogram + prometheus text
# ---------------------------------------------------------------------------

def test_histogram_exact_count_sum_max_and_cumulative_buckets():
    h = Histogram(buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    assert h.count == 5
    assert h.total == pytest.approx(56.05)
    assert h.max == 50.0
    assert h.cumulative() == [(0.1, 1), (1.0, 3), (10.0, 4),
                              (math.inf, 5)]
    # quantiles interpolate inside a bucket; the +Inf bucket reports max
    assert 0.1 <= h.quantile(0.5) <= 1.0
    assert h.quantile(0.99) == 50.0
    snap = h.snapshot_ms()
    assert set(snap) == {"count", "mean_ms", "max_ms", "p50_ms", "p95_ms"}
    assert snap["count"] == 5 and snap["max_ms"] == 50_000.0


def test_empty_histogram_snapshot_is_zeroed():
    snap = Histogram().snapshot_ms()
    assert snap == {"count": 0, "mean_ms": 0.0, "max_ms": 0.0,
                    "p50_ms": 0.0, "p95_ms": 0.0}


def test_histogram_never_drops_samples_unlike_the_old_window():
    # the regression that motivated the migration: 10k observations, the
    # quantile must reflect all of them, not the last 512
    h = Histogram()
    for i in range(10_000):
        h.observe(0.001 if i < 9_000 else 20.0)
    assert h.count == 10_000
    assert h.quantile(0.5) <= 0.001   # old window would report 20.0
    assert h.quantile(0.95) > 1.0


def test_render_metrics_text_format():
    h = Histogram(buckets=(1.0,))
    h.observe(0.5)
    h.observe(2.0)
    text = render_metrics([
        ("t_total", "counter", "Things.", [({"tier": "a"}, 3)]),
        ("g", "gauge", "A gauge.", [(None, 1.5)]),
        ("lat", "histogram", "Latency.", [({"tier": "a"}, h)]),
    ])
    lines = text.splitlines()
    assert "# TYPE t_total counter" in lines
    assert 't_total{tier="a"} 3' in lines
    assert "g 1.5" in lines
    assert 'lat_bucket{le="1",tier="a"} 1' in lines
    assert 'lat_bucket{le="+Inf",tier="a"} 2' in lines
    assert 'lat_sum{tier="a"} 2.5' in lines
    assert 'lat_count{tier="a"} 2' in lines
    assert text.endswith("\n")


# ---------------------------------------------------------------------------
# the hard invariant: recorder on/off => bitwise identical results
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_recorder_does_not_perturb_results(strategy):
    # the golden synthetic workload, one run per registered strategy
    wl = "synthetic:layered:24?seed=7"
    plain = run(small_spec(strategy, workload=wl), store=None)
    rec = Recorder()
    with obs.recording(rec):
        recorded = run(small_spec(strategy, workload=wl), store=None)
    assert recorded.to_json() == plain.to_json()
    # ... and the recorder actually saw the run
    assert [sp.name for sp in rec.spans if sp.parent == -1] == \
        ["resolve-workload", f"strategy:{strategy}"]


def test_profile_view_rides_the_recorder_without_perturbing_results():
    plain = run(small_spec("ga"), store=None)
    profiled = run(small_spec("ga"), store=None, profile=True)
    prof = profiled.meta.pop("profile")
    assert profiled.to_json() == plain.to_json()
    assert prof["wall_s"] > 0
    assert "lookups" in prof


def test_golden_artifact_is_byte_identical_with_telemetry_on():
    spec = golden_spec("synthetic_layered24", "ga")
    golden = json.loads(
        golden_path("synthetic_layered24", "ga").read_text())
    rec = Recorder()
    with obs.recording(rec):
        got = canonical_dict(run(spec))
    assert got == golden
    assert rec.spans      # telemetry was live during the golden run


def test_store_writes_identical_bytes_with_telemetry_on(tmp_path):
    def artifact_bytes(root: Path) -> dict:
        return {p.relative_to(root): p.read_bytes()
                for p in sorted(root.rglob("*.json"))}

    spec = small_spec("ga")
    run(spec, store=ResultStore(tmp_path / "off"))
    with obs.recording(Recorder()):
        run(spec, store=ResultStore(tmp_path / "on"))
    off = artifact_bytes(tmp_path / "off")
    on = artifact_bytes(tmp_path / "on")
    assert off and off == on
    assert spec_key(spec) == spec_key(small_spec("ga"))


# ---------------------------------------------------------------------------
# pinned span-tree shape + per-generation samples for a seeded GA run
# ---------------------------------------------------------------------------

def test_ga_span_tree_shape_is_pinned():
    rec = Recorder()
    with obs.recording(rec):
        run(ga_spec(), store=None)
    tree = rec.span_tree()
    assert [n["name"] for n in tree] == ["resolve-workload", "strategy:ga"]
    gens = tree[1]["children"]
    assert [n["name"] for n in gens] == ["ga.generation"] * 15
    # generation 0 evaluates the seed population plus repaired variants
    assert [c["name"] for c in gens[0]["children"]] == \
        ["evaluate_batch", "evaluate_batch"]
    # every generation with cache misses nests its batch under itself
    for gen in gens:
        assert all(c["name"] == "evaluate_batch" for c in gen["children"])
    series = {name for name, _, _ in rec.samples}
    assert series == {"ga.best_cost", "ga.mean_cost", "ga.diversity"}
    n_best = sum(1 for name, _, _ in rec.samples if name == "ga.best_cost")
    assert n_best == len(gens)
    assert rec.counters["evaluator.lookups"] > 0
    assert rec.counters["repair.rounds"] > 0


# ---------------------------------------------------------------------------
# perfetto / chrome trace-event export
# ---------------------------------------------------------------------------

def test_recorder_export_is_schema_valid_chrome_trace():
    rec = Recorder()
    with obs.recording(rec):
        run(ga_spec(), store=None)
    doc = chrome_trace_doc(recorder_events(rec), counters=rec.counters,
                           meta={"kind": "search"})
    assert validate_telemetry(doc) == []
    json.dumps(doc)    # exporter output must be JSON-serializable
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} >= {"resolve-workload", "strategy:ga",
                                       "ga.generation", "evaluate_batch"}
    cs = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert {e["name"] for e in cs} == {"ga.best_cost", "ga.mean_cost",
                                       "ga.diversity"}


def test_traffic_export_is_schema_valid_chrome_trace():
    from repro.api import build_workload
    from repro.sim import simulate_plan

    res = run(small_spec("greedy"), store=None)
    g = build_workload(res.spec.workload)
    trace = simulate_plan(g, res.groups, res.acc)
    doc = chrome_trace_doc(traffic_events(trace),
                           meta={"kind": "traffic"})
    assert validate_telemetry(doc) == []
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == len(trace.steps)
    # the time base is the accelerator clock: last event ends at makespan
    scale = 1e6 / trace.acc.freq_hz
    assert max(e["ts"] + e["dur"] for e in xs) == \
        pytest.approx(trace.total_cycles * scale, rel=1e-6)
    counters = {e["name"] for e in doc["traceEvents"] if e["ph"] == "C"}
    assert counters == {"DRAM bytes", "NoC bytes", "occupancy"}


# ---------------------------------------------------------------------------
# /metrics endpoint
# ---------------------------------------------------------------------------

def parse_prom(text: str) -> dict:
    out = {}
    for line in text.splitlines():
        assert line, "blank lines are not part of the exposition"
        if line.startswith("#"):
            parts = line.split(None, 3)
            assert parts[1] in ("HELP", "TYPE") and len(parts) == 4
            continue
        key, raw = line.rsplit(" ", 1)
        out[key] = float(raw)
    return out


def test_metrics_endpoint_parses_and_counters_are_monotone(tmp_path):
    svc = PlanService(ResultStore(tmp_path / "store"))
    server = serve_in_thread(svc)
    try:
        spec = small_spec("greedy")
        body = spec.to_json().encode()
        for _ in range(2):
            req = urllib.request.Request(
                server.url + "/plan", data=body,
                headers={"Content-Type": "application/json"},
                method="POST")
            with urllib.request.urlopen(req, timeout=600) as resp:
                assert json.loads(resp.read())["ok"]
        with urllib.request.urlopen(server.url + "/metrics",
                                    timeout=30) as resp:
            ctype = resp.headers["Content-Type"]
            m1 = parse_prom(resp.read().decode())
        assert ctype.startswith("text/plain")
        assert m1["repro_plan_requests_total"] == 2
        assert m1['repro_plan_served_total{tier="search"}'] == 1
        assert m1['repro_plan_served_total{tier="store"}'] == 1
        for tier in ("zoo", "store", "search"):
            key = ('repro_plan_request_latency_seconds_count'
                   f'{{tier="{tier}"}}')
            assert key in m1
            # bucket counts are cumulative in le and end at _count
            buckets = [v for k, v in m1.items()
                       if k.startswith('repro_plan_request_latency_'
                                       f'seconds_bucket{{le=')
                       and f'tier="{tier}"' in k]
            assert buckets == sorted(buckets)
            assert buckets[-1] == m1[key]
        assert m1['repro_store_entries{tier="store"}'] == 1
        assert m1['repro_store_bytes{tier="store"}'] > 0

        # a third request: counters only move forward
        req = urllib.request.Request(
            server.url + "/plan", data=body,
            headers={"Content-Type": "application/json"}, method="POST")
        with urllib.request.urlopen(req, timeout=600) as resp:
            assert json.loads(resp.read())["served_from"] == "store"
        m2 = parse_prom(fetch_metrics(server.url))
        for key, v1 in m1.items():
            if any(s in key for s in ("_total", "_count", "_bucket",
                                      "_sum")):
                assert m2[key] >= v1, key
        assert m2["repro_plan_requests_total"] == 3
        # the back-compat JSON view still mirrors the same histograms
        with urllib.request.urlopen(server.url + "/stats",
                                    timeout=30) as resp:
            stats = json.loads(resp.read())["server"]
        assert set(stats["latency_ms"]) == {"zoo", "store", "search"}
        assert stats["latency_ms"]["store"]["count"] == \
            m2['repro_plan_request_latency_seconds_count{tier="store"}']
    finally:
        server.close()
