"""Workload resolver: URI schemes (`netlib:`/`tpu:`/`synthetic:`/`file:`),
registry openness, spec-time validation, Graph JSON round-trip, and
property-based invariants over the `synthetic:` generators."""

import math
import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.api import (
    ExploreSpec,
    GreedyOptions,
    build_workload,
    graph_fingerprint,
    list_workloads,
    parse_workload,
    register_workload_scheme,
    run,
    workload_schemes,
)
from repro.core import AcceleratorConfig, CachedEvaluator, HWSpace, Objective
from repro.core.graph import (
    Graph,
    graph_from_json,
    graph_to_dict,
    graph_to_json,
)
from repro.core.cost import compute_structure, evaluate_subgraph, finish_cost
from repro.core.partition import is_valid, partition_of, random_partition, split_to_fit

KB = 1 << 10

SYNTH_KINDS = ("layered", "branchy", "diamond", "chain", "pyramid")


def greedy_spec(uri, **kw):
    defaults = dict(
        workload=uri,
        strategy="greedy",
        objective=Objective(metric="ema", alpha=None),
        hw=HWSpace(mode="fixed"),
        sample_budget=200,
        seed=0,
        options=GreedyOptions(eval_budget=2_000),
    )
    defaults.update(kw)
    return ExploreSpec(**defaults)


# ---------------------------------------------------------------------------
# URI parsing + registry
# ---------------------------------------------------------------------------

def test_bare_name_aliases_to_netlib():
    assert parse_workload("resnet50") == ("netlib", "resnet50", {})
    assert graph_fingerprint(build_workload("resnet50")) == \
        graph_fingerprint(build_workload("netlib:resnet50"))


def test_unknown_scheme_and_model_errors():
    with pytest.raises(ValueError, match="unknown workload scheme"):
        build_workload("bogus:thing")
    with pytest.raises(ValueError, match="unknown netlib model"):
        build_workload("netlib:nope")
    with pytest.raises(ValueError, match="unknown netlib model"):
        build_workload("nope")                      # bare alias, same table
    with pytest.raises(ValueError, match="empty workload"):
        build_workload("")


def test_query_string_is_strictly_parsed():
    with pytest.raises(ValueError, match="unknown params"):
        build_workload("synthetic:layered:8?sneed=3")     # typo'd key
    with pytest.raises(ValueError, match="duplicate workload param"):
        build_workload("synthetic:layered:8?seed=1&seed=2")
    with pytest.raises(ValueError, match="not an integer"):
        build_workload("synthetic:layered:8?seed=x")
    with pytest.raises(ValueError, match="bad workload query"):
        build_workload("synthetic:layered:8?seed")


def test_register_custom_scheme_resolves_through_run():
    @register_workload_scheme("twonode", syntax="twonode:<label>",
                              description="test scheme")
    def _build(rest, params):
        g = Graph(f"twonode:{rest}")
        a = g.add_node("a", 8, 64, weight_bytes=256, macs=1000)
        b = g.add_node("b", 8, 64, weight_bytes=256, macs=1000,
                       is_output=True)
        g.add_edge(a, b)
        return g

    assert "twonode" in [s.name for s in workload_schemes()]
    res = run(greedy_spec("twonode:x"))
    assert res.feasible and sum(len(s) for s in res.groups) == 2


def test_spec_validation_rejects_bad_uris_and_keeps_labels():
    # registered schemes get full syntax validation at spec construction
    with pytest.raises(ValueError, match="bad workload query"):
        ExploreSpec(workload="synthetic:layered:8?seed")
    with pytest.raises(ValueError, match="duplicate workload param"):
        ExploreSpec(workload="synthetic:layered:8?seed=1&seed=2")
    with pytest.raises(ValueError, match="empty workload"):
        ExploreSpec(workload="")
    # free-form labels (custom graphs passed via graph=, pre-resolver
    # artifacts) remain legal — with or without a colon; an unregistered
    # prefix only fails when something tries to *resolve* it
    assert ExploreSpec(workload="dd").workload == "dd"
    spec = ExploreSpec(workload="experiment:v2")
    with pytest.raises(ValueError, match="unknown workload scheme"):
        run(spec)


def test_list_workloads_enumerates_every_scheme():
    uris = [u for u, _ in list_workloads()]
    assert "netlib:resnet50" in uris
    assert any(u.startswith("tpu:gemma3-4b:") for u in uris)
    assert any(u.startswith("synthetic:layered:") for u in uris)
    assert any(u.startswith("file:") for u in uris)
    only_tpu = [u for u, _ in list_workloads("tpu")]
    assert only_tpu and all(u.startswith("tpu:") for u in only_tpu)
    with pytest.raises(ValueError, match="unknown workload scheme"):
        list_workloads("bogus")


# ---------------------------------------------------------------------------
# tpu: scheme
# ---------------------------------------------------------------------------

def test_tpu_scheme_builds_block_graphs_with_params():
    g = build_workload("tpu:gemma3-4b:0?tokens=512")
    assert g.n > 5 and any(v.is_output for v in g.nodes)
    assert g.nodes[0].out_len == 512                  # rows = tokens
    # underscore alias resolves to the same config
    assert graph_fingerprint(g) == \
        graph_fingerprint(build_workload("tpu:gemma3_4b:0?tokens=512"))
    # tokens and tp both change the graph (and hence the fingerprint)
    assert graph_fingerprint(g) != \
        graph_fingerprint(build_workload("tpu:gemma3-4b:0?tokens=256"))
    assert graph_fingerprint(g) != \
        graph_fingerprint(build_workload("tpu:gemma3-4b:0?tokens=512&tp=8"))


def test_tpu_scheme_errors():
    with pytest.raises(ValueError, match="unknown tpu config"):
        build_workload("tpu:notamodel:0")
    with pytest.raises(ValueError, match="out of range"):
        build_workload("tpu:gemma3-4b:999")
    with pytest.raises(ValueError, match="needs a layer index"):
        build_workload("tpu:gemma3-4b")
    with pytest.raises(ValueError, match="must be an integer"):
        build_workload("tpu:gemma3-4b:first")
    with pytest.raises(ValueError, match="unknown params"):
        build_workload("tpu:gemma3-4b:0?token=512")


# ---------------------------------------------------------------------------
# synthetic: scheme
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", SYNTH_KINDS)
def test_synthetic_deterministic_and_seed_sensitive(kind):
    a = build_workload(f"synthetic:{kind}:16?seed=4")
    b = build_workload(f"synthetic:{kind}:16?seed=4")
    assert graph_fingerprint(a) == graph_fingerprint(b)
    assert a.n == 16
    other = build_workload(f"synthetic:{kind}:16?seed=5")
    assert graph_fingerprint(a) != graph_fingerprint(other)


def test_pyramid_has_nonuniform_rows_and_multi_input_merges():
    g = build_workload("synthetic:pyramid:24?seed=3")
    # rows halve level by level -> several distinct row counts
    assert len({v.out_len for v in g.nodes}) > 2
    merges = [v for v in range(g.n) if len(g.in_edges(v)) >= 2]
    assert merges
    # at least one merge mixes producers of *different* row counts
    # (a skip edge from an earlier pyramid level)
    assert any(len({g.nodes[e.src].out_len for e in g.in_edges(v)}) > 1
               for v in merges)
    # every window stays inside its producer: F + (out_len-1)*s <= src rows
    for e in g.edges:
        need = e.F + (g.nodes[e.dst].out_len - 1) * e.s
        assert need <= g.nodes[e.src].out_len, (e, need)


def test_synthetic_errors():
    with pytest.raises(ValueError, match="unknown synthetic kind"):
        build_workload("synthetic:spiral:8")
    with pytest.raises(ValueError, match="needs a node count"):
        build_workload("synthetic:layered")
    with pytest.raises(ValueError, match="n >= 2"):
        build_workload("synthetic:layered:1")


# ---------------------------------------------------------------------------
# file: scheme + Graph JSON round-trip
# ---------------------------------------------------------------------------

def test_graph_json_roundtrip_is_lossless():
    g = build_workload("synthetic:branchy:12?seed=9")
    g2 = graph_from_json(graph_to_json(g))
    assert graph_fingerprint(g) == graph_fingerprint(g2)
    assert g2.name == g.name
    assert [v.name for v in g2.nodes] == [v.name for v in g.nodes]
    assert [(v.out_len, v.line_bytes, v.weight_bytes, v.macs, v.is_output)
            for v in g2.nodes] == \
           [(v.out_len, v.line_bytes, v.weight_bytes, v.macs, v.is_output)
            for v in g.nodes]


def test_file_scheme_resolves_and_validates(tmp_path):
    g = build_workload("synthetic:diamond:10?seed=2")
    path = tmp_path / "net.json"
    path.write_text(graph_to_json(g))
    loaded = build_workload(f"file:{path}")
    assert graph_fingerprint(loaded) == graph_fingerprint(g)

    with pytest.raises(ValueError, match="not found"):
        build_workload(f"file:{tmp_path / 'missing.json'}")
    bad = tmp_path / "bad.json"
    bad.write_text("{ not json")
    with pytest.raises(ValueError, match="invalid graph JSON"):
        build_workload(f"file:{bad}")
    wrong = tmp_path / "wrong.json"
    wrong.write_text('{"format": "other", "version": 1}')
    with pytest.raises(ValueError, match="not a cocco-graph"):
        build_workload(f"file:{wrong}")
    d = graph_to_dict(g)
    d["version"] = 99
    stale = tmp_path / "stale.json"
    import json as _json
    stale.write_text(_json.dumps(d))
    with pytest.raises(ValueError, match="unsupported cocco-graph version"):
        build_workload(f"file:{stale}")
    # malformed netlists are load-time errors, not silent wrong costs
    bad_dims = graph_to_dict(g)
    bad_dims["nodes"][0]["out_len"] = 0
    p = tmp_path / "dims.json"
    p.write_text(_json.dumps(bad_dims))
    with pytest.raises(ValueError, match="invalid dimensions"):
        build_workload(f"file:{p}")
    bad_kind = graph_to_dict(g)
    bad_kind["edges"][0]["kind"] = "Full"          # case matters
    p2 = tmp_path / "kind.json"
    p2.write_text(_json.dumps(bad_kind))
    with pytest.raises(ValueError, match="edge kind"):
        build_workload(f"file:{p2}")
    # missing required keys are ValueErrors naming the key, not KeyErrors
    missing = graph_to_dict(g)
    del missing["nodes"][0]["line_bytes"]
    p3 = tmp_path / "missing.json"
    p3.write_text(_json.dumps(missing))
    with pytest.raises(ValueError, match="missing required key 'line_bytes'"):
        build_workload(f"file:{p3}")


def test_file_scheme_explores_end_to_end(tmp_path):
    path = tmp_path / "net.json"
    path.write_text(graph_to_json(build_workload("synthetic:layered:10?seed=3")))
    res = run(greedy_spec(f"file:{path}"))
    assert res.feasible and res.workload == f"file:{path}"


# ---------------------------------------------------------------------------
# property-based invariants over synthetic: workloads
# (skipped, still collecting, when hypothesis is absent — see
#  tests/_hypothesis_compat)
# ---------------------------------------------------------------------------

@given(kind=st.sampled_from(SYNTH_KINDS), n=st.integers(2, 40),
       seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_property_synthetic_graphs_wellformed(kind, n, seed):
    """Generated graphs are DAGs with contiguous, topologically ordered
    node ids, at least one output, and a deterministic fingerprint."""
    uri = f"synthetic:{kind}:{n}?seed={seed}"
    g = build_workload(uri)
    assert g.n == n
    assert [v.idx for v in g.nodes] == list(range(n))   # contiguous ids
    for e in g.edges:
        assert 0 <= e.src < e.dst < n                   # acyclic by order
    assert any(v.is_output for v in g.nodes)
    assert all(v.out_len >= 1 and v.line_bytes >= 1 for v in g.nodes)
    # every non-source node is reachable: it has at least one in-edge
    sources = g.sources()
    assert all(g.in_edges(v) or v in sources for v in range(n))
    assert graph_fingerprint(g) == graph_fingerprint(build_workload(uri))


@given(kind=st.sampled_from(SYNTH_KINDS), n=st.integers(2, 24),
       seed=st.integers(0, 1_000), pseed=st.integers(0, 1_000))
@settings(max_examples=25, deadline=None)
def test_property_partition_cost_finite_and_kernel_pure(kind, n, seed, pseed):
    """Any legal partition of a synthetic graph evaluates to a finite cost,
    and the pure kernel identity holds exactly:
    ``evaluate_subgraph == finish_cost(compute_structure(...))``."""
    g = build_workload(f"synthetic:{kind}:{n}?seed={seed}")
    rng = random.Random(pseed)
    groups = random_partition(g, rng, mean_size=rng.uniform(1.5, 5.0))
    assert is_valid(g, partition_of(groups, g.n))
    acc = AcceleratorConfig()            # paper-default buffers dwarf these
    for s in groups:
        cost = evaluate_subgraph(g, set(s), acc)
        assert cost == finish_cost(compute_structure(g, set(s)), acc)
    plan = CachedEvaluator(g).plan(groups, acc)
    obj = Objective(metric="ema", alpha=None).cost(plan, acc)
    assert math.isfinite(obj) and obj >= 0
    assert math.isfinite(plan.energy_pj)


@given(kind=st.sampled_from(SYNTH_KINDS), n=st.integers(2, 24),
       seed=st.integers(0, 1_000), pseed=st.integers(0, 1_000))
@settings(max_examples=25, deadline=None)
def test_property_split_to_fit_never_over_capacity(kind, n, seed, pseed):
    """In-situ tuning under starvation-level buffers: every returned group
    fits (multi-node groups are feasible; singletons stream)."""
    g = build_workload(f"synthetic:{kind}:{n}?seed={seed}")
    rng = random.Random(pseed)
    groups = random_partition(g, rng, mean_size=4.0)
    acc = AcceleratorConfig(glb_bytes=2 * KB, wbuf_bytes=2 * KB)
    ev = CachedEvaluator(g)
    fitted = split_to_fit(g, groups, acc, ev=ev)
    assert sorted(v for s in fitted for v in s) == list(range(g.n))
    assert is_valid(g, partition_of(fitted, g.n))
    for s in fitted:
        assert ev.subgraph(set(s), acc).feasible, (sorted(s), acc)
