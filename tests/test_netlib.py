"""Paper workload graphs: structure, statistics, schedulability."""

import pytest

from repro.core import AcceleratorConfig, CachedEvaluator
from repro.core.netlib import PAPER_MODELS, build
from repro.core.partition import is_valid, partition_of, singleton_partition


@pytest.mark.parametrize("name", sorted(PAPER_MODELS))
def test_graph_wellformed(name):
    g = build(name)
    assert g.n > 5
    for e in g.edges:
        assert e.src < e.dst
    # exactly one model input (the virtual source), >=1 output
    assert len(g.sources()) >= 1
    assert any(v.is_output for v in g.nodes)


@pytest.mark.parametrize("name", sorted(PAPER_MODELS))
def test_singleton_plan_always_feasible(name):
    g = build(name)
    acc = AcceleratorConfig()
    ev = CachedEvaluator(g)
    plan = ev.plan(singleton_partition(g), acc)
    assert plan.feasible, [
        (s.nodes, s.reason) for s in plan.subgraphs if not s.feasible
    ]
    assert plan.ema_total > 0


def test_model_scale_ordering():
    """ResNet152 > ResNet50 in MACs; GPT > Transformer in weights."""
    r50, r152 = build("resnet50"), build("resnet152")
    tr, gp = build("transformer"), build("gpt")
    assert r152.total_macs() > r50.total_macs()
    assert gp.total_weight_bytes() > tr.total_weight_bytes()


def test_randwire_is_irregular_and_seeded():
    a1, a2 = build("randwire_a"), build("randwire_a")
    assert a1.n == a2.n and len(a1.edges) == len(a2.edges)  # deterministic
    b = build("randwire_b")
    # multi-input merge nodes exist (irregular wiring)
    multi = [v for v in range(a1.n) if len(a1.in_edges(v)) > 2]
    assert multi
    assert b.n != a1.n or b.total_weight_bytes() != a1.total_weight_bytes()


def test_single_netlib_table_no_drift():
    """PAPER_MODELS, netlib.build, and the `netlib:` workload scheme all
    consume one table: the names each surface accepts are identical."""
    from repro.core.netlib import list_models
    from repro.api import build_workload, list_workloads

    assert list_models() == sorted(PAPER_MODELS)
    resolver_names = [uri.split(":", 1)[1]
                      for uri, _ in list_workloads("netlib")]
    assert resolver_names == list_models()
    # build() and the resolver reject unknown names from the same table
    with pytest.raises(ValueError, match="unknown netlib model"):
        build("missing_model")
    with pytest.raises(ValueError, match="unknown netlib model"):
        build_workload("netlib:missing_model")


def test_large_models_have_enough_nodes_for_search():
    for name in ("transformer", "gpt", "randwire_a", "randwire_b", "nasnet"):
        g = build(name)
        assert g.n >= 50, (name, g.n)
