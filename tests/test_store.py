"""Spec-addressed `ResultStore` + parallel `compare`: hit/miss round-trips,
cross-process hash stability, serial/parallel result identity, and recovery
from corrupted store entries."""

import json
import subprocess
import sys
from pathlib import Path

import pytest
from conftest import small_graph

from repro.api import (
    ExploreSpec,
    GAOptions,
    GreedyOptions,
    ResultStore,
    compare,
    run,
    spec_key,
)
from repro.core import AcceleratorConfig, CachedEvaluator, HWSpace, Objective

KB = 1 << 10
REPO_SRC = Path(__file__).resolve().parents[1] / "src"


def fixed_spec(**kw):
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    defaults = dict(
        workload="dd",
        strategy="ga",
        objective=Objective(metric="ema", alpha=None),
        hw=HWSpace(mode="fixed", base=acc),
        sample_budget=300,
        seed=0,
        options=GAOptions(population=20),
    )
    defaults.update(kw)
    return ExploreSpec(**defaults)


# ---------------------------------------------------------------------------
# addressing
# ---------------------------------------------------------------------------

def test_spec_key_is_deterministic_and_spec_sensitive():
    a, b = fixed_spec(), fixed_spec()
    assert spec_key(a) == spec_key(b)
    assert len(spec_key(a)) == 64 and int(spec_key(a), 16) >= 0
    # any spec field change re-addresses the result
    assert spec_key(a) != spec_key(fixed_spec(seed=1))
    assert spec_key(a) != spec_key(fixed_spec(sample_budget=301))
    assert spec_key(a) != spec_key(fixed_spec(strategy="dp", options=None))
    assert spec_key(a) != spec_key(
        fixed_spec(options=GAOptions(population=21)))


def test_spec_key_stable_across_processes(tmp_path):
    """The store key must not depend on interpreter state (hash seeds,
    dict order): a fresh process hashing the same spec gets the same key."""
    spec = fixed_spec(workload="vgg16")
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(spec.to_json())
    code = (
        "import sys; sys.path.insert(0, sys.argv[1])\n"
        "from repro.api import ExploreSpec, spec_key\n"
        "print(spec_key(ExploreSpec.from_json(open(sys.argv[2]).read())))\n"
    )
    keys = {
        subprocess.run(
            [sys.executable, "-c", code, str(REPO_SRC), str(spec_path)],
            capture_output=True, text=True, check=True,
        ).stdout.strip()
        for _ in range(2)
    }
    assert keys == {spec_key(spec)}


def _scheme_uris(tmp_path):
    """One workload URI per built-in scheme (file: built on the fly)."""
    from repro.api import build_workload
    from repro.core.graph import graph_to_json

    file_path = tmp_path / "net.json"
    file_path.write_text(graph_to_json(
        build_workload("synthetic:diamond:10?seed=2")))
    return [
        "netlib:vgg16",
        "tpu:gemma3-4b:0?tokens=256",
        "synthetic:layered:12?seed=1",
        f"file:{file_path}",
    ]


def test_graph_fingerprint_stable_across_processes(tmp_path):
    """Every scheme must build the same graph — same structural digest — in
    a fresh interpreter, or the store's graph_sha replay check would
    spuriously reject cross-process artifacts."""
    from repro.api import build_workload, graph_fingerprint

    uris = _scheme_uris(tmp_path)
    code = (
        "import sys; sys.path.insert(0, sys.argv[1])\n"
        "from repro.api import build_workload, graph_fingerprint\n"
        "for uri in sys.argv[2:]:\n"
        "    print(graph_fingerprint(build_workload(uri)))\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code, str(REPO_SRC), *uris],
        capture_output=True, text=True, check=True)
    assert proc.stdout.split() == [
        graph_fingerprint(build_workload(uri)) for uri in uris]


def test_every_scheme_roundtrips_store_through_run_and_compare(tmp_path):
    """Acceptance: all four URI schemes resolve through api.run/compare and
    a second run of the same spec is a store hit with an identical
    ExploreResult."""
    from repro.api import GreedyOptions

    store = ResultStore(tmp_path / "store")
    for uri in _scheme_uris(tmp_path):
        spec = fixed_spec(workload=uri, strategy="greedy",
                          options=GreedyOptions(eval_budget=1_000))
        misses0, hits0 = store.misses, store.hits
        first = run(spec, store=store)
        assert first.feasible and store.misses == misses0 + 1
        again = run(spec, store=store)
        assert store.hits == hits0 + 1
        assert again.to_dict() == first.to_dict()
        # compare() on the same spec is served from the same addresses
        cmp_results = compare(spec, ["greedy", "dp"], store=store)
        assert cmp_results[0].to_dict() == first.to_dict()
        assert [r.strategy for r in cmp_results] == ["greedy", "dp"]


def test_file_workload_change_invalidates_store_hit(tmp_path):
    """file: URIs do not pin graph content, so a changed file under an
    unchanged URI must re-search, not replay the stale artifact."""
    from repro.api import GreedyOptions, build_workload
    from repro.core.graph import graph_to_json

    path = tmp_path / "net.json"
    path.write_text(graph_to_json(build_workload("synthetic:diamond:10?seed=2")))
    store = ResultStore(tmp_path / "store")
    spec = fixed_spec(workload=f"file:{path}", strategy="greedy",
                      options=GreedyOptions(eval_budget=1_000))
    first = run(spec, store=store)

    path.write_text(graph_to_json(build_workload("synthetic:layered:6?seed=9")))
    second = run(spec, store=store)
    assert second.meta["graph_sha"] != first.meta["graph_sha"]
    assert sum(len(s) for s in second.groups) == 6     # the *new* graph
    # the fresh artifact overwrote the stale one and now replays
    third = run(spec, store=store)
    assert third.to_dict() == second.to_dict()


# ---------------------------------------------------------------------------
# hit / miss round-trip
# ---------------------------------------------------------------------------

def test_store_miss_then_hit_roundtrip(tmp_path):
    store = ResultStore(tmp_path / "store")
    spec = fixed_spec()
    g = small_graph()

    first = run(spec, graph=g, store=store)
    assert store.misses == 1 and store.hits == 0
    assert spec in store and len(store) == 1

    second = run(spec, graph=g, store=store)
    assert store.hits == 1
    assert second.to_dict() == first.to_dict()

    # a different spec is a different address
    other = run(fixed_spec(seed=9), graph=g, store=store)
    assert other.cost is not None and len(store) == 2


def test_store_hit_skips_search_entirely(tmp_path):
    store = ResultStore(tmp_path)
    spec = fixed_spec()
    g = small_graph()
    run(spec, graph=g, store=store)

    ev = CachedEvaluator(g)
    replayed = run(spec, graph=g, ev=ev, store=store)
    assert ev.lookups == 0 and ev.evaluations == 0
    assert replayed.feasible


def test_runtime_extras_bypass_store(tmp_path):
    """init_groups is not part of the spec, so the result must not be
    stored under (or served from) the spec's address."""
    store = ResultStore(tmp_path)
    g = small_graph()
    groups = [set(range(g.n))]
    res = run(fixed_spec(), graph=g, store=store, init_groups=[groups])
    assert res.feasible
    assert len(store) == 0 and store.hits == 0 and store.misses == 0


# ---------------------------------------------------------------------------
# corruption recovery
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("payload", [
    "not json at all {", json.dumps({"version": 1, "nonsense": True}),
])
def test_corrupted_entry_is_quarantined_and_resurveyed(tmp_path, payload):
    store = ResultStore(tmp_path)
    spec = fixed_spec()
    g = small_graph()
    original = run(spec, graph=g, store=store)

    path = store.path_for(spec)
    path.write_text(payload)
    assert store.get(spec) is None                     # miss, not a crash
    assert path.with_suffix(".json.corrupt").exists()  # quarantined aside

    recovered = run(spec, graph=g, store=store)        # re-search + re-store
    assert recovered.to_dict() == original.to_dict()
    assert store.get(spec) is not None


def test_same_label_different_graph_does_not_replay(tmp_path):
    """Spec keys carry no graph identity, so a custom graph sharing another
    graph's workload label must not be served that graph's artifact."""
    from repro.core.graph import Graph

    store = ResultStore(tmp_path)
    spec = fixed_spec()
    cached = run(spec, graph=small_graph(), store=store)

    other = Graph("dd")
    a = other.add_node("a", 8, 256, weight_bytes=1024, macs=1000)
    b = other.add_node("b", 8, 256, weight_bytes=1024, macs=1000,
                       is_output=True)
    other.add_edge(a, b)
    res = run(spec, graph=other, store=store)
    assert res.groups != cached.groups          # searched, not replayed
    assert sum(len(s) for s in res.groups) == 2

    # the original graph still replays its own artifact
    again = run(spec, graph=small_graph(), store=store)
    assert again.meta["graph_sha"] == cached.meta["graph_sha"]


def test_entry_for_a_different_spec_is_rejected(tmp_path):
    """A valid artifact filed under the wrong key (hand-copied file) must
    not be served."""
    store = ResultStore(tmp_path)
    g = small_graph()
    spec_a, spec_b = fixed_spec(), fixed_spec(seed=5)
    run(spec_a, graph=g, store=store)
    store.path_for(spec_b).write_bytes(
        store.path_for(spec_a).read_bytes())
    assert store.get(spec_b) is None
    assert store.get(spec_a) is not None


# ---------------------------------------------------------------------------
# parallel compare
# ---------------------------------------------------------------------------

STRATS = ["greedy", "dp", "ga", "sa", "two_step"]


def serialized(results):
    return [r.to_dict() for r in results]


def test_parallel_compare_matches_serial_bitwise():
    spec = ExploreSpec(
        workload="vgg16",
        strategy="ga",
        objective=Objective(metric="ema", alpha=None),
        hw=HWSpace(mode="fixed"),
        sample_budget=300,
        seed=0,
        options=GAOptions(population=10),
    )
    serial = compare(spec, STRATS)
    parallel = compare(spec, STRATS, jobs=2)
    assert serialized(serial) == serialized(parallel)
    assert [r.strategy for r in parallel] == STRATS


def test_parallel_compare_merges_worker_caches():
    g = small_graph()
    ev = CachedEvaluator(g)
    compare(fixed_spec(), ["greedy", "dp"], graph=g, ev=ev, jobs=2)
    assert ev.merged > 0 and ev.evaluations == 0
    # the merged entries now serve a serial follow-up run
    lookups0 = ev.lookups
    res = run(fixed_spec(strategy="dp", options=None), graph=g, ev=ev)
    assert res.feasible
    assert ev.lookups > lookups0 and ev.evaluations < res.evaluations


def test_parallel_compare_second_pass_is_all_store_hits(tmp_path):
    store = ResultStore(tmp_path)
    spec = fixed_spec(options=GAOptions(population=10), sample_budget=200)
    g = small_graph()
    first = compare(spec, ["greedy", "dp", "ga"], graph=g, jobs=2,
                    store=store)
    assert store.misses == 3

    ev = CachedEvaluator(g)
    again = compare(spec, ["greedy", "dp", "ga"], graph=g, ev=ev, jobs=2,
                    store=store)
    assert store.hits == 3
    assert ev.evaluations == 0 and ev.merged == 0   # zero new search work
    assert serialized(again) == serialized(first)


def test_compare_accepts_full_specs_and_dedupes(tmp_path):
    store = ResultStore(tmp_path)
    g = small_graph()
    spec = fixed_spec(options=GAOptions(population=10), sample_budget=200)
    variants = [
        replace_strategy(spec, "greedy"),
        replace_strategy(spec, "greedy"),            # exact duplicate
        spec,
    ]
    results = compare(spec, variants, graph=g, jobs=2, store=store)
    assert [r.strategy for r in results] == ["greedy", "greedy", "ga"]
    assert results[0].to_dict() == results[1].to_dict()
    assert len(store) == 2                            # duplicate ran once


def replace_strategy(spec, name):
    from dataclasses import replace
    return replace(spec, strategy=name,
                   options=GreedyOptions() if name == "greedy" else None)


def test_compare_rejects_mismatched_workload_specs():
    spec = fixed_spec()
    with pytest.raises(ValueError, match="share the primary spec"):
        compare(spec, [fixed_spec(workload="other")], graph=small_graph())


# ---------------------------------------------------------------------------
# evaluation-count semantics (warmth independence)
# ---------------------------------------------------------------------------

def test_evaluations_independent_of_cache_warmth():
    g = small_graph()
    cold = run(fixed_spec(strategy="dp", options=None), graph=small_graph())
    ev = CachedEvaluator(g)
    run(fixed_spec(strategy="greedy",
                   options=GreedyOptions(eval_budget=500)), graph=g, ev=ev)
    warm = run(fixed_spec(strategy="dp", options=None), graph=g, ev=ev)
    assert warm.evaluations == cold.evaluations
    # and two_step now reports its per-capacity inner GA queries
    ts = run(fixed_spec(strategy="two_step", options=None,
                        sample_budget=200), graph=small_graph())
    assert ts.evaluations > 0


# ---------------------------------------------------------------------------
# maintenance: ls / gc (cross-run eviction)
# ---------------------------------------------------------------------------

def _fill_store(tmp_path, n=4):
    store = ResultStore(tmp_path / "store")
    g = small_graph()
    specs = [fixed_spec(strategy="greedy",
                        options=GreedyOptions(eval_budget=100 + i))
             for i in range(n)]
    for i, spec in enumerate(specs):
        run(spec, graph=g, store=store)
        # well-separated mtimes so LRU order is deterministic on coarse fs
        entry = store.path_for(spec)
        import os
        os.utime(entry, (1_000_000 + i, 1_000_000 + i))
    return store, specs


def test_store_entries_are_lru_ordered(tmp_path):
    store, specs = _fill_store(tmp_path)
    entries = store.entries()
    assert [e.key for e in entries] == [spec_key(s) for s in specs]
    assert all(e.size > 0 for e in entries)
    assert all(e.workload == "dd" and e.strategy == "greedy"
               for e in entries)


def test_store_gc_evicts_oldest_down_to_cap(tmp_path):
    store, specs = _fill_store(tmp_path)
    sizes = [e.size for e in store.entries()]
    cap = sizes[-1] + sizes[-2]  # room for exactly the two newest
    removed, freed = store.gc(max_bytes=cap)
    assert removed == 2 and freed == sizes[0] + sizes[1]
    kept = {e.key for e in store.entries()}
    assert kept == {spec_key(s) for s in specs[2:]}
    assert store.total_bytes() <= cap
    # the evicted specs re-search and re-populate on the next run
    again = run(specs[0], graph=small_graph(), store=store)
    assert again.feasible and specs[0] in store


def test_store_gc_zero_cap_clears_everything_and_corrupt(tmp_path):
    store, _ = _fill_store(tmp_path, n=2)
    (store.root / "junk.json.corrupt").write_text("{}")
    removed, _ = store.gc(max_bytes=0)
    assert removed == 3
    assert store.total_bytes() == 0 and len(store) == 0
