"""Serving engine: batched generation consistency + whisper enc-dec."""

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_caches, lm_apply, lm_init, param_values
from repro.serve import EncDecEngine, Request, ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
    return cfg, values


def greedy_reference(cfg, values, prompt, n_new):
    """Uncached greedy decode re-running the full forward every step."""
    toks = list(map(int, prompt))
    out = []
    for _ in range(n_new):
        logits, _, _ = lm_apply(values, cfg,
                                jnp.asarray([toks], jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_uncached_reference(tiny_lm):
    cfg, values = tiny_lm
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 7).astype(np.int32)
    eng = ServeEngine(cfg, values, ServeConfig(max_batch=4, max_len=64))
    got = eng.generate([Request(rid=0, prompt=prompt, max_new_tokens=6)])
    want = greedy_reference(cfg, values, prompt, 6)
    assert got[0] == want


def test_engine_batches_equal_length_requests(tiny_lm):
    cfg, values = tiny_lm
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    eng = ServeEngine(cfg, values, ServeConfig(max_batch=3, max_len=32))
    got = eng.generate(reqs)
    assert set(got) == {0, 1, 2, 3, 4}
    for i, r in enumerate(reqs):
        want = greedy_reference(cfg, values, r.prompt, 4)
        assert got[i] == want, i


def test_sliding_window_ring_cache_generation():
    """gemma3-style local:global layers: generation through the window-sized
    ring cache must agree with the uncached full-context reference once the
    context exceeds the window (ring wrap exercised)."""
    cfg = get_config("gemma3-4b", smoke=True)  # window 16, period 2
    values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 20).astype(np.int32)  # > window
    eng = ServeEngine(cfg, values, ServeConfig(max_batch=2, max_len=48))
    got = eng.generate([Request(rid=0, prompt=prompt, max_new_tokens=8)])
    want = greedy_reference(cfg, values, prompt, 8)
    assert got[0] == want


def test_whisper_encdec_engine():
    cfg = get_config("whisper-base", smoke=True)
    values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
    eng = EncDecEngine(cfg, values, ServeConfig(max_batch=2, max_len=32))
    frames = np.random.default_rng(2).normal(size=(2, 12, cfg.d_model))
    out = eng.transcribe(frames.astype(np.float32), max_new_tokens=5)
    assert len(out) == 2 and all(len(o) == 5 for o in out)
    assert all(0 <= t < cfg.vocab for o in out for t in o)
