"""End-to-end behaviour tests for the paper's system."""

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax
import numpy as np

from repro.api import ExploreSpec, GAOptions, run
from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.shapes import cells_for, skip_reason
from repro.core import AcceleratorConfig, CachedEvaluator, HWSpace, Objective
from repro.core.netlib import build
from repro.core.partition import is_valid, partition_of, singleton_partition
from repro.core.tpu_adapter import build_block_graph, plan_architecture


def test_cocco_end_to_end_on_resnet50():
    """The paper's core loop: co-explore, get a valid feasible plan that
    beats the unfused singleton execution."""
    g = build("resnet50")
    res = run(ExploreSpec(workload="resnet50", strategy="ga",
                          objective=Objective(metric="energy", alpha=0.002),
                          hw=HWSpace(mode="shared"),
                          sample_budget=1500, seed=0,
                          options=GAOptions(population=40)),
              graph=g)
    assert res.plan.feasible
    assert is_valid(g, partition_of(res.groups, g.n))
    ev = CachedEvaluator(g)
    single = ev.plan(singleton_partition(g), res.acc)
    assert res.plan.ema_total < single.ema_total
    assert any(len(s) > 1 for s in res.groups), "no fusion found"


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-v2-236b",
                                  "jamba-v0.1-52b", "xlstm-350m"])
def test_tpu_planner_fuses_blocks(arch):
    """Cocco-as-execution-planner: the plan must fuse ops (the paper's
    subgraph-in-buffer result transfers to the TPU graph) and cut HBM
    traffic vs unfused execution."""
    cfg = get_config(arch)
    plan = plan_architecture(cfg, sample_budget=800, seed=0)
    assert plan.traffic_saving > 0.3, plan.summary()
    assert any(len(gr) > 1 for gr in plan.fusion_groups)
    assert plan.block_m >= 128


def test_block_graph_shapes_are_consistent():
    cfg = get_config("glm4-9b")
    g = build_block_graph(cfg, 0, tokens=4096)
    for e in g.edges:
        assert e.src < e.dst
    assert any(e.kind == "full" for e in g.edges)  # attention phase boundary


def test_cell_grid_is_complete():
    """40 assigned cells: every (arch x shape) is either runnable or a
    documented skip."""
    n_cells = 0
    n_skips = 0
    for arch in ARCHS:
        for shape in SHAPES:
            n_cells += 1
            reason = skip_reason(arch, shape)
            if reason:
                n_skips += 1
                assert "N/A" in reason
            else:
                assert shape in cells_for(arch)
    assert n_cells == 40
    assert n_skips == 7  # pure full-attention archs skip long_500k


def test_short_training_run_learns():
    """examples/train_tinylm.py path: a few dozen steps on the reduced
    config must reduce loss through the full launcher (mesh, checkpointing,
    fault policy wiring)."""
    from repro.launch.train import run

    class Args:
        arch = "tinyllama-1.1b"
        smoke = True
        steps = 30
        batch = 8
        seq = 64
        lr = 3e-3
        warmup = 5
        seed = 0
        microbatches = 1
        model_parallel = 1
        ckpt_dir = None
        save_every = 100
        log_every = 100
        fail_at = 0

    out = run(Args())
    assert out["last_loss"] < out["first_loss"]
