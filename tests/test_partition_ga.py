"""Partition validity, GA operators (paper §4.4), and search behaviour."""

import random
from dataclasses import replace

import pytest
from _hypothesis_compat import given, settings, st
from conftest import small_graph

from repro.core import (
    AcceleratorConfig,
    CachedEvaluator,
    Graph,
    HWSpace,
    Objective,
    groups_of,
    is_valid,
    normalize,
    partition_of,
    random_partition,
    run_ga,
    singleton_partition,
    split_to_fit,
)
from repro.core.ga import Genome, crossover, mutate
from repro.core.netlib import googlenet, resnet50

KB = 1 << 10
MB = 1 << 20


def test_validity_checks():
    g = small_graph()
    assert is_valid(g, [0, 0, 0, 1, 1, 2, 2, 2])
    assert not is_valid(g, [1, 0, 0, 0, 0, 0, 0, 0])     # edge order violated
    assert not is_valid(g, [0, 1, 0, 0, 0, 0, 0, 1])     # group {1,7} disconnected


def test_normalize_repairs_disconnected_and_cyclic():
    g = small_graph()
    # group {0, 3} with node 1,2 elsewhere: {0,3} is disconnected? no — 0-3 not
    # adjacent, so it must split
    raw = [{0, 3}, {1}, {2}, {4, 5, 6, 7}]
    groups = normalize(g, raw)
    P = partition_of(groups, g.n)
    assert is_valid(g, P)
    # quotient cycle: {0,2,3} and {1} -> 0->1 (g1), 1->3 (g2) ... construct one
    raw = [{0, 2, 3}, {1}, {4, 5, 6, 7}]
    groups = normalize(g, raw)
    assert is_valid(g, partition_of(groups, g.n))


def test_random_partition_always_valid():
    g = resnet50()
    rng = random.Random(0)
    for _ in range(20):
        groups = random_partition(g, rng, mean_size=4.0)
        assert is_valid(g, partition_of(groups, g.n))


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_property_crossover_and_mutations_preserve_validity(seed):
    g = small_graph()
    rng = random.Random(seed)
    hw = HWSpace(mode="separate")
    mom = Genome(random_partition(g, rng), hw.sample(rng))
    dad = Genome(random_partition(g, rng), hw.sample(rng))
    child = crossover(g, mom, dad, hw, rng)
    assert is_valid(g, partition_of(child.groups, g.n))
    for _ in range(10):
        child = mutate(g, child, hw, rng)
        assert is_valid(g, partition_of(child.groups, g.n))
        assert sum(len(s) for s in child.groups) == g.n


def test_split_to_fit_produces_feasible_plan():
    g = resnet50()
    acc = AcceleratorConfig(glb_bytes=64 * KB, wbuf_bytes=72 * KB)
    ev = CachedEvaluator(g)
    groups = split_to_fit(g, [set(range(g.n))], acc, ev=ev)
    plan = ev.plan(groups, acc)
    assert plan.feasible
    assert is_valid(g, partition_of(groups, g.n))


def test_ga_beats_singletons_on_small_graph():
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=64 * KB, wbuf_bytes=72 * KB)
    res = run_ga(g, Objective(metric="ema", alpha=None),
                 HWSpace(mode="fixed", base=acc), sample_budget=600,
                 population=30, seed=0)
    ev = CachedEvaluator(g)
    single = ev.plan(singleton_partition(g), acc)
    assert res.best.plan.ema_total <= single.ema_total
    assert res.best.plan.feasible


def test_ga_co_explore_returns_grid_capacity():
    g = small_graph()
    res = run_ga(g, Objective(metric="energy", alpha=0.002),
                 HWSpace(mode="shared"), sample_budget=400,
                 population=20, seed=1)
    from repro.core import SHARED_CANDIDATES
    assert res.best.acc.shared
    assert res.best.acc.glb_bytes in SHARED_CANDIDATES
    assert res.best.plan.feasible


def test_ga_co_explores_core_axis():
    g = small_graph()
    hw = HWSpace(mode="shared",
                 base=AcceleratorConfig(shared=True, weight_share_cores=2,
                                        n_cores=2),
                 core_candidates=(2, 4))
    res = run_ga(g, Objective(metric="energy", alpha=0.002), hw,
                 sample_budget=400, population=20, seed=1)
    assert res.best.acc.weight_share_cores in (2, 4)
    assert res.best.acc.n_cores == res.best.acc.weight_share_cores
    assert res.best.plan.feasible
    # the §5.4.2 broadcast charge is live in the searched objective
    assert res.best.plan.noc_total == sum(
        (res.best.acc.weight_share_cores - 1) * s.ema_w
        for s in res.best.plan.subgraphs)


def test_hwspace_core_ops_stay_inside_candidates():
    rng = random.Random(11)
    hw = HWSpace(mode="separate", core_candidates=(1, 2, 4))
    for _ in range(50):
        a, b = hw.sample(rng), hw.sample(rng)
        assert a.weight_share_cores in hw.core_candidates
        child = hw.blend(a, b, rng)
        assert child.weight_share_cores in hw.core_candidates
        mutant = hw.mutate(child, rng)
        assert mutant.weight_share_cores in hw.core_candidates
    with pytest.raises(ValueError, match="core_candidates"):
        HWSpace(core_candidates=(0, 2))


def test_empty_core_candidates_preserve_rng_stream():
    """The default () core axis must not draw from the rng, so existing
    seeded searches stay bitwise-identical."""
    base, cored = HWSpace(mode="separate"), \
        HWSpace(mode="separate", core_candidates=(2,))
    r1, r2 = random.Random(7), random.Random(7)
    a1, a2 = base.sample(r1), cored.sample(r2)
    assert a1 == replace(a2, weight_share_cores=1, n_cores=a1.n_cores)
    # after identical work, the un-cored space left the rng untouched by
    # the core axis: next draws agree with a fresh clone
    r3 = random.Random(7)
    base.sample(r3)
    assert r1.getstate() == r3.getstate()


def test_ga_history_monotone():
    g = small_graph()
    res = run_ga(g, Objective(metric="ema", alpha=None), HWSpace(),
                 sample_budget=300, population=20, seed=3)
    costs = [c for _, c in res.history]
    assert all(b <= a + 1e-9 for a, b in zip(costs, costs[1:]))
