"""Gradient compression: error feedback keeps the long-run average unbiased."""

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.parallel.collectives import (
    EFState,
    compress_bf16,
    compressed_grad_step,
    decompress_bf16,
    ef_init,
)


def test_bf16_roundtrip_error_small():
    g = {"w": jnp.linspace(-3, 3, 1000)}
    out = decompress_bf16(compress_bf16(g))
    err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    assert err < 0.02


def test_int8_ef_accumulates_residual():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=256).astype(np.float32))}
    ef = ef_init(g)
    total_sent = jnp.zeros(256)
    n = 50
    for _ in range(n):
        sent, ef = compressed_grad_step(g, ef, mode="int8_ef")
        total_sent = total_sent + sent["w"]
    # long-run average of transmitted grads converges to the true grad
    avg_err = float(jnp.max(jnp.abs(total_sent / n - g["w"])))
    one_step_err = float(jnp.max(jnp.abs(
        compressed_grad_step(g, ef_init(g), mode="int8_ef")[0]["w"] - g["w"])))
    assert avg_err < one_step_err * 0.5
    assert avg_err < 5e-3


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_int8_ef_residual_bounded(seed):
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=64).astype(np.float32) * 10)}
    ef = ef_init(g)
    for _ in range(10):
        _, ef = compressed_grad_step(g, ef, mode="int8_ef")
    scale = float(jnp.max(jnp.abs(g["w"])))
    # residual never exceeds one quantization bucket given stable input
    assert float(jnp.max(jnp.abs(ef.residual["w"]))) <= scale / 127 + 1e-5


def test_mode_none_is_identity():
    g = {"w": jnp.arange(4.0)}
    out, ef = compressed_grad_step(g, None, mode="none")
    assert out is g and ef is None
