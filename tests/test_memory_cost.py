"""Memory manager (paper §3.2) and accelerator cost model (paper §5.1.2)."""

import pytest

from repro.core import (
    AcceleratorConfig,
    CachedEvaluator,
    Graph,
    RegionTable,
    build_region_table,
    evaluate_partition,
    evaluate_subgraph,
    subgraph_footprint,
)
from repro.core.netlib import resnet50, vgg16
from conftest import chain_graph

MB = 1 << 20
KB = 1 << 10


def test_region_table_allocation_and_overhead():
    t = RegionTable(capacity_bytes=1 * MB, max_regions=64)
    r1 = t.allocate(0, 1000)
    r2 = t.allocate(1, 2000)
    assert r1.end == r2.start and t.used_bytes == 3000
    # paper: 272-byte table, ~0.18% area for a 1MB buffer with N=64
    assert t.table_bytes() <= 400
    assert t.area_overhead_fraction() < 0.005


def test_region_table_overflow_raises():
    t = RegionTable(capacity_bytes=4096, max_regions=4)
    t.allocate(0, 4000)
    with pytest.raises(MemoryError):
        t.allocate(1, 200)


def test_build_region_table_chain():
    g, nodes = chain_graph()
    t = build_region_table(g, nodes, capacity_bytes=64 * KB)
    assert len(t.regions) == len(nodes) + 1  # internal + external input
    assert t.used_bytes <= 64 * KB


def test_footprint_matches_schedule():
    g, nodes = chain_graph()
    fp = subgraph_footprint(g, nodes)
    from repro.core import derive_schedule
    sched = derive_schedule(g, nodes)
    assert fp.total_bytes == sum(
        ts.x * g.nodes[t].line_bytes for t, ts in sched.tensors.items()
    )


def test_fusion_reduces_ema():
    """The heart of Fig. 1/Fig. 3: fusing a chain removes the intermediate
    round trips."""
    g, nodes = chain_graph()
    acc = AcceleratorConfig()
    singletons = [{v} for v in sorted(nodes)]
    fused = [set(nodes)]
    p1 = evaluate_partition(g, singletons, acc)
    p2 = evaluate_partition(g, fused, acc)
    assert p2.feasible
    assert p2.ema_total < p1.ema_total


def test_infeasible_when_buffer_too_small():
    g, nodes = chain_graph(length=4096)
    acc = AcceleratorConfig(glb_bytes=2)  # pathological
    c = evaluate_subgraph(g, nodes, acc)
    assert not c.feasible


def test_single_layer_streams_weights():
    """A single layer whose activations exceed the buffer re-streams weights
    per row block instead of becoming infeasible."""
    g = Graph("big")
    i = g.add_node("in", 1024, 4096)
    v = g.add_node("fc", 1024, 4096, weight_bytes=8 * MB, macs=10**9)
    g.add_edge(i, v, F=1, s=1)
    g.nodes[v].is_output = True
    acc = AcceleratorConfig(glb_bytes=4 * KB)
    c = evaluate_subgraph(g, {v}, acc)
    assert c.feasible
    assert c.ema_w >= 8 * MB  # streamed at least once


def test_latency_is_max_of_compute_and_io():
    g, nodes = chain_graph()
    acc = AcceleratorConfig()
    c = evaluate_subgraph(g, nodes, acc)
    assert c.latency_cycles(acc) == max(c.compute_cycles(acc), c.io_cycles(acc))


def test_cached_evaluator_consistency():
    g = resnet50()
    acc = AcceleratorConfig()
    ev = CachedEvaluator(g)
    s = set(range(1, 5))
    a = ev.subgraph(s, acc)
    b = ev.subgraph(s, acc)
    assert a is b and ev.evaluations == 1
    direct = evaluate_subgraph(g, s, acc)
    assert direct.ema_total == a.ema_total


def test_known_model_statistics():
    """Sanity: VGG16 ~138M weights, ResNet50 ~25.5M (INT8 bytes)."""
    v = vgg16()
    r = resnet50()
    assert 130e6 < v.total_weight_bytes() < 145e6
    assert 23e6 < r.total_weight_bytes() < 28e6
