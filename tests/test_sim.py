"""Trace simulator (`repro.sim`): analytical<->simulated cross-validation,
determinism, resolution-independence, occupancy bounds, and the
trace-derived ``bandwidth`` objective metric.

The centerpiece is the golden cross-validation suite: for every workload
scheme's pinned GA and greedy plans (``tests/golden/``), the simulated
total DRAM traffic must equal the analytical kernel's EMA byte-for-byte —
the golden workloads double as an end-to-end oracle for the cost model.
"""

import json
import math
import random

import pytest
from _hypothesis_compat import given, settings, st

from test_golden_workloads import CASES, WORKLOADS, golden_path

from repro.api import (
    DPOptions,
    EnumOptions,
    ExploreResult,
    ExploreSpec,
    GAOptions,
    GreedyOptions,
    SAOptions,
    TwoStepOptions,
    build_workload,
    list_strategies,
    run,
)
from repro.core import (
    AcceleratorConfig,
    CachedEvaluator,
    HWSpace,
    Objective,
    OccupancyTracker,
)
from repro.core.cost import METRICS, time_weighted_percentile
from repro.core.partition import random_partition, singleton_partition, \
    split_to_fit
from repro.sim import (
    PROLOGUE,
    cross_validate,
    cross_validate_trace,
    simulate_plan,
)

KB = 1 << 10
SYNTH_KINDS = ("layered", "branchy", "diamond", "chain", "pyramid")


# ---------------------------------------------------------------------------
# golden cross-validation: simulated DRAM bytes == analytical EMA, exactly,
# for the GA and greedy golden plans of every workload scheme
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload_key,strategy", CASES)
def test_golden_plans_cross_validate_exactly(workload_key, strategy):
    res = ExploreResult.from_dict(
        json.loads(golden_path(workload_key, strategy).read_text()))
    # WORKLOADS holds the machine-local URI (the artifact's file: path is
    # canonicalized to a repo-relative form, so resolve via the test map)
    g = build_workload(WORKLOADS[workload_key])
    trace = simulate_plan(g, res.groups, res.acc)
    report = cross_validate_trace(trace, res.plan)
    assert report.bytes_ok, report.summary()
    assert report.total_simulated == res.plan.ema_total      # exact, no eps
    for check in report.checks:
        assert check.ok, check.to_dict()
    assert report.latency_ok, (report.latency_simulated,
                               report.latency_analytical)
    # and the independently recomputed plan agrees with the archived one
    fresh = cross_validate(g, res.groups, res.acc)
    assert fresh.ok and fresh.total_analytical == report.total_analytical


# ---------------------------------------------------------------------------
# simulator invariants
# ---------------------------------------------------------------------------

def _greedy_plan(uri, **acc_kw):
    g = build_workload(uri)
    acc = AcceleratorConfig(**acc_kw) if acc_kw else AcceleratorConfig()
    spec = ExploreSpec(workload=uri, strategy="greedy",
                       objective=Objective(metric="ema", alpha=None),
                       hw=HWSpace(mode="fixed", base=acc),
                       options=GreedyOptions(eval_budget=2_000))
    res = run(spec)
    assert res.feasible
    return g, res


def test_trace_is_deterministic_and_json_stable():
    g, res = _greedy_plan("synthetic:branchy:16?seed=2")
    t1 = simulate_plan(g, res.groups, res.acc)
    t2 = simulate_plan(g, res.groups, res.acc)
    assert t1.to_json() == t2.to_json()
    assert t1.to_json() == simulate_plan(
        build_workload("synthetic:branchy:16?seed=2"),
        res.groups, res.acc).to_json()


def test_coalescing_preserves_every_total():
    g, res = _greedy_plan("netlib:vgg16")
    fine = simulate_plan(g, res.groups, res.acc)
    for m in (1, 3, 16):
        coarse = simulate_plan(g, res.groups, res.acc, steps_per_subgraph=m)
        assert coarse.total_dram_in == fine.total_dram_in
        assert coarse.total_dram_out == fine.total_dram_out
        assert math.isclose(coarse.total_cycles, fine.total_cycles,
                            rel_tol=1e-9)
        assert len(coarse.steps) <= len(fine.steps)
        assert cross_validate_trace(coarse, res.plan).ok


def test_prologue_and_prefetch_cover_all_weight_traffic():
    g, res = _greedy_plan("netlib:resnet50")
    trace = simulate_plan(g, res.groups, res.acc)
    w_total = sum(s.w_in for s in trace.steps)
    assert w_total == sum(sg.w_first + sg.w_stream for sg in trace.subgraphs)
    assert w_total == sum(s.ema_w for s in res.plan.subgraphs)
    prologue = [s for s in trace.steps if s.subgraph == PROLOGUE]
    if res.plan.subgraphs[0].traffic_breakdown().weight_first:
        assert len(prologue) == 1
        assert prologue[0].w_in == \
            res.plan.subgraphs[0].traffic_breakdown().weight_first


def test_occupancy_stays_within_analytical_footprint():
    g, res = _greedy_plan("netlib:googlenet")
    trace = simulate_plan(g, res.groups, res.acc)
    by_sub = {}
    for s in trace.steps:
        if s.subgraph >= 0:
            by_sub.setdefault(s.subgraph, []).append(s)
    for sg in trace.subgraphs:
        peak = max(s.occ_act for s in by_sub[sg.index])
        assert peak == sg.peak_occ_act
        assert peak <= sg.footprint          # eviction honors the regions
    # weight occupancy shows the double buffer: while subgraph i runs, its
    # resident weights plus the growing prefetch of i+1 are accounted
    if len(trace.subgraphs) > 1:
        i = trace.subgraphs[0].index
        last = by_sub[i][-1]
        nxt_first = trace.subgraphs[1].w_first
        own = res.plan.subgraphs[0].weight_resident
        assert last.occ_w == own + nxt_first


def test_streamed_single_layer_restreams_weights_mid_subgraph():
    # starvation buffers force single-layer weight streaming on vgg16
    g, res = _greedy_plan("netlib:vgg16", glb_bytes=24 * KB,
                          wbuf_bytes=24 * KB)
    trace = simulate_plan(g, res.groups, res.acc)
    streamed = [sg for sg in trace.subgraphs if sg.stream_blocks > 1]
    assert streamed, "expected streamed subgraphs under 24KB buffers"
    for sg in streamed:
        assert sg.w_stream == sg.w_first * (sg.stream_blocks - 1)
        assert sg.region_count is None       # no static region layout
    assert cross_validate_trace(trace, res.plan).ok


def test_infeasible_plans_are_rejected():
    g = build_workload("synthetic:diamond:8?seed=1")
    acc = AcceleratorConfig(glb_bytes=2 * KB, wbuf_bytes=2 * KB)
    with pytest.raises(ValueError, match="infeasible"):
        simulate_plan(g, [set(range(g.n))], acc)


# ---------------------------------------------------------------------------
# multi-core (weight_share_cores > 1): per-core lowering + NoC broadcast
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("share", (2, 3, 4))
@pytest.mark.parametrize("uri", ("synthetic:layered:16?seed=7",
                                 "netlib:vgg16"))
def test_multicore_plans_cross_validate_exactly(uri, share):
    g, res = _greedy_plan(uri, weight_share_cores=share, n_cores=share)
    report = cross_validate(g, res.groups, res.acc)
    assert report.ok, report.summary()
    # the simulated fabric traffic IS the analytical §5.4.2 charge
    assert report.noc_simulated == report.noc_analytical
    assert report.noc_analytical == res.plan.noc_total
    assert res.plan.noc_total == sum(
        (share - 1) * s.ema_w for s in res.plan.subgraphs)
    assert res.plan.noc_total > 0
    for check in report.checks:
        assert check.noc_simulated == check.noc_analytical


@pytest.mark.parametrize("share", (1, 2, 3))
def test_multicore_prologue_shards_weights_per_core(share):
    g, res = _greedy_plan("netlib:vgg16", weight_share_cores=share,
                          n_cores=share)
    trace = simulate_plan(g, res.groups, res.acc)
    prologue = [s for s in trace.steps if s.subgraph == PROLOGUE]
    first = res.plan.subgraphs[0].traffic_breakdown().weight_first
    if not first:
        pytest.skip("plan has no weight prologue")
    # one DRAM shard per core, summing exactly to the first load; each
    # shard's broadcast reaches the share-1 peer cores
    assert len(prologue) == share
    assert sum(s.w_in for s in prologue) == first
    assert sum(s.noc_bytes for s in prologue) == (share - 1) * first
    assert [s.core for s in prologue] == list(range(share))
    # occupancy climbs to the per-core residency, not the full tensor
    assert prologue[-1].occ_w == res.plan.subgraphs[0].weight_resident
    assert cross_validate_trace(trace, res.plan).ok


def test_single_core_trace_has_no_noc_traffic():
    g, res = _greedy_plan("netlib:vgg16")
    trace = simulate_plan(g, res.groups, res.acc)
    assert trace.total_noc_bytes == 0
    assert all(s.noc_bytes == 0 for s in trace.steps)
    assert res.plan.noc_total == 0
    assert res.plan.metric("noc_p95") == 0.0
    assert res.plan.metric("noc_link_peak") == 0.0


def test_accelerator_config_rejects_bad_core_counts():
    with pytest.raises(ValueError, match="weight_share_cores must be >= 1"):
        AcceleratorConfig(weight_share_cores=0)
    with pytest.raises(ValueError, match="weight_share_cores must be >= 1"):
        AcceleratorConfig(weight_share_cores=-2)
    with pytest.raises(ValueError, match="n_cores must be >= 1"):
        AcceleratorConfig(n_cores=0)
    AcceleratorConfig(weight_share_cores=1, n_cores=1)   # boundary is fine


# ---------------------------------------------------------------------------
# the bandwidth metric: trace-derived, selectable by every strategy
# ---------------------------------------------------------------------------

def test_plan_metric_equals_trace_profile_at_subgraph_resolution():
    g, res = _greedy_plan("netlib:resnet50")
    coarse = simulate_plan(g, res.groups, res.acc, steps_per_subgraph=1)
    prof = coarse.bandwidth_profile()
    assert math.isclose(res.plan.bandwidth_percentile(95.0),
                        prof.percentiles["p95"], rel_tol=1e-9)
    assert math.isclose(res.plan.metric("bandwidth"),
                        prof.percentiles["p95"], rel_tol=1e-9)
    # one timeline model: the analytical peak IS the trace peak at
    # one-step-per-subgraph resolution
    assert math.isclose(res.plan.peak_bandwidth(), prof.peak, rel_tol=1e-9)
    # the link-bound prologue is excluded from the requirement statistics,
    # so plans whose demand sits below the DRAM rate keep their signal
    assert prof.peak < res.acc.dram_bytes_per_sec or any(
        b / c * res.acc.freq_hz >= res.acc.dram_bytes_per_sec
        for b, c in res.plan.traffic_segments() if c > 0)
    # segments + prologue and the coalesced trace agree byte-for-byte
    segs = res.plan.traffic_segments()
    pro_bytes, _pro_cycles = res.plan.prologue_traffic()
    assert sum(b for b, _ in segs) + pro_bytes == coarse.total_dram_bytes


def test_noc_metrics_equal_trace_profile_at_subgraph_resolution():
    share = 2
    g, res = _greedy_plan("netlib:vgg16", weight_share_cores=share,
                          n_cores=share)
    coarse = simulate_plan(g, res.groups, res.acc, steps_per_subgraph=1)
    agg = coarse.noc_profile()
    link = coarse.noc_profile(links=share)
    # one timeline model, two views of it: the analytical NoC metrics ARE
    # the trace's fabric profile at one-step-per-subgraph resolution
    assert math.isclose(res.plan.metric("noc_p95"),
                        agg.percentiles["p95"], rel_tol=1e-9)
    assert math.isclose(res.plan.noc_percentile(95.0),
                        agg.percentiles["p95"], rel_tol=1e-9)
    assert math.isclose(res.plan.metric("noc_link_peak"), link.peak,
                        rel_tol=1e-9)
    # the symmetric rotation fabric spreads the broadcast over `share` links
    assert math.isclose(link.peak * share, agg.peak, rel_tol=1e-9)
    assert res.plan.metric("noc_p95") > 0
    # same segment timeline as the DRAM side: byte totals line up with the
    # coalesced trace including the prologue broadcast
    segs = res.plan.noc_segments()
    pro_noc = sum(s.noc_bytes for s in coarse.steps if s.subgraph < 0)
    assert sum(b for b, _ in segs) + pro_noc == coarse.total_noc_bytes


STRATEGY_OPTS = {
    "ga": GAOptions(population=8),
    "greedy": GreedyOptions(eval_budget=500),
    "dp": DPOptions(),
    "enum": EnumOptions(state_budget=50_000),
    "sa": SAOptions(),
    "two_step": TwoStepOptions(capacity_samples=2, samples_per_capacity=60),
}


@pytest.mark.parametrize("strategy", sorted(STRATEGY_OPTS))
def test_bandwidth_metric_selectable_by_every_strategy(strategy):
    spec = ExploreSpec(workload="synthetic:chain:6?seed=1",
                       strategy=strategy,
                       objective=Objective(metric="bandwidth", alpha=None),
                       hw=HWSpace(mode="fixed"),
                       sample_budget=120, seed=0,
                       options=STRATEGY_OPTS[strategy])
    res = run(spec)
    assert res.feasible
    # reported cost is always the *true* metric, even for the additive-DP
    # baselines that decompose by the documented ema surrogate
    assert res.cost == res.plan.metric("bandwidth")
    assert math.isfinite(res.cost) and res.cost > 0


def test_objective_decomposition_surrogate():
    for m in ("bandwidth", "noc_p95", "noc_link_peak"):
        obj = Objective(metric=m, alpha=None)
        assert not obj.is_additive
        assert obj.decomposition() == Objective(metric="ema", alpha=None)
    for m in ("ema", "energy", "latency"):
        obj = Objective(metric=m, alpha=0.002)
        assert obj.is_additive and obj.decomposition() is obj


@pytest.mark.parametrize("metric", ("noc_p95", "noc_link_peak"))
@pytest.mark.parametrize("strategy", sorted(STRATEGY_OPTS))
def test_noc_metrics_selectable_by_every_strategy(strategy, metric):
    acc = AcceleratorConfig(weight_share_cores=2, n_cores=2)
    spec = ExploreSpec(workload="synthetic:chain:6?seed=1",
                       strategy=strategy,
                       objective=Objective(metric=metric, alpha=None),
                       hw=HWSpace(mode="fixed", base=acc),
                       sample_budget=120, seed=0,
                       options=STRATEGY_OPTS[strategy])
    res = run(spec)
    assert res.feasible
    assert res.cost == res.plan.metric(metric)
    # zero is a legitimate optimum here: a plan whose whole broadcast rides
    # on the prologue has no steady-state fabric requirement
    assert math.isfinite(res.cost) and res.cost >= 0


def test_strategy_registry_covers_all_six():
    assert set(STRATEGY_OPTS) <= set(list_strategies())


def test_unknown_metric_rejected_at_spec_construction():
    with pytest.raises(ValueError, match="valid metrics"):
        Objective(metric="speed")
    # deserialization goes through the same gate
    spec = ExploreSpec(workload="resnet50")
    d = spec.to_dict()
    d["objective"]["metric"] = "nope"
    with pytest.raises(ValueError, match="valid metrics"):
        ExploreSpec.from_dict(d)
    # and the plan-level metric lists its options too
    g, res = _greedy_plan("synthetic:chain:4?seed=0")
    with pytest.raises(ValueError, match="valid metrics"):
        res.plan.metric("nope")
    assert set(METRICS) == {"ema", "energy", "latency", "bandwidth",
                            "noc_p95", "noc_link_peak"}


def test_time_weighted_percentile_basics():
    assert time_weighted_percentile([], 95.0) == 0.0
    assert time_weighted_percentile([(5.0, 1.0)], 50.0) == 5.0
    # 90% of the time at bw 1, 10% at bw 100: p50 is 1, p99 is 100
    pairs = [(1.0, 9.0), (100.0, 1.0)]
    assert time_weighted_percentile(pairs, 50.0) == 1.0
    assert time_weighted_percentile(pairs, 99.0) == 100.0
    assert time_weighted_percentile(pairs, 90.0) == 1.0


def test_occupancy_tracker_caps_at_allocation():
    occ = OccupancyTracker(caps_rows={1: 4, 2: 2},
                           line_bytes={1: 10, 2: 100})
    assert occ.advance({1: 2}) == 20
    assert occ.advance({1: 4, 2: 1}) == 4 * 10 + 100   # tensor 1 capped
    assert occ.advance({2: 5}) == 4 * 10 + 2 * 100     # tensor 2 capped
    assert occ.peak_bytes == 240


# ---------------------------------------------------------------------------
# property-based: any feasible plan of any synthetic workload cross-validates
# ---------------------------------------------------------------------------

@given(kind=st.sampled_from(SYNTH_KINDS), n=st.integers(2, 20),
       seed=st.integers(0, 1_000), pseed=st.integers(0, 1_000))
@settings(max_examples=20, deadline=None)
def test_property_any_feasible_plan_cross_validates(kind, n, seed, pseed):
    g = build_workload(f"synthetic:{kind}:{n}?seed={seed}")
    rng = random.Random(pseed)
    acc = AcceleratorConfig(glb_bytes=16 * KB, wbuf_bytes=16 * KB)
    ev = CachedEvaluator(g)
    groups = split_to_fit(g, random_partition(g, rng, mean_size=3.0),
                          acc, ev=ev)
    report = cross_validate(g, groups, acc)
    assert report.ok, report.summary()
    # singleton plans cross-validate too (the always-feasible baseline)
    singles = cross_validate(g, singleton_partition(g), AcceleratorConfig())
    assert singles.ok, singles.summary()
