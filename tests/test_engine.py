"""Batched evaluation engine: pure kernel, executor backends, and the
backend-invariance contract (every backend returns identical results)."""

import random
from dataclasses import asdict, replace

import pytest
from backend_parity import available_backends, backend_params
from conftest import small_graph

from repro.api import ExploreSpec, GAOptions, SAOptions, run
from repro.core import (
    AcceleratorConfig,
    CachedEvaluator,
    CostKernel,
    HWSpace,
    Objective,
    compute_structure,
    evaluate_subgraph,
    finish_cost,
    make_executor,
    random_partition,
    split_to_fit,
    split_to_fit_batch,
)
from repro.core.cost import SubgraphStructure
from repro.core.engine import (
    BACKENDS,
    ProcessExecutor,
    SerialExecutor,
    VectorExecutor,
    backend_status,
    needs_scalar_fallback,
)
from repro.core.netlib import build

KB = 1 << 10


def fixed_spec(**kw):
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    defaults = dict(
        workload="dd",
        strategy="ga",
        objective=Objective(metric="energy", alpha=0.002),
        hw=HWSpace(mode="shared", base=acc),
        sample_budget=300,
        seed=0,
        options=GAOptions(population=20),
    )
    defaults.update(kw)
    return ExploreSpec(**defaults)


def random_queries(g, n_parts=12, seed=0):
    """A corpus of (subgraph, hardware-point) queries over random partitions."""
    rng = random.Random(seed)
    hw = HWSpace(mode="separate")
    queries = []
    for _ in range(n_parts):
        acc = hw.sample(rng)
        for s in random_partition(g, rng, mean_size=rng.uniform(1.5, 6.0)):
            queries.append((frozenset(s), acc))
    return queries


# ---------------------------------------------------------------------------
# the pure kernel
# ---------------------------------------------------------------------------

def test_kernel_equals_evaluate_subgraph():
    g = build("resnet50")
    kernel = CostKernel(g)
    for nodes, acc in random_queries(g, n_parts=4):
        assert asdict(kernel.cost(nodes, acc)) == \
            asdict(evaluate_subgraph(g, set(nodes), acc))


def test_structure_finish_split_is_pure():
    g = small_graph()
    nodes = {0, 1, 2, 3}
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    st1 = compute_structure(g, nodes)
    st2 = compute_structure(g, nodes)
    assert st1 == st2                      # deterministic, value-comparable
    assert finish_cost(st1, acc) == finish_cost(st2, acc)
    # the structure half never depends on the hardware point
    assert st1 == compute_structure(g, set(nodes))


# ---------------------------------------------------------------------------
# evaluate_batch
# ---------------------------------------------------------------------------

def test_evaluate_batch_matches_serial_subgraph_calls():
    g = small_graph()
    queries = random_queries(g, n_parts=6)
    ev_a, ev_b = CachedEvaluator(g), CachedEvaluator(g)
    batch = ev_a.evaluate_batch([(set(n), acc) for n, acc in queries])
    serial = [ev_b.subgraph(set(n), acc) for n, acc in queries]
    assert [asdict(c) for c in batch] == [asdict(c) for c in serial]
    assert ev_a.lookups == ev_b.lookups
    assert ev_a.evaluations == ev_b.evaluations  # distinct misses only


def test_evaluate_batch_dedupes_and_preserves_order():
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    ev = CachedEvaluator(g)
    qs = [({0}, acc), ({1}, acc), ({0}, acc), ({0, 1}, acc), ({1}, acc)]
    costs = ev.evaluate_batch(qs)
    assert [c.nodes for c in costs] == [(0,), (1,), (0,), (0, 1), (1,)]
    assert ev.evaluations == 3             # {0}, {1}, {0,1} computed once each
    assert ev.lookups == 5


def test_split_to_fit_batch_matches_per_item():
    g = build("resnet50")
    rng = random.Random(3)
    acc = AcceleratorConfig(glb_bytes=64 * KB, wbuf_bytes=72 * KB)
    items = [([set(range(g.n))], acc)]
    items += [(random_partition(g, rng, mean_size=8.0), acc)
              for _ in range(3)]
    batched = split_to_fit_batch(g, [([set(s) for s in gr], a)
                                     for gr, a in items], CachedEvaluator(g))
    for (gr, a), got in zip(items, batched):
        assert got == split_to_fit(g, [set(s) for s in gr], a,
                                   ev=CachedEvaluator(g))


# ---------------------------------------------------------------------------
# executor backends
# ---------------------------------------------------------------------------

def test_vector_backend_equals_scalar_kernel_exactly():
    g = build("resnet50")
    queries = random_queries(g, n_parts=12, seed=7)
    scalar = CostKernel(g)
    vec = VectorExecutor()
    got = vec.evaluate(CostKernel(g), queries)
    want = [scalar.cost(nodes, acc) for nodes, acc in queries]
    for a, b in zip(got, want):
        assert asdict(a) == asdict(b)      # exact equality, floats included


def test_vector_backend_streaming_and_overflow_paths():
    g = build("resnet50")
    # tiny buffers force streaming (singletons) and overflow (multi-node)
    accs = [AcceleratorConfig(glb_bytes=2 * KB, wbuf_bytes=2 * KB),
            AcceleratorConfig(glb_bytes=4 * KB, wbuf_bytes=0, shared=True),
            AcceleratorConfig(glb_bytes=512 * KB, wbuf_bytes=1 * KB)]
    queries = [(frozenset({v}), acc) for v in range(0, g.n, 5)
               for acc in accs]
    queries += [(frozenset({v, v + 1}), acc)
                for v in range(0, g.n - 1, 7) for acc in accs]
    got = VectorExecutor().evaluate(CostKernel(g), queries)
    kernel = CostKernel(g)
    reasons = set()
    for (nodes, acc), a in zip(queries, got):
        assert asdict(a) == asdict(kernel.cost(nodes, acc))
        reasons.add(a.reason.split(" in ")[0])
    assert "streamed" in reasons           # the corpus exercised streaming


def test_process_executor_matches_serial():
    g = small_graph()
    queries = random_queries(g, n_parts=6, seed=2)
    ex = ProcessExecutor(jobs=2)
    try:
        got = ex.evaluate(CostKernel(g), queries)
    finally:
        ex.close()
    want = SerialExecutor().evaluate(CostKernel(g), queries)
    assert [asdict(c) for c in got] == [asdict(c) for c in want]


def test_pool_context_avoids_forking_a_jax_parent():
    """Once jax is imported, process pools must not use the raw ``fork``
    start method: jax's at-fork hook warns (and the runtime can deadlock).
    ``pool_mp_context`` switches to ``forkserver``; with no jax in the
    process it keeps the platform default."""
    import sys

    from repro.core.engine import pool_mp_context

    ctx = pool_mp_context()
    if "jax" in sys.modules:
        assert ctx.get_start_method() == "forkserver"
    else:
        import multiprocessing as mp

        assert ctx.get_start_method() == mp.get_context().get_start_method()


def test_process_executor_is_fork_warning_clean_with_jax_loaded():
    """End-to-end regression for the `os.fork() ... JAX is multithreaded`
    RuntimeWarning: spin up a real worker pool after importing jax (skips
    when jax is absent).  Needs > 2*jobs distinct queries so the executor
    actually spawns workers instead of evaluating inline."""
    import warnings

    pytest.importorskip("jax")
    g = small_graph()
    queries = random_queries(g, n_parts=3, seed=5)
    assert len(queries) > 2
    ex = ProcessExecutor(jobs=1)
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            got = ex.evaluate(CostKernel(g), queries)
    finally:
        ex.close()
    want = SerialExecutor().evaluate(CostKernel(g), queries)
    assert [asdict(c) for c in got] == [asdict(c) for c in want]


def test_make_executor_resolution():
    assert isinstance(make_executor(None, 1), SerialExecutor)
    ex = make_executor(None, 3)
    assert isinstance(ex, ProcessExecutor) and ex.jobs == 3
    assert isinstance(make_executor("vector", 1), VectorExecutor)
    with pytest.raises(ValueError, match="unknown eval backend"):
        make_executor("gpu", 1)


def test_make_executor_unknown_backend_lists_valid_backends():
    with pytest.raises(ValueError) as exc:
        make_executor("gpu", 1)
    for backend in BACKENDS:
        assert backend in str(exc.value)


def test_backend_status_reports_why_unavailable(monkeypatch):
    import repro.core.engine as engine

    ok, why = backend_status("bogus")
    assert not ok and "valid backends" in why
    # simulate a missing jax install regardless of this container
    monkeypatch.setattr(engine, "_JAX_STATUS",
                        (False, "ModuleNotFoundError: No module named 'jax'"))
    ok, why = backend_status("jax")
    assert not ok
    assert "No module named 'jax'" in why and "pip install jax" in why
    with pytest.raises(ValueError, match="unavailable"):
        make_executor("jax", 1)


# ---------------------------------------------------------------------------
# scalar-fallback guard boundaries (pinned exactly for vector and jax)
# ---------------------------------------------------------------------------

def test_fallback_guard_boundary_capacity_2_53():
    """Capacities become unsafe for float64 division at exactly 2**53."""
    st = SubgraphStructure(nodes=(0,), footprint=10 * KB, weight_total=KB)
    wbuf = 144 * KB
    edge = 1 << 53
    assert not needs_scalar_fallback(
        st, AcceleratorConfig(glb_bytes=edge - 1, wbuf_bytes=wbuf))
    assert needs_scalar_fallback(
        st, AcceleratorConfig(glb_bytes=edge, wbuf_bytes=wbuf))
    assert needs_scalar_fallback(
        st, AcceleratorConfig(glb_bytes=edge + 1, wbuf_bytes=wbuf))
    # the wbuf capacity is guarded identically
    assert needs_scalar_fallback(
        st, AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=edge))


def test_fallback_guard_boundary_sizes_2_31():
    """Footprint / total weights above 2**31 could overflow the int64
    block-count product, so they fall back at exactly 2**31."""
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    edge = 1 << 31
    ok = SubgraphStructure(nodes=(0,), footprint=edge - 1,
                           weight_total=edge - 1)
    assert not needs_scalar_fallback(ok, acc)
    assert needs_scalar_fallback(replace(ok, footprint=edge), acc)
    assert needs_scalar_fallback(replace(ok, weight_total=edge), acc)
    # schedule failures always take the scalar path (reason strings)
    assert needs_scalar_fallback(replace(ok, sched_error="no schedule"), acc)


def test_fallback_guard_boundary_noc_product():
    """The §5.4.2 broadcast charge multiplies weight bytes by the share
    count, so the guard scales with ``weight_share_cores``: the product
    falls back at exactly 2**31 (bounding the int64 noc term well below
    2**62)."""
    edge = 1 << 31
    acc4 = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB,
                             weight_share_cores=4, n_cores=4)
    ok = SubgraphStructure(nodes=(0,), footprint=KB,
                           weight_total=edge // 4 - 1)
    assert not needs_scalar_fallback(ok, acc4)
    assert needs_scalar_fallback(
        replace(ok, weight_total=edge // 4), acc4)
    # a single core keeps the original weight_total boundary
    acc1 = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    assert not needs_scalar_fallback(
        replace(ok, weight_total=edge - 1), acc1)
    assert needs_scalar_fallback(replace(ok, weight_total=edge), acc1)


@pytest.mark.parametrize("backend,jobs", backend_params())
def test_fallback_boundary_queries_stay_bitwise_exact(backend, jobs):
    """Batched backends answer guard-straddling queries identically to the
    scalar kernel (the fallback partition is an implementation detail)."""
    g = small_graph()
    edge_accs = [
        AcceleratorConfig(glb_bytes=(1 << 53) - 1, wbuf_bytes=144 * KB),
        AcceleratorConfig(glb_bytes=(1 << 53), wbuf_bytes=144 * KB),
        AcceleratorConfig(glb_bytes=(1 << 53) + 1, wbuf_bytes=144 * KB),
        AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=(1 << 53)),
        AcceleratorConfig(glb_bytes=2 * KB, wbuf_bytes=2 * KB),
    ]
    queries = [(frozenset({v}), acc) for v in range(g.n)
               for acc in edge_accs]
    queries += [(frozenset({v, v + 1}), acc) for v in range(g.n - 1)
                for acc in edge_accs]
    ex = make_executor(backend, jobs)
    kernel = CostKernel(g)
    try:
        got = ex.evaluate(CostKernel(g), queries)
    finally:
        ex.close()
    for (nodes, acc), a in zip(queries, got):
        assert asdict(a) == asdict(kernel.cost(nodes, acc)), (nodes, acc)


# ---------------------------------------------------------------------------
# backend invariance of whole strategy runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,jobs", backend_params())
def test_parallel_ga_bitwise_identical_to_serial(backend, jobs):
    spec = fixed_spec()
    serial = run(spec, graph=small_graph())
    other = run(spec, graph=small_graph(), eval_backend=backend,
                eval_jobs=jobs)
    assert other.to_json() == serial.to_json()


def test_parallel_sa_and_enum_identical_to_serial():
    for strategy, options in (("sa", SAOptions()), ("enum", None)):
        spec = fixed_spec(strategy=strategy, options=options)
        serial = run(spec, graph=small_graph())
        parallel = run(spec, graph=small_graph(), eval_jobs=2)
        assert parallel.to_json() == serial.to_json(), strategy


def test_count_run_distinct_queries_invariant_across_backends():
    spec = fixed_spec()
    counts = {}
    for backend, jobs in available_backends():
        res = run(spec, graph=small_graph(), eval_backend=backend,
                  eval_jobs=jobs)
        counts[backend] = res.evaluations
    assert len(counts) >= 3  # serial + process + vector always resolve
    assert len(set(counts.values())) == 1, counts


def test_evaluations_count_distinct_queries_despite_canonical_hits():
    """``evaluations`` (and run()'s distinct-query count) are pinned to the
    raw (nodes, hw-point) key: a canonical structure hit still counts as a
    distinct evaluation — the canonical memo accelerates, never re-defines,
    the accounting."""
    g = small_graph()  # nodes 1 and 2 are isomorphic singletons
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    ev = CachedEvaluator(g, canonical=True)
    with ev.count_run() as touched:
        ev.subgraph({1}, acc)
        ev.subgraph({2}, acc)
    assert ev.evaluations == 2            # two distinct raw queries...
    assert len(touched) == 2
    assert ev.kernel.structure_misses == 1  # ...but one schedule derivation
    assert ev.kernel.structure_canon_hits == 1


def test_results_and_evaluations_invariant_under_canonical_toggle(
        monkeypatch):
    """REPRO_STRUCT_CANON=0 (the honest-measurement escape hatch) changes
    nothing observable: bitwise-identical results, same evaluations."""
    spec = fixed_spec()
    base = run(spec, graph=small_graph())
    monkeypatch.setenv("REPRO_STRUCT_CANON", "0")
    off = run(spec, graph=small_graph())
    assert off.to_json() == base.to_json()
    assert off.evaluations == base.evaluations


def test_search_result_evaluations_invariant_across_backends():
    """run_ga's raw SearchResult.evaluations (true cache misses), not just
    the distinct-query count run() reports, must not depend on the backend."""
    from repro.core import run_ga
    counts = []
    for backend, jobs in available_backends():
        g = small_graph()
        ev = CachedEvaluator(g, executor=make_executor(backend, jobs))
        res = run_ga(g, Objective(metric="ema", alpha=None), HWSpace(),
                     sample_budget=60, population=10, seed=0, ev=ev)
        ev.close()
        counts.append(res.evaluations)
    assert len(set(counts)) == 1, counts
