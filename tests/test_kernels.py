"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per deliverable (c): sweep shapes/dtypes per kernel and assert_allclose
against ref.py, plus hypothesis property tests.
"""

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.kernels import flash_attention, fused_rmsnorm, fused_swiglu
from repro.kernels import ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SWEEP = [
    # (B, H, S, d, causal, window, block_q, block_k)
    (1, 2, 128, 64, True, 0, 64, 64),
    (2, 1, 256, 32, True, 0, 128, 64),
    (1, 2, 128, 64, False, 0, 64, 128),
    (1, 1, 256, 64, True, 64, 64, 64),      # sliding window
    (1, 2, 128, 128, True, 32, 32, 32),
    (2, 2, 64, 16, True, 0, 64, 64),        # single block
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", ATTN_SWEEP)
def test_flash_attention_matches_ref(case, dtype):
    B, H, S, d, causal, window, bq, bk = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q, k, v = (rand(kk, (B, H, S, d), dtype) for kk in ks)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@given(st.sampled_from([64, 128, 256]), st.sampled_from([32, 64]),
       st.booleans(), st.sampled_from([0, 32, 128]))
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(S, d, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(S * d + window), 3)
    q, k, v = (rand(kk, (1, 2, S, d), jnp.float32) for kk in ks)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_blocks_do_not_change_result():
    """Block-size invariance: the tiling is numerically irrelevant."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (rand(kk, (1, 1, 256, 64), jnp.float32) for kk in ks)
    a = flash_attention(q, k, v, block_q=32, block_k=64, interpret=True)
    b = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused SwiGLU
# ---------------------------------------------------------------------------

FFN_SWEEP = [
    # (M, d, f, block_m, block_f)
    (128, 64, 256, 64, 128),
    (256, 128, 512, 128, 512),
    (64, 32, 64, 64, 64),
    (512, 64, 128, 256, 64),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FFN_SWEEP)
def test_fused_swiglu_matches_ref(case, dtype):
    M, d, f, bm, bf = case
    ks = jax.random.split(jax.random.PRNGKey(M + f), 4)
    x = rand(ks[0], (M, d), dtype)
    wg = rand(ks[1], (d, f), dtype) / np.sqrt(d)
    wi = rand(ks[2], (d, f), dtype) / np.sqrt(d)
    wo = rand(ks[3], (f, d), dtype) / np.sqrt(f)
    got = fused_swiglu(x, wg, wi, wo, block_m=bm, block_f=bf, interpret=True)
    want = ref.swiglu_ref(x, wg, wi, wo)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@given(st.sampled_from([64, 128]), st.sampled_from([32, 64]),
       st.sampled_from([64, 128, 256]))
@settings(max_examples=10, deadline=None)
def test_fused_swiglu_property(M, d, f):
    ks = jax.random.split(jax.random.PRNGKey(M * d + f), 4)
    x = rand(ks[0], (M, d), jnp.float32)
    wg = rand(ks[1], (d, f), jnp.float32) / np.sqrt(d)
    wi = rand(ks[2], (d, f), jnp.float32) / np.sqrt(d)
    wo = rand(ks[3], (f, d), jnp.float32) / np.sqrt(f)
    got = fused_swiglu(x, wg, wi, wo, block_m=64, block_f=64, interpret=True)
    want = ref.swiglu_ref(x, wg, wi, wo)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused RMSNorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64, 64), (256, 128), (128, 512)])
def test_fused_rmsnorm_matches_ref(shape, dtype):
    M, d = shape
    ks = jax.random.split(jax.random.PRNGKey(M + d), 2)
    x = rand(ks[0], (M, d), dtype)
    scale = rand(ks[1], (d,), jnp.float32)
    got = fused_rmsnorm(x, scale, block_m=64, interpret=True)
    want = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_rmsnorm_scale_invariance_property():
    """rmsnorm(c*x) == rmsnorm(x) for any c > 0 (up to eps)."""
    x = rand(jax.random.PRNGKey(0), (64, 128), jnp.float32)
    s = jnp.ones(128)
    a = fused_rmsnorm(x, s, interpret=True)
    b = fused_rmsnorm(37.0 * x, s, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)
