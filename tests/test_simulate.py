"""Mechanical validation of the execution scheme with real data (§3.1–3.2):
correctness, full reuse, capacity sufficiency, and tightness."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from conftest import chain_graph, fig5_like_graph

from repro.core import DeadlockError, FULL, Graph, derive_schedule, simulate_subgraph


def test_chain_executes_correctly_with_derived_capacity():
    g, nodes = chain_graph()
    res = simulate_subgraph(g, nodes, seed=1)
    # full reuse: each external row loaded exactly once
    for t, n in res.dram_loads.items():
        assert n <= g.nodes[t].out_len
    sched = derive_schedule(g, nodes)
    for t, occ in res.max_occupancy.items():
        assert occ <= sched.tensors[t].x


def test_diamond_with_lcm_alignment_executes():
    g, (m2, m1, n0, n1, n2, n3, n4) = fig5_like_graph()
    internal = {n0, n1, n2, n3, n4}
    res = simulate_subgraph(g, internal, out_tile=2, seed=3)
    assert res.rounds > 0
    # updates followed the derived relative rates: node with double the
    # upd_num performed ~double the updates
    sched = derive_schedule(g, internal, out_tile=2)


def test_capacity_below_window_span_deadlocks():
    """No schedule can run a consumer whose F-row window exceeds the producer
    allocation: the x values cannot be shrunk below the window span."""
    g, nodes = chain_graph()
    # the input tensor's consumer has F=3: capacity 2 can never hold a window
    with pytest.raises(DeadlockError):
        simulate_subgraph(g, nodes, seed=1, capacity_override={0: 2})


def test_full_edge_phase_execution():
    g = Graph("attn")
    i = g.add_node("in", 32, 1)
    q = g.add_node("q", 32, 1)
    a = g.add_node("a", 32, 1)
    o = g.add_node("o", 32, 1, is_output=True)
    g.add_edge(i, q, F=1, s=1)
    g.add_edge(q, a, kind=FULL)
    g.add_edge(a, o, F=1, s=1)
    res = simulate_subgraph(g, {q, a, o}, seed=5)
    assert res.max_occupancy[q] == 32  # whole tensor became resident


@st.composite
def random_dag_1d(draw):
    """Random 2-branch DAGs with stride-consistent merge points."""
    length = draw(st.integers(48, 96))
    f1 = draw(st.integers(1, 4))
    f2 = draw(st.integers(1, 4))
    f3 = draw(st.integers(1, 3))
    s = draw(st.integers(1, 2))
    return length, f1, f2, f3, s


@given(random_dag_1d())
@settings(max_examples=40, deadline=None)
def test_property_random_diamond_executes(spec):
    length, f1, f2, f3, s = spec
    g = Graph("rand")
    inp = g.add_node("in", length, 1)
    # two branches with the same total stride s
    l1 = (length - f1) // s + 1
    l2 = (length - f2) // s + 1
    lo = min(l1, l2)
    a = g.add_node("a", lo, 1)
    b = g.add_node("b", lo, 1)
    g.add_edge(inp, a, F=f1, s=s)
    g.add_edge(inp, b, F=f2, s=s)
    lm = (lo - f3) + 1
    if lm < 4:
        return
    m = g.add_node("m", lm, 1, is_output=True)
    g.add_edge(a, m, F=f3, s=1)
    g.add_edge(b, m, F=f3, s=1)
    res = simulate_subgraph(g, {a, b, m}, seed=7)
    sched = derive_schedule(g, {a, b, m})
    for t, occ in res.max_occupancy.items():
        assert occ <= sched.tensors[t].x
