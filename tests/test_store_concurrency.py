"""Concurrency hardening of `ResultStore`: per-key cross-process locking,
stale-lock/stale-temp recovery, write/gc race protection, quarantine
safety under concurrent overwrites, and a multi-process hammer proving
"N identical requests -> exactly one search" end to end.
"""

import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import pytest

from repro.api import (
    ExploreSpec,
    ResultStore,
    StoreLockTimeout,
    StoreReadOnly,
    run,
    spec_key,
)
from repro.core import HWSpace, Objective
from repro.serve.plans import resolve_plan

REPO_SRC = Path(__file__).resolve().parents[1] / "src"
KEY = "a" * 64


def greedy_spec(**kw):
    defaults = dict(
        workload="synthetic:chain:6?seed=1",
        strategy="greedy",
        objective=Objective(metric="ema", alpha=None),
        hw=HWSpace(mode="fixed"),
        sample_budget=100,
        seed=0,
    )
    defaults.update(kw)
    return ExploreSpec(**defaults)


# ---------------------------------------------------------------------------
# exclusive(): the per-key lock
# ---------------------------------------------------------------------------

def test_exclusive_is_mutually_exclusive_across_threads(tmp_path):
    store = ResultStore(tmp_path)
    inside = []
    overlapped = []

    def worker():
        with store.exclusive(KEY, timeout=30.0, poll=0.001):
            inside.append(1)
            if len(inside) - len(overlapped) > 1:
                overlapped.append(1)
            time.sleep(0.01)
            overlapped.append(0)

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(inside) == 6
    assert 1 not in overlapped          # never two holders at once
    assert not store.lock_path(KEY).exists()


def test_exclusive_times_out_with_holder_info(tmp_path):
    store = ResultStore(tmp_path)
    held = threading.Event()
    release = threading.Event()

    def holder():
        with store.exclusive(KEY):
            held.set()
            release.wait(10)

    t = threading.Thread(target=holder)
    t.start()
    held.wait(10)
    try:
        with pytest.raises(StoreLockTimeout) as exc:
            with store.exclusive(KEY, timeout=0.2, poll=0.01):
                pass
        assert str(os.getpid()) in str(exc.value)   # holder pid surfaced
    finally:
        release.set()
        t.join()


def test_exclusive_reclaims_stale_lock(tmp_path):
    store = ResultStore(tmp_path)
    lock = store.lock_path(KEY)
    lock.write_text("999999@deadhost 0.0\n")
    old = time.time() - 10_000
    os.utime(lock, (old, old))
    t0 = time.monotonic()
    with store.exclusive(KEY, timeout=5.0, stale_after=1.0, poll=0.01):
        assert lock.exists()            # we hold a *fresh* lock now
    assert time.monotonic() - t0 < 4.0
    assert not lock.exists()
    assert not list(tmp_path.glob("*.stale-*"))     # reclaim leaves no grave


def test_exclusive_waits_for_fresh_lock(tmp_path):
    """A fresh lock (live holder) is never reclaimed, only waited on."""
    store = ResultStore(tmp_path)
    store.lock_path(KEY).write_text("live\n")
    with pytest.raises(StoreLockTimeout):
        with store.exclusive(KEY, timeout=0.2, poll=0.01):
            pass
    assert store.lock_path(KEY).exists()


def test_read_only_store_rejects_mutation(tmp_path):
    rw = ResultStore(tmp_path / "zoo")
    spec = greedy_spec()
    rw.put(spec, run(spec))
    ro = ResultStore(tmp_path / "zoo", read_only=True)
    assert ro.get(spec) is not None
    for call in (lambda: ro.put(spec, run(spec)),
                 lambda: ro.gc(0),
                 lambda: ro.clear(),
                 lambda: ro.exclusive(KEY).__enter__()):
        with pytest.raises(StoreReadOnly):
            call()
    with pytest.raises(FileNotFoundError):
        ResultStore(tmp_path / "missing", read_only=True)


# ---------------------------------------------------------------------------
# write/gc race protection
# ---------------------------------------------------------------------------

def test_dotfile_debris_is_invisible_to_readers(tmp_path):
    store = ResultStore(tmp_path)
    spec = greedy_spec()
    store.put(spec, run(spec))
    (tmp_path / ".tmp-abc123.tmp").write_text("in-progress write")
    (tmp_path / f".{KEY}.lock").write_text("held\n")
    (tmp_path / ".sneaky.json").write_text("{}")
    assert len(store) == 1
    assert [e.key for e in store.entries()] == [spec_key(spec)]
    assert store.total_bytes() == store.path_for(spec).stat().st_size
    with pytest.raises(KeyError):
        store.resolve_key(".sneaky.")


def test_gc_spares_fresh_debris_and_sweeps_stale(tmp_path):
    store = ResultStore(tmp_path)
    spec = greedy_spec()
    store.put(spec, run(spec))
    fresh_tmp = tmp_path / ".tmp-fresh.tmp"
    fresh_tmp.write_text("a concurrent put in progress")
    stale_tmp = tmp_path / ".tmp-stale.tmp"
    stale_tmp.write_text("crashed writer leftovers")
    stale_lock = tmp_path / f".{KEY}.lock"
    stale_lock.write_text("crashed holder\n")
    old = time.time() - 10_000
    for p in (stale_tmp, stale_lock):
        os.utime(p, (old, old))
    removed, _freed = store.gc(max_bytes=1 << 30, stale_after=600.0)
    assert removed == 2
    assert fresh_tmp.exists()                   # live write untouched
    assert not stale_tmp.exists() and not stale_lock.exists()
    assert len(store) == 1                      # the artifact survived


def test_gc_always_removes_quarantined_artifacts(tmp_path):
    store = ResultStore(tmp_path)
    spec = greedy_spec()
    path = store.put(spec, run(spec))
    path.write_text("garbage")                  # corrupt it in place
    assert store.get(spec) is None              # quarantined -> miss
    assert store.quarantined == 1
    assert path.with_suffix(".json.corrupt").exists()
    store.gc(max_bytes=1 << 30)
    assert not path.with_suffix(".json.corrupt").exists()


def test_quarantine_preserves_concurrent_fresh_overwrite(tmp_path):
    """A reader holding stale corrupt bytes must not quarantine the valid
    artifact a concurrent writer just published over them."""
    store = ResultStore(tmp_path)
    spec = greedy_spec()
    path = store.put(spec, run(spec))
    good = path.read_bytes()
    store._quarantine(path, reason="judged corrupt from stale bytes",
                      expected_payload=b"some old corrupt payload")
    assert path.read_bytes() == good            # fresh write preserved
    assert store.quarantined == 0
    assert not path.with_suffix(".json.corrupt").exists()


def test_crash_mid_write_then_recovery(tmp_path):
    """A writer that died mid-``put`` while holding the key lock leaves a
    stale temp file and a stale lock; the next resolver reclaims the lock,
    searches, publishes — and gc clears the debris.  Nothing is ever
    quarantined."""
    store = ResultStore(tmp_path)
    spec = greedy_spec()
    key = spec_key(spec)
    stale_tmp = tmp_path / ".tmp-dead.tmp"
    stale_tmp.write_text('{"half": "an artifa')
    lock = store.lock_path(key)
    lock.write_text("999999@deadhost 0.0\n")
    old = time.time() - 10_000
    for p in (stale_tmp, lock):
        os.utime(p, (old, old))
    res, source = resolve_plan(spec, store=store)
    assert source == "search"
    assert store.get(spec).to_json() == res.to_json()
    assert not list(tmp_path.glob("*.corrupt"))
    store.gc(max_bytes=1 << 30)
    assert not stale_tmp.exists()
    assert sorted(p.name for p in tmp_path.iterdir()) == [f"{key}.json"]


# ---------------------------------------------------------------------------
# the multi-process hammer: N processes, one spec, exactly one search
# ---------------------------------------------------------------------------

_HAMMER_WORKER = """
import sys, time, pathlib
store_dir, go_file = sys.argv[1], sys.argv[2]
from repro.api import ResultStore
from repro.serve.plans import resolve_plan
from test_store_concurrency import greedy_spec
spec = greedy_spec(workload="synthetic:layered:10?seed=9")
store = ResultStore(store_dir)
while not pathlib.Path(go_file).exists():
    time.sleep(0.005)
res, source = resolve_plan(spec, store=store)
print(f"{source} {res.cost!r}")
"""


def test_multiprocess_hammer_searches_exactly_once(tmp_path):
    n = 4
    store_dir = tmp_path / "store"
    go_file = tmp_path / "go"
    env = dict(os.environ,
               PYTHONPATH=f"{REPO_SRC}:{Path(__file__).parent}")
    procs = [subprocess.Popen(
        [sys.executable, "-c", _HAMMER_WORKER, str(store_dir), str(go_file)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, env=env)
        for _ in range(n)]
    go_file.write_text("go")
    outs = [p.communicate(timeout=120) for p in procs]
    assert all(p.returncode == 0 for p in procs), outs
    lines = [out.strip() for out, _err in outs]
    sources = sorted(line.split()[0] for line in lines)
    assert sources == ["search"] + ["store"] * (n - 1), lines
    assert len(set(lines)) <= 2 and len({l.split()[1] for l in lines}) == 1

    # the hammered store is bitwise-identical to a serial run's store
    spec = greedy_spec(workload="synthetic:layered:10?seed=9")
    serial = ResultStore(tmp_path / "serial")
    resolve_plan(spec, store=serial)
    key = spec_key(spec)
    assert (store_dir / f"{key}.json").read_bytes() == \
        (tmp_path / "serial" / f"{key}.json").read_bytes()
    # and no debris survived: one artifact, no locks, no temps, no corpses
    assert sorted(p.name for p in store_dir.iterdir()) == [f"{key}.json"]
