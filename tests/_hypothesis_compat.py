"""Optional-hypothesis shim: ``from _hypothesis_compat import given, settings, st``.

When hypothesis is installed (see requirements-dev.txt) this re-exports the
real API.  When it isn't, the decorators turn each property test into a
skipped test — so the suite still collects and every non-property test runs.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Absorbs any strategy construction (st.integers(...), @st.composite)."""

        def __call__(self, *_args, **_kwargs):
            return self

        def __getattr__(self, _name):
            return self

    st = _AnyStrategy()
