"""Multi-device semantics via subprocesses (the main process is locked to one
CPU device; these spawn fresh interpreters with
--xla_force_host_platform_device_count).

Covers: sharded train step == single-device train step (SPMD correctness),
pipeline-parallel stage loop, elastic checkpoint resharding.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

# the subprocesses import jax with a rebuilt PYTHONPATH, so gate on the
# parent's view of the install (optional dep: skip whole module when absent)
pytest.importorskip("jax")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=ROOT)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS
        from repro.configs import get_config
        from repro.models import lm_init, param_values, is_param
        from repro.parallel.sharding import mesh_context, logical_sharding
        from repro.launch.mesh import rules_for
        from repro.train import AdamWConfig, adamw_init
        from repro.train.trainstep import make_train_step
        from repro.data import DataConfig, SyntheticLM

        cfg = get_config('tinyllama-1.1b', smoke=True)
        opt_cfg = AdamWConfig(lr=1e-3, schedule='constant', warmup_steps=0)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=8, seed=0))
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
        opt = adamw_init(values, opt_cfg)
        step = make_train_step(cfg, opt_cfg)

        # single device
        p1, o1, m1 = jax.jit(step)(values, opt, batch)

        # 4x2 (data, model) mesh
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        rules = rules_for(cfg, 'train')
        with mesh, mesh_context(mesh, rules):
            ptree = jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))
            psh = jax.tree.map(lambda p: logical_sharding(p.axes, mesh),
                               ptree, is_leaf=is_param)
            vs = jax.device_put(values, psh)
            os_ = adamw_init(vs, opt_cfg)
            p2, o2, m2 = jax.jit(step)(vs, os_, batch)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
        worst = max(jax.tree_util.tree_leaves(d))
        print('LOSS', float(m1['loss']), float(m2['loss']), 'WORST', worst)
        assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-3
        assert worst < 5e-3, worst
        print('OK')
    """)
    out = run_py(code, devices=8)
    assert "OK" in out


def test_pipeline_stage_loop_matches_sequential():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply

        P, M, mb, d = 4, 8, 2, 16
        mesh = jax.make_mesh((P,), ('pod',))
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (P, d, d)) / np.sqrt(d)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

        def fn(w, h):
            return jnp.tanh(h @ w)

        got = pipeline_apply(fn, ws, x, mesh, axis='pod')
        want = x
        for s in range(P):
            want = jnp.tanh(want @ ws[s])
        err = float(jnp.max(jnp.abs(got - want)))
        print('ERR', err)
        assert err < 1e-5, err
        print('OK')
    """)
    out = run_py(code, devices=4)
    assert "OK" in out


def test_dryrun_cli_multi_pod_cell(tmp_path):
    """The dry-run entrypoint end-to-end: one light cell on the 512-device
    multi-pod mesh must lower, compile, and emit its roofline JSON."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)  # dryrun.py sets its own 512-device flag
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-350m",
         "--shape", "decode_32k", "--mesh", "multi", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    path = os.path.join(str(tmp_path),
                        "xlstm-350m__decode_32k__pod2x16x16.json")
    assert os.path.exists(path)
    with open(path) as f:
        row = json.load(f)
    assert row["devices"] == 512
    assert row["bottleneck"] in ("compute", "memory", "collective")


def test_tripaware_collective_counting():
    """Collectives inside a scan body count trip-count times (the basis of
    the roofline collective term)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.launch.roofline import (collective_bytes,
                                           collective_bytes_tripaware)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        w1 = jax.device_put(jnp.ones((16, 64, 64)),
                            NamedSharding(mesh, PS(None, None, 'model')))
        def f(x, w1):
            def body(c, w):
                y = c @ w
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, PS('data', None)))
                return jnp.tanh(y), None
            y, _ = jax.lax.scan(body, x, w1)
            return y.sum()
        x = jax.device_put(jnp.ones((8, 64)),
                           NamedSharding(mesh, PS('data', None)))
        text = jax.jit(jax.grad(f)).lower(x, w1).compile().as_text()
        plain, _ = collective_bytes(text)
        aware, _ = collective_bytes_tripaware(text)
        assert plain > 0
        ratio = aware / plain
        print('RATIO', ratio)
        assert 8 <= ratio <= 16.5, ratio   # 16-step scan dominates
        print('OK')
    """)
    out = run_py(code, devices=8)
    assert "OK" in out


def test_elastic_restart_reshards_checkpoint(tmp_path):
    save_code = textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.checkpoint import CheckpointConfig, CheckpointManager
        mesh = jax.make_mesh((8,), ('model',))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh, PS('model', None)))
        mgr = CheckpointManager(CheckpointConfig(directory=r'{tmp_path}',
                                                 async_save=False))
        mgr.save(5, {{'w': w}})
        print('SAVED')
    """)
    out = run_py(save_code, devices=8)
    assert "SAVED" in out
    restore_code = textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.checkpoint import CheckpointManager, CheckpointConfig, reshard_to
        from repro.runtime import plan_mesh, build_mesh
        # restart on 6 devices: elastic plan keeps model axis = 2
        plan = plan_mesh(6, model_parallel=2)
        mesh = build_mesh(plan)
        mgr = CheckpointManager(CheckpointConfig(directory=r'{tmp_path}'))
        restored, meta = mgr.restore({{'w': np.zeros((8, 8), np.float32)}})
        sh = {{'w': NamedSharding(mesh, PS('model', None))}}
        w = reshard_to(restored, sh)['w']
        assert meta['step'] == 5
        np.testing.assert_array_equal(np.asarray(w),
                                      np.arange(64.0).reshape(8, 8))
        print('RESHARDED to', w.sharding)
    """)
    out = run_py(restore_code, devices=6)
    assert "RESHARDED" in out
