"""Logical-axis sharding rules + mesh planning (single process, no devices
locked — specs only; multi-device execution covered by test_multidevice)."""

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as PS

from repro.configs import get_config
from repro.launch.mesh import rules_for
from repro.models import cache_axes, init_caches, is_param, lm_init
from repro.parallel.sharding import (
    DEFAULT_RULES,
    mesh_context,
    spec_for,
)


def fake_mesh(shape=(2, 2), names=("data", "model")):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, names)


def test_spec_resolution_and_pod_dropping():
    mesh = fake_mesh()
    spec = spec_for(("batch", None, "heads"), DEFAULT_RULES, mesh)
    # 'pod' doesn't exist on this mesh -> dropped from the batch entry
    assert spec == PS("data", None, "model")


def test_duplicate_mesh_axis_suppressed():
    mesh = fake_mesh()
    spec = spec_for(("heads", "ff"), DEFAULT_RULES, mesh)  # both -> model
    assert spec == PS("model", None)


def test_multi_pod_batch_spec():
    mesh = fake_mesh((2, 2, 2), ("pod", "data", "model"))
    spec = spec_for(("batch", "seq"), DEFAULT_RULES, mesh)
    assert spec == PS(("pod", "data"), None)


def test_rules_disable_unshardable_axes():
    cfg = get_config("xlstm-350m")  # 4 heads: cannot shard 16 ways
    rules = rules_for(cfg, "train")
    assert rules["heads"] is None
    assert rules["kv_heads"] is None
    cfg2 = get_config("glm4-9b")    # 2 kv heads
    rules2 = rules_for(cfg2, "train")
    assert rules2["kv_heads"] is None
    assert rules2["heads"] == "model"


def test_decode_rules_shard_cache_sequence():
    cfg = get_config("glm4-9b")
    rules = rules_for(cfg, "decode")
    assert rules["seq_kv"] == "model"
    long_rules = rules_for(cfg, "decode_long")
    assert long_rules["seq_kv"] == ("data", "model")
    assert long_rules["batch"] is None


def test_param_axes_align_with_tree():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    ptree = jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))
    leaves = [p for p in jax.tree_util.tree_leaves(
        ptree, is_leaf=is_param) if is_param(p)]
    assert leaves, "eval_shape should preserve Param nodes"
    for p in leaves:
        assert len(p.axes) == len(p.value.shape), (p.axes, p.value.shape)


def test_cache_axes_structure_matches_caches():
    import jax.numpy as jnp
    for arch in ("glm4-9b", "deepseek-v2-236b", "jamba-v0.1-52b",
                 "xlstm-350m", "gemma3-4b"):
        cfg = get_config(arch, smoke=True)
        caches = jax.eval_shape(lambda c=cfg: init_caches(c, 2, 64,
                                                          jnp.float32))
        axes = cache_axes(cfg)
        cl = jax.tree_util.tree_structure(caches)
        al = jax.tree_util.tree_structure(
            axes, is_leaf=lambda x: isinstance(x, tuple))
        assert cl == al, arch
        flat_c = jax.tree_util.tree_leaves(caches)
        flat_a = jax.tree_util.tree_leaves(
            axes, is_leaf=lambda x: isinstance(x, tuple))
        for c, a in zip(flat_c, flat_a):
            assert len(a) == len(c.shape), (arch, a, c.shape)
