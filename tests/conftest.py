"""Shared test graphs (previously scattered across test modules and pulled
in through fragile ``from tests.test_*`` imports).

Plain helpers (not fixtures) so hypothesis property tests can build fresh
graphs per example: ``from conftest import small_graph`` works because
pytest puts this directory on ``sys.path`` (rootdir insertion, no
``__init__.py`` here).
"""

from repro.core import Graph


def pytest_configure(config):
    # Regression guard for the jax-after-fork class of bugs: CPython warns
    # (and jax can deadlock) when a process pool forks a process that
    # already imported the multithreaded jax runtime.  The engine's pools
    # switch to the forkserver start method once jax is loaded
    # (repro.core.engine.pool_mp_context), so any reappearance of this
    # warning is a real bug — fail loudly instead of scrolling by.
    config.addinivalue_line(
        "filterwarnings",
        "error:os\\.fork\\(\\) was called:RuntimeWarning")


def small_graph():
    """An 8-node two-diamond graph."""
    g = Graph("dd")
    n = [g.add_node(f"n{i}", 32, 16, weight_bytes=256, macs=10_000)
         for i in range(8)]
    edges = [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (4, 5), (4, 6), (5, 7),
             (6, 7)]
    for a, b in edges:
        g.add_edge(n[a], n[b], F=1, s=1)
    g.nodes[n[7]].is_output = True
    return g


def chain_graph(length=64, specs=((3, 1), (3, 2), (2, 1))):
    """A 1D conv chain; returns (graph, internal-node set)."""
    g = Graph("chain")
    prev = g.add_node("in", length, 1)
    nodes = []
    cur = length
    for i, (F, s) in enumerate(specs):
        cur = (cur - F) // s + 1
        idx = g.add_node(f"l{i}", cur, 1)
        g.add_edge(prev, idx, F=F, s=s)
        nodes.append(idx)
        prev = idx
    g.nodes[prev].is_output = True
    return g, set(nodes)


def fig5_like_graph():
    """A 1D two-input diamond with heterogeneous kernels/strides, in the
    spirit of the paper's Fig. 5 example: output nodes drive backward
    derivation with LCM alignment."""
    g = Graph("fig5")
    n_m2 = g.add_node("in-2", out_len=64, line_bytes=1)       # input node -2
    n_m1 = g.add_node("in-1", out_len=33, line_bytes=1)       # input node -1
    n0 = g.add_node("n0", out_len=30, line_bytes=1)           # F=4, s=2 on in-2
    n1 = g.add_node("n1", out_len=31, line_bytes=1)           # F=3/s=2 ; F=3/s=1
    n2 = g.add_node("n2", out_len=31, line_bytes=1)           # F=3, s=1 on in-1
    n3 = g.add_node("n3", out_len=30, line_bytes=1, is_output=True)
    n4 = g.add_node("n4", out_len=30, line_bytes=1, is_output=True)
    g.add_edge(n_m2, n0, F=4, s=2)
    g.add_edge(n_m2, n1, F=3, s=2)
    g.add_edge(n_m1, n1, F=3, s=1)   # n1 merges two inputs (strides 2 and 1)
    g.add_edge(n_m1, n2, F=3, s=1)
    g.add_edge(n0, n3, F=1, s=1)
    g.add_edge(n1, n3, F=2, s=1)
    g.add_edge(n1, n4, F=2, s=1)
    g.add_edge(n2, n4, F=2, s=1)
    return g, (n_m2, n_m1, n0, n1, n2, n3, n4)
