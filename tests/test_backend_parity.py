"""The cross-backend differential-parity suite (acceptance gate for the
``jax`` executor backend, and for any future backend).

Sweeps the harness corpus (``tests/backend_parity.py``: golden workloads
from all four URI schemes + seeded ``synthetic:`` fuzz graphs + adversarial
guard-boundary hardware points) through every available backend and asserts
exact ``SubgraphCost`` equality field-by-field, plus full-strategy bitwise
invariance: all six strategies produce byte-identical ``ExploreResult``s
across all backends for fixed seeds.

When jax is not installed the jax rows *skip* (they never fail) — the
``test-jax-backend`` CI job runs them, the default job proves the skips.
"""

import random

import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from backend_parity import (
    SYNTH_KINDS,
    assert_backend_parity,
    assert_costs_equal,
    available_backends,
    backend_params,
    corpus_queries,
    fuzz_corpus,
    scheme_corpus,
    strategy_results,
)
from conftest import small_graph

from repro.api import (
    EnumOptions,
    ExploreSpec,
    GAOptions,
    SAOptions,
    build_workload,
    list_strategies,
)
from repro.core import (
    AcceleratorConfig,
    CachedEvaluator,
    HWSpace,
    Objective,
    compute_structure,
    evaluate_subgraph,
    finish_cost,
    make_executor,
    random_partition,
)

KB = 1 << 10


# ---------------------------------------------------------------------------
# corpus sweeps: SubgraphCost equality field-by-field
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,jobs", backend_params())
def test_scheme_corpus_parity(backend, jobs):
    """Golden workloads of all four URI schemes, adversarial HW points."""
    for label, g, queries in scheme_corpus():
        assert_backend_parity(g, queries, backend, jobs)


@pytest.mark.parametrize("backend,jobs", backend_params())
def test_fuzz_corpus_parity(backend, jobs):
    """Seeded synthetic fuzz graphs of every generator kind."""
    for label, g, queries in fuzz_corpus():
        assert_backend_parity(g, queries, backend, jobs)


def test_jax_pallas_variant_matches_serial():
    """The Pallas streaming-block kernel variant is bit-identical too."""
    if not available_backends(include_serial=False):
        pytest.skip("no non-serial backends")
    if ("jax", 1) not in available_backends():
        pytest.skip("jax not installed")
    for label, g, queries in scheme_corpus():
        assert_backend_parity(g, queries, "jax", pallas=True)


def test_jax_executor_handles_empty_and_all_fallback_batches():
    if ("jax", 1) not in available_backends():
        pytest.skip("jax not installed")
    from repro.core.cost import CostKernel

    g = small_graph()
    ex = make_executor("jax")
    assert ex.evaluate(CostKernel(g), []) == []
    # every lane beyond the float64-exact guard -> pure scalar-fallback batch
    acc = AcceleratorConfig(glb_bytes=1 << 60, wbuf_bytes=1 << 60)
    queries = [(frozenset({v}), acc) for v in range(4)]
    got = ex.evaluate(CostKernel(g), queries)
    want = [CostKernel(g).cost(n, a) for n, a in queries]
    for a, b in zip(got, want):
        assert_costs_equal(a, b, "all-fallback batch")


# ---------------------------------------------------------------------------
# full-strategy bitwise invariance (all six strategies x all backends)
# ---------------------------------------------------------------------------

def _strategy_spec(strategy, workload="dd"):
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    options = {
        "ga": GAOptions(population=16),
        "sa": SAOptions(),
        "enum": EnumOptions(state_budget=20_000),
    }.get(strategy)
    return ExploreSpec(
        workload=workload,
        strategy=strategy,
        objective=Objective(metric="energy", alpha=0.002),
        hw=HWSpace(mode="shared", base=acc),
        sample_budget=240,
        seed=0,
        options=options,
    )


@pytest.mark.parametrize("strategy", sorted(list_strategies()))
def test_all_strategies_bitwise_invariant_across_backends(strategy):
    spec = _strategy_spec(strategy)
    results = strategy_results(spec, small_graph())
    assert len(results) >= 2  # serial + at least one batched backend
    reference = results.pop("serial")
    for backend, got in results.items():
        assert got == reference, (
            f"strategy {strategy!r}: backend {backend!r} diverged from "
            f"serial")


def test_strategy_invariance_on_a_real_workload():
    """One heavier cross-check on a resolver workload (GA, co-exploration
    HW space) so invariance is not only pinned on the toy graph."""
    spec = _strategy_spec("ga", workload="synthetic:layered:24?seed=7")
    g = build_workload(spec.workload)
    results = strategy_results(spec, g)
    reference = results.pop("serial")
    for backend, got in results.items():
        assert got == reference, f"{backend} diverged"


# ---------------------------------------------------------------------------
# property-based fuzz: random feasible (graph, plan, acc) triples
# (hypothesis when present; the manual sweep below is the no-hypothesis
#  fallback and always runs)
# ---------------------------------------------------------------------------

def _check_triple(kind, n, gseed, pseed):
    """One fuzz case: parity of every backend on a random partition of a
    random synthetic graph at random + stress hardware points, plus the
    pure-kernel identity ``evaluate_subgraph == finish_cost(
    compute_structure(...))``."""
    g = build_workload(f"synthetic:{kind}:{n}?seed={gseed}")
    rng = random.Random(pseed)
    hw = HWSpace(mode="separate")
    accs = [hw.sample(rng),
            AcceleratorConfig(glb_bytes=2 * KB, wbuf_bytes=2 * KB),
            AcceleratorConfig(glb_bytes=96 * KB, wbuf_bytes=0, shared=True)]
    groups = random_partition(g, rng, mean_size=rng.uniform(1.5, 5.0))
    queries = [(frozenset(s), acc) for acc in accs for s in groups]
    for acc in accs:
        for s in groups:
            assert evaluate_subgraph(g, set(s), acc) == \
                finish_cost(compute_structure(g, set(s)), acc)
    serial_plans = [CachedEvaluator(g).plan(groups, acc) for acc in accs]
    for backend, jobs in available_backends(include_serial=False):
        assert_backend_parity(g, queries, backend, jobs)
        # plan-level: the batched plan path reproduces the serial plans
        ev = CachedEvaluator(g, executor=make_executor(backend, jobs))
        try:
            plans = ev.plan_batch([(groups, acc) for acc in accs])
        finally:
            ev.close()
        for got, want in zip(plans, serial_plans):
            assert len(got.subgraphs) == len(want.subgraphs)
            for a, b in zip(got.subgraphs, want.subgraphs):
                assert_costs_equal(a, b, f"plan_batch[{backend}]")
            assert got.ema_total == want.ema_total
            assert got.energy_pj == want.energy_pj


@given(kind=st.sampled_from(SYNTH_KINDS), n=st.integers(2, 20),
       gseed=st.integers(0, 1_000), pseed=st.integers(0, 1_000))
@settings(max_examples=25, deadline=None)
def test_property_backend_parity_random_triples(kind, n, gseed, pseed):
    _check_triple(kind, n, gseed, pseed)


def test_manual_sweep_backend_parity_random_triples():
    """Deterministic fuzz sweep, >= 100 cases: the no-hypothesis fallback
    (this is the path CPU-only/no-dev containers exercise)."""
    cases = [(kind, 4 + (gseed * 7 + pseed * 3) % 13, gseed, pseed)
             for kind in SYNTH_KINDS
             for gseed in range(7)
             for pseed in range(3)]
    assert len(cases) >= 100
    for kind, n, gseed, pseed in cases:
        _check_triple(kind, n, gseed, pseed)


def test_manual_sweep_runs_even_with_hypothesis_present():
    """The fallback sweep is not itself hypothesis-gated."""
    import inspect

    src = inspect.getsource(test_manual_sweep_backend_parity_random_triples)
    assert "@given" not in src
    assert HAVE_HYPOTHESIS in (True, False)  # the shim always defines it
