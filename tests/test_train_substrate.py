"""Optimizer, train step, microbatching, data pipeline."""

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, PrefetchingLoader, SyntheticLM
from repro.models import lm_init, param_values
from repro.train import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
    make_train_step,
    schedule_lr,
)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, clip_norm=0.0,
                      schedule="constant")
    state = adamw_init(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - jnp.array([1.0, 2.0, -1.0])))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               [1.0, 2.0, -1.0], atol=1e-2)


def test_clip_norm_limits_update():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                      schedule="constant")
    state = adamw_init(params, cfg)
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw_update(g, state, params, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert float(metrics["clip_scale"]) == pytest.approx(1 / 200.0)


def test_lr_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine", min_lr_frac=0.1)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1.0)
    assert lrs[100] == pytest.approx(0.1, abs=1e-6)
    assert all(b <= a + 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


def test_train_step_reduces_loss_over_steps():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                          schedule="cosine")
    opt = adamw_init(values, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=8, seed=0))
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        values, opt, metrics = step(values, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::8]
    assert np.isfinite(losses).all()


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    values = param_values(lm_init(jax.random.PRNGKey(0), cfg))
    opt_cfg = AdamWConfig(lr=1e-3, schedule="constant", warmup_steps=0)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16,
                                  global_batch=8, seed=1))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    s1 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=1))
    s4 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=4))
    p1, _, _ = s1(values, adamw_init(values, opt_cfg), batch)
    p4, _, _ = s4(values, adamw_init(values, opt_cfg), batch)
    # microbatch mean-of-grads == full-batch grad only if every microbatch
    # has identical token counts (true here); updates must agree closely
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree_util.tree_leaves(d)) < 5e-3


def test_synthetic_data_is_deterministic_and_learnable():
    cfg = DataConfig(vocab=97, seq_len=64, global_batch=4, seed=7)
    a = SyntheticLM(cfg).batch_at(3)
    b = SyntheticLM(cfg).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetching_loader_replays_stream():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=2, seed=3)
    src = SyntheticLM(cfg)
    loader = PrefetchingLoader(src, start_step=0)
    first = next(loader)
    loader.close()
    np.testing.assert_array_equal(first["tokens"], src.batch_at(0)["tokens"])
