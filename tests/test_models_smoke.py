"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
asserting output shapes and no NaNs (deliverable f)."""

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import (
    encdec_apply,
    init_caches,
    lm_apply,
    lm_init,
    lm_loss,
    param_values,
)

B, S = 2, 32


def make_batch(cfg, key):
    kt, ke = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(ke, (B, 16, cfg.d_model))
    elif cfg.frontend != "none":
        batch["extra_embeds"] = jax.random.normal(
            ke, (B, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, smoke=True)
            key = jax.random.PRNGKey(0)
            params = lm_init(key, cfg)
            cache[arch] = (cfg, param_values(params))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(built, arch):
    cfg, values = built(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    if cfg.is_encdec:
        logits, _, enc_out, _ = encdec_apply(values, cfg, batch["frames"],
                                             batch["tokens"])
        assert enc_out.shape == (B, 16, cfg.d_model)
    else:
        logits, _, _ = lm_apply(values, cfg, batch["tokens"],
                                extra_embeds=batch.get("extra_embeds"))
        s_extra = 0 if "extra_embeds" not in batch else cfg.n_frontend_tokens
        assert logits.shape == (B, S + s_extra, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch


@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.xfail(
        # Not a gradient bug: along -grad the loss decreases at 0.3x/0.1x/
        # 0.03x/0.01x of this test's normalized step, but the full step
        # crosses a top-k routing (capacity-dispatch) boundary of the MoE
        # objective and lands higher (6.2213 -> 6.2499).  The objective is
        # only piecewise-smooth in the router params, so a fixed-size step
        # is not guaranteed to descend; flaky at the seed, kept non-strict.
        reason="MoE top-k routing discontinuity at this init/step size",
        strict=False)) if a == "arctic-480b" else a
    for a in ARCHS])
def test_train_step_decreases_loss(built, arch):
    """One SGD step on a fixed batch must reduce the loss (gradients flow)."""
    cfg, values = built(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(2))

    def loss_fn(v):
        return lm_loss(v, cfg, batch)[0]

    loss0, grads = jax.value_and_grad(loss_fn)(values)
    assert bool(jnp.isfinite(loss0)), arch
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
    assert bool(gnorm > 0), f"{arch}: zero gradients"
    lr = 1e-2 / np.sqrt(float(gnorm) + 1e-9)
    stepped = jax.tree.map(lambda v, g: v - lr * g.astype(v.dtype),
                           values, grads)
    loss1 = loss_fn(stepped)
    assert bool(jnp.isfinite(loss1)), arch
    assert float(loss1) < float(loss0) + 1e-3, (
        f"{arch}: loss {loss0} -> {loss1}")


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(built, arch):
    """Token-by-token decode with caches must agree with the full forward."""
    cfg, values = built(arch)
    if cfg.is_encdec:
        pytest.skip("enc-dec decode covered in test_serve")
    if cfg.n_experts:
        # MoE capacity dropping differs between 32-token prefill and 1-token
        # decode steps (expected); raise capacity so no tokens drop and the
        # equivalence is exact.
        cfg = cfg.with_(capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full_logits, _, _ = lm_apply(values, cfg, tokens)

    caches = init_caches(cfg, B, max_len=S + 4, dtype=jnp.float32)

    @jax.jit
    def decode(values, caches, tok, pos):
        lg, caches, _ = lm_apply(values, cfg, tok, positions=pos,
                                 caches=caches)
        return lg, caches

    outs = []
    for t in range(S):
        pos = jnp.full((B, 1), t, jnp.int32)
        lg, caches = decode(values, caches, tokens[:, t: t + 1], pos)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-3,
    )


def test_param_counts_match_assignment_scale():
    """Full configs land in the advertised parameter ranges."""
    expect = {
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "glm4-9b": (8e9, 10.5e9),
        "gemma3-4b": (3e9, 5e9),
        "granite-3-8b": (7e9, 9.5e9),
        "xlstm-350m": (0.25e9, 0.55e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "arctic-480b": (420e9, 520e9),
        "llava-next-34b": (30e9, 38e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = cfg.param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_layout_periods_are_small():
    """Scan layout keeps unrolled HLO small for every arch."""
    for arch in ARCHS:
        cfg = get_config(arch)
        pre, p, reps, rem = cfg.layout()
        assert pre + p + rem <= 12, (arch, cfg.layout())
        assert pre + p * reps + rem == cfg.n_layers
