"""Canonical structure memoization: the content-fingerprint tier of
:class:`CostKernel`, the disk-backed :class:`StructureCache`, and the
cross-process shipping of canonical entries.

The load-bearing property, fuzzed here: *equal canonical keys imply
field-for-field equal structures* (up to the ``nodes`` stamp) — so a
canonical hit is bitwise-indistinguishable from a fresh
``compute_structure`` call, and every golden artifact stays byte-identical
with the memo on.
"""

import random
from dataclasses import asdict
from dataclasses import fields as dataclass_fields

import pytest
from _hypothesis_compat import given, settings, st
from backend_parity import SYNTH_KINDS, scheme_corpus
from conftest import small_graph

from repro.api import build_workload
from repro.core import (
    AcceleratorConfig,
    CachedEvaluator,
    CostKernel,
    Graph,
    compute_structure,
    make_executor,
    random_partition,
)
from repro.core.cost import SubgraphStructure, canonical_structure_key
from repro.core.structcache import StructureCache

KB = 1 << 10

_STRUCT_PAYLOAD = tuple(f.name for f in dataclass_fields(SubgraphStructure)
                        if f.name != "nodes")


def _node_sets(g, seed=0, n_parts=4):
    """Distinct node sets from random partitions (the GA query shape)."""
    rng = random.Random(seed)
    seen, out = set(), []
    for _ in range(n_parts):
        for s in random_partition(g, rng, mean_size=rng.uniform(1.5, 6.0)):
            fs = frozenset(s)
            if fs not in seen:
                seen.add(fs)
                out.append(fs)
    return out


def _assert_structs_equal(got, want, context=""):
    ga, wa = asdict(got), asdict(want)
    assert ga == wa, (
        f"structure mismatch {context}: "
        + "; ".join(f"{k}: {ga[k]!r} != {wa[k]!r}"
                    for k in ga if ga[k] != wa[k]))


# ---------------------------------------------------------------------------
# canonical hits are bitwise-identical to fresh computation
# ---------------------------------------------------------------------------

def test_canonical_structures_match_fresh_on_scheme_corpus():
    """Every URI scheme's golden workload, warm canonical memo vs fresh
    compute_structure: field-for-field equality including the nodes stamp."""
    for label, g, _queries in scheme_corpus():
        kernel = CostKernel(g, canonical=True)
        for fs in _node_sets(g, seed=7):
            _assert_structs_equal(kernel.structure(fs),
                                  compute_structure(g, set(fs)),
                                  context=f"[{label}] nodes={sorted(fs)}")


def test_canonical_structures_match_fresh_on_synthetic_sweep():
    """Deterministic fuzz sweep over every synthetic kind (the
    no-hypothesis fallback path)."""
    cases = [(kind, 4 + (gseed * 7 + pseed * 3) % 13, gseed, pseed)
             for kind in SYNTH_KINDS
             for gseed in range(4)
             for pseed in range(2)]
    for kind, n, gseed, pseed in cases:
        g = build_workload(f"synthetic:{kind}:{n}?seed={gseed}")
        kernel = CostKernel(g, canonical=True)
        for fs in _node_sets(g, seed=pseed, n_parts=3):
            _assert_structs_equal(kernel.structure(fs),
                                  compute_structure(g, set(fs)),
                                  context=f"[{kind}:{n}?seed={gseed}] "
                                          f"nodes={sorted(fs)}")


@given(kind=st.sampled_from(SYNTH_KINDS), n=st.integers(2, 20),
       gseed=st.integers(0, 1_000), pseed=st.integers(0, 1_000))
@settings(max_examples=25, deadline=None)
def test_property_canonical_structures_match_fresh(kind, n, gseed, pseed):
    g = build_workload(f"synthetic:{kind}:{n}?seed={gseed}")
    kernel = CostKernel(g, canonical=True)
    for fs in _node_sets(g, seed=pseed, n_parts=3):
        _assert_structs_equal(kernel.structure(fs),
                              compute_structure(g, set(fs)))


def test_canonical_costs_equal_canonical_off():
    """The full cost (structure + finish) is invariant under the memo."""
    g = build_workload("tpu:gemma3-4b:0?tokens=512")
    on, off = CostKernel(g, canonical=True), CostKernel(g, canonical=False)
    accs = [AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB),
            AcceleratorConfig(glb_bytes=512 * KB, wbuf_bytes=0, shared=True)]
    for fs in _node_sets(g, seed=3):
        for acc in accs:
            assert asdict(on.cost(fs, acc)) == asdict(off.cost(fs, acc))
    assert on.structure_canon_hits > 0  # the workload has repeated blocks
    assert on.structure_misses < off.structure_misses


# ---------------------------------------------------------------------------
# isomorphic subgraphs collapse to one derivation
# ---------------------------------------------------------------------------

def test_isomorphic_subgraphs_share_one_entry():
    g = small_graph()  # nodes 1 and 2 are the isomorphic diamond arms
    kernel = CostKernel(g, canonical=True)
    st1 = kernel.structure(frozenset({1}))
    st2 = kernel.structure(frozenset({2}))
    assert kernel.structure_misses == 1
    assert kernel.structure_canon_hits == 1
    assert st1.nodes == (1,) and st2.nodes == (2,)  # re-stamped per query
    assert all(getattr(st1, f) == getattr(st2, f) for f in _STRUCT_PAYLOAD)
    # the two-node arms {1,3} / {2,3} are isomorphic too
    kernel.structure(frozenset({1, 3}))
    kernel.structure(frozenset({2, 3}))
    assert kernel.structure_misses == 2
    assert kernel.structure_canon_hits == 2
    # raw tier answers repeats without touching the canonical tier
    kernel.structure(frozenset({2}))
    assert kernel.structure_raw_hits == 1
    assert kernel.structure_canon_hits == 2


def test_canonical_key_distinguishes_non_isomorphic():
    g = small_graph()
    keys = {canonical_structure_key(g, s)
            for s in ({1}, {0}, {1, 3}, {0, 1}, {0, 1, 2, 3})}
    assert len(keys) == 5  # {0} has no producer, {1} does; etc.
    assert canonical_structure_key(g, {1}) == canonical_structure_key(g, {2})
    assert (canonical_structure_key(g, {1, 3})
            == canonical_structure_key(g, {2, 3}))
    # out_tile is part of the fingerprint
    assert (canonical_structure_key(g, {1}, out_tile=2)
            != canonical_structure_key(g, {1}, out_tile=1))


def _stride_mismatch_graph():
    """Two disjoint isomorphic copies of a diamond whose parallel paths
    carry mismatched total strides, so ``derive_schedule`` fails with a
    message naming concrete node indices."""
    g = Graph("mismatch")
    copies = []
    for c in range(2):
        x = g.add_node(f"x{c}", 64, 1)
        y1 = g.add_node(f"y1_{c}", 32, 1)
        y2 = g.add_node(f"y2_{c}", 64, 1)
        z = g.add_node(f"z{c}", 32, 1, is_output=True)
        g.add_edge(x, y1, F=1, s=2)   # total stride to z: 2
        g.add_edge(x, y2, F=1, s=1)   # total stride to z: 1 -> mismatch
        g.add_edge(y1, z, F=1, s=1)
        g.add_edge(y2, z, F=2, s=1)
        copies.append({x, y1, y2, z})
    return g, copies


def test_sched_error_structures_never_cached_canonically():
    """Error messages embed node indices, so isomorphic failing subgraphs
    must each derive their own (label-correct) error."""
    g, (a, b) = _stride_mismatch_graph()
    kernel = CostKernel(g, canonical=True)
    st_a = kernel.structure(frozenset(a))
    st_b = kernel.structure(frozenset(b))
    assert st_a.sched_error is not None and st_b.sched_error is not None
    assert st_a.sched_error != st_b.sched_error  # each names its own nodes
    assert kernel.structure_misses == 2          # no canonical sharing
    assert kernel.structure_canon_hits == 0
    assert len(kernel.canon_snapshot()) == 0
    _assert_structs_equal(st_a, compute_structure(g, a))
    _assert_structs_equal(st_b, compute_structure(g, b))
    # the raw tier still answers exact repeats
    kernel.structure(frozenset(a))
    assert kernel.structure_raw_hits == 1


# ---------------------------------------------------------------------------
# the disk-backed StructureCache
# ---------------------------------------------------------------------------

def test_structcache_roundtrip_and_warm_start(tmp_path):
    g = small_graph()
    cache = StructureCache(tmp_path / "structs")
    k1 = CostKernel(g, canonical=True, struct_cache=cache)
    sets = [frozenset({1}), frozenset({2}), frozenset({1, 3}),
            frozenset({0, 1, 2, 3})]
    for fs in sets:
        k1.structure(fs)
    assert cache.writes == k1.structure_misses == 3  # {2},{2,3} were canon
    assert len(cache) == 3
    # a fresh kernel over the same directory derives nothing
    cache2 = StructureCache(tmp_path / "structs")
    k2 = CostKernel(g, canonical=True, struct_cache=cache2)
    for fs in sets:
        _assert_structs_equal(k2.structure(fs), compute_structure(g, set(fs)))
    assert k2.structure_misses == 0
    assert k2.structure_disk_hits == 3   # one per distinct fingerprint
    assert k2.structure_canon_hits == 1  # {2} hits the adopted {1} entry


def test_structcache_rejects_corrupt_and_foreign_entries(tmp_path):
    g = small_graph()
    cache = StructureCache(tmp_path)
    key = canonical_structure_key(g, {1})
    st = compute_structure(g, {1})
    cache.put(key, st)
    got = cache.get(key)
    assert got is not None and got.nodes == ()
    assert all(getattr(got, f) == getattr(st, f) for f in _STRUCT_PAYLOAD)
    # tampered payload -> miss, not a wrong answer
    path = cache._path(key)
    path.write_text("{not json")
    assert cache.get(key) is None
    # an entry whose embedded key disagrees with the query key -> miss
    other = canonical_structure_key(g, {0, 1})
    cache.put(other, compute_structure(g, {0, 1}))
    cache._path(other).replace(path)
    assert cache.get(key) is None
    assert cache.get(canonical_structure_key(g, {4})) is None  # absent


def test_structcache_refuses_sched_error_entries(tmp_path):
    g, (a, _b) = _stride_mismatch_graph()
    cache = StructureCache(tmp_path)
    st = compute_structure(g, a)
    assert st.sched_error is not None
    with pytest.raises(ValueError, match="sched_error"):
        cache.put(canonical_structure_key(g, a), st)
    assert len(cache) == 0


# ---------------------------------------------------------------------------
# cross-process shipping (process backend, parallel compare)
# ---------------------------------------------------------------------------

def test_process_workers_ship_canonical_structures_back():
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    ev = CachedEvaluator(g, executor=make_executor("process", 2))
    try:
        queries = [(fs, acc) for fs in _node_sets(g, seed=5)]
        ev.evaluate_batch(queries)
    finally:
        ev.close()
    canon = ev.structure_snapshot()
    assert canon, "parent adopted no canonical entries from workers"
    assert ev.kernel.structure_merged == len(canon)
    # adopted entries are real structures: payload matches fresh derivation
    # (the wire format ships them label-free, nodes=(), like the disk tier)
    by_key = {canonical_structure_key(g, set(fs)): fs for fs, _ in queries}
    for key, st in canon.items():
        assert st.sched_error is None
        assert st.nodes == ()
        want = compute_structure(g, set(by_key[key]))
        assert all(getattr(st, f) == getattr(want, f)
                   for f in _STRUCT_PAYLOAD)
    # the parent now serves those fingerprints without deriving
    before = ev.kernel.structure_misses
    for fs, _ in queries:
        kernel_st = ev.kernel.structure(frozenset(fs))
        _assert_structs_equal(kernel_st, compute_structure(g, set(fs)))
    assert ev.kernel.structure_misses == before


def test_process_workers_share_disk_cache(tmp_path):
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    cache = StructureCache(tmp_path / "structs")
    ev = CachedEvaluator(g, struct_cache=cache,
                         executor=make_executor("process", 2))
    try:
        ev.evaluate_batch([(fs, acc) for fs in _node_sets(g, seed=5)])
    finally:
        ev.close()
    assert len(cache) > 0  # workers wrote through to the shared directory
    # a cold serial evaluator warm-starts from the directory alone
    ev2 = CachedEvaluator(g, struct_cache=StructureCache(tmp_path / "structs"))
    ev2.subgraph({1}, acc)
    assert ev2.kernel.structure_misses == 0
    assert ev2.kernel.structure_disk_hits == 1


# ---------------------------------------------------------------------------
# deterministic CI smoke: pinned counter values on a fixed tpu: workload
# ---------------------------------------------------------------------------

def test_canonical_hit_counts_pinned_on_tpu_block():
    """A fixed workload + fixed query corpus yields exactly reproducible
    cache-tier counters (the CI smoke for the structure-half fast path).

    The 11-node gemma3 block is attribute-heterogeneous, so only its truly
    isomorphic queries collapse (29 distinct node sets -> 27 derivations);
    the big collapses live in models with repeated blocks
    (``netlib:``/``synthetic:``), exercised by the corpus tests above and
    measured in docs/benchmarks.md."""
    g = build_workload("tpu:gemma3-4b:0?tokens=512")
    assert g.n == 11
    kernel = CostKernel(g, canonical=True)
    sets = list(_node_sets(g, seed=7, n_parts=12))
    singles = [frozenset({v}) for v in range(g.n)]
    sets += [fs for fs in singles if fs not in set(sets)]
    for fs in sets:
        kernel.structure(fs)
    for fs in sets:  # second pass: all raw hits
        kernel.structure(fs)
    assert len(sets) == 29
    assert kernel.structure_misses == 27
    assert kernel.structure_canon_hits == len(sets) - 27
    assert kernel.structure_raw_hits == len(sets)
