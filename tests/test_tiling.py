"""Consumption-centric execution scheme (paper §3.1, Fig. 4–6)."""

import math
from fractions import Fraction

import pytest
from _hypothesis_compat import given, settings, st
from conftest import fig5_like_graph

from repro.core import FULL, Graph, derive_schedule, sequential_graph
from repro.core.tiling import production_centric_footprint


def test_chain_backward_derivation():
    """Paper footnote 1: x(u) = F + (tile-1)*s backwards through a chain."""
    gg = Graph("chain")
    inp = gg.add_node("in", 64, 1)
    a = gg.add_node("c0", 62, 1)
    b = gg.add_node("c1", 30, 1)
    c = gg.add_node("c2", 28, 1, is_output=True)
    gg.add_edge(inp, a, F=3, s=1)
    gg.add_edge(a, b, F=3, s=2)
    gg.add_edge(b, c, F=3, s=1)
    sched = derive_schedule(gg, {a, b, c}, out_tile=1)
    t = sched.tensors
    # output: delta=1, x=1
    assert t[c].delta == 1 and t[c].x == 1
    # b: consumer c has delta=1, s=1 -> delta(b)=1, x = f_c(1) = 3
    assert t[b].delta == 1 and t[b].x == 3
    # a: consumer b has F=3, s=2: delta(a) = lcm(1*2) = 2,
    # x = f_b(2/2=1) = F + delta - s = 3
    assert t[a].delta == 2 and t[a].x == 3
    # input: consumer a delta=2, s=1 -> delta=2, x = f_a(2) = 3+(2-1) = 4
    assert t[inp].delta == 2 and t[inp].x == 4
    assert t[inp].external


def test_lcm_alignment_two_consumers():
    """Delta(u) = lcm{Delta(v)*s(v)} over consumers (paper stage 2)."""
    g, (m2, m1, n0, n1, n2, n3, n4) = fig5_like_graph()
    sched = derive_schedule(g, {n0, n1, n2, n3, n4}, out_tile=2)
    t = sched.tensors
    assert t[n3].delta == 2 and t[n4].delta == 2
    # n1 feeds n3 (F=2,s=1) and n4 (F=2,s=1): delta = lcm(2,2) = 2
    assert t[n1].delta == 2
    assert t[n1].x == 2 + (2 - 1) * 1  # f(2) = 3
    # in-2 feeds n0 (s=2) and n1 (s=2): delta = lcm(delta0*2, delta1*2)
    assert t[m2].delta == math.lcm(t[n0].delta * 2, t[n1].delta * 2)
    # x(in-2) = max over consumers of f_v(delta/s)
    k0 = t[m2].delta // 2
    k1 = t[m2].delta // 2
    assert t[m2].x == max(4 + (k0 - 1) * 2, 3 + (k1 - 1) * 2)


def test_upd_num_coprime_and_balanced():
    """Stage 3: minimal co-prime rates satisfying per-edge balance."""
    g, nodes = fig5_like_graph()
    m2, m1, n0, n1, n2, n3, n4 = nodes
    internal = {n0, n1, n2, n3, n4}
    sched = derive_schedule(g, internal, out_tile=2)
    t = sched.tensors
    upds = [ts.upd_num for ts in t.values()]
    assert all(u >= 1 for u in upds)
    g_all = 0
    for u in upds:
        g_all = math.gcd(g_all, u)
    assert g_all == 1  # co-prime minimal solution (paper stage 3)
    # per-edge steady-state balance: rate(u)*delta(u) == rate(v)*delta(v)*s
    for e in g.edges:
        if e.src in t and e.dst in t and e.kind != FULL:
            lhs = t[e.src].upd_num * t[e.src].delta
            rhs = t[e.dst].upd_num * t[e.dst].delta * e.s
            assert lhs == rhs, (e, lhs, rhs)


def test_full_edge_forces_whole_tensor_resident():
    g = Graph("attn")
    i = g.add_node("in", 128, 4)
    q = g.add_node("qkv", 128, 12)
    a = g.add_node("attn", 128, 4)
    o = g.add_node("proj", 128, 4, is_output=True)
    g.add_edge(i, q, F=1, s=1)
    g.add_edge(q, a, kind=FULL)
    g.add_edge(a, o, F=1, s=1)
    sched = derive_schedule(g, {q, a, o})
    assert sched.tensors[q].x == 128          # fully resident
    assert sched.tensors[q].full_resident
    assert sched.phases == 2


def test_inconsistent_parallel_strides_rejected():
    g = Graph("bad")
    i = g.add_node("in", 64, 1)
    a = g.add_node("a", 32, 1)     # stride 2 path
    b = g.add_node("b", 64, 1)     # stride 1 path
    m = g.add_node("m", 32, 1, is_output=True)
    g.add_edge(i, a, F=2, s=2)
    g.add_edge(i, b, F=1, s=1)
    g.add_edge(a, m, F=1, s=1)
    g.add_edge(b, m, F=1, s=1)     # merge of mismatched rates
    with pytest.raises(ValueError):
        derive_schedule(g, {a, b, m})


def test_consumption_beats_production_centric():
    """Paper Fig. 4: the production-centric strawman strands extra rows."""
    g, (m2, m1, n0, n1, n2, n3, n4) = fig5_like_graph()
    internal = {n0, n1, n2, n3, n4}
    sched = derive_schedule(g, internal, out_tile=2)
    cons_rows = sum(ts.x for ts in sched.tensors.values())
    prod_rows = sum(production_centric_footprint(g, internal, in_tile=6).values())
    assert cons_rows <= prod_rows


@st.composite
def random_chain(draw):
    n = draw(st.integers(2, 6))
    layers = []
    length = draw(st.integers(40, 80))
    for i in range(n):
        F = draw(st.integers(1, 5))
        s = draw(st.integers(1, 3))
        layers.append((F, s))
    return length, layers


@given(random_chain())
@settings(max_examples=60, deadline=None)
def test_property_chain_balance(chain):
    """Balance + window invariants hold for arbitrary chains."""
    length, layers = chain
    g = Graph("prop")
    prev = g.add_node("in", length, 1)
    lens = [length]
    nodes = []
    for i, (F, s) in enumerate(layers):
        out = (lens[-1] - F) // s + 1
        if out < 2:
            return  # degenerate
        idx = g.add_node(f"l{i}", out, 1)
        g.add_edge(prev, idx, F=F, s=s)
        prev = idx
        lens.append(out)
        nodes.append(idx)
    g.nodes[prev].is_output = True
    sched = derive_schedule(g, set(nodes), out_tile=1)
    t = sched.tensors
    for e in g.edges:
        if e.dst in t and e.src in t:
            # balance
            assert (t[e.src].upd_num * t[e.src].delta
                    == t[e.dst].upd_num * t[e.dst].delta * e.s)
            # window sufficiency: one consumer update fits in producer alloc
            k = t[e.src].delta // e.s
            assert t[e.src].x >= min(e.window(max(k, 1)),
                                     g.nodes[e.src].out_len)
    gg = 0
    for ts in t.values():
        gg = math.gcd(gg, ts.upd_num)
    assert gg == 1
