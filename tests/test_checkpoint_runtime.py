"""Checkpoint atomicity/retention/resume + fault-tolerance runtime."""

import os
import shutil

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    CheckpointConfig,
    CheckpointManager,
    checkpoint_steps,
    load_checkpoint,
    save_checkpoint,
)
from repro.runtime import (
    Decision,
    FaultConfig,
    HeartbeatMonitor,
    MeshPlan,
    NodeState,
    RestartPolicy,
    mitigate_stragglers,
    plan_mesh,
    rescale_batch,
    shrink_after_failure,
)


def tree():
    return {"a": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
            "b": np.ones(5, np.int32)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 10, tree())
    restored, meta = load_checkpoint(d, template=tree())
    np.testing.assert_array_equal(restored["a"]["w"], tree()["a"]["w"])
    assert meta["step"] == 10


def test_uncommitted_checkpoints_ignored(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, tree())
    # simulate a crash mid-save: committed marker missing
    broken = os.path.join(d, "step_00000002")
    shutil.copytree(os.path.join(d, "step_00000001"), broken)
    os.remove(os.path.join(broken, "_COMMITTED"))
    assert checkpoint_steps(d) == [1]
    _, meta = load_checkpoint(d)
    assert meta["step"] == 1


def test_corruption_detected(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 5, tree())
    path = os.path.join(d, "step_00000005", "arrays_0.npz")
    with open(path, "r+b") as f:
        f.seek(30)
        f.write(b"\x00\x01\x02\x03")
    with pytest.raises(IOError):
        load_checkpoint(d, verify=True, template=tree())


def test_manager_retention_and_resume(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(
        directory=str(tmp_path), save_every=2, keep_last=2, async_save=False))
    for step in range(1, 9):
        if mgr.should_save(step):
            mgr.save(step, {"x": np.full(3, step, np.float32)})
    assert checkpoint_steps(str(tmp_path)) == [6, 8]
    restored, meta = mgr.restore({"x": np.zeros(3, np.float32)})
    assert meta["step"] == 8
    np.testing.assert_array_equal(restored["x"], [8, 8, 8])


def test_async_save_waits(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path),
                                             async_save=True))
    mgr.save(4, tree())
    mgr.wait()
    assert checkpoint_steps(str(tmp_path)) == [4]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_detects_dead_and_straggler():
    clock = FakeClock()
    cfg = FaultConfig(heartbeat_interval_s=1.0, dead_after_missed=3,
                      straggler_factor=2.0)
    mon = HeartbeatMonitor(cfg, ["n0", "n1", "n2"], clock=clock)
    for t in range(10):
        clock.t = float(t)
        mon.heartbeat("n0", step_time_s=1.0)
        mon.heartbeat("n1", step_time_s=5.0)  # slow
        # n2 silent after t=2
        if t <= 2:
            mon.heartbeat("n2", step_time_s=1.0)
    states = mon.survey()
    assert states["n0"] == NodeState.HEALTHY
    assert states["n1"] == NodeState.SLOW
    assert states["n2"] == NodeState.DEAD


def test_restart_policy_budget():
    clock = FakeClock()
    cfg = FaultConfig(max_restarts_per_hour=2)
    mon = HeartbeatMonitor(cfg, ["n0"], clock=clock)
    pol = RestartPolicy(cfg, clock=clock)
    assert pol.decide(mon, step_failed=False) == Decision.CONTINUE
    assert pol.decide(mon, step_failed=True) == Decision.RESTART_SAME
    assert pol.decide(mon, step_failed=True) == Decision.RESTART_SAME
    assert pol.decide(mon, step_failed=True) == Decision.HALT
    clock.t += 3601
    mon.heartbeat("n0")  # node is alive; only the budget window moved
    assert pol.decide(mon, step_failed=True) == Decision.RESTART_SAME


def test_straggler_mitigation_rebalances():
    clock = FakeClock()
    cfg = FaultConfig(straggler_factor=2.0)
    mon = HeartbeatMonitor(cfg, ["a", "b"], clock=clock)
    for _ in range(5):
        mon.heartbeat("a", 1.0)
        mon.heartbeat("b", 10.0)
    new = mitigate_stragglers(mon, {"a": 4, "b": 4})
    assert new == {"a": 5, "b": 3}


def test_elastic_mesh_planning():
    plan = plan_mesh(512, model_parallel=16, multi_pod=True, pod_size=256)
    assert plan.shape == (2, 16, 16)
    assert plan.axis_names == ("pod", "data", "model")
    single = plan_mesh(256, model_parallel=16)
    assert single.shape == (16, 16)
    # lose 17 devices from the single pod: data axis shrinks, model kept
    shrunk = shrink_after_failure(single, lost_devices=17)
    assert shrunk.shape == (14, 16)
    assert rescale_batch(256, old_data=16, new_data=14) == 224
    # lose a whole pod from the multi-pod mesh
    shrunk2 = shrink_after_failure(plan, lost_devices=256)
    assert shrunk2.shape == (16, 16)
