"""Golden regression: seed-fixed GA/greedy results for one workload per URI
scheme, pinned bitwise and asserted identical across every evaluation
backend that resolves (``serial`` / ``vector`` / ``process`` / ``jax`` —
the same invariance `tests/test_engine.py` pins for the engine itself; an
uninstalled jax shows up as a *skip*, not a hole).

The ``ga_full`` case is FULL-budget-shaped: a paper-scale GA population so
the batched backends see generation-sized miss batches, not toy ones.

Golden artifacts live in ``tests/golden/``; regenerate them after an
*intentional* cost-model or search change with::

    PYTHONPATH=src python tests/test_golden_workloads.py --regen
"""

import json
from pathlib import Path

import pytest
from backend_parity import backend_params

from repro.api import ExploreSpec, GAOptions, GreedyOptions, run
from repro.core import AcceleratorConfig, HWSpace, Objective

KB = 1 << 10
GOLDEN_DIR = Path(__file__).resolve().parent / "golden"
FILE_GRAPH = GOLDEN_DIR / "workload_diamond.json"
# golden artifacts must be machine-independent, so the file: workload's
# absolute path is canonicalized to this repo-relative form before compare
FILE_URI_CANON = "file:tests/golden/workload_diamond.json"

WORKLOADS = {
    "netlib_resnet50": "netlib:resnet50",
    "tpu_gemma3-4b_L0": "tpu:gemma3-4b:0?tokens=512",
    "synthetic_layered24": "synthetic:layered:24?seed=7",
    "file_diamond": f"file:{FILE_GRAPH}",
}

# case key -> (strategy, options, sample_budget).  ``ga_full`` mirrors the
# paper's generation shape (population 64, 20 generations) so the batched
# executors are pinned on generation-sized miss batches too.  ``ga_noc``
# is the multi-core case: a weight-sharing base config, the GA co-exploring
# the core axis (HWSpace.core_candidates), and the trace-derived
# ``noc_p95`` objective — pinning the §5.4.2 NoC charge across backends.
STRATEGIES = {
    "ga": ("ga", GAOptions(population=10), 300),
    "greedy": ("greedy", GreedyOptions(eval_budget=2_000), 300),
    "ga_full": ("ga", GAOptions(population=64), 1_280),
    "ga_noc": ("ga", GAOptions(population=10), 300),
}

CASES = [(w, s) for w in WORKLOADS for s in ("ga", "greedy")]
CASES += [("synthetic_layered24", "ga_full")]
CASES += [("synthetic_layered24", "ga_noc")]


def golden_spec(workload_key: str, strategy_key: str) -> ExploreSpec:
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    strategy, options, budget = STRATEGIES[strategy_key]
    objective = Objective(metric="ema", alpha=None)
    hw = HWSpace(mode="fixed", base=acc)
    if strategy_key == "ga_noc":
        objective = Objective(metric="noc_p95", alpha=0.002)
        hw = HWSpace(
            mode="shared",
            base=AcceleratorConfig(shared=True, weight_share_cores=2,
                                   n_cores=2),
            core_candidates=(2, 4),
        )
    return ExploreSpec(
        workload=WORKLOADS[workload_key],
        strategy=strategy,
        objective=objective,
        hw=hw,
        sample_budget=budget,
        seed=0,
        options=options,
    )


def canonical_dict(res) -> dict:
    """`ExploreResult` as a parsed-JSON dict (tuples already lowered to
    lists, exactly what a golden file parses back to), with the
    machine-local file: path replaced by its repo-relative form so goldens
    compare bitwise everywhere."""
    d = json.loads(res.to_json())
    local_uri = WORKLOADS["file_diamond"]
    if d["workload"] == local_uri:
        d["workload"] = FILE_URI_CANON
    if d.get("spec") and d["spec"]["workload"] == local_uri:
        d["spec"]["workload"] = FILE_URI_CANON
    return d


def golden_path(workload_key: str, strategy: str) -> Path:
    return GOLDEN_DIR / f"{workload_key}.{strategy}.json"


@pytest.mark.parametrize("backend,jobs", backend_params(include_serial=True))
@pytest.mark.parametrize("workload_key,strategy", CASES)
def test_golden_result_pinned_across_backends(workload_key, strategy,
                                              backend, jobs):
    spec = golden_spec(workload_key, strategy)
    golden = json.loads(golden_path(workload_key, strategy).read_text())

    got = canonical_dict(run(spec, eval_backend=backend, eval_jobs=jobs))
    assert got == golden, (
        f"{workload_key}/{strategy} [{backend}] drifted from tests/golden/ "
        f"— if the cost model or search changed intentionally, regenerate "
        f"with `PYTHONPATH=src python tests/test_golden_workloads.py "
        f"--regen`; if only this backend drifted, its arithmetic broke "
        f"bitwise parity")


def test_checked_in_file_workload_is_valid_graph_json():
    from repro.api import build_workload, graph_fingerprint
    from repro.core.graph import graph_from_json

    g = graph_from_json(FILE_GRAPH.read_text())
    assert g.name == "golden_diamond" and g.n == 12
    assert graph_fingerprint(build_workload(f"file:{FILE_GRAPH}")) == \
        graph_fingerprint(g)


def _regen() -> None:
    from repro.api import build_workload
    from repro.core.graph import graph_to_json

    GOLDEN_DIR.mkdir(exist_ok=True)
    if not FILE_GRAPH.exists():
        g = build_workload("synthetic:diamond:12?seed=5")
        g.name = "golden_diamond"
        FILE_GRAPH.write_text(graph_to_json(g))
        print(f"wrote {FILE_GRAPH}")
    for workload_key, strategy in CASES:
        d = canonical_dict(run(golden_spec(workload_key, strategy)))
        path = golden_path(workload_key, strategy)
        path.write_text(json.dumps(d, indent=2) + "\n")
        print(f"wrote {path}  (cost={d['cost']})")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
        sys.exit(2)
