"""Unified exploration API: spec/result serialization, strategy registry
parity, and determinism."""

import math
from dataclasses import replace

import pytest
from conftest import small_graph

from repro.api import (
    DPOptions,
    EnumOptions,
    ExploreResult,
    ExploreSpec,
    GAOptions,
    GreedyOptions,
    SAOptions,
    TwoStepOptions,
    compare,
    get_strategy,
    list_strategies,
    register_strategy,
    run,
)
from repro.core import (
    AcceleratorConfig,
    CachedEvaluator,
    HWSpace,
    Objective,
    singleton_partition,
)

KB = 1 << 10

ALL_STRATEGIES = ("dp", "enum", "ga", "greedy", "sa", "two_step")


def fixed_spec(**kw):
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    defaults = dict(
        workload="dd",
        strategy="ga",
        objective=Objective(metric="ema", alpha=None),
        hw=HWSpace(mode="fixed", base=acc),
        sample_budget=400,
        seed=0,
        options=GAOptions(population=20),
    )
    defaults.update(kw)
    return ExploreSpec(**defaults)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_all_six_strategies_registered():
    assert set(ALL_STRATEGIES) <= set(list_strategies())
    for name in ALL_STRATEGIES:
        entry = get_strategy(name)
        assert entry.name == name and callable(entry.fn)


def test_unknown_strategy_raises_with_known_list():
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("nope")


def test_register_custom_strategy():
    @register_strategy("singletons_only", DPOptions)
    def _singletons(spec, opts, g, ev):
        groups = singleton_partition(g)
        plan = ev.plan(groups, spec.hw.base)
        cost = spec.objective.cost(plan, spec.hw.base)
        return ExploreResult(
            workload=spec.workload, strategy=spec.strategy, groups=groups,
            acc=spec.hw.base, plan=plan, cost=cost,
            objective=spec.objective, history=[(1, cost)], samples=1,
            evaluations=ev.evaluations)

    res = run(fixed_spec(strategy="singletons_only", options=None),
              graph=small_graph())
    assert res.n_subgraphs == 8 and res.feasible


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip_exact():
    spec = ExploreSpec(
        workload="resnet50",
        strategy="ga",
        objective=Objective(metric="energy", alpha=0.002),
        hw=HWSpace(mode="shared"),
        sample_budget=1234,
        seed=7,
        out_tile=2,
        options=GAOptions(population=33, seed_from=("dp", "greedy")),
    )
    assert ExploreSpec.from_json(spec.to_json()) == spec


def test_spec_core_candidates_roundtrip_and_stable_serialization():
    spec = ExploreSpec(
        workload="resnet50",
        hw=HWSpace(mode="shared", core_candidates=(1, 2, 4)),
    )
    rt = ExploreSpec.from_json(spec.to_json())
    assert rt == spec
    assert rt.hw.core_candidates == (1, 2, 4)
    # the default (un-explored) core axis is omitted from the JSON, so the
    # spec_key addresses of every pre-core-axis artifact stay valid
    plain = ExploreSpec(workload="resnet50", hw=HWSpace(mode="shared"))
    assert "core_candidates" not in plain.to_dict()["hw"]
    assert ExploreSpec.from_json(plain.to_json()) == plain


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_spec_roundtrip_every_strategy_defaults(strategy):
    spec = ExploreSpec(workload="vgg16", strategy=strategy)
    rt = ExploreSpec.from_json(spec.to_json())
    assert rt == spec
    assert type(rt.options) is type(spec.options)


def test_result_json_roundtrip_preserves_cost_groups_plan():
    g = small_graph()
    spec = fixed_spec()
    res = run(spec, graph=g)
    rt = ExploreResult.from_json(res.to_json())
    assert rt.cost == res.cost
    assert rt.groups == res.groups
    assert rt.history == res.history
    assert rt.spec == spec
    assert rt.acc == res.acc
    assert rt.plan.ema_total == res.plan.ema_total
    assert rt.plan.feasible == res.plan.feasible
    assert math.isclose(rt.plan.energy_pj, res.plan.energy_pj)


def test_infeasible_enum_result_roundtrips():
    res = ExploreResult(
        workload="x", strategy="enum", groups=[], acc=AcceleratorConfig(),
        plan=None, cost=math.inf, objective=Objective(),
        history=[], samples=0, meta={"complete": False})
    rt = ExploreResult.from_json(res.to_json())
    assert rt.plan is None and rt.cost == math.inf
    assert not rt.feasible
    assert "no plan" in rt.summary()


# ---------------------------------------------------------------------------
# parity: every strategy runs through run() and returns ExploreResult
# ---------------------------------------------------------------------------

def test_registry_parity_shared_evaluator():
    g = small_graph()
    ev = CachedEvaluator(g)
    spec = fixed_spec(sample_budget=2000, options=GAOptions(population=40))
    results = {}
    for name, opts in (("greedy", GreedyOptions(eval_budget=2000)),
                       ("dp", DPOptions()),
                       ("ga", spec.options)):
        results[name] = run(replace(spec, strategy=name, options=opts),
                            graph=g, ev=ev)
    for name, r in results.items():
        assert isinstance(r, ExploreResult)
        assert r.strategy == name
        assert r.feasible and r.cost < math.inf
        assert sum(len(s) for s in r.groups) == g.n
        assert r.objective == spec.objective
    # one shared evaluator: later strategies hit its cache
    assert ev.lookups > ev.evaluations
    # GA (seeded by nothing, 2k samples) matches/beats both baselines here
    assert results["ga"].cost <= results["dp"].cost + 1e-9
    assert results["ga"].cost <= results["greedy"].cost + 1e-9


def test_all_six_run_on_one_spec():
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    per_strategy = {
        "ga": GAOptions(population=20),
        "greedy": GreedyOptions(eval_budget=500),
        "dp": DPOptions(),
        "enum": EnumOptions(),
        "sa": SAOptions(),
        "two_step": TwoStepOptions(capacity_samples=2,
                                   samples_per_capacity=100),
    }
    for name, opts in per_strategy.items():
        hw = HWSpace(mode="shared" if name in ("sa", "two_step") else "fixed",
                     base=acc)
        res = run(fixed_spec(strategy=name, options=opts, hw=hw,
                             sample_budget=300),
                  graph=small_graph())
        assert isinstance(res, ExploreResult), name
        assert res.feasible, name
        assert res.samples > 0, name
    # enum on the small graph is exact and complete
    enum_res = run(fixed_spec(strategy="enum", options=EnumOptions()),
                   graph=small_graph())
    assert enum_res.meta["complete"]


def test_two_step_on_fixed_hw_space_keeps_base_point():
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    res = run(fixed_spec(strategy="two_step",
                         options=TwoStepOptions(capacity_samples=2,
                                                samples_per_capacity=100)),
              graph=g)
    assert res.acc.glb_bytes == acc.glb_bytes
    assert res.acc.wbuf_bytes == acc.wbuf_bytes
    assert res.acc.shared == acc.shared


def test_ga_seed_from_baselines_not_worse():
    g = small_graph()
    ev = CachedEvaluator(g)
    seeded = run(fixed_spec(options=GAOptions(population=20,
                                              seed_from=("dp", "greedy")),
                            sample_budget=300),
                 graph=g, ev=ev)
    dp = run(fixed_spec(strategy="dp", options=None), graph=g, ev=ev)
    assert seeded.cost <= dp.cost + 1e-9
    assert seeded.meta["seeded_from"] == ["dp", "greedy"]


def test_compare_shares_one_evaluator():
    g = small_graph()
    ev = CachedEvaluator(g)
    results = compare(fixed_spec(), ["greedy", "dp", "ga"], graph=g, ev=ev)
    assert [r.strategy for r in results] == ["greedy", "dp", "ga"]
    assert all(r.feasible for r in results)
    assert ev.lookups > ev.evaluations


def test_wrong_options_type_raises():
    with pytest.raises(TypeError, match="expects options"):
        run(fixed_spec(strategy="greedy", options=GAOptions()),
            graph=small_graph())


# ---------------------------------------------------------------------------
# determinism (the reproducibility contract serialization promises)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy,options", [
    ("ga", GAOptions(population=20)),
    ("sa", SAOptions()),
])
def test_same_spec_same_result(strategy, options):
    hw = HWSpace(mode="shared",
                 base=AcceleratorConfig(glb_bytes=128 * KB,
                                        wbuf_bytes=144 * KB))
    spec = fixed_spec(strategy=strategy, options=options, hw=hw,
                      sample_budget=300)
    a = run(spec, graph=small_graph())
    b = run(ExploreSpec.from_json(spec.to_json()), graph=small_graph())
    assert a.cost == b.cost
    assert a.groups == b.groups
    assert a.acc == b.acc


# ---------------------------------------------------------------------------
# removed shims (core.cocco keeps only a pointer docstring)
# ---------------------------------------------------------------------------

def test_deprecated_shims_are_gone():
    import repro.core
    import repro.core.cocco as cocco

    for name in ("co_explore", "partition_only", "CoccoResult"):
        assert not hasattr(cocco, name)
        assert not hasattr(repro.core, name)
    assert "repro.api" in (cocco.__doc__ or "")
