"""Differential-parity harness: the reusable fixture layer behind the
cross-backend acceptance gate.

Every executor backend (``serial`` / ``process`` / ``vector`` / ``jax``)
must return *bitwise-identical* :class:`SubgraphCost`s and whole-strategy
``ExploreResult``s.  This module is importable (not collected — no
``test_`` prefix) and supplies:

* :func:`backend_params` — pytest params over the backend matrix, with
  unavailable backends (jax not installed) rendered as *skips*, never
  silent holes, so ``tests/test_engine.py`` / ``tests/test_golden_
  workloads.py`` / ``tests/test_backend_parity.py`` parametrize over new
  backends with zero per-test edits;
* the query corpus: golden workloads from all four URI schemes, seeded
  ``synthetic:`` fuzz graphs, and adversarial hardware points sitting on
  the scalar-fallback guard boundaries (near ``2**53`` capacities,
  ``2**31`` footprint/weight products);
* :func:`assert_costs_equal` / :func:`assert_backend_parity` — exact
  field-by-field ``SubgraphCost`` comparison of every backend against the
  scalar serial reference;
* :func:`strategy_results` — full-strategy bitwise invariance (one search
  per backend, compared as serialized JSON).
"""

import random
from dataclasses import asdict
from dataclasses import fields as dataclass_fields
from pathlib import Path

import pytest

from repro.api import build_workload
from repro.core import AcceleratorConfig, CostKernel, HWSpace
from repro.core.cost import SubgraphCost
from repro.core.engine import BACKENDS, backend_status, make_executor
from repro.core.partition import random_partition

KB = 1 << 10
GOLDEN_DIR = Path(__file__).resolve().parent / "golden"

# (backend, eval_jobs) rows every invariance test parametrizes over; the
# serial row is the reference most tests compare *against*, so it is
# excluded by default
BACKEND_MATRIX = (("serial", 1), ("process", 2), ("vector", 1), ("jax", 1))

# one golden workload per URI scheme (the same four the golden-artifact
# suite pins)
SCHEME_WORKLOADS = (
    "netlib:resnet50",
    "tpu:gemma3-4b:0?tokens=512",
    "synthetic:layered:24?seed=7",
    f"file:{GOLDEN_DIR / 'workload_diamond.json'}",
)

SYNTH_KINDS = ("layered", "branchy", "diamond", "chain", "pyramid")

_COST_FIELDS = tuple(f.name for f in dataclass_fields(SubgraphCost))


def backend_params(include_serial=False):
    """``pytest.param(backend, jobs)`` rows over :data:`BACKEND_MATRIX`.

    Unavailable backends come back marked ``skip`` with the engine's
    why-not message (e.g. the jax import failure), so a missing optional
    dependency shows up as a skip in the test report instead of silently
    shrinking coverage.
    """
    params = []
    for backend, jobs in BACKEND_MATRIX:
        if backend == "serial" and not include_serial:
            continue
        ok, why = backend_status(backend)
        marks = [] if ok else [pytest.mark.skip(reason=why)]
        params.append(pytest.param(backend, jobs, id=backend, marks=marks))
    return params


def available_backends(include_serial=True):
    """The (backend, jobs) rows that resolve right now, for plain loops."""
    return [(b, j) for b, j in BACKEND_MATRIX
            if (include_serial or b != "serial") and backend_status(b)[0]]


def adversarial_accs():
    """Hardware points that stress every ``finish_cost`` branch and both
    sides of the scalar-fallback guards."""
    return [
        # paper-ish separate and shared points
        AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB),
        AcceleratorConfig(glb_bytes=512 * KB, wbuf_bytes=0, shared=True),
        # starvation buffers: single-layer streaming + multi-node overflow
        AcceleratorConfig(glb_bytes=2 * KB, wbuf_bytes=2 * KB),
        AcceleratorConfig(glb_bytes=4 * KB, wbuf_bytes=0, shared=True),
        # weight buffer overflow with a roomy global buffer
        AcceleratorConfig(glb_bytes=512 * KB, wbuf_bytes=1 * KB),
        # multi-core weight sharing
        AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB,
                          weight_share_cores=4),
        # float64-exactness boundary: last batchable capacity, first
        # scalar-fallback capacity, and one past it
        AcceleratorConfig(glb_bytes=(1 << 53) - 1, wbuf_bytes=144 * KB),
        AcceleratorConfig(glb_bytes=(1 << 53), wbuf_bytes=144 * KB),
        AcceleratorConfig(glb_bytes=(1 << 53) + 1, wbuf_bytes=144 * KB),
        AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=(1 << 53)),
    ]


def corpus_queries(g, seed=0, n_parts=4):
    """Distinct (frozenset, acc) queries over ``g``: random partitions
    probed at every adversarial hardware point plus sampled HW-space
    points (the co-exploration shape)."""
    rng = random.Random(seed)
    hw = HWSpace(mode="separate")
    parts = [random_partition(g, rng, mean_size=rng.uniform(1.5, 6.0))
             for _ in range(n_parts)]
    queries = []
    for acc in adversarial_accs() + [hw.sample(rng) for _ in range(4)]:
        for part in parts:
            for s in part:
                queries.append((frozenset(s), acc))
    # de-dup while preserving order, like CachedEvaluator's miss batching
    # (AcceleratorConfig is a frozen dataclass, so queries hash directly)
    seen = set()
    out = []
    for q in queries:
        if q not in seen:
            seen.add(q)
            out.append(q)
    return out


def scheme_corpus():
    """(label, graph, queries) for one golden workload per URI scheme."""
    for uri in SCHEME_WORKLOADS:
        g = build_workload(uri)
        yield uri.split(":", 1)[0], g, corpus_queries(g, seed=7)


def fuzz_corpus(n_graphs_per_kind=2):
    """(label, graph, queries) for seeded synthetic fuzz graphs."""
    for kind in SYNTH_KINDS:
        for seed in range(n_graphs_per_kind):
            uri = f"synthetic:{kind}:14?seed={100 + seed}"
            g = build_workload(uri)
            yield uri, g, corpus_queries(g, seed=seed)


def assert_costs_equal(got, want, context=""):
    """Exact field-by-field ``SubgraphCost`` equality (floats included)."""
    ga, wa = asdict(got), asdict(want)
    if ga == wa:
        return
    diffs = [f"{name}: {ga[name]!r} != {wa[name]!r}"
             for name in _COST_FIELDS if ga[name] != wa[name]]
    raise AssertionError(
        f"SubgraphCost mismatch {context}: " + "; ".join(diffs))


def assert_backend_parity(g, queries, backend, jobs=1, **executor_kw):
    """One backend's batch answers equal the scalar serial reference."""
    if executor_kw:
        from repro.core.engine import JaxExecutor

        assert backend == "jax", "executor kwargs are jax-only"
        ex = JaxExecutor(**executor_kw)
    else:
        ex = make_executor(backend, jobs)
    reference = CostKernel(g)
    try:
        got = ex.evaluate(CostKernel(g), queries)
    finally:
        ex.close()
    assert len(got) == len(queries)
    for (nodes, acc), cost in zip(queries, got):
        assert_costs_equal(
            cost, reference.cost(nodes, acc),
            context=f"[{backend}{executor_kw or ''}] nodes={sorted(nodes)} "
                    f"glb={acc.glb_bytes} wbuf={acc.wbuf_bytes} "
                    f"shared={acc.shared} share={acc.weight_share_cores}")


def strategy_results(spec, graph, backends=None):
    """Run ``spec`` once per backend; return ``{backend: result_json}``.

    The caller asserts all values are identical — full-strategy bitwise
    invariance, the acceptance gate for any new backend.
    """
    from repro.api import run

    out = {}
    for backend, jobs in (backends or available_backends()):
        res = run(spec, graph=graph, eval_backend=backend, eval_jobs=jobs)
        out[backend] = res.to_json()
    return out
