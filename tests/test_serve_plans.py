"""Plan server (`repro.serve.plans`): tiered zoo→store→search resolution,
in-flight request deduplication, warm evaluator reuse, fingerprint
revalidation for unstable workloads, and the HTTP protocol + /stats schema.
"""

import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.api import ExploreSpec, ResultStore, spec_key
from repro.core import HWSpace, Objective
from repro.core.graph import graph_to_json
from repro.serve.plans import (
    PlanService,
    fetch_stats,
    request_plan,
    resolve_plan,
    serve_in_thread,
)
from repro.serve.zoo import build_zoo, verify_zoo, zoo_coverage, zoo_specs


def greedy_spec(workload="synthetic:chain:6?seed=1", **kw):
    defaults = dict(
        workload=workload,
        strategy="greedy",
        objective=Objective(metric="ema", alpha=None),
        hw=HWSpace(mode="fixed"),
        sample_budget=100,
        seed=0,
    )
    defaults.update(kw)
    return ExploreSpec(**defaults)


# ---------------------------------------------------------------------------
# resolve_plan: the tiered building block
# ---------------------------------------------------------------------------

def test_resolve_plan_cold_then_store_hit(tmp_path):
    store = ResultStore(tmp_path / "store")
    spec = greedy_spec()
    first, src1 = resolve_plan(spec, store=store)
    second, src2 = resolve_plan(spec, store=store)
    assert (src1, src2) == ("search", "store")
    assert second.to_json() == first.to_json()     # replay is bitwise
    assert store.writes == 1


def test_resolve_plan_without_store_always_searches():
    spec = greedy_spec()
    calls = []

    def searcher(s):
        calls.append(s)
        from repro.api import run
        return run(s)

    _, src = resolve_plan(spec, searcher=searcher)
    _, src2 = resolve_plan(spec, searcher=searcher)
    assert (src, src2) == ("search", "search") and len(calls) == 2


def test_resolve_plan_zoo_tier_wins_and_store_stays_clean(tmp_path):
    spec = greedy_spec()
    zoo_rw = ResultStore(tmp_path / "zoo")
    resolve_plan(spec, store=zoo_rw)               # build the zoo artifact
    zoo = ResultStore(tmp_path / "zoo", read_only=True)
    store = ResultStore(tmp_path / "store")
    res, src = resolve_plan(spec, store=store, zoo=zoo)
    assert src == "zoo"
    assert len(store) == 0                         # zoo hits are not copied
    assert res.cost == pytest.approx(res.objective.cost(res.plan, res.acc))


def test_resolve_plan_revalidates_file_workloads(tmp_path):
    """A ``file:`` URI is not content-stable: when the file changes, the
    archived plan must not replay against the new graph."""
    from conftest import chain_graph, small_graph

    path = tmp_path / "net.json"
    path.write_text(graph_to_json(small_graph()))
    spec = greedy_spec(workload=f"file:{path}")
    store = ResultStore(tmp_path / "store")
    _, src1 = resolve_plan(spec, store=store)
    _, src2 = resolve_plan(spec, store=store)
    assert (src1, src2) == ("search", "store")
    path.write_text(graph_to_json(chain_graph(8)[0]))   # file changed
    _, src3 = resolve_plan(spec, store=store)
    assert src3 == "search"


# ---------------------------------------------------------------------------
# PlanService: dedup, counters, warm evaluators
# ---------------------------------------------------------------------------

def test_service_cold_then_hit(tmp_path):
    svc = PlanService(ResultStore(tmp_path / "store"))
    try:
        spec = greedy_spec()
        a = svc.plan(spec)
        b = svc.plan(spec)
        assert (a.served_from, b.served_from) == ("search", "store")
        assert not a.deduped and not b.deduped
        assert svc.searches == 1 and svc.store_hits == 1
        assert b.result.to_json() == a.result.to_json()
        assert a.key == b.key == spec_key(spec)
    finally:
        svc.close()


def test_concurrent_identical_requests_search_exactly_once(tmp_path):
    """N identical concurrent requests: one search, N-1 dedup joins, and
    every caller gets the identical result."""
    n = 8
    svc = PlanService(ResultStore(tmp_path / "store"), workers=4)
    spec = greedy_spec("synthetic:layered:10?seed=5")
    out = [None] * n
    barrier = threading.Barrier(n)

    def hit(i):
        barrier.wait()
        out[i] = svc.plan(spec)

    try:
        threads = [threading.Thread(target=hit, args=(i,)) for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert svc.searches == 1
        assert svc.dedup_joins == n - 1
        assert sum(r.deduped for r in out) == n - 1
        payloads = {r.result.to_json() for r in out}
        assert len(payloads) == 1
        assert len(svc.store) == 1
    finally:
        svc.close()


def test_distinct_specs_do_not_dedup(tmp_path):
    svc = PlanService(ResultStore(tmp_path / "store"), workers=2)
    try:
        a = svc.plan(greedy_spec(seed=0))
        b = svc.plan(greedy_spec(seed=1))
        assert svc.searches == 2 and svc.dedup_joins == 0
        assert a.key != b.key
    finally:
        svc.close()


def test_warm_evaluator_reused_across_same_workload_searches(tmp_path):
    """Two different specs over one workload share one cached evaluator
    (same graph fingerprint + out_tile -> the second search starts warm)."""
    svc = PlanService(ResultStore(tmp_path / "store"))
    try:
        svc.plan(greedy_spec(sample_budget=50))
        svc.plan(greedy_spec(sample_budget=60))       # different spec_key
        assert svc.searches == 2
        assert svc.stats()["server"]["warm_evaluators"] == 1
        svc.plan(greedy_spec(workload="synthetic:layered:8?seed=2"))
        assert svc.stats()["server"]["warm_evaluators"] == 2
    finally:
        svc.close()


def test_service_zoo_tier_is_read_only(tmp_path):
    spec = greedy_spec()
    build_zoo(ResultStore(tmp_path / "zoo"), [spec])
    zoo = ResultStore(tmp_path / "zoo", read_only=True)
    before = sorted(p.name for p in (tmp_path / "zoo").iterdir())
    svc = PlanService(ResultStore(tmp_path / "store"), zoo=zoo)
    try:
        resp = svc.plan(spec)
        assert resp.served_from == "zoo"
        assert svc.zoo_hits == 1 and svc.searches == 0
        assert len(svc.store) == 0
        assert sorted(p.name for p in (tmp_path / "zoo").iterdir()) == before
    finally:
        svc.close()


def test_closed_service_rejects_requests(tmp_path):
    svc = PlanService(ResultStore(tmp_path / "store"))
    svc.close()
    with pytest.raises(RuntimeError):
        svc.plan(greedy_spec())


# ---------------------------------------------------------------------------
# HTTP shell + clients
# ---------------------------------------------------------------------------

def test_http_roundtrip_hit_and_stats_schema(tmp_path):
    svc = PlanService(ResultStore(tmp_path / "store"))
    server = serve_in_thread(svc)
    try:
        spec = greedy_spec()
        first = request_plan(server.url, spec)
        second = request_plan(server.url, spec)
        assert first["ok"] and first["served_from"] == "search"
        assert second["served_from"] == "store"
        assert second["result"] == first["result"]
        assert second["key"] == spec_key(spec)
        stats = fetch_stats(server.url)
        assert stats["ok"]
        server_doc = stats["server"]
        for field in ("version", "uptime_s", "workers", "requests",
                      "searches", "store_hits", "zoo_hits", "dedup_joins",
                      "errors", "in_flight", "warm_evaluators", "latency_ms"):
            assert field in server_doc, field
        assert server_doc["requests"] == 2
        assert server_doc["searches"] == 1
        assert server_doc["store_hits"] == 1
        assert set(server_doc["latency_ms"]) == {"zoo", "store", "search"}
        assert stats["store"]["entries"] == 1
        assert stats["zoo"] is None
    finally:
        server.close()


def test_http_bad_spec_is_400_and_unknown_route_404(tmp_path):
    svc = PlanService(ResultStore(tmp_path / "store"))
    server = serve_in_thread(svc)
    try:
        req = urllib.request.Request(
            server.url + "/plan", data=b"{not json",
            headers={"Content-Type": "application/json"}, method="POST")
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(req, timeout=10)
        assert exc.value.code == 400
        assert not json.loads(exc.value.read().decode())["ok"]
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(server.url + "/nope", timeout=10)
        assert exc.value.code == 404
        with urllib.request.urlopen(server.url + "/healthz",
                                    timeout=10) as resp:
            assert json.loads(resp.read().decode()) == {"ok": True}
    finally:
        server.close()


def test_http_search_failure_is_500(tmp_path):
    svc = PlanService(ResultStore(tmp_path / "store"))
    server = serve_in_thread(svc)
    try:
        bad = greedy_spec(workload="netlib:no-such-model")
        req = urllib.request.Request(
            server.url + "/plan", data=bad.to_json().encode(),
            headers={"Content-Type": "application/json"}, method="POST")
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(req, timeout=30)
        assert exc.value.code == 500
        assert fetch_stats(server.url)["server"]["errors"] == 1
    finally:
        server.close()


# ---------------------------------------------------------------------------
# zoo: grid, build resumability, coverage, verification
# ---------------------------------------------------------------------------

def test_zoo_build_is_resumable_and_coverage_tracks(tmp_path):
    specs = zoo_specs(workloads=["synthetic:chain:6?seed=1"],
                      strategies=["greedy"],
                      objectives=[("ema", None), ("energy", 0.002)],
                      budget=100)
    assert len(specs) == 2
    store = ResultStore(tmp_path / "zoo")
    assert all(r["status"] == "missing" for r in zoo_coverage(store, specs))
    first = build_zoo(store, specs)
    assert (first.built, first.replayed, first.failed) == (2, 0, 0)
    again = build_zoo(store, specs)                 # resume: all hits
    assert (again.built, again.replayed, again.failed) == (0, 2, 0)
    assert all(r["status"] == "archived" for r in zoo_coverage(store, specs))
    assert zoo_coverage(None, specs)[0]["status"] == "missing"


def test_zoo_build_reports_failures_and_continues(tmp_path):
    good = greedy_spec()
    bad = greedy_spec(workload="netlib:no-such-model")
    store = ResultStore(tmp_path / "zoo")
    report = build_zoo(store, [bad, good])
    assert (report.built, report.failed) == (1, 1)
    assert len(report.errors) == 1 and "no-such-model" in report.errors[0]


def test_zoo_verify_clean_and_detects_tampering(tmp_path):
    store = ResultStore(tmp_path / "zoo")
    build_zoo(store, [greedy_spec()])
    assert verify_zoo(store) == []
    # tamper: rename the artifact to a foreign address
    artifact = next(store.root.glob("*.json"))
    artifact.rename(store.root / ("0" * 64 + ".json"))
    problems = verify_zoo(store)
    assert len(problems) == 1 and "hashes to" in problems[0]


def test_zoo_verify_detects_cost_drift(tmp_path):
    store = ResultStore(tmp_path / "zoo")
    build_zoo(store, [greedy_spec()])
    artifact = next(store.root.glob("*.json"))
    doc = json.loads(artifact.read_text())
    doc["cost"] = doc["cost"] * 2 + 1.0
    artifact.write_text(json.dumps(doc))
    problems = verify_zoo(store, rebuild_graphs=False)
    assert len(problems) == 1 and "re-scored" in problems[0]
