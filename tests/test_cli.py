"""`python -m repro` CLI: explore / compare / spec+result artifacts."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.api import ExploreResult, ExploreSpec
from repro.api.cli import main

REPO_ROOT = Path(__file__).resolve().parents[1]


def test_compare_smoke(capsys):
    rc = main(["compare", "--workload", "vgg16",
               "--strategies", "greedy,dp,ga",
               "--budget", "300", "--opt", "population=10"])
    assert rc == 0
    out = capsys.readouterr().out
    lines = [ln for ln in out.splitlines() if ln.strip()]
    header = lines[0].split()
    assert header[:3] == ["rank", "strategy", "cost"]
    body = "\n".join(lines[1:])
    for name in ("greedy", "dp", "ga"):
        assert name in body
    assert "best:" in out


def test_explore_writes_artifacts(tmp_path, capsys):
    out_path = tmp_path / "result.json"
    spec_path = tmp_path / "spec.json"
    rc = main(["explore", "--workload", "vgg16", "--strategy", "greedy",
               "--save-spec", str(spec_path), "--out", str(out_path)])
    assert rc == 0
    assert "vgg16[greedy]" in capsys.readouterr().out

    spec = ExploreSpec.from_json(spec_path.read_text())
    assert spec.workload == "vgg16" and spec.strategy == "greedy"

    res = ExploreResult.from_json(out_path.read_text())
    assert res.feasible
    assert res.spec == spec


def test_explore_from_spec_file_reproduces(tmp_path, capsys):
    spec_path = tmp_path / "spec.json"
    out_a = tmp_path / "a.json"
    out_b = tmp_path / "b.json"
    assert main(["explore", "--workload", "vgg16", "--strategy", "ga",
                 "--budget", "200", "--opt", "population=10",
                 "--save-spec", str(spec_path), "--out", str(out_a)]) == 0
    assert main(["explore", "--spec", str(spec_path),
                 "--out", str(out_b)]) == 0
    a = ExploreResult.from_json(out_a.read_text())
    b = ExploreResult.from_json(out_b.read_text())
    assert a.cost == b.cost
    assert a.groups == b.groups


def test_explore_profile_prints_structure_counters(tmp_path, capsys):
    rc = main(["explore", "--workload", "vgg16", "--strategy", "ga",
               "--budget", "200", "--opt", "population=10", "--profile"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "profile: wall" in out
    assert "derive_schedule" in out
    assert "canonical" in out and "raw" in out
    # profiled run with a store: the stored artifact carries no timings,
    # and the replay says so instead of printing a bogus profile
    store = tmp_path / "store"
    args = ["explore", "--workload", "vgg16", "--strategy", "greedy",
            "--profile", "--store-dir", str(store),
            "--out", str(tmp_path / "r.json")]
    assert main(args) == 0
    first = capsys.readouterr().out
    assert "profile: wall" in first
    stored = ExploreResult.from_json((tmp_path / "r.json").read_text())
    assert "profile" in stored.meta  # --out sees the in-memory profile...
    raw = json.loads(next(store.glob("*.json")).read_text())
    assert "profile" not in raw["meta"]  # ...the store never does
    assert main(args) == 0
    assert "store hit — no search ran" in capsys.readouterr().out


def test_explore_struct_cache_dir_round_trip(tmp_path, capsys):
    cache_dir = tmp_path / "structs"
    args = ["explore", "--workload", "vgg16", "--strategy", "ga",
            "--budget", "200", "--opt", "population=10", "--profile",
            "--struct-cache-dir", str(cache_dir),
            "--out", str(tmp_path / "cold.json")]
    assert main(args) == 0
    cold_out = capsys.readouterr().out
    assert "disk hits" in cold_out
    assert any(cache_dir.glob("*.json"))  # the cold run populated the cache
    cold = ExploreResult.from_json((tmp_path / "cold.json").read_text())
    warm_args = list(args)
    warm_args[-1] = str(tmp_path / "warm.json")
    assert main(warm_args) == 0
    warm = ExploreResult.from_json((tmp_path / "warm.json").read_text())
    assert warm.meta["profile"]["structure_misses"] == 0  # fully warm
    assert warm.meta["profile"]["structure_disk_hits"] > 0
    # the warm run is bitwise-identical to the cold one (minus timings)
    cold.meta.pop("profile"), warm.meta.pop("profile")
    assert warm.to_json() == cold.to_json()


def test_compare_out_is_ranked_json(tmp_path, capsys):
    out_path = tmp_path / "cmp.json"
    rc = main(["compare", "--workload", "vgg16", "--strategies", "greedy,dp",
               "--out", str(out_path)])
    assert rc == 0
    rows = json.loads(out_path.read_text())
    assert len(rows) == 2
    costs = [r["cost"] for r in rows]
    assert costs == sorted(costs)
    # each row is a loadable ExploreResult
    for r in rows:
        assert ExploreResult.from_dict(r).feasible


def test_bad_arguments_exit_nonzero():
    with pytest.raises(SystemExit):
        main(["explore"])                      # neither --spec nor --workload
    with pytest.raises(SystemExit):
        main(["explore", "--workload", "vgg16", "--strategy", "nope"])
    with pytest.raises(SystemExit):
        main(["explore", "--workload", "vgg16", "--opt", "population"])


def test_unknown_eval_backend_exits_2_and_lists_backends(capsys):
    from repro.core.engine import BACKENDS

    rc = main(["explore", "--workload", "vgg16", "--strategy", "greedy",
               "--budget", "100", "--eval-backend", "bogus"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "unknown eval backend 'bogus'" in err
    for backend in BACKENDS:
        assert backend in err


def test_unavailable_jax_backend_exits_2_with_why(capsys, monkeypatch):
    """When jax is not importable the CLI reports the import failure and
    how to fix it, instead of a traceback."""
    import repro.core.engine as engine

    monkeypatch.setattr(engine, "_JAX_STATUS",
                        (False, "ModuleNotFoundError: No module named 'jax'"))
    rc = main(["explore", "--workload", "vgg16", "--strategy", "greedy",
               "--budget", "100", "--eval-backend", "jax"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "'jax' is unavailable" in err
    assert "No module named 'jax'" in err
    assert "pip install jax" in err


def test_explore_eval_backend_jax_matches_serial(tmp_path, capsys):
    from backend_parity import available_backends

    if ("jax", 1) not in available_backends():
        pytest.skip("jax not installed")
    serial_out = tmp_path / "serial.json"
    jax_out = tmp_path / "jax.json"
    base = ["explore", "--workload", "vgg16", "--strategy", "ga",
            "--budget", "200", "--opt", "population=10"]
    assert main(base + ["--out", str(serial_out)]) == 0
    assert main(base + ["--eval-backend", "jax",
                        "--out", str(jax_out)]) == 0
    capsys.readouterr()
    assert jax_out.read_text() == serial_out.read_text()


def test_module_entrypoint_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(REPO_ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "compare", "--workload", "vgg16",
         "--strategies", "greedy,dp", "--budget", "200"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "rank" in proc.stdout and "best:" in proc.stdout


def test_explore_eval_jobs_matches_serial(tmp_path, capsys):
    serial_out = tmp_path / "serial.json"
    parallel_out = tmp_path / "parallel.json"
    base = ["explore", "--workload", "vgg16", "--strategy", "ga",
            "--budget", "200", "--opt", "population=10"]
    assert main(base + ["--out", str(serial_out)]) == 0
    assert main(base + ["--eval-jobs", "2",
                        "--out", str(parallel_out)]) == 0
    capsys.readouterr()
    assert parallel_out.read_text() == serial_out.read_text()


def test_store_ls_and_gc_cli(tmp_path, capsys):
    store_dir = tmp_path / "store"
    rc = main(["explore", "--workload", "vgg16", "--strategy", "greedy",
               "--store-dir", str(store_dir)])
    assert rc == 0
    capsys.readouterr()

    assert main(["store", "ls", "--store-dir", str(store_dir)]) == 0
    out = capsys.readouterr().out
    assert "vgg16" in out and "greedy" in out and "1 entries" in out

    assert main(["store", "gc", "--store-dir", str(store_dir),
                 "--max-bytes", "0"]) == 0
    out = capsys.readouterr().out
    assert "evicted 1 entries" in out

    assert main(["store", "ls", "--store-dir", str(store_dir)]) == 0
    assert "0 entries" in capsys.readouterr().out


def test_workloads_ls_cli(capsys):
    from repro.core.netlib import list_models

    assert main(["workloads", "ls"]) == 0
    out = capsys.readouterr().out
    assert "netlib:resnet50" in out
    assert "tpu:<config>:<layer>" in out
    assert "synthetic:layered:<n>[?seed=S]" in out
    assert "file:<path>.json" in out

    assert main(["workloads", "ls", "--scheme", "netlib",
                 "--uris-only"]) == 0
    out = capsys.readouterr().out
    assert out.split() == [f"netlib:{n}" for n in list_models()]

    # --uris-only is script-friendly: every line is a concrete URI the
    # resolver accepts (no templates like tpu:<arch>:0..N)
    from repro.api import parse_workload
    assert main(["workloads", "ls", "--uris-only"]) == 0
    uris = capsys.readouterr().out.split()
    assert uris and all(".." not in u and "<" not in u for u in uris)
    for uri in uris:
        parse_workload(uri)
    assert "tpu:gemma3-4b:0" in uris and "tpu:gemma3-4b:33" in uris

    assert main(["workloads", "ls", "--scheme", "bogus"]) == 2
    assert "unknown workload scheme" in capsys.readouterr().err


def test_workloads_ls_json_is_machine_readable(capsys):
    from repro.api import parse_workload

    assert main(["workloads", "ls", "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert set(doc) == {"schemes", "workloads"}
    names = {s["name"] for s in doc["schemes"]}
    assert {"netlib", "tpu", "synthetic", "file"} <= names
    for s in doc["schemes"]:
        assert set(s) == {"name", "syntax", "description", "stable"}
    assert doc["workloads"], "concrete URIs expected"
    for w in doc["workloads"]:
        assert set(w) == {"uri", "scheme", "description"}
        assert "<" not in w["uri"] and ".." not in w["uri"]
        parse_workload(w["uri"])                  # every entry resolves

    # --scheme filters both sections
    assert main(["workloads", "ls", "--json", "--scheme", "netlib"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert [s["name"] for s in doc["schemes"]] == ["netlib"]
    assert all(w["scheme"] == "netlib" for w in doc["workloads"])


def test_trace_cli_exports_deterministic_valid_json(tmp_path, capsys):
    import sys

    sys.path.insert(0, str(REPO_ROOT / "scripts"))
    try:
        from check_trace_schema import validate_trace_dict
    finally:
        sys.path.pop(0)

    out_a, out_b = tmp_path / "a.json", tmp_path / "b.json"
    base = ["trace", "synthetic:layered:16?seed=2", "--strategy", "greedy"]
    assert main(base + ["--out", str(out_a)]) == 0
    out = capsys.readouterr().out
    assert "cross-validation OK" in out and "bandwidth: peak=" in out
    assert main(base + ["--out", str(out_b)]) == 0
    capsys.readouterr()
    # byte-identical across runs for a fixed seed
    assert out_a.read_text() == out_b.read_text()

    doc = json.loads(out_a.read_text())
    assert validate_trace_dict(doc) == []
    assert doc["meta"]["validation"]["ok"] is True
    tot = doc["totals"]
    assert tot["dram_bytes"] == tot["dram_in"] + tot["dram_out"]
    assert tot["dram_bytes"] == \
        doc["meta"]["validation"]["total_analytical_bytes"]

    # --steps-per-subgraph coalesces the timeline but preserves every total
    out_c = tmp_path / "c.json"
    assert main(base + ["--steps-per-subgraph", "2",
                        "--out", str(out_c)]) == 0
    capsys.readouterr()
    coarse = json.loads(out_c.read_text())
    assert validate_trace_dict(coarse) == []
    assert coarse["totals"] == doc["totals"]
    assert len(coarse["steps"]) < len(doc["steps"])


def test_trace_cli_replays_archived_plan(tmp_path, capsys):
    res_path = tmp_path / "res.json"
    assert main(["explore", "--workload", "synthetic:diamond:10?seed=2",
                 "--strategy", "greedy", "--out", str(res_path)]) == 0
    capsys.readouterr()
    assert main(["trace", "--plan", str(res_path)]) == 0
    out = capsys.readouterr().out
    assert "synthetic:diamond:10?seed=2[greedy]" in out
    assert "cross-validation OK" in out

    # a conflicting workload URI alongside --plan is rejected, not ignored
    with pytest.raises(SystemExit, match="cannot be combined"):
        main(["trace", "netlib:resnet50", "--plan", str(res_path)])
    # ...and so is a positional URI that disagrees with --workload
    with pytest.raises(SystemExit, match="conflicting workloads"):
        main(["trace", "synthetic:chain:8?seed=1",
              "--workload", "netlib:vgg16"])


def test_explore_accepts_workload_uris(tmp_path, capsys):
    out_path = tmp_path / "res.json"
    rc = main(["explore", "--workload", "synthetic:layered:12?seed=1",
               "--strategy", "greedy", "--out", str(out_path)])
    assert rc == 0
    assert "synthetic:layered:12?seed=1[greedy]" in capsys.readouterr().out
    res = ExploreResult.from_json(out_path.read_text())
    assert res.feasible and res.workload == "synthetic:layered:12?seed=1"

    assert main(["explore", "--workload", "bogus:thing"]) == 2
    assert "unknown workload scheme" in capsys.readouterr().err


def test_store_cli_without_dir_exits():
    import os
    env_had = os.environ.pop("REPRO_STORE_DIR", None)
    try:
        with pytest.raises(SystemExit, match="store maintenance"):
            main(["store", "ls"])
    finally:
        if env_had is not None:
            os.environ["REPRO_STORE_DIR"] = env_had


def test_store_ls_json_is_machine_readable(tmp_path, capsys):
    store_dir = tmp_path / "store"
    assert main(["explore", "--workload", "synthetic:chain:6?seed=1",
                 "--strategy", "greedy", "--budget", "100",
                 "--store-dir", str(store_dir)]) == 0
    capsys.readouterr()
    assert main(["store", "ls", "--store-dir", str(store_dir),
                 "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["root"] == str(store_dir)
    assert doc["count"] == 1 and doc["total_bytes"] > 0
    (entry,) = doc["entries"]
    assert len(entry["key"]) == 64
    assert entry["workload"] == "synthetic:chain:6?seed=1"
    assert entry["strategy"] == "greedy"
    assert entry["size"] > 0 and entry["mtime"] > 0
    # full keys round-trip into --seed-from-store / store maintenance
    assert (store_dir / f"{entry['key']}.json").is_file()


def test_zoo_build_dry_run_ls_verify(tmp_path, capsys):
    zoo_dir = tmp_path / "zoo"
    grid = ["--zoo-dir", str(zoo_dir),
            "--workloads", "synthetic:chain:6?seed=1",
            "--strategies", "greedy", "--objectives", "ema,energy:0.002",
            "--budget", "100"]

    assert main(["zoo", "build", "--dry-run"] + grid) == 0
    out = capsys.readouterr().out
    assert "2 zoo specs (dry run" in out and "energy:0.002" in out
    assert not zoo_dir.exists()                 # dry run builds nothing

    assert main(["zoo", "ls", "--json"] + grid) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["archived"] == 0 and doc["missing"] == 2

    assert main(["zoo", "build"] + grid) == 0
    assert "2 built" in capsys.readouterr().out
    assert main(["zoo", "build"] + grid) == 0   # resumable: all replay
    assert "2 already archived" in capsys.readouterr().out

    assert main(["zoo", "ls", "--json"] + grid) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["archived"] == 2 and doc["missing"] == 0
    assert all(r["status"] == "archived" for r in doc["rows"])

    assert main(["zoo", "verify", "--zoo-dir", str(zoo_dir)]) == 0
    assert "2 artifacts verified clean" in capsys.readouterr().out


def test_explore_seed_from_store_warm_starts(tmp_path, capsys):
    store_dir = tmp_path / "store"
    base = ["--workload", "synthetic:layered:8?seed=3", "--strategy", "ga",
            "--opt", "population=10", "--store-dir", str(store_dir)]
    assert main(["explore", "--budget", "200"] + base) == 0
    capsys.readouterr()
    assert main(["store", "ls", "--store-dir", str(store_dir),
                 "--json"]) == 0
    key = json.loads(capsys.readouterr().out)["entries"][0]["key"]

    # a unique >=8-char prefix resolves; the seeded spec addresses a NEW
    # store entry (seed_from_keys is part of the spec hash)
    assert main(["explore", "--budget", "400",
                 "--seed-from-store", key[:12]] + base) == 0
    capsys.readouterr()
    assert main(["store", "ls", "--store-dir", str(store_dir),
                 "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["count"] == 2

    # guard rails: needs a store, a ga-family strategy, and no --spec
    with pytest.raises(SystemExit, match="resolves keys against a store"):
        main(["explore", "--workload", "x", "--strategy", "ga",
              "--no-store", "--seed-from-store", key[:12]])
    with pytest.raises(SystemExit, match="seed_from_keys"):
        main(["explore", "--workload", "x", "--strategy", "greedy",
              "--store-dir", str(store_dir), "--seed-from-store", key[:12]])
    assert main(["explore", "--budget", "200",
                 "--seed-from-store", "deadbeef"] + base) == 2
    assert "no store entry matches" in capsys.readouterr().err


def test_serve_plans_cli_help_and_missing_store():
    with pytest.raises(SystemExit):            # argparse --help exits 0
        main(["serve-plans", "--help"])
    env_had = os.environ.pop("REPRO_STORE_DIR", None)
    try:
        with pytest.raises(SystemExit, match="serve-plans needs"):
            main(["serve-plans", "--port", "0"])
    finally:
        if env_had is not None:
            os.environ["REPRO_STORE_DIR"] = env_had
