"""Baseline optimizers (paper §4.2) and the paper's relative-ordering claims."""

import math

import pytest

from repro.core import AcceleratorConfig, CachedEvaluator, Objective
from repro.core.baselines import (
    dp_partition,
    enumerate_partitions,
    greedy_partition,
    run_sa,
    run_two_step,
)
from repro.core.ga import HWSpace, run_ga
from conftest import small_graph

KB = 1 << 10


def test_enumeration_is_optimal_on_small_graph():
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    obj = Objective(metric="ema", alpha=None)
    ev = CachedEvaluator(g)
    res = enumerate_partitions(g, acc, obj, ev=ev)
    assert res.complete and res.groups is not None
    # GA should match the enumeration optimum on a small graph (paper §5.2)
    ga = run_ga(g, obj, HWSpace(mode="fixed", base=acc), sample_budget=2000,
                population=40, seed=0, ev=ev)
    assert math.isclose(ga.best.plan.ema_total, res.plan.ema_total,
                        rel_tol=1e-9)


def test_greedy_runs_and_is_feasible():
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    obj = Objective(metric="ema")
    groups, plan, n_eval = greedy_partition(g, acc, obj)
    assert plan.feasible and n_eval > 0
    assert sum(len(s) for s in groups) == g.n


def test_dp_respects_depth_order_constraint():
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    obj = Objective(metric="ema")
    groups, plan, _ = dp_partition(g, acc, obj)
    assert plan.feasible
    assert sum(len(s) for s in groups) == g.n


def test_enumeration_not_worse_than_heuristics():
    """Enumeration is exact: its EMA lower-bounds greedy and DP (Fig. 11)."""
    g = small_graph()
    acc = AcceleratorConfig(glb_bytes=128 * KB, wbuf_bytes=144 * KB)
    obj = Objective(metric="ema")
    ev = CachedEvaluator(g)
    enum = enumerate_partitions(g, acc, obj, ev=ev)
    _, gplan, _ = greedy_partition(g, acc, obj, ev=ev)
    _, dplan, _ = dp_partition(g, acc, obj, ev=ev)
    assert enum.plan.ema_total <= gplan.ema_total + 1e-9
    assert enum.plan.ema_total <= dplan.ema_total + 1e-9


def test_sa_runs_and_improves():
    g = small_graph()
    obj = Objective(metric="energy", alpha=0.002)
    hw = HWSpace(mode="shared")
    res = run_sa(g, obj, hw, sample_budget=400, seed=0)
    costs = [c for _, c in res.history]
    assert costs[-1] <= costs[0]
    assert res.best.plan.feasible


def test_two_step_runs():
    g = small_graph()
    obj = Objective(metric="energy", alpha=0.002)
    hw = HWSpace(mode="shared")
    res = run_two_step(g, obj, hw, sampler="random", capacity_samples=3,
                       samples_per_capacity=150, seed=0)
    assert res.best is not None and res.best.plan.feasible
