"""The deferred-weight-gradient sLSTM custom VJP must match jax AD of the
plain scan exactly (the §Perf fix that removes the per-timestep all-reduce)."""

import pytest

pytest.importorskip("jax")  # optional dep: skip whole module when absent

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _slstm_scan, _slstm_scan_plain


def _setup(seed=0, B=2, S=16, H=2, dh=8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    wx = jax.random.normal(ks[0], (B, S, H, 4 * dh))
    rrec = jax.random.normal(ks[1], (H, dh, 4 * dh)) / np.sqrt(dh)
    z = jnp.zeros((B, H, dh))
    return wx, rrec, z, z + 1e-6, z, z - 10.0


def test_forward_matches_plain():
    args = _setup()
    hs1, fin1 = _slstm_scan(*args)
    hs2, fin2 = _slstm_scan_plain(*args)
    np.testing.assert_allclose(np.asarray(hs1), np.asarray(hs2), rtol=1e-6)
    for a, b in zip(fin1, fin2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_gradients_match_plain_ad():
    args = _setup(seed=1)

    def loss_custom(wx, rrec):
        hs, (cl, nl, hl, ml) = _slstm_scan(wx, rrec, *args[2:])
        return jnp.sum(jnp.sin(hs)) + jnp.sum(cl * nl) + jnp.sum(hl)

    def loss_plain(wx, rrec):
        hs, (cl, nl, hl, ml) = _slstm_scan_plain(wx, rrec, *args[2:])
        return jnp.sum(jnp.sin(hs)) + jnp.sum(cl * nl) + jnp.sum(hl)

    g1 = jax.grad(loss_custom, argnums=(0, 1))(args[0], args[1])
    g2 = jax.grad(loss_plain, argnums=(0, 1))(args[0], args[1])
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_initial_state_gradients_match():
    args = _setup(seed=2)

    def mk(fn):
        def loss(c0, h0):
            hs, _ = fn(args[0], args[1], c0, args[3], h0, args[5])
            return jnp.sum(hs ** 2)
        return loss

    g1 = jax.grad(mk(_slstm_scan), argnums=(0, 1))(args[2], args[4])
    g2 = jax.grad(mk(_slstm_scan_plain), argnums=(0, 1))(args[2], args[4])
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
