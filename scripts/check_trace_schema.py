#!/usr/bin/env python3
"""Validate a trace JSON file against the documented ``cocco-trace`` schema.

Stdlib-only (runs in CI without the package on the path)::

    python scripts/check_trace_schema.py runs/trace.json

Checks the structural contract from ``docs/architecture.md`` ("Trace
simulator" section) plus the internal invariants that make a trace
trustworthy: totals are consistent with the per-step timeline, the
bandwidth profile is internally ordered (p50 <= p95 <= p99 <= peak), and
the embedded cross-validation verdict (if present) agrees with the
totals.  Importable: ``validate_trace_dict(doc)`` returns a list of error
strings (empty == valid), which `tests/test_cli.py` reuses.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List

TRACE_FORMAT = "cocco-trace"
TRACE_FORMAT_VERSION = 1

_TOP_KEYS = {"format", "version", "graph", "acc", "out_tile", "groups",
             "totals", "profile", "subgraphs"}
_TOTAL_KEYS = {"dram_in", "dram_out", "dram_bytes", "cycles"}
_PROFILE_KEYS = {"peak", "sustained", "p50", "p95", "p99", "total_bytes",
                 "total_cycles"}
_SUBGRAPH_KEYS = {"index", "nodes", "act_in", "act_out", "w_first",
                  "w_stream", "stream_blocks", "cycles", "n_steps",
                  "peak_occ_act", "peak_occ_w", "footprint", "region_count",
                  "region_table_bytes"}
_STEP_KEYS = {"subgraph", "step", "t_cycles", "cycles", "act_in", "act_out",
              "w_in", "occ_act", "occ_w", "rows", "macs"}


def _num(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_trace_dict(doc: Dict[str, Any]) -> List[str]:
    """Return schema/invariant violations (empty list == valid trace)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return [f"trace document must be an object, got {type(doc).__name__}"]
    missing = _TOP_KEYS - set(doc)
    if missing:
        errs.append(f"missing top-level keys: {sorted(missing)}")
        return errs
    if doc["format"] != TRACE_FORMAT:
        errs.append(f"format must be {TRACE_FORMAT!r}, got {doc['format']!r}")
    if doc["version"] != TRACE_FORMAT_VERSION:
        errs.append(f"unsupported version {doc['version']!r}")

    totals = doc["totals"]
    if not isinstance(totals, dict) or _TOTAL_KEYS - set(totals):
        errs.append(f"totals needs keys {sorted(_TOTAL_KEYS)}")
    else:
        for k in _TOTAL_KEYS:
            if not _num(totals[k]) or totals[k] < 0:
                errs.append(f"totals.{k} must be a non-negative number")
        if totals["dram_bytes"] != totals["dram_in"] + totals["dram_out"]:
            errs.append("totals.dram_bytes != dram_in + dram_out")

    prof = doc["profile"]
    if not isinstance(prof, dict) or _PROFILE_KEYS - set(prof):
        errs.append(f"profile needs keys {sorted(_PROFILE_KEYS)}")
    else:
        for k in _PROFILE_KEYS:
            if not _num(prof[k]) or prof[k] < 0:
                errs.append(f"profile.{k} must be a non-negative number")
        eps = 1e-6
        if not (prof["p50"] <= prof["p95"] * (1 + eps)
                and prof["p95"] <= prof["p99"] * (1 + eps)
                and prof["p99"] <= prof["peak"] * (1 + eps)):
            errs.append("profile percentiles must satisfy "
                        "p50 <= p95 <= p99 <= peak")
        if isinstance(totals, dict) and "dram_bytes" in totals \
                and prof.get("total_bytes") != totals["dram_bytes"]:
            errs.append("profile.total_bytes != totals.dram_bytes")

    subs = doc["subgraphs"]
    if not isinstance(subs, list) or not subs:
        errs.append("subgraphs must be a non-empty list")
        subs = []
    for i, sg in enumerate(subs):
        if not isinstance(sg, dict) or _SUBGRAPH_KEYS - set(sg):
            errs.append(f"subgraphs[{i}] needs keys "
                        f"{sorted(_SUBGRAPH_KEYS)}")
            continue
        if sg["index"] != i:
            errs.append(f"subgraphs[{i}].index must be {i}")
        for k in ("act_in", "act_out", "w_first", "w_stream"):
            if not isinstance(sg[k], int) or sg[k] < 0:
                errs.append(f"subgraphs[{i}].{k} must be a "
                            f"non-negative integer")
        if not isinstance(sg["nodes"], list) or not sg["nodes"]:
            errs.append(f"subgraphs[{i}].nodes must be a non-empty list")

    if "steps" in doc:
        steps = doc["steps"]
        if not isinstance(steps, list) or not steps:
            errs.append("steps, when present, must be a non-empty list")
            steps = []
        t_prev = -1.0
        sums = {"act_in": 0, "act_out": 0, "w_in": 0}
        for i, stp in enumerate(steps):
            if not isinstance(stp, dict) or _STEP_KEYS - set(stp):
                errs.append(f"steps[{i}] needs keys {sorted(_STEP_KEYS)}")
                continue
            if not _num(stp["cycles"]) or stp["cycles"] < 0:
                errs.append(f"steps[{i}].cycles must be non-negative")
            if not _num(stp["t_cycles"]):
                errs.append(f"steps[{i}].t_cycles must be a number")
            elif stp["t_cycles"] < t_prev - 1e-6:
                errs.append(f"steps[{i}].t_cycles must be non-decreasing")
            else:
                t_prev = stp["t_cycles"]
            for k in sums:
                if isinstance(stp.get(k), int) and stp[k] >= 0:
                    sums[k] += stp[k]
                else:
                    errs.append(f"steps[{i}].{k} must be a "
                                f"non-negative integer")
        if isinstance(totals, dict) and not (_TOTAL_KEYS - set(totals)):
            if sums["act_in"] + sums["w_in"] != totals["dram_in"]:
                errs.append("sum of step loads != totals.dram_in")
            if sums["act_out"] != totals["dram_out"]:
                errs.append("sum of step stores != totals.dram_out")

    meta = doc.get("meta")
    if isinstance(meta, dict) and isinstance(meta.get("validation"), dict):
        val = meta["validation"]
        if val.get("ok") is not True:
            errs.append("meta.validation.ok is not true "
                        "(simulated traffic drifted from the analytical EMA)")
        elif isinstance(totals, dict) and \
                val.get("total_simulated_bytes") != totals.get("dram_bytes"):
            errs.append("meta.validation.total_simulated_bytes "
                        "!= totals.dram_bytes")
    return errs


def main(argv: List[str]) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    path = argv[1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as err:
        print(f"error: cannot read {path}: {err}", file=sys.stderr)
        return 1
    errs = validate_trace_dict(doc)
    if errs:
        for e in errs:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        print(f"{path}: INVALID ({len(errs)} errors)", file=sys.stderr)
        return 1
    n_steps = len(doc.get("steps", []))
    print(f"{path}: valid {TRACE_FORMAT} v{TRACE_FORMAT_VERSION} — "
          f"{len(doc['subgraphs'])} subgraphs, {n_steps} steps, "
          f"{doc['totals']['dram_bytes']} DRAM bytes")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
