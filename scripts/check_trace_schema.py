#!/usr/bin/env python3
"""Validate a trace JSON file against the documented ``cocco-trace`` schema.

Stdlib-only (runs in CI without the package on the path)::

    python scripts/check_trace_schema.py runs/trace.json

Checks the structural contract from ``docs/architecture.md`` ("Trace
simulator" section) plus the internal invariants that make a trace
trustworthy: totals are consistent with the per-step timeline, the
bandwidth profile is internally ordered (p50 <= p95 <= p99 <= peak), and
the embedded cross-validation verdict (if present) agrees with the
totals.  Version 2 adds the NoC fabric contract (per-step ``noc_bytes`` /
``core``, a top-level ``noc`` section with aggregate and per-link
profiles).  Version 3 adds per-tensor occupancy timelines: every step
carries ``occ_tensors`` ([tensor id, bytes] pairs summing exactly to
``occ_act``; empty on prologue/weight-only steps).  Version-1/-2
documents are still accepted.  Importable: ``validate_trace_dict(doc)``
returns a list of error strings (empty == valid), which
`tests/test_cli.py` reuses.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List

TRACE_FORMAT = "cocco-trace"
TRACE_FORMAT_VERSIONS = (1, 2, 3)

_TOP_KEYS = {"format", "version", "graph", "acc", "out_tile", "groups",
             "totals", "profile", "subgraphs"}
_TOTAL_KEYS = {"dram_in", "dram_out", "dram_bytes", "cycles"}
_PROFILE_KEYS = {"peak", "sustained", "p50", "p95", "p99", "total_bytes",
                 "total_cycles"}
_SUBGRAPH_KEYS = {"index", "nodes", "act_in", "act_out", "w_first",
                  "w_stream", "stream_blocks", "cycles", "n_steps",
                  "peak_occ_act", "peak_occ_w", "footprint", "region_count",
                  "region_table_bytes"}
_STEP_KEYS = {"subgraph", "step", "t_cycles", "cycles", "act_in", "act_out",
              "w_in", "occ_act", "occ_w", "rows", "macs"}
# v2 additions (NoC fabric traffic + per-core attribution)
_SUBGRAPH_KEYS_V2 = _SUBGRAPH_KEYS | {"noc_bytes"}
_STEP_KEYS_V2 = _STEP_KEYS | {"noc_bytes", "core"}
# v3 additions (per-tensor occupancy timelines)
_STEP_KEYS_V3 = _STEP_KEYS_V2 | {"occ_tensors"}
_NOC_KEYS = {"links", "total_bytes", "aggregate", "per_link"}


def _num(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _check_profile(prof: Any, where: str, errs: List[str]) -> None:
    """Shared checks for any BandwidthProfile-shaped object."""
    if not isinstance(prof, dict) or _PROFILE_KEYS - set(prof):
        errs.append(f"{where} needs keys {sorted(_PROFILE_KEYS)}")
        return
    for k in _PROFILE_KEYS:
        if not _num(prof[k]) or prof[k] < 0:
            errs.append(f"{where}.{k} must be a non-negative number")
    eps = 1e-6
    if not (prof["p50"] <= prof["p95"] * (1 + eps)
            and prof["p95"] <= prof["p99"] * (1 + eps)
            and prof["p99"] <= prof["peak"] * (1 + eps)):
        errs.append(f"{where} percentiles must satisfy "
                    f"p50 <= p95 <= p99 <= peak")


def validate_trace_dict(doc: Dict[str, Any]) -> List[str]:
    """Return schema/invariant violations (empty list == valid trace)."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return [f"trace document must be an object, got {type(doc).__name__}"]
    missing = _TOP_KEYS - set(doc)
    if missing:
        errs.append(f"missing top-level keys: {sorted(missing)}")
        return errs
    if doc["format"] != TRACE_FORMAT:
        errs.append(f"format must be {TRACE_FORMAT!r}, got {doc['format']!r}")
    version = doc["version"]
    if version not in TRACE_FORMAT_VERSIONS:
        errs.append(f"unsupported version {version!r}")
        return errs
    v2 = version >= 2
    v3 = version >= 3
    sub_keys = _SUBGRAPH_KEYS_V2 if v2 else _SUBGRAPH_KEYS
    step_keys = (_STEP_KEYS_V3 if v3
                 else _STEP_KEYS_V2 if v2 else _STEP_KEYS)

    totals = doc["totals"]
    total_keys = _TOTAL_KEYS | ({"noc_bytes"} if v2 else set())
    if not isinstance(totals, dict) or total_keys - set(totals):
        errs.append(f"totals needs keys {sorted(total_keys)}")
    else:
        for k in total_keys:
            if not _num(totals[k]) or totals[k] < 0:
                errs.append(f"totals.{k} must be a non-negative number")
        if totals["dram_bytes"] != totals["dram_in"] + totals["dram_out"]:
            errs.append("totals.dram_bytes != dram_in + dram_out")

    prof = doc["profile"]
    _check_profile(prof, "profile", errs)
    if isinstance(prof, dict) and isinstance(totals, dict) \
            and "dram_bytes" in totals \
            and prof.get("total_bytes") != totals["dram_bytes"]:
        errs.append("profile.total_bytes != totals.dram_bytes")

    noc = doc.get("noc")
    if v2:
        if not isinstance(noc, dict) or _NOC_KEYS - set(noc):
            errs.append(f"v2 noc section needs keys {sorted(_NOC_KEYS)}")
            noc = None
        else:
            if not isinstance(noc["links"], int) or noc["links"] < 1:
                errs.append("noc.links must be a positive integer "
                            "(weight_share_cores)")
            if not isinstance(noc["total_bytes"], int) \
                    or noc["total_bytes"] < 0:
                errs.append("noc.total_bytes must be a non-negative integer")
            _check_profile(noc["aggregate"], "noc.aggregate", errs)
            _check_profile(noc["per_link"], "noc.per_link", errs)
            if isinstance(noc["aggregate"], dict) \
                    and noc["aggregate"].get("total_bytes") \
                    != noc["total_bytes"]:
                errs.append("noc.aggregate.total_bytes != noc.total_bytes")
            # symmetric rotation fabric: each of `links` links carries
            # 1/links of the aggregate broadcast
            if isinstance(noc["aggregate"], dict) \
                    and isinstance(noc["per_link"], dict) \
                    and isinstance(noc["links"], int) and noc["links"] >= 1:
                agg, per = noc["aggregate"], noc["per_link"]
                for k in ("peak", "total_bytes"):
                    if _num(agg.get(k)) and _num(per.get(k)) and not (
                            abs(per[k] * noc["links"] - agg[k])
                            <= 1e-6 * max(agg[k], 1.0)):
                        errs.append(f"noc.per_link.{k} * links != "
                                    f"noc.aggregate.{k}")
            if isinstance(totals, dict) \
                    and totals.get("noc_bytes") != noc["total_bytes"]:
                errs.append("totals.noc_bytes != noc.total_bytes")

    subs = doc["subgraphs"]
    if not isinstance(subs, list) or not subs:
        errs.append("subgraphs must be a non-empty list")
        subs = []
    noc_sub_sum = 0
    for i, sg in enumerate(subs):
        if not isinstance(sg, dict) or sub_keys - set(sg):
            errs.append(f"subgraphs[{i}] needs keys {sorted(sub_keys)}")
            continue
        if sg["index"] != i:
            errs.append(f"subgraphs[{i}].index must be {i}")
        check = ("act_in", "act_out", "w_first", "w_stream")
        if v2:
            check += ("noc_bytes",)
        for k in check:
            if not isinstance(sg[k], int) or sg[k] < 0:
                errs.append(f"subgraphs[{i}].{k} must be a "
                            f"non-negative integer")
        if v2 and isinstance(sg.get("noc_bytes"), int):
            noc_sub_sum += sg["noc_bytes"]
        if not isinstance(sg["nodes"], list) or not sg["nodes"]:
            errs.append(f"subgraphs[{i}].nodes must be a non-empty list")

    if "steps" in doc:
        steps = doc["steps"]
        if not isinstance(steps, list) or not steps:
            errs.append("steps, when present, must be a non-empty list")
            steps = []
        t_prev = -1.0
        sums = {"act_in": 0, "act_out": 0, "w_in": 0}
        if v2:
            sums["noc_bytes"] = 0
        for i, stp in enumerate(steps):
            if not isinstance(stp, dict) or step_keys - set(stp):
                errs.append(f"steps[{i}] needs keys {sorted(step_keys)}")
                continue
            if not _num(stp["cycles"]) or stp["cycles"] < 0:
                errs.append(f"steps[{i}].cycles must be non-negative")
            if not _num(stp["t_cycles"]):
                errs.append(f"steps[{i}].t_cycles must be a number")
            elif stp["t_cycles"] < t_prev - 1e-6:
                errs.append(f"steps[{i}].t_cycles must be non-decreasing")
            else:
                t_prev = stp["t_cycles"]
            for k in sums:
                if isinstance(stp.get(k), int) and stp[k] >= 0:
                    sums[k] += stp[k]
                else:
                    errs.append(f"steps[{i}].{k} must be a "
                                f"non-negative integer")
            if v3:
                occ_t = stp.get("occ_tensors")
                if not isinstance(occ_t, list):
                    errs.append(f"steps[{i}].occ_tensors must be a list")
                    continue
                total = 0
                shape_ok = True
                for pair in occ_t:
                    if (not isinstance(pair, list) or len(pair) != 2
                            or not isinstance(pair[0], int)
                            or not isinstance(pair[1], int)
                            or isinstance(pair[0], bool)
                            or isinstance(pair[1], bool)
                            or pair[0] < 0 or pair[1] <= 0):
                        errs.append(f"steps[{i}].occ_tensors entries must "
                                    f"be [tensor >= 0, bytes > 0] pairs")
                        shape_ok = False
                        break
                    total += pair[1]
                if shape_ok and isinstance(stp.get("occ_act"), int) \
                        and total != stp["occ_act"]:
                    errs.append(f"steps[{i}]: sum(occ_tensors bytes) "
                                f"!= occ_act")
        if isinstance(totals, dict) and not (_TOTAL_KEYS - set(totals)):
            if sums["act_in"] + sums["w_in"] != totals["dram_in"]:
                errs.append("sum of step loads != totals.dram_in")
            if sums["act_out"] != totals["dram_out"]:
                errs.append("sum of step stores != totals.dram_out")
            if v2 and steps and sums["noc_bytes"] != totals.get("noc_bytes"):
                errs.append("sum of step noc_bytes != totals.noc_bytes")

    meta = doc.get("meta")
    if isinstance(meta, dict) and isinstance(meta.get("validation"), dict):
        val = meta["validation"]
        if val.get("ok") is not True:
            errs.append("meta.validation.ok is not true "
                        "(simulated traffic drifted from the analytical EMA)")
        else:
            if isinstance(totals, dict) and \
                    val.get("total_simulated_bytes") != totals.get(
                        "dram_bytes"):
                errs.append("meta.validation.total_simulated_bytes "
                            "!= totals.dram_bytes")
            if v2 and isinstance(noc, dict) and \
                    val.get("noc_simulated_bytes") != noc.get("total_bytes"):
                errs.append("meta.validation.noc_simulated_bytes "
                            "!= noc.total_bytes")
    return errs


def main(argv: List[str]) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    path = argv[1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as err:
        print(f"error: cannot read {path}: {err}", file=sys.stderr)
        return 1
    errs = validate_trace_dict(doc)
    if errs:
        for e in errs:
            print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        print(f"{path}: INVALID ({len(errs)} errors)", file=sys.stderr)
        return 1
    n_steps = len(doc.get("steps", []))
    noc = ""
    if doc.get("version", 1) >= 2:
        noc = (f", {doc['noc']['total_bytes']} NoC bytes over "
               f"{doc['noc']['links']} links")
    print(f"{path}: valid {TRACE_FORMAT} v{doc['version']} — "
          f"{len(doc['subgraphs'])} subgraphs, {n_steps} steps, "
          f"{doc['totals']['dram_bytes']} DRAM bytes{noc}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
