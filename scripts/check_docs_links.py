#!/usr/bin/env python3
"""Docs link-check: every relative link/anchor in the markdown docs must
resolve, so README/docs can't rot silently as the tree moves.

Checks, for README.md and docs/*.md:

* ``[text](target)`` links — relative targets must exist on disk (external
  ``http(s)://`` links are not fetched); ``#fragment`` anchors into a
  markdown file must match one of its headings (GitHub slug rules,
  simplified).
* paths the prose names in backticks that look like repo paths
  (``src/...``, ``docs/...``, ``benchmarks/...``, ...) must exist.

Exit 0 when everything resolves, 1 with a per-problem report otherwise.
Stdlib only — runs anywhere the repo checks out.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
DOC_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `backticked` repo paths: at least one slash, rooted at a known top-level dir
CODEPATH_RE = re.compile(
    r"`((?:src|docs|benchmarks|tests|examples|scripts|\.github)/[^`\s]+)`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def github_slug(heading: str) -> str:
    slug = heading.strip().lower()
    slug = re.sub(r"[`*_]", "", slug)
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def anchors_of(md_path: Path) -> set:
    return {github_slug(h) for h in HEADING_RE.findall(md_path.read_text())}


def check_file(doc: Path) -> list:
    problems = []
    text = doc.read_text()
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, fragment = target.partition("#")
        resolved = (doc.parent / path_part).resolve() if path_part else doc
        if not resolved.exists():
            problems.append(f"{doc.relative_to(REPO)}: broken link {target!r}")
            continue
        if fragment and resolved.suffix == ".md":
            if github_slug(fragment) not in anchors_of(resolved):
                problems.append(
                    f"{doc.relative_to(REPO)}: bad anchor {target!r}")
    for codepath in CODEPATH_RE.findall(text):
        # prose may name a path with trailing decorations; strip them
        candidate = REPO / codepath.rstrip("/").split(" ")[0]
        if not candidate.exists():
            problems.append(
                f"{doc.relative_to(REPO)}: named path `{codepath}` missing")
    return problems


def main() -> int:
    missing_docs = [d for d in DOC_FILES if not d.exists()]
    if missing_docs:
        for d in missing_docs:
            print(f"missing doc file: {d.relative_to(REPO)}")
        return 1
    problems = [p for doc in DOC_FILES for p in check_file(doc)]
    for p in problems:
        print(p)
    print(f"checked {len(DOC_FILES)} files: "
          f"{'OK' if not problems else f'{len(problems)} problem(s)'}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
